#!/usr/bin/env python3
"""Check that intra-repo markdown links resolve.

Walks every tracked ``*.md`` file (skipping caches and VCS dirs),
extracts ``[text](target)`` links, and verifies that each *relative*
target — after stripping any ``#anchor`` — exists on disk, resolved
against the linking file's directory.  External links (``http(s)://``,
``mailto:``) and pure in-page anchors (``#...``) are ignored.

Exits non-zero listing every dead link (file:line -> target), so the CI
docs job fails the moment a rename orphans a reference.

Run: python tools/check_links.py [root]
"""

from __future__ import annotations

import os
import re
import sys

SKIP_DIRS = {".git", ".pytest_cache", "__pycache__", "node_modules",
             ".claude", "experiments"}
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def md_files(root: str):
    """Yield every markdown file under ``root``, skipping cache/VCS dirs."""
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in SKIP_DIRS]
        for f in filenames:
            if f.endswith(".md"):
                yield os.path.join(dirpath, f)


def check_file(path: str, root: str):
    """Scan one markdown file; returns (dead, n_links) where ``dead``
    is [(lineno, target), ...] for unresolvable relative links."""
    dead = []
    n_links = 0
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            for target in LINK_RE.findall(line):
                n_links += 1
                if target.startswith(EXTERNAL) or target.startswith("#"):
                    continue
                rel = target.split("#", 1)[0]
                if not rel:
                    continue
                base = root if rel.startswith("/") else os.path.dirname(path)
                resolved = os.path.normpath(os.path.join(base,
                                                         rel.lstrip("/")))
                if not os.path.exists(resolved):
                    dead.append((lineno, target))
    return dead, n_links


def main() -> int:
    root = os.path.abspath(sys.argv[1] if len(sys.argv) > 1
                           else os.path.join(os.path.dirname(__file__), ".."))
    n_files = n_links = 0
    failures = []
    for path in sorted(md_files(root)):
        n_files += 1
        dead, links = check_file(path, root)
        n_links += links
        for lineno, target in dead:
            failures.append(f"{os.path.relpath(path, root)}:{lineno} -> "
                            f"{target}")
    if failures:
        print(f"DEAD LINKS ({len(failures)}):")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"ok: {n_files} markdown files, {n_links} links, all resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
