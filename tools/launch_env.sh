#!/usr/bin/env sh
# Apply the recommended launch environment for this machine.
#
#   . tools/launch_env.sh          # source into the current shell
#
# The knobs themselves (tcmalloc LD_PRELOAD, large-alloc threshold,
# TF log level, XLA step-marker / host-device-count flags) live in ONE
# place — src/repro/launch/env.py — so this wrapper just evals its
# export lines; `python -m repro.launch.env` shows them with a
# divergence report for the current process.
eval "$(PYTHONPATH=src python -m repro.launch.env | grep '^export ')"
