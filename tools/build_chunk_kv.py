#!/usr/bin/env python
"""Offline chunk-KV builder CLI: prefill every datastore chunk once,
page its per-layer K/V (chunk-local RoPE), and write one ``.npz``
artifact the serving stack loads as a ``ChunkKVStore``.

  python tools/build_chunk_kv.py --out experiments/chunk_kv.npz \
      --docs 64 --page-size 4 --seed 3

At serve time, pass the loaded store to ``DecodeRunner(...,
chunk_store=ChunkKVStore.load(path))`` with ``EngineConfig(
chunk_kv=True)``: retrieved documents' KV is then spliced into paged
decode by block-table edit instead of being re-prefilled (TurboRAG
reordered RoPE; see docs/ARCHITECTURE.md "life of a chunk").

The chunk corpus is the repo's deterministic synthetic one (tokens are
a pure function of ``(seed, doc_id)``), so rebuilding the artifact on
any machine is byte-stable given the same arch/seed.  ``--clusters``
optionally attaches a doc→IVF-cluster map (uniform assignment from the
doc id, matching ``core.datastore``'s synthetic layout) so lookahead
prefetch can resolve predicted clusters to chunk pages.
"""

from __future__ import annotations

import argparse
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
if os.path.isdir(_SRC) and _SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(_SRC))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", required=True, help="output .npz path")
    ap.add_argument("--arch", default="llama3-8b",
                    help="arch name (reduced preset is used)")
    ap.add_argument("--docs", type=int, default=64,
                    help="build chunks for doc ids [0, N)")
    ap.add_argument("--page-size", type=int, default=4,
                    help="KV page size in tokens (must match the serve "
                         "slab's page_size)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--min-len", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=24)
    ap.add_argument("--clusters", type=int, default=0,
                    help="attach doc->cluster map over this many IVF "
                         "clusters (0 = unmapped)")
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.data.chunk_kv import build_chunk_kv
    from repro.models import transformer as tf

    cfg = get_arch(args.arch).reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(args.seed),
                            dtype=jnp.float32)
    cluster_of = ((lambda d: d % args.clusters) if args.clusters > 0
                  else None)
    store = build_chunk_kv(params, cfg, range(args.docs),
                           page_size=args.page_size, seed=args.seed,
                           min_len=args.min_len, max_len=args.max_len,
                           cluster_of=cluster_of)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    store.save(args.out)
    print(f"chunk-KV store: {len(store)} docs, {store.total_pages()} pages "
          f"of {args.page_size} tokens -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
