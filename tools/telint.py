#!/usr/bin/env python
"""telint: the repo's lease/clock/kernel-discipline lint + trace
invariant checker (rule catalog: docs/ANALYSIS.md).

Static lint (rules TL001–TL005 over src/repro), ratcheted:

  python tools/telint.py                          # list all findings
  python tools/telint.py --ratchet analysis/baseline.json
                                                  # fail only on NEW ones
  python tools/telint.py --update-baseline analysis/baseline.json
                                                  # re-grandfather

Dynamic happens-before check on a recorded trace (JSONL stream from
``repro.obs.export.write_jsonl`` = full checks; Perfetto JSON = the
span/transfer/admission subset):

  python tools/telint.py --trace experiments/bench/openloop_trace.jsonl

``--report out.json`` writes a machine-readable report (CI artifact).
Exit status: 0 clean, 1 findings, 2 usage error.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# run from a checkout without PYTHONPATH (CI calls `python tools/telint.py`)
_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
if os.path.isdir(_SRC) and _SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(_SRC))

from repro.analysis import lint as lint_mod                  # noqa: E402


def _repo_root() -> str:
    return os.path.abspath(
        os.path.join(os.path.dirname(os.path.abspath(__file__)), ".."))


def run_static(args) -> tuple:
    """(exit code, report dict) for the static half."""
    root = _repo_root()
    violations = lint_mod.lint_tree(args.root, repo_root=root,
                                    rules=args.rules)
    report = {
        "mode": "static",
        "root": args.root,
        "total": len(violations),
        "violations": [vars(v) for v in violations],
    }
    if args.update_baseline:
        lint_mod.dump_baseline(violations,
                               os.path.join(root, args.update_baseline))
        print(f"baseline updated: {args.update_baseline} "
              f"({len(violations)} grandfathered finding(s))")
        return 0, report
    if args.ratchet:
        baseline = lint_mod.load_baseline(os.path.join(root, args.ratchet))
        new, stale = lint_mod.ratchet(violations, baseline)
        report["baseline"] = args.ratchet
        report["new"] = [vars(v) for v in new]
        report["stale"] = stale
        for v in new:
            print(v.render())
        if stale:
            print(f"note: {len(stale)} baseline entr"
                  f"{'y is' if len(stale) == 1 else 'ies are'} stale "
                  f"(fixed since grandfathering) — run "
                  f"--update-baseline to tighten the ratchet:")
            for k in stale:
                print(f"  {k}")
        print(f"telint: {len(violations)} finding(s), "
              f"{len(new)} new vs baseline ({len(baseline)} grandfathered)")
        return (1 if new else 0), report
    for v in violations:
        print(v.render())
    print(f"telint: {len(violations)} finding(s)")
    return (1 if violations else 0), report


def run_trace(args) -> tuple:
    """(exit code, report dict) for the dynamic half."""
    from repro.analysis import invariants as inv
    path = args.trace
    if path.endswith(".jsonl"):
        events = inv.events_from_jsonl(path)
        source = "jsonl"
    else:
        with open(path) as f:
            doc = json.load(f)
        events = inv.events_from_perfetto(doc)
        source = "perfetto"
        print("note: Perfetto input — race/ordering checks only "
              "(pool conservation needs the .jsonl stream)")
    rep = inv.check_events(events, drained=args.drained,
                           must_drain=tuple(args.must_drain or ()))
    print(f"{path} ({source}): {rep.summary()}")
    report = {
        "mode": "trace", "trace": path, "source": source,
        "checked_events": rep.checked_events,
        "stats": rep.stats,
        "outstanding": rep.outstanding,
        "violations": [vars(v) for v in rep.violations],
    }
    return (0 if rep.ok else 1), report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="telint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--root", default="src/repro",
                    help="tree to lint (repo-relative; default src/repro)")
    ap.add_argument("--rules", nargs="*", default=None, metavar="TLnnn",
                    help="restrict to specific rule ids")
    ap.add_argument("--ratchet", default=None, metavar="BASELINE",
                    help="fail only on findings NOT in this baseline")
    ap.add_argument("--update-baseline", default=None, metavar="BASELINE",
                    help="write the current findings as the new baseline")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="check happens-before invariants on a recorded "
                         "trace (.jsonl = full checks, .json Perfetto = "
                         "ordering subset) instead of linting")
    ap.add_argument("--drained", action="store_true",
                    help="with --trace: the stream covers a full drain — "
                         "also enforce end-of-run conditions")
    ap.add_argument("--must-drain", nargs="*", default=None, metavar="OWNER",
                    help="with --trace --drained: owner categories whose "
                         "pool balance must end at zero (e.g. prefetch kv)")
    ap.add_argument("--report", default=None, metavar="OUT.json",
                    help="write a machine-readable findings report")
    args = ap.parse_args(argv)

    if args.trace:
        code, report = run_trace(args)
    else:
        code, report = run_static(args)
    if args.report:
        os.makedirs(os.path.dirname(os.path.abspath(args.report)),
                    exist_ok=True)
        with open(args.report, "w") as f:
            json.dump(report, f, indent=2)
        print(f"report written: {args.report}")
    return code


if __name__ == "__main__":
    sys.exit(main())
