#!/usr/bin/env python
"""Validate a Chrome/Perfetto trace-event JSON file emitted by
``repro.obs.export.write_trace`` (CI runs this on the openloop smoke
trace so the exporter cannot silently drift from the format
ui.perfetto.dev loads; format documented in docs/OBSERVABILITY.md).

Checks, beyond JSON well-formedness:

* top level is ``{"traceEvents": [...]}``;
* every event has a phase ``ph`` and a ``pid``, with ``ts >= 0`` on
  timed phases;
* complete spans (``"X"``) have non-negative ``dur``;
* async begin/end pairs (``"b"``/``"e"``) balance per (cat, id);
* counter events (``"C"``) exist and include the ledger-occupancy and
  pool-free-pages tracks the acceptance criteria require.

After format validation the trace is replayed through the
happens-before invariant checker (``repro.analysis.invariants``):
use-before-land races, double releases, ledger drift and
stall-without-resume all fail the check.  The lossless sibling
``<trace>.jsonl`` stream is preferred (full checks, including pool
conservation); when only the Perfetto JSON exists the events are
reconstructed from it (race/ordering checks only).  Pass an explicit
JSONL path as a second argument to override the sibling lookup.

Usage:  python tools/check_trace.py experiments/bench/openloop_trace.json
        python tools/check_trace.py trace.json stream.jsonl
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, Tuple

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.abspath(__file__)), os.pardir, "src"))

from repro.analysis import (check_events, events_from_jsonl,     # noqa: E402
                            events_from_perfetto)

# phases that must carry a timestamp
_TIMED = {"X", "B", "E", "b", "e", "i", "C"}

# counter tracks write_trace always emits on a served run
REQUIRED_COUNTERS = {"ledger_occupancy", "pool_free_pages"}


def validate_trace(doc: Dict) -> Dict[str, int]:
    """Assert ``doc`` is a loadable trace; returns phase counts."""
    assert isinstance(doc, dict), type(doc)
    events = doc.get("traceEvents")
    assert isinstance(events, list), "missing traceEvents list"
    assert events, "empty traceEvents"

    phases: Dict[str, int] = {}
    async_open: Dict[Tuple[str, object], int] = {}
    counters = set()
    for i, ev in enumerate(events):
        assert isinstance(ev, dict), (i, ev)
        ph = ev.get("ph")
        assert isinstance(ph, str) and ph, f"event {i} missing ph: {ev}"
        assert "pid" in ev, f"event {i} missing pid: {ev}"
        phases[ph] = phases.get(ph, 0) + 1
        if ph in _TIMED:
            ts = ev.get("ts")
            assert isinstance(ts, (int, float)) and ts >= -1e-9, \
                f"event {i} bad ts: {ev}"
        if ph == "X":
            dur = ev.get("dur")
            assert isinstance(dur, (int, float)) and dur >= -1e-9, \
                f"event {i} bad dur: {ev}"
        elif ph in ("b", "e"):
            key = (ev.get("cat"), ev.get("id"))
            assert key[1] is not None, f"async event {i} missing id: {ev}"
            async_open[key] = async_open.get(key, 0) + (1 if ph == "b" else -1)
        elif ph == "C":
            assert isinstance(ev.get("args"), dict) and ev["args"], \
                f"counter event {i} missing args: {ev}"
            counters.add(ev.get("name"))

    unbalanced = {k: v for k, v in async_open.items() if v != 0}
    assert not unbalanced, f"unbalanced async spans: {unbalanced}"
    missing = REQUIRED_COUNTERS - counters
    assert not missing, \
        f"missing required counter tracks: {sorted(missing)} " \
        f"(have {sorted(counters)})"
    return phases


def check_invariants(doc: Dict, path: str,
                     jsonl: str = None) -> int:
    """Replay the trace's happens-before invariants; returns the
    violation count (0 = clean).  Prefers the lossless JSONL stream."""
    if int(doc.get("otherData", {}).get("dropped_events", 0) or 0):
        print("invariants: skipped (recorder dropped events — the "
              "surviving window cannot balance)")
        return 0
    if jsonl is None:
        sibling = os.path.splitext(path)[0] + ".jsonl"
        jsonl = sibling if os.path.exists(sibling) else None
    if jsonl is not None:
        events, src = events_from_jsonl(jsonl), jsonl
    else:
        events = events_from_perfetto(doc)
        src = f"{path} (reconstructed — race/ordering checks only)"
    rep = check_events(events)
    for v in rep.violations:
        print(v.render())
    print(f"invariants {src}: {rep.summary()}")
    return len(rep.violations)


def main(argv) -> int:
    if len(argv) not in (2, 3):
        print(__doc__)
        return 2
    with open(argv[1]) as f:
        doc = json.load(f)
    phases = validate_trace(doc)
    total = sum(phases.values())
    print(f"OK {argv[1]}: {total} events "
          + " ".join(f"{ph}={n}" for ph, n in sorted(phases.items())))
    return 1 if check_invariants(doc, argv[1],
                                 argv[2] if len(argv) == 3 else None) else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
