"""Training substrate: optimizer, accumulation, checkpoint fault tolerance."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.data import DataConfig, TokenStream
from repro.training import (OptConfig, init_training, latest_step,
                            make_train_step, restore_checkpoint,
                            save_checkpoint, schedule)


def test_schedule_shape():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                    min_lr_frac=0.1)
    assert float(schedule(jnp.asarray(0), cfg)) == 0.0
    assert float(schedule(jnp.asarray(10), cfg)) == pytest.approx(1e-3)
    assert float(schedule(jnp.asarray(100), cfg)) == pytest.approx(1e-4)


@pytest.mark.slow
def test_grad_accumulation_matches_full_batch():
    cfg = get_arch("llama3-8b").reduced()
    opt = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    params, opt_state = init_training(cfg, opt, jax.random.PRNGKey(0))
    data = TokenStream(cfg, DataConfig(global_batch=8, seq_len=16, seed=3))
    batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
    s1 = jax.jit(make_train_step(cfg, opt, attn_chunk=16, loss_chunk=16))
    s2 = jax.jit(make_train_step(cfg, opt, attn_chunk=16, loss_chunk=16,
                                 accum_steps=4))
    p1, _, m1 = s1(params, opt_state, batch)
    p2, _, m2 = s2(params, opt_state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-3)


def test_moment_dtype_bf16_state():
    cfg = get_arch("llama3-8b").reduced()
    opt = OptConfig(moment_dtype="bfloat16")
    _, opt_state = init_training(cfg, opt, jax.random.PRNGKey(0))
    assert all(l.dtype == jnp.bfloat16
               for l in jax.tree.leaves(opt_state["m"]))


def test_checkpoint_crash_tolerance(tmp_path):
    cfg = get_arch("internvl2-1b").reduced()
    opt = OptConfig()
    params, opt_state = init_training(cfg, opt, jax.random.PRNGKey(1))
    d = str(tmp_path)
    save_checkpoint(d, 5, {"params": params, "cursor": {"step": 5, "seed": 0}})
    save_checkpoint(d, 9, {"params": params, "cursor": {"step": 9, "seed": 0}})
    # simulate crash mid-write of step 12
    os.makedirs(os.path.join(d, "step_00000012.tmp"))
    assert latest_step(d) == 9
    step, state = restore_checkpoint(d, {"params": params,
                                         "cursor": {"step": 0, "seed": 0}})
    assert step == 9 and state["cursor"]["step"] == 9
    # restore an older step explicitly
    step5, _ = restore_checkpoint(d, {"params": params,
                                      "cursor": {"step": 0, "seed": 0}},
                                  step=5)
    assert step5 == 5


def test_checkpoint_gc_keeps_latest(tmp_path):
    cfg = get_arch("internvl2-1b").reduced()
    params, _ = init_training(cfg, OptConfig(), jax.random.PRNGKey(1))
    d = str(tmp_path)
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, {"params": params}, keep=2)
    dirs = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]


def test_exact_resume_reproduces_stream():
    cfg = get_arch("llama3-8b").reduced()
    d1 = TokenStream(cfg, DataConfig(global_batch=2, seq_len=8, seed=7))
    for _ in range(3):
        d1.next_batch()
    cur = d1.cursor()
    b_next = d1.next_batch()
    d2 = TokenStream(cfg, DataConfig(global_batch=2, seq_len=8, seed=7))
    d2.restore(cur)
    np.testing.assert_array_equal(d2.next_batch()["tokens"],
                                  b_next["tokens"])
    with pytest.raises(AssertionError):
        d3 = TokenStream(cfg, DataConfig(global_batch=2, seq_len=8, seed=8))
        d3.restore(cur)
