"""Pallas kernel parity vs pure-jnp oracles (interpret mode), swept over
shapes and dtypes as required for every kernel."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("P,ps,d,B,k", [
    (12, 64, 128, 3, 5),
    (4, 32, 96, 1, 3),
    (16, 128, 256, 8, 16),
    (7, 16, 64, 2, 4),          # odd page count -> padding path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ivf_topk_parity(P, ps, d, B, k, dtype):
    rng = np.random.default_rng(P * 1000 + B)
    pages = jnp.asarray(rng.standard_normal((P, ps, d)), dtype)
    ids = jnp.asarray(rng.permutation(P * ps).reshape(P, ps), jnp.int32)
    ids = ids.at[1, ps // 2:].set(-1)             # padded tail
    mask = jnp.asarray(rng.random((B, P)) > 0.3)  # per-query page masks
    q = jnp.asarray(rng.standard_normal((B, d)), dtype)
    s_ref, i_ref = ref.ivf_topk_ref(pages, ids, mask, q, k)
    s_k, i_k = ops.ivf_topk(pages, ids, mask, q, k, tile=max(ps * 2, 64),
                            mode="kernel_interpret")
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-4)
    # ids must match wherever scores are distinct; compare via score lookup
    np.testing.assert_array_equal(np.asarray(i_k >= 0), np.asarray(i_ref >= 0))


def test_ivf_topk_shared_mask_broadcast():
    rng = np.random.default_rng(0)
    pages = jnp.asarray(rng.standard_normal((6, 32, 64)), jnp.float32)
    ids = jnp.arange(6 * 32, dtype=jnp.int32).reshape(6, 32)
    mask1 = jnp.asarray(rng.random(6) > 0.4)
    q = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    s1, i1 = ops.ivf_topk(pages, ids, mask1, q, 4, mode="kernel_interpret")
    s2, i2 = ops.ivf_topk(pages, ids, jnp.broadcast_to(mask1, (4, 6)), q, 4,
                          mode="kernel_interpret")
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))


@pytest.mark.parametrize("Nc,d,B,nprobe", [(128, 128, 3, 16), (96, 64, 1, 8)])
def test_centroid_probe_parity(Nc, d, B, nprobe):
    rng = np.random.default_rng(Nc)
    cents = jnp.asarray(rng.standard_normal((Nc, d)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    valid = jnp.asarray(rng.random(Nc) > 0.2)
    sp, ip = ops.centroid_probe(cents, q, nprobe, valid=valid,
                                tile=32, mode="kernel_interpret")
    sr, ir = ops.centroid_probe(cents, q, nprobe, valid=valid, mode="ref")
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ir))


@pytest.mark.parametrize("B,S,KVH,G,Dh,window", [
    (2, 256, 4, 3, 64, 0),
    (2, 256, 4, 3, 64, 50),
    (1, 128, 1, 8, 32, 0),      # MQA
    (3, 64, 2, 1, 128, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_parity(B, S, KVH, G, Dh, window, dtype):
    rng = np.random.default_rng(S + window)
    q = jnp.asarray(rng.standard_normal((B, KVH, G, Dh)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, KVH, Dh)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, KVH, Dh)), dtype)
    pos = jnp.asarray(rng.integers(1, S, B), jnp.int32)
    o_ref = ref.flash_decode_ref(q, k, v, pos, window)
    o_k = ops.flash_decode(q, k, v, pos, window=window, tile=64,
                           mode="kernel_interpret")
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("B,KVH,G,Dh,ps,MB,window", [
    (3, 2, 4, 32, 16, 5, 0),
    (3, 2, 4, 32, 16, 5, 20),
    (1, 1, 8, 64, 8, 3, 0),      # MQA
    (2, 4, 1, 64, 32, 2, 10),
])
@pytest.mark.parametrize("mode", ["ref", "kernel_interpret"])
def test_flash_decode_paged_parity(B, KVH, G, Dh, ps, MB, window, mode):
    """Paged == dense over ragged block tables, incl. partially filled
    last blocks and unallocated (-1) tail entries."""
    rng = np.random.default_rng(B * 100 + ps + window)
    NP = B * MB + 4                             # slab bigger than needed
    q = jnp.asarray(rng.standard_normal((B, KVH, G, Dh)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((NP, ps, KVH, Dh)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((NP, ps, KVH, Dh)), jnp.float32)
    # non-contiguous slots per request; lengths hit partial last blocks
    perm = rng.permutation(NP)[:B * MB].reshape(B, MB)
    lengths = rng.integers(1, MB * ps + 1, B)
    bt = perm.copy()
    for b in range(B):
        bt[b, -(-int(lengths[b]) // ps):] = -1  # unallocated tail
    bt = jnp.asarray(bt, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    out = ops.flash_decode_paged(q, kp, vp, bt, lengths, window=window,
                                 mode=mode)
    # oracle: gather the table into a dense cache, dense kernel at
    # pos = lengths - 1
    dense_k = kp[jnp.maximum(bt, 0)].reshape(B, MB * ps, KVH, Dh)
    dense_v = vp[jnp.maximum(bt, 0)].reshape(B, MB * ps, KVH, Dh)
    o_ref = ref.flash_decode_ref(q, dense_k, dense_v, lengths - 1, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(o_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("B,d,Nc,P,ps,nprobe,k", [
    (4, 64, 24, 18, 8, 7, 5),
    (1, 32, 16, 6, 16, 3, 4),
    (6, 128, 32, 24, 4, 16, 8),
])
@pytest.mark.parametrize("mode", ["ref", "kernel_interpret"])
def test_probe_and_topk_matches_composition(B, d, Nc, P, ps, nprobe, k, mode):
    """Fused one-launch retrieval == centroid_probe -> page mask ->
    ivf_topk on random page tables (incl. unsearchable -1 slots and
    padded page tails)."""
    rng = np.random.default_rng(B * 31 + Nc)
    qs = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    cents = jnp.asarray(rng.standard_normal((Nc, d)), jnp.float32)
    pages = jnp.asarray(rng.standard_normal((P, ps, d)), jnp.float32)
    pids = jnp.asarray(rng.permutation(P * ps).reshape(P, ps), jnp.int32)
    pids = pids.at[0, ps // 2:].set(-1)                 # padded page tail
    pc = jnp.asarray(rng.integers(-1, Nc, P), jnp.int32)  # -1 = unsearchable
    s_f, i_f = ops.probe_and_topk(qs, cents, pages, pids, pc, nprobe=nprobe,
                                  k=k, cent_tile=8, page_tile=2, mode=mode)
    # unfused composition via the public ops
    ps_, pi_ = ops.centroid_probe(cents, qs, nprobe, mode="ref")
    lut = np.zeros((B, Nc), bool)
    for b in range(B):
        lut[b, np.asarray(pi_)[b][np.isfinite(np.asarray(ps_)[b])]] = True
    pcn = np.asarray(pc)
    mask = np.zeros((B, P), bool)
    mask[:, pcn >= 0] = lut[:, pcn[pcn >= 0]]
    s_u, i_u = ops.ivf_topk(pages, pids, jnp.asarray(mask), qs, k, mode="ref")
    np.testing.assert_array_equal(np.asarray(i_f), np.asarray(i_u))
    np.testing.assert_allclose(np.asarray(s_f), np.asarray(s_u),
                               rtol=1e-5, atol=1e-5)


def test_resolve_mode_env_and_aliases(monkeypatch):
    """ONE dispatch layer: explicit mode > REPRO_KERNEL_MODE env >
    backend autodetect; aliases resolve; unknown modes raise."""
    monkeypatch.delenv(ops.MODE_ENV_VAR, raising=False)
    auto = ops.resolve_mode("auto")
    assert auto == ("kernel" if jax.default_backend() == "tpu" else "ref")
    assert ops.resolve_mode(None) == auto
    # aliases
    assert ops.resolve_mode("tpu") == "kernel"
    assert ops.resolve_mode("compiled") == "kernel"
    assert ops.resolve_mode("oracle") == "ref"
    assert ops.resolve_mode("interpret") == "kernel_interpret"
    # env only applies when the call says "auto"
    monkeypatch.setenv(ops.MODE_ENV_VAR, "interpret")
    assert ops.resolve_mode("auto") == "kernel_interpret"
    assert ops.resolve_mode("ref") == "ref"
    monkeypatch.setenv(ops.MODE_ENV_VAR, "bogus")
    with pytest.raises(ValueError):
        ops.resolve_mode("auto")
    with pytest.raises(ValueError):
        ops.resolve_mode("not-a-mode")


def test_env_mode_flips_whole_stack(monkeypatch):
    """REPRO_KERNEL_MODE=interpret routes a default-mode op through the
    pallas interpreter — same numbers as the oracle."""
    rng = np.random.default_rng(3)
    cents = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((2, 16)), jnp.float32)
    monkeypatch.setenv(ops.MODE_ENV_VAR, "interpret")
    si, ii = ops.centroid_probe(cents, q, 4)
    monkeypatch.delenv(ops.MODE_ENV_VAR)
    sr, ir = ops.centroid_probe(cents, q, 4, mode="ref")
    np.testing.assert_allclose(np.asarray(si), np.asarray(sr), rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(ii), np.asarray(ir))


def test_flash_decode_matches_model_decode_attention():
    """Kernel semantics == the pure-JAX decode attention used by serve_step."""
    from repro.models.attention import _decode_attention
    rng = np.random.default_rng(7)
    B, S, KVH, G, Dh = 2, 128, 4, 2, 64
    q = jnp.asarray(rng.standard_normal((B, 1, KVH, G, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KVH, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KVH, Dh)), jnp.float32)
    pos = jnp.asarray([60, 127], jnp.int32)
    a = _decode_attention(q, k, v, pos=pos, window=None, softcap_val=None,
                          chunk=S)
    b = ops.flash_decode(q[:, 0] / np.sqrt(1.0), k, v, pos, window=0,
                         tile=32, mode="kernel_interpret")
    np.testing.assert_allclose(np.asarray(a[:, 0]), np.asarray(b),
                               rtol=1e-4, atol=1e-4)
