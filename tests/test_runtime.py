"""Event-driven RetrievalRuntime: transfer engine, legacy-model
equivalence, and continuous-batching overlap timelines."""

import numpy as np
import pytest

import repro.core as core
from repro.configs import get_arch
from repro.core.transfer import TransferEngine
from repro.serving import (EngineConfig, LatencyContext, RequestState,
                           RetrievalRuntime, TeleRAGEngine, make_traces)
from repro.serving.trace import RequestTrace, StageTrace
from tests.conftest import unit_queries

MODES = ("telerag", "cpu_baseline", "runtime_fetch")


def make_engine(small_index, mode="telerag", seed=5, buffer_pages=160):
    cfg = EngineConfig(nprobe=16, top_k=3, buffer_pages=buffer_pages,
                       lookahead_rank=32, kernel_mode="ref", chips=8,
                       mode=mode, seed=seed)
    return TeleRAGEngine(small_index, cfg, get_arch("llama3-8b"))


# ---------------------------------------------------------------------------
# TransferEngine: double-buffered link, in-flight events
# ---------------------------------------------------------------------------


def test_transfer_engine_double_buffered_link(small_index):
    buf = core.PrefetchBuffer(small_index.paged, num_pages=64)
    te = TransferEngine(buf, link_bw=1e9, channels=2)
    # two copies submitted together start together (separate channels)
    e1 = te.submit([], now=0.0, nbytes=int(1e9))      # 1 s copy
    e2 = te.submit([], now=0.0, nbytes=int(5e8))      # 0.5 s copy
    assert e1.channel != e2.channel
    assert e1.start_t == e2.start_t == 0.0
    assert e1.end_t == pytest.approx(1.0)
    # a third queues on the earliest-free channel
    e3 = te.submit([], now=0.1, nbytes=int(1e8))
    assert e3.channel == e2.channel
    assert e3.start_t == pytest.approx(0.5)           # waited for channel
    assert e3.queued_s == pytest.approx(0.4)
    assert te.in_flight(0.25) == [e1, e2]
    assert te.drained_at() == pytest.approx(max(e1.end_t, e3.end_t))
    # overlap is interval intersection, not totals
    assert e1.overlaps(0.9, 2.0) and not e1.overlaps(1.0, 2.0)


def test_transfer_engine_dispatches_real_loads(small_index):
    buf = core.PrefetchBuffer(small_index.paged, num_pages=64)
    te = TransferEngine(buf, link_bw=32e9)
    ev = te.submit([0, 1], now=0.0)
    assert buf.is_resident(0) and buf.is_resident(1)
    assert ev.nbytes == sum(small_index.paged.cluster_bytes(c)
                            for c in (0, 1))
    assert ev.duration == pytest.approx(ev.nbytes / 32e9)


def test_transfer_ready_t_per_request_view(small_index):
    buf = core.PrefetchBuffer(small_index.paged, num_pages=64)
    te = TransferEngine(buf, link_bw=1e9)
    ev = te.submit([], now=0.0, nbytes=int(1e9))      # [0, 1]
    # consumer dispatching later sees the window from its own boundary
    assert te.ready_t(ev, 0.0) == pytest.approx(1.0)
    assert te.ready_t(ev, 0.4) == pytest.approx(1.4)
    # but never earlier than the physical completion
    assert te.ready_t(ev, -1.0) == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# Acceptance: static batch == legacy max()-composed model, all modes
# ---------------------------------------------------------------------------


def _legacy_latency(result, mode, *, t_cc, cluster_bytes, link_bw):
    """The pre-runtime closed forms, composed per round (unchanged code
    paths on RoundTelemetry)."""
    tot = 0.0
    for r in result.rounds:
        if mode == "telerag":
            tot += r.t_telerag()
        elif mode == "cpu_baseline":
            tot += r.t_cpu_baseline(t_cc)
        else:
            tot += r.t_runtime_fetch(cluster_bytes, link_bw)
    return tot


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("pipe", ("hyde", "iter", "irg"))
def test_static_batch_event_clock_matches_legacy_model(
        small_store, small_index, rng, mode, pipe):
    """The never-re-form mode (``reform=False``, what the deprecated
    shims run) reproduces the legacy max()-composed closed forms: the
    admission group stays the wave for every round, so each round's
    telemetry composes exactly as the pre-runtime lockstep loop did."""
    eng = make_engine(small_index, mode)
    t_cc = eng.effective_tcc()
    ctx = LatencyContext(t_cc=t_cc, cluster_bytes=1e6, link_bw=32e9)
    runtime = RetrievalRuntime(eng, ctx=ctx, reform=False)
    q = unit_queries(small_store, rng, 4)
    traces = make_traces(pipe, 4, seed=11)
    recs = [runtime.submit(q[i], traces[i]) for i in range(4)]
    runtime.run()
    for rec in recs:
        assert rec.state == RequestState.COMPLETE
        assert len(rec.result.rounds) == rec.trace.rounds
        legacy = _legacy_latency(rec.result, mode, t_cc=t_cc,
                                 cluster_bytes=1e6, link_bw=32e9)
        assert rec.latency == pytest.approx(legacy, abs=1e-6)
        # the policy-registry path agrees with the closed forms too
        assert rec.result.latency(mode, t_cc=t_cc, cluster_bytes=1e6,
                                  link_bw=32e9) == pytest.approx(legacy,
                                                                 abs=1e-9)


def test_timeline_spans_are_causal(small_store, small_index, rng):
    eng = make_engine(small_index, "telerag")
    runtime = RetrievalRuntime(eng)
    q = unit_queries(small_store, rng, 3)
    recs = [runtime.submit(q[i], t)
            for i, t in enumerate(make_traces("iter", 3, seed=2))]
    runtime.run()
    for rec in recs:
        assert rec.admit_t <= rec.complete_t
        for rnd in range(rec.trace.rounds):
            gen = [s for s in rec.spans("generate") if s.round_index == rnd]
            ret = [s for s in rec.spans("retrieve") if s.round_index == rnd]
            assert len(gen) == 1 and len(ret) == 1
            assert gen[0].start <= gen[0].end <= ret[0].start <= ret[0].end
    # the global event log is time-ordered
    times = [t for t, _, _ in runtime.event_log]
    assert times == sorted(times)


# ---------------------------------------------------------------------------
# Acceptance: interleaved arrivals — prefetch in flight during another
# request's generation window (event timeline, not totals)
# ---------------------------------------------------------------------------


def _long_gen_trace(request_id, gen_tokens):
    return RequestTrace(pipeline="hyde", request_id=request_id,
                        stages=[StageTrace("generate", gen_tokens),
                                StageTrace("retrieve"),
                                StageTrace("generate", 8)],
                        rewrite_sigma=0.1)


def test_interleaved_arrival_prefetch_overlaps_generation(
        small_store, small_index, rng):
    # buffer headroom: the planner never plans past free pages, so a
    # mid-flight wave needs slack left over from the first wave's budget
    eng = make_engine(small_index, "telerag", seed=7, buffer_pages=512)
    runtime = RetrievalRuntime(eng)
    # disjoint cluster neighbourhoods so wave B must move fresh bytes
    cents = small_index.centroids / np.linalg.norm(
        small_index.centroids, axis=-1, keepdims=True)
    qa = cents[:2].astype(np.float32)
    qb = cents[-2:].astype(np.float32)

    t_llm_a = eng.llm_window_seconds(4000, 2)
    assert t_llm_a > 0
    mid = 0.5 * t_llm_a       # wave B lands mid-way through A's windows

    recs_a = [runtime.submit(qa[i], _long_gen_trace(i, 4000))
              for i in range(2)]
    recs_b = [runtime.submit(qb[i], _long_gen_trace(10 + i, 4000), mid)
              for i in range(2)]
    runtime.run()

    assert all(r.state == RequestState.COMPLETE for r in recs_a + recs_b)
    # wave B was admitted while wave A was still generating
    assert recs_b[0].admit_t == pytest.approx(mid)
    assert all(r.admit_t == 0.0 for r in recs_a)

    b_transfers = [e for e in eng.transfer.events
                   if e.kind == "prefetch" and e.nbytes > 0
                   and e.submit_t >= mid * 0.999]
    assert b_transfers, "wave B dispatched no prefetch bytes"

    a_gen = [s for r in recs_a for s in r.spans("generate")
             if s.round_index == 0]
    # event-timeline assertion: B's copy occupies the link strictly
    # inside an A generation window — overlap as interval intersection
    hits = [(e, s) for e in b_transfers for s in a_gen
            if e.overlaps(s.start, s.end)]
    assert hits, (b_transfers, a_gen)
    ev, span = hits[0]
    assert span.start < ev.start_t < span.end     # starts mid-window
    # and A's requests were still incomplete when B's transfer started
    assert all(ev.start_t < r.complete_t for r in recs_a)


def test_runtime_is_reusable_across_waves(small_store, small_index, rng):
    """Clock is monotonic across run() calls; latencies stay relative."""
    eng = make_engine(small_index, "telerag")
    runtime = RetrievalRuntime(eng)
    q = unit_queries(small_store, rng, 2)
    r1 = [runtime.submit(q[i], t)
          for i, t in enumerate(make_traces("hyde", 2, seed=3))]
    runtime.run()
    r2 = [runtime.submit(q[i], t)
          for i, t in enumerate(make_traces("hyde", 2, seed=4))]
    runtime.run()
    assert r2[0].admit_t >= r1[0].complete_t      # no time travel
    assert all(r.latency > 0 for r in r1 + r2)
