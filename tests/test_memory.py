"""Unified paged device-memory subsystem: pool leases/refcounts and
reservations, byte-accurate ledger accounting, admission control
(reserve/stall/spill), and the PRESSURE_STALLED runtime path."""

import numpy as np
import pytest

import repro.core as core
from repro.configs import get_arch
from repro.core.schedulers import TeleRAGScheduler
from repro.memory import (AdmissionController, DevicePagePool, MemoryLedger,
                          PoolExhausted)
from repro.serving import (EngineConfig, KVCacheManager, PipelineExecutor,
                           RequestState, RetrievalRuntime, TeleRAGEngine,
                           make_traces)
from tests.conftest import unit_queries


# ---------------------------------------------------------------------------
# DevicePagePool: leases, refcounts, reservations, block tables
# ---------------------------------------------------------------------------


def test_pool_lease_release_accounting(small_index):
    pool = DevicePagePool(small_index.paged, num_pages=32)
    pb = pool.page_nbytes
    lease = pool.lease_slots(5, "prefetch", tag=7)
    assert lease is not None and lease.num_pages == 5
    assert sorted(lease.slots) == sorted(set(lease.slots))   # distinct slots
    assert pool.free_pages() == 27 and pool.used_pages == 5
    assert pool.ledger.bytes_of("prefetch") == 5 * pb
    # refcount: retain defers the free until the last holder releases
    pool.retain(lease)
    assert pool.release(lease) == 0
    assert pool.free_pages() == 27
    assert pool.release(lease) == 5
    assert pool.free_pages() == 32
    assert pool.ledger.bytes_of("prefetch") == 0
    assert pool.ledger.peak_bytes == 5 * pb


def test_pool_byte_lease_exact_ledger_page_rounded_slots(small_index):
    pool = DevicePagePool(small_index.paged, num_pages=16)
    pb = pool.page_nbytes
    nbytes = int(2.5 * pb)
    lease = pool.lease_bytes(nbytes, "kv", tag=("b", "l"))
    assert lease.num_pages == 3                 # page-rounded slab footprint
    assert lease.nbytes == nbytes               # exact bytes on the ledger
    assert pool.ledger.bytes_of("kv") == nbytes
    pool.release(lease)
    assert pool.ledger.bytes_of("kv") == 0


def test_pool_reservations_gate_allocation(small_index):
    pool = DevicePagePool(small_index.paged, num_pages=10)
    res = pool.reserve(6, "waveA")
    assert res is not None and pool.reservable_pages() == 4
    # a second reservation cannot eat into the first's headroom
    assert pool.reserve(5, "waveB") is None
    # nor can an unreserved lease
    assert pool.lease_slots(5, "prefetch") is None
    # allocation under the reservation consumes it
    lease = pool.lease_slots(4, "prefetch", reservation=res)
    assert lease is not None and res.pages == 2
    assert pool.cancel(res) == 2                # remainder returns
    assert pool.reservable_pages() == 6
    pool.release(lease)
    assert pool.reservable_pages() == 10


def test_pool_free_events_notify_subscribers(small_index):
    pool = DevicePagePool(small_index.paged, num_pages=8)
    freed = []
    pool.subscribe(freed.append)
    lease = pool.lease_slots(3, "prefetch")
    assert freed == []
    pool.release(lease)
    assert freed == [3]
    res = pool.reserve(4, "w")
    pool.cancel(res)
    assert freed == [3, 4]                      # cancelled headroom counts


# ---------------------------------------------------------------------------
# MemoryLedger: byte accuracy, occupancy, snapshots
# ---------------------------------------------------------------------------


def test_ledger_charges_and_occupancy():
    led = MemoryLedger(capacity_bytes=1000)
    led.charge("weights", 600)
    led.charge("kv", 150)
    led.credit("kv", 50)
    assert led.bytes_of("kv") == 100
    assert led.total_bytes() == 700
    assert led.occupancy() == pytest.approx(0.7)
    assert led.peak_bytes == 750
    snap = led.snapshot()
    assert snap["total"] == 700 and snap["capacity"] == 1000
    with pytest.raises(ValueError):
        led.credit("weights", 601)              # over-credit is a bug


def test_engine_ledger_tracks_buffer_and_weights(small_store, small_index,
                                                 rng):
    eng = TeleRAGEngine(small_index,
                        EngineConfig(nprobe=16, top_k=3, buffer_pages=160,
                                     lookahead_rank=32, kernel_mode="ref",
                                     chips=8),
                        get_arch("llama3-8b"))
    assert eng.ledger.bytes_of("weights") == \
        get_arch("llama3-8b").param_count() * 2
    ex = PipelineExecutor(eng)
    q = unit_queries(small_store, rng, 2)
    ex.execute_batch(q, make_traces("hyde", 2, seed=1))
    # end_batch evicted everything: prefetch charge fully credited back
    assert eng.ledger.bytes_of("prefetch") == 0
    assert eng.ledger.peak_bytes > eng.ledger.bytes_of("weights")


# ---------------------------------------------------------------------------
# KVCacheManager leases from the shared pool
# ---------------------------------------------------------------------------


def test_kv_lease_charges_ledger_byte_accurately(small_index):
    arch = get_arch("llama3-8b").reduced()
    pool = DevicePagePool(small_index.paged, num_pages=4096)
    kv = KVCacheManager(arch, pool=pool)
    lease = kv.acquire(2, 64)
    assert lease.nbytes == kv.nbytes(2, 64)
    assert pool.ledger.bytes_of("kv") == lease.nbytes     # exact, to the byte
    kv.release(lease)
    assert pool.ledger.bytes_of("kv") == lease.nbytes     # recycled, resident
    assert kv.drop(2, 64) == lease.nbytes
    assert pool.ledger.bytes_of("kv") == 0


def test_kv_and_prefetch_compete_for_the_same_pages(small_index):
    arch = get_arch("llama3-8b").reduced()
    pool = DevicePagePool(small_index.paged, num_pages=8)
    buf = core.PrefetchBuffer(small_index.paged, pool=pool)
    kv = KVCacheManager(arch, pool=pool)
    need = -(-kv.nbytes(1, 32) // pool.page_nbytes)
    assert need <= 8, "test arch too large for the test pool"
    # fill the slab with cluster pages, leaving less than the KV needs
    cs, pages = [], 0
    for c in range(small_index.paged.num_clusters):
        npg = int(small_index.paged.cluster_num_pages[c])
        if pool.free_pages() - (pages + npg) < need:
            break
        cs.append(c)
        pages += npg
    extra = [c for c in range(small_index.paged.num_clusters)
             if c not in cs][:1]
    buf.load_clusters(cs + extra)               # now < need pages free
    with pytest.raises(PoolExhausted):
        kv.acquire(1, 32)
    buf.evict_clusters(cs + extra)              # prefetch frees -> KV fits
    lease = kv.acquire(1, 32)
    assert lease.page_lease is not None
    kv.release(lease)


@pytest.mark.parametrize("arch_name,zeroed", [("llama3-8b", False),
                                              ("rwkv6-3b", True)])
def test_kv_reuse_zeroing_policy(arch_name, zeroed):
    """Recurrent (SSM) state is zeroed on reuse; attention caches are
    recycled unzeroed (stale entries are masked by per-sequence pos)."""
    import jax
    import jax.numpy as jnp
    kv = KVCacheManager(get_arch(arch_name).reduced())
    l1 = kv.acquire(1, 32)
    l1.cache = jax.tree.map(lambda a: a + 1.0, l1.cache)   # poison
    kv.release(l1)
    l2 = kv.acquire(1, 32)
    peak = max(float(jnp.max(jnp.abs(a)))
               for a in jax.tree.leaves(l2.cache))
    if zeroed:
        assert peak == 0.0
    else:
        assert peak > 0.0


# ---------------------------------------------------------------------------
# AdmissionController: reserve / spill / cap decisions
# ---------------------------------------------------------------------------


def test_admission_reserve_then_cap_without_waiters(small_index):
    pool = DevicePagePool(small_index.paged, num_pages=10)
    adm = AdmissionController(pool)
    t1 = adm.admit(6, "w1", can_wait=True)
    assert t1 is not None and not t1.capped
    # an outstanding reservation is a pending release -> w2 stalls on it
    assert adm.admit(8, "w2", can_wait=True) is None
    assert adm.stats.stalled == 1
    # consume w1's grant as plain unpinned residency, commit the rest:
    # now nothing outstanding will ever free pages -> cap, never deadlock
    lease = pool.lease_slots(6, "prefetch", reservation=t1.reservation)
    adm.commit(t1)
    t2 = adm.admit(8, "w2", can_wait=True)
    assert t2 is not None and t2.capped and t2.pages_granted == 4
    assert adm.stats.capped == 1 and adm.stats.shortfall_pages == 4
    adm.commit(t2)
    pool.release(lease)
    assert pool.reservable_pages() == 10


def test_admission_stalls_when_a_future_free_exists(small_index):
    pool = DevicePagePool(small_index.paged, num_pages=10)
    adm = AdmissionController(pool)
    kv_lease = pool.lease_bytes(6 * pool.page_nbytes, "kv")
    assert adm.holds_pending_release()
    assert adm.admit(8, "w", can_wait=True) is None       # parks instead
    assert adm.stats.stalled == 1
    pool.release(kv_lease)
    t = adm.admit(8, "w", can_wait=True)
    assert t is not None and not t.capped
    adm.commit(t)


def test_admission_spills_cold_residency(small_store, small_index, rng):
    eng = TeleRAGEngine(small_index,
                        EngineConfig(nprobe=16, top_k=3, buffer_pages=64,
                                     lookahead_rank=32, kernel_mode="ref",
                                     seed=2),
                        get_arch("llama3-8b"))
    cs = []
    for c in range(small_index.paged.num_clusters):
        if eng.pool.free_pages() < int(small_index.paged.cluster_num_pages[c]):
            break
        cs.append(c)
    eng.buffer.load_clusters(cs)
    eng.cache.on_fetched(cs)
    full = eng.pool.free_pages()
    ticket = eng.admission.admit(20, "wave", can_wait=False)
    assert ticket is not None and ticket.pages_granted == 20
    assert ticket.spilled_pages >= 20 - full              # evicted cold pages
    eng.admission.commit(ticket)


# ---------------------------------------------------------------------------
# Acceptance: PRESSURE_STALLED lifecycle under a pool sized below one
# wave's plan — stall, resume on the page-free event, complete; and
# telemetry equivalence under an over-provisioned pool
# ---------------------------------------------------------------------------


def _pressured_runtime(small_index, pool_clusters=6):
    pages_per_cluster = float(np.mean(small_index.paged.cluster_num_pages))
    pool_pages = int(pool_clusters * pages_per_cluster)
    eng = TeleRAGEngine(small_index,
                        EngineConfig(nprobe=12, top_k=3,
                                     buffer_pages=pool_pages,
                                     lookahead_rank=16, kernel_mode="ref",
                                     chips=8, seed=3),
                        get_arch("llama3-8b"))
    # never-re-form mode: this suite pins the legacy group-granular
    # release ordering (a wave's shared pins free when its LAST member
    # completes); the per-request fine-grained release is covered in
    # tests/test_continuous.py
    return eng, RetrievalRuntime(
        eng, scheduler=TeleRAGScheduler(cache_aware=False), micro_batch=2,
        reform=False)


def test_pressure_stall_event_ordering_and_completion(small_store,
                                                      small_index, rng):
    eng, runtime = _pressured_runtime(small_index)
    cents = small_index.centroids / np.linalg.norm(
        small_index.centroids, axis=-1, keepdims=True)
    # two similarity groups with disjoint cluster neighbourhoods
    q = np.concatenate([cents[:2], cents[-2:]]).astype(np.float32)
    traces = make_traces("hyde", 4, seed=5)
    recs = [runtime.submit(q[i], traces[i]) for i in range(4)]
    runtime.run()

    # every request completed despite the pressure (no deadlock, no drop)
    assert all(r.state == RequestState.COMPLETE for r in recs)
    assert not eng.admission.parked
    assert eng.admission.stats.stalled >= 1
    assert eng.admission.stats.resumed >= 1

    stall_t = {rid: t for t, label, rid in runtime.event_log
               if label == "pressure_stall"}
    resume_t = {rid: t for t, label, rid in runtime.event_log
                if label == "pressure_resume"}
    complete_t = {rid: t for t, label, rid in runtime.event_log
                  if label == "complete"}
    assert stall_t, "no request ever entered PRESSURE_STALLED"
    stalled_ids = set(stall_t)
    first_wave_completes = [t for rid, t in complete_t.items()
                            if rid not in stalled_ids]
    for rid in stalled_ids:
        # pinned event ordering: stall at admit-time pressure, resume
        # exactly on the page-free event of the completing wave (its
        # pins release when its LAST member completes), complete after
        assert rid in resume_t and rid in complete_t
        assert stall_t[rid] <= resume_t[rid] <= complete_t[rid]
        assert resume_t[rid] == pytest.approx(max(first_wave_completes))
        rec = next(r for r in recs if r.request_id == rid)
        stall_spans = rec.spans("pressure_stall")
        assert stall_spans and stall_spans[0].end == \
            pytest.approx(resume_t[rid])
        # the stall is real latency, not hidden time
        assert rec.latency >= stall_spans[0].end - stall_spans[0].start

    # no rejected-cluster leaks: every hotness entry is resident (the
    # invariant the cache cleanup relies on), nothing half-loaded
    assert set(eng.cache.hotness) <= eng.buffer.resident_clusters() or \
        not eng.cache.hotness


def test_overprovisioned_pool_matches_default_telemetry(small_store,
                                                        small_index, rng):
    """Pool size must be invisible to telemetry when memory is ample:
    execute_batch under a 4x over-provisioned pool reproduces the
    default-sized run's RoundTelemetry to 1e-6 (the pre-refactor
    values, pinned transitively by test_runtime's legacy-model check)."""
    q = unit_queries(small_store, rng, 4)
    results = []
    for pool_pages in (160, 640):
        # cache on: the second batch also exercises consolidate's quota,
        # which must key off buffer_pages, never the pool extent
        cfg = EngineConfig(nprobe=16, top_k=3, buffer_pages=160,
                           pool_pages=pool_pages, lookahead_rank=32,
                           kernel_mode="ref", chips=8, seed=5,
                           cache_enabled=True)
        eng = TeleRAGEngine(small_index, cfg, get_arch("llama3-8b"))
        ex = PipelineExecutor(eng)
        res = ex.execute_batch(q.copy(), make_traces("iter", 4, seed=11))
        res += ex.execute_batch(q.copy(), make_traces("iter", 4, seed=12))
        assert eng.admission.stats.stalled == 0
        assert eng.admission.stats.capped == 0
        results.append(res)
    base, over = results
    for rb, ro in zip(base, over):
        np.testing.assert_array_equal(np.concatenate(rb.doc_ids),
                                      np.concatenate(ro.doc_ids))
        assert len(rb.rounds) == len(ro.rounds)
        for a, b in zip(rb.rounds, ro.rounds):
            for f in ("t_llm_window", "bytes_prefetched", "t_prefetch",
                      "hits", "misses", "t_host_search", "t_dev_search",
                      "t_merge"):
                assert getattr(a, f) == pytest.approx(getattr(b, f),
                                                      abs=1e-6), f
