"""telint acceptance: the static lint rules against synthetic positive
and negative snippets, ratchet semantics, the happens-before invariant
checker against hand-corrupted streams AND a clean served trace, plus
the lease-leak regressions the lint drove (a raising decode hook or a
raising ``init_cache`` must not strand pool pages).

The corrupted-stream tests prove the checker FAILS on each injected
violation class — a checker that passes everything is not a checker.
"""

import json

import pytest

from repro.analysis import (check_events, check_recorder,
                            events_from_perfetto, lint_source)
from repro.analysis import invariants as inv
from repro.analysis.lint import dump_baseline, load_baseline, ratchet
from repro.configs import get_arch
from repro.obs import EventClock, SystemClock, to_perfetto
from repro.serving import (EngineConfig, RagRequest, TeleRAGEngine,
                           TeleRAGServer, make_traces)
from repro.serving.runtime import RetrievalRuntime
from tests.conftest import unit_queries

SERVING = "src/repro/serving/x.py"        # in TL002/TL004 scope
LAUNCH = "src/repro/launch/x.py"          # outside the clocked core


def _rules(src, path=SERVING, only=None):
    return sorted({v.rule for v in lint_source(src, path, rules=only)})


# ---------------------------------------------------------------------------
# TL001: lease leak
# ---------------------------------------------------------------------------


def test_tl001_unreleased_acquire_fires():
    src = ("def f(pool):\n"
           "    lease = pool.lease_slots(4, owner='x')\n"
           "    return 1\n")
    vs = lint_source(src, SERVING, rules=("TL001",))
    assert [v.rule for v in vs] == ["TL001"]
    assert "never released" in vs[0].message
    assert vs[0].symbol == "f"


def test_tl001_release_without_protection_still_fires():
    src = ("def f(pool):\n"
           "    lease = pool.lease_slots(4)\n"
           "    work()\n"
           "    pool.release(lease)\n")
    vs = lint_source(src, SERVING, rules=("TL001",))
    assert len(vs) == 1 and "not on exception paths" in vs[0].message


def test_tl001_try_finally_release_is_clean():
    src = ("def f(pool):\n"
           "    lease = pool.lease_slots(4)\n"
           "    try:\n"
           "        work()\n"
           "    finally:\n"
           "        pool.release(lease)\n")
    assert _rules(src, only=("TL001",)) == []


def test_tl001_except_cleanup_is_clean():
    src = ("def f(pool):\n"
           "    lease = pool.lease_slots(4)\n"
           "    try:\n"
           "        work()\n"
           "    except BaseException:\n"
           "        pool.release(lease)\n"
           "        raise\n")
    assert _rules(src, only=("TL001",)) == []


def test_tl001_escapes_are_clean():
    returned = ("def f(pool):\n"
                "    lease = pool.lease_slots(4)\n"
                "    return lease\n")
    stored = ("def f(self, pool):\n"
              "    lease = pool.lease_slots(4)\n"
              "    self.leases[3] = lease\n")
    appended = ("def f(pool, out):\n"
                "    lease = pool.lease_slots(4)\n"
                "    out.append(lease)\n")
    for src in (returned, stored, appended):
        assert _rules(src, only=("TL001",)) == []


def test_tl001_constructed_object_escape_is_clean():
    # the residency idiom: the lease is wrapped into an object that is
    # stored on the owner — ownership transferred transitively
    src = ("def f(self, pool, d):\n"
           "    lease = pool.lease_bytes(100, 'chunk_kv')\n"
           "    res = Residency(doc_id=d, lease=lease)\n"
           "    self.resident[d] = res\n")
    assert _rules(src, only=("TL001",)) == []
    # but wrapping alone is not an escape: a dropped wrapper still leaks
    src = ("def f(pool, d):\n"
           "    lease = pool.lease_bytes(100, 'chunk_kv')\n"
           "    res = Residency(doc_id=d, lease=lease)\n"
           "    return 1\n")
    assert _rules(src, only=("TL001",)) == ["TL001"]


def test_tl001_discarded_acquire_fires():
    src = ("def f(buffer, m, cs):\n"
           "    buffer.pin_clusters(m, cs)\n")
    vs = lint_source(src, SERVING, rules=("TL001",))
    assert len(vs) == 1 and "discarded" in vs[0].message
    assert vs[0].detail == "discard:pin_clusters"


def test_tl001_keyed_registry_release_excuses_discard():
    # the runtime idiom: pins registered under key ``m`` are dropped by
    # a protected ``unpin(m)`` — the lease object itself is never named
    src = ("def f(buffer, m, cs):\n"
           "    try:\n"
           "        buffer.pin_clusters(m, cs)\n"
           "        work()\n"
           "    except BaseException:\n"
           "        buffer.unpin(m)\n"
           "        raise\n")
    assert _rules(src, only=("TL001",)) == []


def test_tl001_loop_alias_credits_the_iterated_list():
    # releasing ``pins`` inside ``for m, pins in zip(keys, hit_pins)``
    # must credit ``hit_pins``; the except-side ``unpin(m)`` protects
    # the listcomp acquire through its key argument
    src = ("def f(eng, keys, sets):\n"
           "    hit_pins = [eng.buffer.pin_clusters(m, cs)\n"
           "                for m, cs in zip(keys, sets)]\n"
           "    try:\n"
           "        work()\n"
           "    except BaseException:\n"
           "        for m in keys:\n"
           "            eng.buffer.unpin(m)\n"
           "        raise\n"
           "    for m, pins in zip(keys, hit_pins):\n"
           "        eng.buffer.release_pins(m, pins)\n")
    assert _rules(src, only=("TL001",)) == []


# ---------------------------------------------------------------------------
# TL002: wall-clock discipline
# ---------------------------------------------------------------------------


def test_tl002_wall_clock_in_core_fires_but_launch_is_exempt():
    src = ("import time\n"
           "def f():\n"
           "    return time.perf_counter()\n")
    assert _rules(src, path=SERVING, only=("TL002",)) == ["TL002"]
    assert _rules(src, path=LAUNCH, only=("TL002",)) == []
    # the injectable clock module is the one sanctioned site
    assert _rules(src, path="src/repro/obs/clock.py",
                  only=("TL002",)) == []


def test_tl002_from_import_form_fires():
    src = ("from time import perf_counter\n"
           "def f():\n"
           "    return perf_counter()\n")
    vs = lint_source(src, SERVING, rules=("TL002",))
    assert len(vs) == 1 and vs[0].detail == "perf_counter"


def test_tl002_non_clock_time_attrs_are_clean():
    src = ("import time\n"
           "def f():\n"
           "    time.sleep(0.1)\n")
    assert _rules(src, only=("TL002",)) == []


# ---------------------------------------------------------------------------
# TL003: kernel-mode discipline
# ---------------------------------------------------------------------------


def test_tl003_interpret_kwarg_outside_kernels_fires():
    src = "y = pallas_call(f, interpret=True)\n"
    assert _rules(src, path=SERVING, only=("TL003",)) == ["TL003"]
    assert _rules(src, path="src/repro/kernels/x.py",
                  only=("TL003",)) == []


def test_tl003_interpret_mode_literal_fires():
    src = "res = search(q, kernel_mode='interpret')\n"
    vs = lint_source(src, SERVING, rules=("TL003",))
    assert len(vs) == 1 and "interpret" in vs[0].detail
    # non-interpret literals are fine
    assert _rules("res = search(q, kernel_mode='ref')\n",
                  only=("TL003",)) == []


# ---------------------------------------------------------------------------
# TL004: tenant threading
# ---------------------------------------------------------------------------


def test_tl004_untenanted_admit_fires_in_scope_only():
    src = "t = eng.admission.admit(8, owner='w1')\n"
    assert "TL004" in _rules(src, path=SERVING, only=("TL004",))
    assert _rules(src, path=LAUNCH, only=("TL004",)) == []
    assert _rules("t = eng.admission.admit(8, tenant='a')\n",
                  only=("TL004",)) == []
    # **kwargs may carry the tenant: not provable, not flagged
    assert _rules("t = eng.admission.admit(8, **kw)\n",
                  only=("TL004",)) == []


# ---------------------------------------------------------------------------
# TL005: swallowed pressure
# ---------------------------------------------------------------------------


def test_tl005_bare_and_swallowing_excepts_fire():
    bare = ("try:\n    f()\nexcept:\n    pass\n")
    swallow = ("try:\n    f()\nexcept PoolExhausted:\n    pass\n")
    handled = ("try:\n    f()\nexcept PoolExhausted:\n    park()\n")
    named = ("try:\n    f()\nexcept ValueError:\n    pass\n")
    assert _rules(bare, only=("TL005",)) == ["TL005"]
    assert _rules(swallow, only=("TL005",)) == ["TL005"]
    assert _rules(handled, only=("TL005",)) == []
    assert _rules(named, only=("TL005",)) == []


# ---------------------------------------------------------------------------
# Ratchet baseline
# ---------------------------------------------------------------------------


def test_ratchet_grandfathers_baseline_and_catches_new(tmp_path):
    leaky = ("def f(pool):\n"
             "    lease = pool.lease_slots(4)\n"
             "    return 1\n")
    vs = lint_source(leaky, SERVING, rules=("TL001",))
    path = str(tmp_path / "baseline.json")
    dump_baseline(vs, path)
    base = load_baseline(path)
    assert base == {vs[0].key: 1}

    # same findings: nothing new
    new, stale = ratchet(vs, base)
    assert new == [] and stale == []

    # a second leak in another function is NEW even with a baseline
    vs2 = lint_source(leaky + "def g(pool):\n"
                              "    l2 = pool.lease_slots(2)\n"
                              "    return 1\n", SERVING,
                      rules=("TL001",))
    new, _ = ratchet(vs2, base)
    assert len(new) == 1 and new[0].symbol == "g"

    # fixing the grandfathered one reports its key as stale, still passes
    new, stale = ratchet([], base)
    assert new == [] and stale == [vs[0].key]


def test_baseline_schema_is_versioned(tmp_path):
    path = str(tmp_path / "b.json")
    with open(path, "w") as f:
        json.dump({"schema": "something-else", "violations": {}}, f)
    with pytest.raises(AssertionError):
        load_baseline(path)


# ---------------------------------------------------------------------------
# Happens-before invariant checker: hand-corrupted streams
# ---------------------------------------------------------------------------


def _clean_stream():
    """A minimal well-ordered wave: admit -> reserve -> issue ->
    dispatch -> land -> retrieve -> release -> complete."""
    return [
        {"kind": "request", "label": "admit", "t": 0.0, "replica": -1,
         "request_id": 0, "tenant": "shared"},
        {"kind": "admission.admit", "t": 0.10, "replica": 0, "wave_id": 1,
         "owner": "w1", "pages_requested": 4, "pages_granted": 4},
        {"kind": "transfer.issue", "t": 0.10, "replica": 0,
         "transfer_id": 7, "nbytes": 100, "start_t": 0.10, "end_t": 0.30},
        {"kind": "wave.dispatch", "t": 0.10, "replica": 0, "wave_id": 1,
         "size": 1, "request_ids": (0,), "transfer_id": 7, "nbytes": 100},
        {"kind": "pool.lease", "t": 0.10, "replica": 0, "owner": "prefetch",
         "pages": 4, "nbytes": 100},
        {"kind": "span", "name": "retrieve", "t": 0.35, "dur": 0.01,
         "replica": 0, "request_id": 0, "wave_id": 1},
        {"kind": "pool.release", "t": 0.50, "replica": 0,
         "owner": "prefetch", "pages": 4, "nbytes": 100},
        {"kind": "request", "label": "complete", "t": 0.60, "replica": -1,
         "request_id": 0, "tenant": "shared"},
    ]


def test_clean_stream_passes_fully_drained():
    rep = check_events(_clean_stream(), drained=True,
                       must_drain=("prefetch", "kv"))
    assert rep.ok, rep.summary()
    assert rep.stats["transfers"] == 1
    assert rep.stats["waves_dispatched"] == 1
    assert rep.outstanding == {}


def test_use_before_land_race_is_caught():
    evs = _clean_stream()
    retrieve = next(e for e in evs if e.get("name") == "retrieve")
    retrieve["t"] = 0.20                   # transfer lands at 0.30
    rep = check_events(evs)
    assert rep.of(inv.USE_BEFORE_LAND), rep.summary()
    assert rep.of(inv.USE_BEFORE_LAND)[0].wave_id == 1


def test_dispatch_without_admission_is_caught():
    evs = [e for e in _clean_stream()
           if e["kind"] != "admission.admit"]
    rep = check_events(evs)
    assert rep.of(inv.DISPATCH_WITHOUT_ADMISSION), rep.summary()

    # admission AFTER the dispatch is just as wrong
    evs = _clean_stream()
    next(e for e in evs if e["kind"] == "admission.admit")["t"] = 0.2
    rep = check_events(evs)
    assert rep.of(inv.DISPATCH_WITHOUT_ADMISSION), rep.summary()


def test_double_release_and_ledger_drift_are_caught():
    evs = _clean_stream()
    evs.append({"kind": "pool.release", "t": 0.55, "replica": 0,
                "owner": "prefetch", "pages": 4, "nbytes": 100})
    rep = check_events(evs)
    assert rep.of(inv.DOUBLE_RELEASE), rep.summary()

    # byte drift without a page dip: releasing fatter bytes than leased
    evs = _clean_stream()
    next(e for e in evs if e["kind"] == "pool.release")["nbytes"] = 160
    rep = check_events(evs)
    assert rep.of(inv.LEDGER_DRIFT) and not rep.of(inv.DOUBLE_RELEASE)


def test_held_at_drain_is_caught_only_for_named_owners():
    evs = [e for e in _clean_stream() if e["kind"] != "pool.release"]
    rep = check_events(evs, drained=True, must_drain=("prefetch",))
    assert rep.of(inv.HELD_AT_DRAIN), rep.summary()
    # warm residency is legal when the owner is not required to drain
    rep = check_events(evs, drained=True, must_drain=("kv",))
    assert rep.ok, rep.summary()
    assert rep.outstanding == {"r0:prefetch": 4}


def test_stall_without_resume_is_caught():
    evs = _clean_stream()
    evs.append({"kind": "request", "label": "pressure_stall", "t": 0.7,
                "replica": 0, "request_id": 0, "tenant": "shared"})
    rep = check_events(evs, drained=True)
    assert rep.of(inv.STALL_WITHOUT_RESUME), rep.summary()
    # not drained yet: a parked request is a normal transient
    assert check_events(evs).ok


def test_transfer_inverted_and_lifecycle_disorder_are_caught():
    evs = _clean_stream()
    issue = next(e for e in evs if e["kind"] == "transfer.issue")
    issue["end_t"] = 0.05                  # lands before it starts
    rep = check_events(evs)
    assert rep.of(inv.TRANSFER_INVERTED), rep.summary()

    evs = _clean_stream()
    next(e for e in evs
         if e["kind"] == "request" and e["label"] == "complete")["t"] = -1.0
    rep = check_events(evs)
    assert rep.of(inv.LIFECYCLE_DISORDER), rep.summary()


def test_kv_conservation_and_decode_ordering():
    good = [
        {"kind": "kv.acquire", "t": 0.0, "replica": 0},
        {"kind": "decode", "t": 0.1, "replica": 0, "request_id": 3},
        {"kind": "kv.release", "t": 0.2, "replica": 0},
    ]
    assert check_events(good, drained=True, must_drain=("kv",)).ok

    rep = check_events(good + [{"kind": "kv.release", "t": 0.3,
                                "replica": 0}])
    assert rep.of(inv.KV_DOUBLE_RELEASE)

    rep = check_events([good[1], good[0], good[2]])
    assert rep.of(inv.DECODE_WITHOUT_KV)

    rep = check_events(good[:2], drained=True, must_drain=("kv",))
    assert rep.of(inv.HELD_AT_DRAIN)


# ---------------------------------------------------------------------------
# Paged-lease discipline: hand-corrupted block-table KV streams
# ---------------------------------------------------------------------------


def _paged_lease_stream(lease_id=5, pages=6, max_len=24, appends=3):
    """One clean paged lease lifecycle: acquire -> append* -> release,
    page-conserving, lengths within capacity."""
    evs = [{"kind": "kv.acquire", "t": 0.0, "replica": 0,
            "lease_id": lease_id, "pages": pages, "max_len": max_len,
            "batch": 2, "nbytes": 1000}]
    for i in range(appends):
        evs.append({"kind": "kv.append", "t": 0.1 + 0.1 * i, "replica": 0,
                    "lease_id": lease_id, "pages": pages,
                    "max_len": max_len, "length": i + 1})
    evs.append({"kind": "kv.release", "t": 0.9, "replica": 0,
                "lease_id": lease_id, "pages": pages, "max_len": max_len,
                "nbytes": 1000})
    return evs


def test_clean_paged_lease_stream_passes_drained():
    rep = check_events(_paged_lease_stream(), drained=True,
                       must_drain=("kv",))
    assert rep.ok, rep.summary()
    assert rep.stats["paged_leases"] == 1


def test_paged_append_after_release_is_caught():
    evs = _paged_lease_stream()
    evs.append({"kind": "kv.append", "t": 1.0, "replica": 0,
                "lease_id": 5, "pages": 6, "max_len": 24, "length": 4})
    rep = check_events(evs)
    assert rep.of(inv.KV_APPEND_OUT_OF_LEASE), rep.summary()


def test_paged_append_before_acquire_is_caught():
    evs = _paged_lease_stream()
    evs.insert(0, {"kind": "kv.append", "t": -0.1, "replica": 0,
                   "lease_id": 5, "pages": 6, "max_len": 24, "length": 1})
    rep = check_events(evs)
    assert rep.of(inv.KV_APPEND_OUT_OF_LEASE), rep.summary()

    # an append against a lease id that never existed is just as wrong
    evs = _paged_lease_stream()
    evs[1] = dict(evs[1], lease_id=99)
    rep = check_events(evs)
    assert rep.of(inv.KV_APPEND_OUT_OF_LEASE), rep.summary()


def test_paged_append_past_capacity_is_caught():
    evs = _paged_lease_stream(max_len=24)
    next(e for e in evs if e["kind"] == "kv.append")["length"] = 25
    rep = check_events(evs)
    assert rep.of(inv.KV_APPEND_OVERFLOW), rep.summary()


def test_paged_page_conservation_mismatch_at_release_is_caught():
    evs = _paged_lease_stream(pages=6)
    next(e for e in evs if e["kind"] == "kv.release")["pages"] = 5
    rep = check_events(evs)
    assert rep.of(inv.KV_PAGE_CONSERVATION), rep.summary()


def test_paged_lease_double_release_and_reuse_are_caught():
    evs = _paged_lease_stream()
    evs.append(dict(next(e for e in evs if e["kind"] == "kv.release"),
                    t=1.0))
    rep = check_events(evs)
    assert rep.of(inv.KV_DOUBLE_RELEASE), rep.summary()

    # re-acquiring a finished lease id: ids are unique by construction
    evs = _paged_lease_stream()
    evs.append(dict(evs[0], t=1.1))
    rep = check_events(evs)
    assert rep.of(inv.KV_LEASE_REUSE), rep.summary()


def test_open_paged_lease_is_held_at_drain():
    evs = [e for e in _paged_lease_stream() if e["kind"] != "kv.release"]
    rep = check_events(evs, drained=True, must_drain=("kv",))
    assert rep.of(inv.HELD_AT_DRAIN), rep.summary()
    # same stream is a normal transient while the run is still going,
    # and legal at drain when kv is not required to empty
    assert check_events(evs).ok
    assert check_events(evs, drained=True, must_drain=("prefetch",)).ok


def test_dense_lease_events_are_exempt_from_paged_discipline():
    """Dense bucket leases emit lease_id=-1: none of the paged checks
    may fire on them (kv.acquire/release counting still applies)."""
    evs = [
        {"kind": "kv.acquire", "t": 0.0, "replica": 0, "lease_id": -1},
        {"kind": "kv.release", "t": 0.2, "replica": 0, "lease_id": -1},
        {"kind": "kv.acquire", "t": 0.3, "replica": 0, "lease_id": -1},
        {"kind": "kv.release", "t": 0.5, "replica": 0, "lease_id": -1},
    ]
    rep = check_events(evs, drained=True, must_drain=("kv",))
    assert rep.ok, rep.summary()
    assert rep.stats["paged_leases"] == 0


# ---------------------------------------------------------------------------
# Chunk-KV discipline: hand-corrupted splice / residency streams
# ---------------------------------------------------------------------------


def _spliced_lease_stream(lease_id=5, pages=6, max_len=24):
    """A paged lease that splices two chunk pages ahead of its fresh
    blocks (raising capacity to 32) and then appends past the ORIGINAL
    max_len — legal only because the splice raised it."""
    return [
        {"kind": "kv.acquire", "t": 0.0, "replica": 0, "lease_id": lease_id,
         "pages": pages, "max_len": max_len, "batch": 1, "nbytes": 1000},
        {"kind": "kv.splice", "t": 0.05, "replica": 0, "lease_id": lease_id,
         "pages": 2, "max_len": max_len + 8, "batch": 1, "nbytes": 0},
        {"kind": "kv.append", "t": 0.1, "replica": 0, "lease_id": lease_id,
         "pages": pages, "max_len": max_len + 8, "length": max_len + 3},
        {"kind": "kv.release", "t": 0.9, "replica": 0, "lease_id": lease_id,
         "pages": pages, "max_len": max_len + 8, "nbytes": 1000},
    ]


def test_spliced_lease_stream_is_clean_and_raises_capacity():
    """The splice raises the lease ceiling: an append past the fresh
    max_len but under the spliced capacity must NOT overflow."""
    rep = check_events(_spliced_lease_stream(), drained=True,
                       must_drain=("kv",))
    assert rep.ok, rep.summary()
    # without the splice the same append IS an overflow
    evs = [e for e in _spliced_lease_stream() if e["kind"] != "kv.splice"]
    assert check_events(evs).of(inv.KV_APPEND_OVERFLOW)


def test_splice_outside_lease_window_is_caught():
    # splice after the lease was released
    evs = _spliced_lease_stream()
    evs.append(dict(next(e for e in evs if e["kind"] == "kv.splice"),
                    t=1.0))
    rep = check_events(evs)
    assert rep.of(inv.KV_SPLICE_OUT_OF_LEASE), rep.summary()

    # splice against a lease id that never existed
    evs = _spliced_lease_stream()
    evs[1] = dict(evs[1], lease_id=99)
    rep = check_events(evs)
    assert rep.of(inv.KV_SPLICE_OUT_OF_LEASE), rep.summary()


def test_kv_drop_without_parked_bucket_is_caught():
    """kv.drop recycles a parked dense bucket's bytes; a drop with no
    prior dense release is a recycle-pool accounting hole."""
    rep = check_events([{"kind": "kv.drop", "t": 0.1, "replica": 0}])
    assert rep.of(inv.KV_RECYCLE_MISMATCH), rep.summary()
    # park (dense release, lease_id=-1) then drop is the legal order
    evs = [
        {"kind": "kv.acquire", "t": 0.0, "replica": 0, "lease_id": -1},
        {"kind": "kv.release", "t": 0.1, "replica": 0, "lease_id": -1},
        {"kind": "kv.drop", "t": 0.2, "replica": 0},
    ]
    rep = check_events(evs, drained=True, must_drain=("kv",))
    assert rep.ok, rep.summary()


def _chunk_stream(doc_id=7, pages=2):
    """One clean chunk residency lifecycle: load -> pin -> unpin ->
    evict, page-conserving."""
    return [
        {"kind": "chunk.load", "t": 0.0, "replica": 0, "doc_id": doc_id,
         "pages": pages, "nbytes": 100, "pins": 0, "tenant": "shared"},
        {"kind": "chunk.pin", "t": 0.1, "replica": 0, "doc_id": doc_id,
         "pages": pages, "nbytes": 0, "pins": 1, "tenant": "shared"},
        {"kind": "chunk.unpin", "t": 0.2, "replica": 0, "doc_id": doc_id,
         "pages": pages, "nbytes": 0, "pins": 0, "tenant": "shared"},
        {"kind": "chunk.evict", "t": 0.3, "replica": 0, "doc_id": doc_id,
         "pages": pages, "nbytes": 100, "pins": 0, "tenant": "shared"},
    ]


def test_clean_chunk_stream_passes_and_counts_loads():
    rep = check_events(_chunk_stream(), drained=True,
                       must_drain=("chunk_kv",))
    assert rep.ok, rep.summary()
    assert rep.stats["chunk_loads"] == 1


def test_chunk_pin_before_load_is_caught():
    evs = [e for e in _chunk_stream() if e["kind"] != "chunk.load"]
    rep = check_events(evs)
    assert rep.of(inv.CHUNK_PIN_BEFORE_LOAD), rep.summary()


def test_chunk_unpin_without_pin_is_caught():
    # unpin with no pin outstanding (the pin never happened)
    evs = [e for e in _chunk_stream() if e["kind"] != "chunk.pin"]
    rep = check_events(evs)
    assert rep.of(inv.CHUNK_UNPIN_WITHOUT_PIN), rep.summary()

    # a second unpin after the refcount already hit zero
    evs = _chunk_stream()
    evs.insert(3, dict(evs[2], t=0.25))
    rep = check_events(evs)
    assert rep.of(inv.CHUNK_UNPIN_WITHOUT_PIN), rep.summary()


def test_chunk_evict_while_pinned_is_caught():
    evs = [e for e in _chunk_stream() if e["kind"] != "chunk.unpin"]
    rep = check_events(evs)
    assert rep.of(inv.CHUNK_EVICT_WHILE_PINNED), rep.summary()


def test_chunk_page_conservation_violations_are_caught():
    # double load without an intervening evict double-counts residency
    evs = _chunk_stream()
    evs.insert(1, dict(evs[0], t=0.05))
    assert check_events(evs).of(inv.CHUNK_PAGE_CONSERVATION)

    # evicting a chunk that was never loaded
    evs = [dict(e, doc_id=99) for e in _chunk_stream()
           if e["kind"] == "chunk.evict"]
    assert check_events(evs).of(inv.CHUNK_PAGE_CONSERVATION)

    # evicting fewer pages than were loaded leaks the difference
    evs = _chunk_stream()
    next(e for e in evs if e["kind"] == "chunk.evict")["pages"] = 1
    assert check_events(evs).of(inv.CHUNK_PAGE_CONSERVATION)


def test_warm_chunk_residency_at_drain_needs_opt_in():
    """Un-evicted chunks are warm cache — legal at drain unless the
    run declared chunk_kv must empty (e.g. after ChunkKVCache.drain)."""
    evs = [e for e in _chunk_stream() if e["kind"] != "chunk.evict"]
    rep = check_events(evs, drained=True, must_drain=("chunk_kv",))
    assert rep.of(inv.HELD_AT_DRAIN), rep.summary()
    assert check_events(evs, drained=True, must_drain=("kv",)).ok


# ---------------------------------------------------------------------------
# Invariants on REAL traces: a served run is clean, and the Perfetto
# export round-trips enough structure for the race/ordering checks
# ---------------------------------------------------------------------------


def _serve(small_index, small_store, rng, n=6):
    srv = TeleRAGServer(small_index, EngineConfig(
        nprobe=16, top_k=3, buffer_pages=200, lookahead_rank=32,
        kernel_mode="ref", chips=8, cache_enabled=True, seed=5), 2,
        get_arch("llama3-8b"), micro_batch=2)
    q = unit_queries(small_store, rng, n)
    traces = make_traces("hyde", n, seed=11)
    resp = srv.serve([RagRequest(q=q[i], trace=traces[i])
                      for i in range(n)])
    assert all(r.state.value == "complete" for r in resp)
    return srv


def test_served_trace_passes_invariants_drained(small_index, small_store,
                                                rng):
    srv = _serve(small_index, small_store, rng)
    rep = check_recorder(srv.recorder, drained=True, must_drain=("kv",))
    assert rep.ok, rep.summary()
    assert rep.stats["waves_dispatched"] > 0
    assert rep.stats["pool_edges"] > 0


def test_perfetto_reconstruction_passes_and_catches_races(
        small_index, small_store, rng):
    srv = _serve(small_index, small_store, rng)
    evs = events_from_perfetto(to_perfetto(srv.recorder))
    rep = check_events(evs)
    assert rep.ok, rep.summary()
    assert rep.stats["transfers"] > 0
    assert rep.stats["waves_dispatched"] > 0

    # corrupt the reconstruction: drag one wave's retrieve span before
    # its transfer lands — the checker must notice on Perfetto data too
    dispatch = next(e for e in evs if e["kind"] == "wave.dispatch"
                    and e["transfer_id"] >= 0)
    land = next(e for e in evs if e["kind"] == "transfer.land"
                and e["transfer_id"] == dispatch["transfer_id"]
                and e["replica"] == dispatch["replica"])
    moved = False
    for e in evs:
        if (e["kind"] == "span" and e.get("name") == "retrieve"
                and e["wave_id"] == dispatch["wave_id"]
                and e["replica"] == dispatch["replica"]):
            e["t"] = land["end_t"] - 1.0
            moved = True
    assert moved
    assert check_events(evs).of(inv.USE_BEFORE_LAND)


# ---------------------------------------------------------------------------
# Regressions: the TL001 fixes this PR made must hold under fault
# ---------------------------------------------------------------------------


def _cfg(**kw):
    defaults = dict(nprobe=16, top_k=3, buffer_pages=200, lookahead_rank=32,
                    kernel_mode="ref", chips=8, cache_enabled=False, seed=5)
    defaults.update(kw)
    return EngineConfig(**defaults)


@pytest.mark.trace_unchecked        # the fault aborts mid-wave: pins are
def test_raising_decode_hook_leaves_no_stranded_pages(  # released, but the
        small_index, small_store, rng):  # request never completes
    def hook(records, gen_tokens, rnd):
        raise RuntimeError("decode died")

    eng = TeleRAGEngine(small_index, _cfg(), get_arch("llama3-8b"))
    runtime = RetrievalRuntime(eng, on_generate=hook)
    q = unit_queries(small_store, rng, 2)
    for i, tr in enumerate(make_traces("hyde", 2, seed=3)):
        runtime.submit(q[i], tr)
    free_before = eng.pool.free_pages()
    with pytest.raises(RuntimeError):
        runtime.run()
    # the admission reservation was returned and every member pin
    # dropped — residency remains (warm cache), but nothing is pinned
    # or reserved, so end_batch can evict back to a full free list
    assert eng.pool.reserved_pages() == 0
    assert eng.buffer.pages_pinned_by_others(object()) == 0
    eng.end_batch()
    assert eng.pool.free_pages() == eng.pool.num_pages
    assert eng.pool.free_pages() >= free_before


def test_kv_acquire_releases_pages_when_init_cache_raises(
        small_index, monkeypatch):
    from repro.memory.pool import DevicePagePool
    from repro.serving import KVCacheManager
    from repro.serving import kv_cache as kv_mod

    cfg = get_arch("llama3-8b").reduced()
    pool = DevicePagePool(small_index.paged, num_pages=256)
    kv = KVCacheManager(cfg, pool=pool)
    free_before = pool.free_pages()

    def boom(*a, **kw):
        raise RuntimeError("OOM during init_cache")

    monkeypatch.setattr(kv_mod.tf, "init_cache", boom)
    with pytest.raises(RuntimeError):
        kv.acquire(2, 64, fresh=True)
    assert pool.free_pages() == free_before
    assert pool.reserved_pages() == 0


# ---------------------------------------------------------------------------
# Injectable clock
# ---------------------------------------------------------------------------


def test_event_clock_is_deterministic_and_system_clock_is_real():
    ec = EventClock()
    assert not ec.real
    assert ec.perf() == ec.perf() == 0.0
    sc = SystemClock()
    assert sc.real
    assert sc.perf() <= sc.perf()


def test_engine_default_clock_keeps_calibration_deterministic(
        small_index, small_store, rng):
    eng = TeleRAGEngine(small_index, _cfg(), get_arch("llama3-8b"))
    assert isinstance(eng.wall, EventClock)
    # under the event clock, elapsed wall time is 0 — calibration must
    # fall back to the modeled constant, identically on every machine
    assert eng.calibrate_tcc() == pytest.approx(eng.effective_tcc())
