"""Flight recorder, exporters, metrics registry, and overlap analyzer.

Pins the observability subsystem's contracts:
  * ``Span.intersects`` open-interval edge semantics (zero-length
    spans, touching endpoints) — the overlap accounting rests on it;
  * recorder correctness on a served run: every admitted request's
    lifecycle marks are ordered admit <= generate-dispatch <= complete,
    every dispatched wave has a form and a complete, every issued
    transfer lands, and ``runtime.event_log`` is exactly the
    ``legacy_tuples`` view;
  * ``ServerTelemetry``/``TenantTelemetry`` are registry-backed views
    numerically equal to the response stream they summarize;
  * the Perfetto export passes ``tools/check_trace.py`` (the CI gate)
    including the required counter tracks;
  * ``analyze`` reports a positive mean overlap ratio on a
    hyde/iter prefetching mix;
  * ``benchmarks.common.write_report`` round-trips through
    ``validate_report``.
"""

import dataclasses
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.configs import get_arch
from repro.obs import (FlightRecorder, MetricsRegistry, analyze,
                       to_perfetto, write_trace)
from repro.serving import (EngineConfig, RagRequest, Span, TeleRAGServer,
                           make_traces)
from tests.conftest import unit_queries

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cfg(**kw):
    defaults = dict(nprobe=16, top_k=3, buffer_pages=200, lookahead_rank=32,
                    kernel_mode="ref", chips=8, cache_enabled=True, seed=5)
    defaults.update(kw)
    return EngineConfig(**defaults)


def _serve_mix(small_store, small_index, rng, n=10, replicas=2,
               trace=None, stagger=True):
    """A hyde/iter mix through a continuous 2-replica server; returns
    (server, responses)."""
    srv = TeleRAGServer(small_index, _cfg(), replicas, get_arch("llama3-8b"),
                        micro_batch=3, continuous=True, trace=trace)
    q = unit_queries(small_store, rng, n)
    half = n // 2
    # make_traces numbers ids 0..n-1 per call — re-id so the mix's
    # request ids are unique (the recorder correlates by request_id)
    traces = [dataclasses.replace(t, request_id=i) for i, t in enumerate(
        make_traces("hyde", half, seed=3)
        + make_traces("iter", n - half, seed=4))]
    arr = np.cumsum(rng.exponential(0.03, n)) if stagger else np.zeros(n)
    resp = srv.serve([RagRequest(q=q[i], trace=traces[i],
                                 arrival_t=float(arr[i]))
                      for i in range(n)])
    assert len(resp) == n
    return srv, resp


# ---------------------------------------------------------------------------
# Span.intersects: open-interval edge semantics
# ---------------------------------------------------------------------------


def test_span_intersects_open_interval_edges():
    # zero-length span strictly inside the open interval: intersects
    assert Span("x", 1.0, 1.0).intersects(0.0, 2.0)
    # zero-length span AT either endpoint: does not
    assert not Span("x", 0.0, 0.0).intersects(0.0, 2.0)
    assert not Span("x", 2.0, 2.0).intersects(0.0, 2.0)
    # touching endpoints (span ends where interval starts / vice versa)
    assert not Span("x", -1.0, 0.0).intersects(0.0, 2.0)
    assert not Span("x", 2.0, 3.0).intersects(0.0, 2.0)
    # any positive-measure intersection counts
    assert Span("x", -1.0, 0.5).intersects(0.0, 2.0)
    assert Span("x", 1.5, 9.0).intersects(0.0, 2.0)
    assert Span("x", -1.0, 9.0).intersects(0.0, 2.0)
    # degenerate query interval: an instant strictly inside the span's
    # interior counts, an instant at a span endpoint does not
    assert Span("x", 0.0, 2.0).intersects(1.0, 1.0)
    assert not Span("x", 0.0, 2.0).intersects(0.0, 0.0)
    assert not Span("x", 0.0, 2.0).intersects(2.0, 2.0)
    # overlaps() is the back-compat alias
    assert Span("x", 1.0, 1.0).overlaps(0.0, 2.0)
    assert not Span("x", 2.0, 3.0).overlaps(0.0, 2.0)


# ---------------------------------------------------------------------------
# Recorder correctness on a served run
# ---------------------------------------------------------------------------


def test_request_lifecycle_marks_are_ordered(small_store, small_index, rng):
    srv, resp = _serve_mix(small_store, small_index, rng)
    rec = srv.recorder
    for r in resp:
        m = rec.request_marks(r.request_id)
        assert "submit" in m and "admit" in m and "complete" in m, m
        # submit <= admit <= first generate dispatch <= complete
        assert m["submit"] <= m["admit"] + 1e-9
        gen = m.get("generate", m["admit"])
        assert m["admit"] <= gen + 1e-9
        assert gen <= m["complete"] + 1e-9
        # the marks agree with the typed response record
        assert m["complete"] == pytest.approx(r.complete_t)
        assert m["admit"] == pytest.approx(r.admit_t)


def test_no_orphan_wave_or_transfer_events(small_store, small_index, rng):
    srv, _ = _serve_mix(small_store, small_index, rng)
    rec = srv.recorder
    formed = {(e.replica, e.wave_id) for e in rec.of("wave.form")}
    completed = {(e.replica, e.wave_id) for e in rec.of("wave.complete")}
    dispatched = rec.of("wave.dispatch")
    assert dispatched, "continuous run must dispatch waves"
    for ev in dispatched:
        key = (ev.replica, ev.wave_id)
        assert key in formed, f"dispatch without form: {ev}"
        assert key in completed, f"dispatch without complete: {ev}"
        assert ev.size == len(ev.request_ids) > 0
    # every issued transfer lands, ordered, with matching byte counts
    issues = {(e.replica, e.transfer_id): e for e in rec.of("transfer.issue")}
    lands = {(e.replica, e.transfer_id): e for e in rec.of("transfer.land")}
    assert issues and set(issues) == set(lands)
    for key, iss in issues.items():
        assert lands[key].nbytes == iss.nbytes
        assert iss.t <= lands[key].t + 1e-9
    # every dispatch-correlated transfer id was actually issued
    for ev in dispatched:
        if ev.transfer_id >= 0:
            assert (ev.replica, ev.transfer_id) in issues


def test_event_log_is_the_legacy_view(small_store, small_index, rng):
    """Each replica runtime's ``event_log`` property IS the recorder's
    per-lane legacy view: same tuples, legacy labels only, time-ordered
    within the lane, no server-side ``submit`` marks leaking in."""
    from repro.obs.recorder import LEGACY_LABELS  # noqa: PLC0415

    srv, _ = _serve_mix(small_store, small_index, rng)
    total = 0
    for i, rt in enumerate(srv.runtimes):
        log = rt.event_log
        assert log == srv.recorder.legacy_tuples(i)
        total += len(log)
        for t, label, rid in log:
            assert label in LEGACY_LABELS
            assert isinstance(t, float) and isinstance(rid, int)
        times = [t for t, _, _ in log]
        assert times == sorted(times)
    assert total > 0, "served run must populate the legacy view"


def test_runtime_event_log_shim(small_store, small_index, rng):
    """A standalone runtime (no server) still records through its
    engine's own recorder and exposes the shim."""
    from repro.serving import TeleRAGEngine  # noqa: PLC0415
    from repro.serving.runtime import RetrievalRuntime  # noqa: PLC0415

    eng = TeleRAGEngine(small_index, _cfg(), get_arch("llama3-8b"))
    rt = RetrievalRuntime(eng)
    q = unit_queries(small_store, rng, 4)
    for i, tr in enumerate(make_traces("hyde", 4, seed=9)):
        rt.submit(q[i], tr)
    rt.run()
    log = rt.event_log
    assert log, "shim must reproduce the legacy tuples"
    assert log == rt.recorder.legacy_tuples(rt.replica_id)
    assert {label for _, label, _ in log} >= {"admit", "complete"}


def test_shared_recorder_injection(small_store, small_index, rng):
    """A caller-supplied recorder receives the whole server's stream."""
    mine = FlightRecorder()
    srv, _ = _serve_mix(small_store, small_index, rng, trace=mine)
    assert srv.recorder is mine
    assert mine.of("request") and mine.of("pool.lease")
    replicas = {e.replica for e in mine.events}
    assert {0, 1} <= replicas, replicas


def test_recorder_capacity_drops_oldest_half():
    rec = FlightRecorder(capacity=8)
    from repro.obs.recorder import RequestEvent  # noqa: PLC0415
    for i in range(9):
        rec.emit(RequestEvent(t=float(i), kind="request", request_id=i,
                              label="admit"))
    assert rec.dropped > 0
    assert len(rec.events) <= 8
    # the recent past is kept
    assert rec.events[-1].request_id == 8


# ---------------------------------------------------------------------------
# Telemetry == registry views, numerically pinned
# ---------------------------------------------------------------------------


def test_tenant_telemetry_is_registry_view(small_store, small_index, rng):
    srv, resp = _serve_mix(small_store, small_index, rng)
    tel = srv.telemetry()
    assert tel.completed == len(resp)
    lats = np.array([r.latency_s for r in resp])
    queues = np.array([r.queue_s for r in resp])
    (tt,) = tel.tenants
    assert tt.tenant == "shared"
    assert tt.completed == len(resp)
    assert tt.p50_latency_s == pytest.approx(
        float(np.percentile(lats, 50)), abs=1e-6)
    assert tt.p99_latency_s == pytest.approx(
        float(np.percentile(lats, 99)), abs=1e-6)
    assert tt.mean_queue_s == pytest.approx(float(queues.mean()), abs=1e-6)
    # the registry carries the same series under the same labels
    hist = srv.metrics.histogram("request_latency_s", tenant="shared")
    assert hist.count == len(resp)
    assert srv.metrics.counter("requests_completed",
                               tenant="shared").value == len(resp)


def test_metrics_registry_primitives():
    m = MetricsRegistry()
    c = m.counter("hits", tenant="a")
    c.inc()
    c.inc(2.0)
    assert c.value == 3.0
    assert m.counter("hits", tenant="a") is c          # get-or-create
    assert m.counter("hits", tenant="b") is not c      # distinct labels
    g = m.gauge("depth")
    g.set(7.0)
    assert g.value == 7.0
    h = m.histogram("lat")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.count == 4
    assert h.percentile(50) == pytest.approx(np.percentile(
        [1.0, 2.0, 3.0, 4.0], 50))
    s = m.series("occ", replica=0)
    s.sample(1.0, 0.5)
    s.sample(0.5, 0.25)
    assert s.last == 0.5                                # clock order, not emission
    assert [t for t, _ in s.sorted_samples()] == [0.5, 1.0]


# ---------------------------------------------------------------------------
# Perfetto export passes the CI validator
# ---------------------------------------------------------------------------


def _load_check_trace():
    path = os.path.join(REPO, "tools", "check_trace.py")
    spec = importlib.util.spec_from_file_location("check_trace", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_perfetto_export_validates(small_store, small_index, rng, tmp_path):
    srv, resp = _serve_mix(small_store, small_index, rng)
    doc = to_perfetto(srv.recorder)
    check = _load_check_trace()
    phases = check.validate_trace(doc)
    assert phases.get("X", 0) > 0                      # spans on lanes
    assert phases.get("C", 0) > 0                      # counter tracks
    # async request spans balance and cover every request
    assert phases.get("b", 0) == phases.get("e", 0) == len(resp)
    counters = {e["name"] for e in doc["traceEvents"] if e["ph"] == "C"}
    assert {"ledger_occupancy", "pool_free_pages"} <= counters
    # write_trace round-trips through JSON to the identical document
    out = tmp_path / "trace.json"
    write_trace(srv.recorder, str(out))
    with open(out) as f:
        assert json.load(f) == json.loads(json.dumps(doc))
    assert check.main(["check_trace", str(out)]) == 0


# ---------------------------------------------------------------------------
# Overlap analyzer on a prefetching mix
# ---------------------------------------------------------------------------


def test_analyzer_positive_overlap_on_prefetch_mix(small_store, small_index,
                                                   rng):
    srv, resp = _serve_mix(small_store, small_index, rng)
    rep = analyze(srv.recorder)
    assert rep.n_requests == len(resp)
    assert rep.prefetched_rounds, "mix must move prefetch bytes"
    assert 0.0 < rep.mean_overlap_ratio <= 1.0
    for rnd in rep.rounds:
        assert 0.0 <= rnd.ratio <= 1.0 + 1e-9
        assert rnd.hidden_s <= rnd.transfer_s + 1e-9
    assert rep.wave_sizes and min(rep.wave_sizes) >= 1
    for key in ("link_s", "pressure_s", "queue_s"):
        assert rep.stall[key] >= 0.0
    # pure function of the trace: re-analysis is identical
    rep2 = analyze(srv.recorder)
    assert rep2.mean_overlap_ratio == rep.mean_overlap_ratio
    assert rep.summary()                               # printable


# ---------------------------------------------------------------------------
# Bench report schema round-trip
# ---------------------------------------------------------------------------


def test_bench_report_roundtrip(tmp_path):
    from benchmarks import common  # noqa: PLC0415
    rows = [{"rate": 1.0, "p50_ms": 3.5}, {"rate": 2.0, "p50_ms": 4.5}]
    common.set_report_dir(str(tmp_path))
    try:
        path = common.write_report("unittest",
                                   metrics=common.summarize_rows(rows),
                                   rows=rows, meta={"seed": 0})
        with open(path) as f:
            report = json.load(f)
    finally:
        common.set_report_dir(None)
    assert os.path.basename(path) == "BENCH_unittest.json"
    common.validate_report(report)
    assert report["schema"] == common.REPORT_SCHEMA
    assert report["metrics"]["n_rows"] == 2
    assert report["metrics"]["mean_p50_ms"] == pytest.approx(4.0)
    assert report["rows"] == rows
    bad = dict(report, schema="nope")
    with pytest.raises(AssertionError):
        common.validate_report(bad)
