"""Per-request continuous batching: dynamic wave forming, straggler
isolation, mid-stream admission, pressure-park rejoin, decode events
driving the clock, decode-only requests, per-tenant KV accounting, and
the never-re-form mode's equivalence to the legacy group path."""

import numpy as np
import pytest

from repro.configs import get_arch
from repro.serving import (DecodeEvent, EngineConfig, KVCacheManager,
                           RagRequest, RequestState, RetrievalRuntime,
                           TeleRAGEngine, TeleRAGServer, make_traces)
from repro.serving.trace import RequestTrace, StageTrace
from tests.conftest import unit_queries


def _cfg(seed=5, **kw):
    defaults = dict(nprobe=16, top_k=3, buffer_pages=256, lookahead_rank=32,
                    kernel_mode="ref", chips=8, seed=seed)
    defaults.update(kw)
    return EngineConfig(**defaults)


def _engine(small_index, **kw):
    return TeleRAGEngine(small_index, _cfg(**kw), get_arch("llama3-8b"))


def _two_round_trace(request_id, gen0, gen1=64, sigma=0.0):
    """Two retrieval rounds with controllable window lengths (sigma=0
    keeps query drift deterministic across wave compositions)."""
    return RequestTrace(
        pipeline="iter", request_id=request_id,
        stages=[StageTrace("generate", gen0), StageTrace("retrieve"),
                StageTrace("generate", gen1), StageTrace("retrieve"),
                StageTrace("generate", 8)],
        rewrite_sigma=sigma)


# ---------------------------------------------------------------------------
# Acceptance: straggler isolation — a slow batch-mate no longer drags
# the fast request's next round (impossible under static groups)
# ---------------------------------------------------------------------------


def _run_straggler(small_index, q, *, reform):
    eng = _engine(small_index)
    runtime = RetrievalRuntime(eng, reform=reform)
    slow = runtime.submit(q[0], _two_round_trace(0, gen0=4000))
    fast = runtime.submit(q[1], _two_round_trace(1, gen0=64))
    runtime.run()
    assert slow.state == fast.state == RequestState.COMPLETE
    return eng, runtime, slow, fast


def test_straggler_isolation_fast_request_reforms_alone(
        small_store, small_index, rng):
    """Request B (fast) starts — and here even finishes — its round 1
    before slow batch-mate A finishes round 0.  Under static groups the
    round-1 frontier is one shared event executing BOTH members as one
    batch; under wave re-forming B's round 1 runs in a wave of its own
    the moment B is ready."""
    q = unit_queries(small_store, rng, 2)
    eng, runtime, slow, fast = _run_straggler(small_index, q, reform=True)

    slow_r0_end = slow.result.rounds[0].round_end_t
    fast_r1 = fast.result.rounds[1]
    # B's round 1 started (and was wave-formed) before A finished round 0
    assert fast_r1.round_start_t < slow_r0_end
    # ... in a wave WITHOUT the straggler: its decode batch is 1
    assert fast_r1.batch == 1
    w = next(w for w in runtime.wave_log if w.wid == fast_r1.wave_id)
    assert w.request_ids == (fast.request_id,)
    assert w.t == pytest.approx(fast_r1.round_start_t)
    # round 0 DID batch them together (same arrival instant)
    assert fast.result.rounds[0].batch == 2
    assert fast.result.rounds[0].wave_id == slow.result.rounds[0].wave_id
    # the straggler's own round 1 runs later, in its own wave
    assert slow.result.rounds[1].wave_id != fast_r1.wave_id
    assert slow.result.rounds[1].round_start_t > fast_r1.round_start_t

    # contrast: the never-re-form mode keeps B batched with A for every
    # round (the legacy group semantics the shims are pinned to)
    _, _, slow_s, fast_s = _run_straggler(small_index, q, reform=False)
    assert fast_s.result.rounds[1].batch == 2
    # re-forming can only help the fast request (smaller decode batch)
    assert fast.complete_t <= fast_s.complete_t + 1e-12


# ---------------------------------------------------------------------------
# Acceptance: mid-stream admission joins an in-flight wave
# ---------------------------------------------------------------------------


def test_midstream_admission_joins_inflight_wave(small_store, small_index,
                                                 rng):
    """A request arriving exactly at a round frontier is wave-formed
    WITH the in-flight requests' next rounds — mixed round indices in
    one wave, which no static-group executor can express."""
    q = unit_queries(small_store, rng, 3)
    # probe run: find the (deterministic) round-1 frontier time
    eng = _engine(small_index)
    runtime = RetrievalRuntime(eng)
    a = runtime.submit(q[0], _two_round_trace(0, gen0=256))
    b = runtime.submit(q[0], _two_round_trace(1, gen0=256))   # same q/trace
    runtime.run()
    t1 = a.result.rounds[1].round_start_t
    assert t1 == b.result.rounds[1].round_start_t             # same frontier

    # live run: C arrives exactly when A and B become ready for round 1
    eng = _engine(small_index)
    runtime = RetrievalRuntime(eng)
    a = runtime.submit(q[0], _two_round_trace(0, gen0=256))
    b = runtime.submit(q[0], _two_round_trace(1, gen0=256))
    c = runtime.submit(q[2], _two_round_trace(2, gen0=64), arrival_t=t1)
    runtime.run()
    assert c.admit_t == pytest.approx(t1)
    joined = c.result.rounds[0]
    assert joined.batch == 3                    # C decodes WITH a and b
    w = next(w for w in runtime.wave_log if w.wid == joined.wave_id)
    assert sorted(w.request_ids) == [0, 1, 2]
    assert sorted(w.rounds) == [0, 1, 1]        # mixed round indices
    assert a.result.rounds[1].wave_id == joined.wave_id


# ---------------------------------------------------------------------------
# Acceptance: a pressure-parked request rejoins a freshly-formed wave
# on wake (its ex-batch-mates were never stalled)
# ---------------------------------------------------------------------------


def test_parked_request_rejoins_wave_on_wake(small_store, small_index, rng):
    pages_per_cluster = float(np.mean(small_index.paged.cluster_num_pages))
    eng = TeleRAGEngine(
        small_index,
        EngineConfig(nprobe=12, top_k=3,
                     buffer_pages=int(6 * pages_per_cluster),
                     lookahead_rank=16, kernel_mode="ref", chips=8, seed=3),
        get_arch("llama3-8b"))
    runtime = RetrievalRuntime(eng, micro_batch=2)
    cents = small_index.centroids / np.linalg.norm(
        small_index.centroids, axis=-1, keepdims=True)
    q = np.concatenate([cents[:2], cents[-2:]]).astype(np.float32)
    traces = make_traces("hyde", 4, seed=5)
    recs = [runtime.submit(q[i], traces[i]) for i in range(4)]
    runtime.run()

    assert all(r.state == RequestState.COMPLETE for r in recs)
    assert not eng.admission.parked
    stalled = [r for r in recs if r.spans("pressure_stall")]
    assert stalled, "the pool pressure never parked anyone"
    clean = [r for r in recs if not r.spans("pressure_stall")]
    assert clean, "everyone stalled — no isolation to show"
    # the stall was fully isolated to the pressured wave: the clean
    # requests ran unstalled, and the park lifted exactly when one of
    # them completed and freed its per-request pins (fine-grained
    # release — not "when the whole ex-wave drained")
    first_resume = min(s.end for r in stalled
                       for s in r.spans("pressure_stall"))
    assert min(r.complete_t for r in clean) <= first_resume + 1e-12
    assert any(abs(first_resume - r.complete_t) < 1e-9 for r in clean)
    for r in stalled:
        # the resumed request rode a wave formed AT its wake-up time
        resume = r.spans("pressure_stall")[0].end
        rt0 = r.result.rounds[0]
        assert rt0.round_start_t == pytest.approx(resume)
        w = next(w for w in runtime.wave_log if w.wid == rt0.wave_id)
        assert w.t == pytest.approx(resume)
        assert r.request_id in w.request_ids


# ---------------------------------------------------------------------------
# Acceptance: never-re-form == dynamic former on single-wave workloads
# (the degenerate mode really is the same executor)
# ---------------------------------------------------------------------------


def test_never_reform_and_dynamic_agree_on_single_round_waves(
        small_store, small_index, rng):
    """For simultaneous single-round requests the dynamic former forms
    exactly the admission group, so both modes must produce identical
    doc ids and round telemetry — the degenerate mode is a special case
    of one executor, not a second code path."""
    q = unit_queries(small_store, rng, 4)
    results = []
    for reform in (False, True):
        eng = _engine(small_index)
        runtime = RetrievalRuntime(eng, reform=reform)
        traces = make_traces("hyde", 4, seed=11)
        recs = [runtime.submit(q[i], traces[i]) for i in range(4)]
        runtime.run()
        results.append(recs)
    legacy, dynamic = results
    for a, b in zip(legacy, dynamic):
        assert len(a.result.doc_ids) == len(b.result.doc_ids)
        for da, db in zip(a.result.doc_ids, b.result.doc_ids):
            np.testing.assert_array_equal(da, db)
        for ra, rb in zip(a.result.rounds, b.result.rounds):
            for f in ("batch", "gen_tokens", "t_llm_window", "hits",
                      "misses", "t_prefetch", "t_host_search"):
                assert getattr(ra, f) == pytest.approx(getattr(rb, f),
                                                       abs=1e-9), f
        assert a.latency == pytest.approx(b.latency, abs=1e-9)


# ---------------------------------------------------------------------------
# Decode events drive the event clock
# ---------------------------------------------------------------------------


def test_decode_events_replace_modeled_generation_windows(
        small_store, small_index, rng):
    """When the decode hook returns per-request DecodeEvents, each
    request's generation window on the event clock is the OBSERVED
    decode time (extrapolated per-step), not the hardware model's."""
    per_tok = 1e-3
    calls = []

    def hook(records, gen_tokens, rnd):
        calls.append(tuple(r.request_id for r in records))
        # "observed": half the steps ran, at per_tok seconds each
        return [DecodeEvent(request_id=r.request_id,
                            tokens=max(1, g // 2),
                            seconds=per_tok * max(1, g // 2))
                for r, g in zip(records, gen_tokens)]

    eng = _engine(small_index)
    runtime = RetrievalRuntime(eng, on_generate=hook)
    q = unit_queries(small_store, rng, 2)
    traces = make_traces("iter", 2, seed=7)
    recs = [runtime.submit(q[i], traces[i]) for i in range(2)]
    runtime.run()
    assert calls
    for rec in recs:
        assert rec.state == RequestState.COMPLETE
        for rt in rec.result.rounds:
            # extrapolated to the full window at the observed rate
            assert rt.t_llm_window == pytest.approx(per_tok * rt.gen_tokens)
            model = eng.llm_window_seconds(rt.gen_tokens, rt.batch)
            assert rt.t_llm_window != pytest.approx(model)

    # an event with zero observed steps (nothing to decode for that
    # member's window) must fall back to the MODELED window, not erase
    # the generation time — regression: a [retrieve, generate] trace
    # has a 0-token round window but a real tail
    eng3 = _engine(small_index)
    runtime3 = RetrievalRuntime(
        eng3, include_tail=True,
        on_generate=lambda recs, toks, rnd: [
            DecodeEvent(r.request_id, tokens=0, seconds=0.0)
            for r in recs])
    trace = RequestTrace(pipeline="irg", request_id=0,
                         stages=[StageTrace("retrieve"),
                                 StageTrace("generate", 96)],
                         rewrite_sigma=0.0)
    rec3 = runtime3.submit(unit_queries(small_store, rng, 1)[0], trace)
    runtime3.run()
    tail = rec3.spans("generate_tail")
    assert tail and tail[0].end - tail[0].start == pytest.approx(
        eng3.llm_window_seconds(96, 1))

    # a hook returning None keeps the modeled windows (back-compat)
    eng2 = _engine(small_index)
    runtime2 = RetrievalRuntime(eng2,
                                on_generate=lambda recs, toks, rnd: None)
    recs2 = [runtime2.submit(q[i], t)
             for i, t in enumerate(make_traces("iter", 2, seed=7))]
    runtime2.run()
    for rec in recs2:
        for rt in rec.result.rounds:
            assert rt.t_llm_window == pytest.approx(
                eng2.llm_window_seconds(rt.gen_tokens, rt.batch))


# ---------------------------------------------------------------------------
# Regression: decode-only traces ride the normal per-request path
# ---------------------------------------------------------------------------


def _decode_only_trace(request_id, gen=64):
    return RequestTrace(pipeline="hyde", request_id=request_id,
                        stages=[StageTrace("generate", gen)],
                        rewrite_sigma=0.0)


@pytest.mark.parametrize("reform", (False, True))
def test_decode_only_requests_complete_with_their_window(
        small_store, small_index, rng, reform):
    """A trace with zero retrieval rounds is a decode-only request on
    the normal path (no special-case admit branch): it completes after
    its generation window (include_tail) instead of instantaneously,
    and under wave forming it joins the decode batch like anyone."""
    q = unit_queries(small_store, rng, 2)
    eng = _engine(small_index)
    runtime = RetrievalRuntime(eng, include_tail=True, reform=reform)
    normal = runtime.submit(q[0], make_traces("hyde", 1, seed=3)[0])
    dec = runtime.submit(q[1], _decode_only_trace(99))
    runtime.run()
    assert dec.state == RequestState.COMPLETE
    assert not dec.result.rounds and not dec.result.doc_ids
    tail = dec.spans("generate_tail")
    assert len(tail) == 1
    assert dec.complete_t == pytest.approx(tail[0].end)
    assert dec.complete_t > dec.admit_t
    assert normal.state == RequestState.COMPLETE
    if reform:
        # it was wave-formed together with the normal request
        w = runtime.wave_log[0]
        assert sorted(w.request_ids) == sorted([normal.request_id, 99])


def test_decode_only_wavemate_survives_a_pressure_park(small_store,
                                                       small_index, rng):
    """A decode-only request wave-formed next to retrieval requests
    whose admission parks must NOT be swallowed by the park: it needs
    no pool pages, so it runs (as its own wave) and completes while its
    retrieval wave-mates sit PRESSURE_STALLED."""
    pages_per_cluster = float(np.mean(small_index.paged.cluster_num_pages))
    eng = TeleRAGEngine(
        small_index,
        EngineConfig(nprobe=12, top_k=3,
                     buffer_pages=int(6 * pages_per_cluster),
                     lookahead_rank=16, kernel_mode="ref", chips=8, seed=3),
        get_arch("llama3-8b"))
    runtime = RetrievalRuntime(eng, include_tail=True)
    cents = small_index.centroids / np.linalg.norm(
        small_index.centroids, axis=-1, keepdims=True)
    mid = 1e-5                         # while wave A is still in flight
    a = [runtime.submit(cents[i].astype(np.float32),
                        make_traces("hyde", 2, seed=5)[i]) for i in range(2)]
    b = runtime.submit(cents[-1].astype(np.float32),
                       make_traces("hyde", 3, seed=5)[2], arrival_t=mid)
    dec = runtime.submit(unit_queries(small_store, rng, 1)[0],
                         _decode_only_trace(50), arrival_t=mid)
    runtime.run()
    assert all(r.state == RequestState.COMPLETE for r in a + [b, dec])
    assert b.spans("pressure_stall"), "b never parked — no pressure"
    # the decode-only wave-mate ran through the park, unstalled
    assert not dec.spans("pressure_stall")
    assert dec.complete_t < b.spans("pressure_stall")[0].end


def test_decode_only_without_tail_completes_at_admit(small_store,
                                                     small_index, rng):
    q = unit_queries(small_store, rng, 1)
    eng = _engine(small_index)
    runtime = RetrievalRuntime(eng, include_tail=False)
    dec = runtime.submit(q[0], _decode_only_trace(5))
    runtime.run()
    assert dec.state == RequestState.COMPLETE
    assert dec.complete_t == pytest.approx(dec.admit_t)


# ---------------------------------------------------------------------------
# The wave former: EDF / tenant-purity / caps (SchedulerPolicy hook)
# ---------------------------------------------------------------------------


def test_reform_wave_default_is_edf_tenant_pure_and_capped():
    from repro.core.schedulers import SchedulerPolicy

    class R:
        def __init__(self, tenant, priority=0, deadline_t=float("inf")):
            self.tenant, self.priority, self.deadline_t = (tenant, priority,
                                                           deadline_t)

    ready = [R("a", deadline_t=3.0), R("b"), R("a", deadline_t=1.0),
             R("a", priority=-1), R("b"), R("a")]
    waves = SchedulerPolicy().reform_wave(ready, micro_batch=2)
    # every request placed exactly once
    placed = sorted(i for w in waves for i in w)
    assert placed == list(range(len(ready)))
    # tenant-pure waves, capped at 2
    for w in waves:
        assert len({ready[i].tenant for i in w}) == 1
        assert len(w) <= 2
    # priority class first, then EDF: request 3 leads the first wave,
    # then tenant a's deadline holders in deadline order
    assert waves[0][0] == 3
    a_order = [i for w in waves for i in w if ready[i].tenant == "a"]
    assert a_order == [3, 2, 0, 5]

    # no micro_batch cap => one wave per tenant
    waves = SchedulerPolicy().reform_wave(ready)
    assert len(waves) == 2


def test_deferring_former_cannot_livelock_the_drain(small_store,
                                                    small_index, rng):
    """A custom former that always defers lone requests (waiting for a
    batch-mate that never comes) must not hang run(): the forced
    frontier places deferred requests with the base former."""
    from repro.core.schedulers import SchedulerPolicy

    class WaitForPair(SchedulerPolicy):
        def reform_wave(self, ready, *, micro_batch=None, now=0.0):
            waves = super().reform_wave(ready, micro_batch=2, now=now)
            return [w for w in waves if len(w) >= 2]   # defer singletons

    q = unit_queries(small_store, rng, 3)
    eng = _engine(small_index)
    runtime = RetrievalRuntime(eng, scheduler=WaitForPair())
    traces = make_traces("hyde", 3, seed=3)
    recs = [runtime.submit(q[i], traces[i]) for i in range(3)]
    runtime.run()                                      # must terminate
    assert all(r.state == RequestState.COMPLETE for r in recs)


def test_continuous_server_forwards_scheduler_as_wave_former(
        small_store, small_index, rng):
    """A custom SchedulerPolicy.reform_wave override drives the replica
    runtimes' wave forming under continuous dispatch."""
    from repro.core.schedulers import TeleRAGScheduler

    calls = []

    class Spy(TeleRAGScheduler):
        def reform_wave(self, ready, *, micro_batch=None, now=0.0):
            calls.append(len(ready))
            return super().reform_wave(ready, micro_batch=micro_batch,
                                       now=now)

    q = unit_queries(small_store, rng, 4)
    srv = TeleRAGServer(small_index, _cfg(), 1, get_arch("llama3-8b"),
                        scheduler=Spy(), micro_batch=2, continuous=True)
    resp = srv.serve([RagRequest(q=q[i], pipeline="hyde")
                      for i in range(4)])
    assert all(r.state == RequestState.COMPLETE for r in resp)
    assert calls, "the custom former never ran"


# ---------------------------------------------------------------------------
# Per-tenant KV accounting (satellite): leases tag the owning tenant
# ---------------------------------------------------------------------------


def test_kv_leases_carry_tenant_bytes_on_ledger(small_index):
    arch = get_arch("llama3-8b").reduced()
    eng = TeleRAGEngine(
        small_index,
        _cfg(buffer_pages=64, pool_pages=1024,
             tenant_shares={"a": (8, None), "b": (8, None)}),
        get_arch("llama3-8b"))
    kv = KVCacheManager(arch, pool=eng.pool)
    lease = kv.acquire(2, 64, tenant="a")
    nb = lease.nbytes
    assert nb > 0
    assert eng.ledger.tenant_bytes("a") == nb
    assert eng.ledger.snapshot()["tenant:a"] == nb
    assert eng.pool.tenant_bytes("a", owner="kv") == nb
    assert eng.pool.tenant_pages("a") == lease.page_lease.num_pages

    # recycling re-attributes the bucket to whoever reuses it
    kv.release(lease)
    assert eng.ledger.tenant_bytes("a") == nb      # bytes stay resident
    lease_b = kv.acquire(2, 64, tenant="b")
    assert eng.ledger.tenant_bytes("a") == 0
    assert eng.ledger.tenant_bytes("b") == nb
    assert eng.pool.tenant_bytes("b", owner="kv") == nb
    kv.release(lease_b)
    kv.drop_all()
    assert eng.ledger.tenant_bytes("b") == 0
    assert eng.ledger.bytes_of("kv") == 0


def test_server_telemetry_surfaces_tenant_kv_bytes(small_store, small_index,
                                                   rng):
    q = unit_queries(small_store, rng, 2)
    arch = get_arch("llama3-8b")
    holder = {}

    def decode_hook(replica, records, gen_tokens, rnd):
        if "kv" not in holder:
            holder["kv"] = KVCacheManager(arch.reduced(),
                                          pool=srv.engines[replica].pool)
        kv = holder["kv"]
        lease = kv.acquire(len(records), 32, tenant=records[0].tenant)
        kv.release(lease)
        holder["nbytes"] = lease.nbytes

    srv = TeleRAGServer(
        small_index,
        _cfg(buffer_pages=64, pool_pages=2048,
             tenant_shares={"a": (8, None)}),
        1, arch, decode_hook=decode_hook, continuous=True)
    resp = srv.serve([RagRequest(q=q[i], pipeline="hyde", tenant="a")
                      for i in range(2)])
    assert all(r.state == RequestState.COMPLETE for r in resp)
    tele = srv.telemetry().tenant("a")
    assert tele is not None
    # the recycled bucket's live lease is attributed to tenant "a"
    assert tele.kv_bytes == holder["nbytes"]
    assert srv.engines[0].ledger.tenant_bytes("a") >= holder["nbytes"]


# ---------------------------------------------------------------------------
# The continuous server: mid-stream dispatch + per-request completions
# ---------------------------------------------------------------------------


def test_continuous_server_completes_all_and_counts_per_request(
        small_store, small_index, rng):
    """Heterogeneous round counts, staggered arrivals, two replicas:
    every request completes, responses stay in submission order, and
    telemetry counts completions per request (not per batch drain)."""
    q = unit_queries(small_store, rng, 8)
    traces = (make_traces("hyde", 4, seed=3)
              + make_traces("iter", 4, seed=4))
    srv = TeleRAGServer(small_index, _cfg(seed=3), 2,
                        get_arch("llama3-8b"), micro_batch=2,
                        continuous=True)
    resp = srv.serve([RagRequest(q=q[i], trace=traces[i],
                                 arrival_t=0.005 * (i % 3))
                      for i in range(8)])
    assert [r.request_id for r in resp] == [t.request_id for t in traces]
    assert all(r.state == RequestState.COMPLETE for r in resp)
    tele = srv.telemetry()
    assert tele.completed == 8
    assert tele.dispatched_batches >= 2


def test_continuous_mode_mean_latency_no_worse_than_static(
        small_store, small_index, rng):
    """Same workload through both disciplines: per-request waves never
    queue behind a busy replica and decode at their true batch size, so
    mean arrival→complete latency must not regress.  (The pool is sized
    so lookahead admission is not the binding constraint — under a
    saturated pool the admission controller serializes waves and the
    comparison measures memory pressure, not batching discipline.)"""
    q = unit_queries(small_store, rng, 6)
    means = {}
    for continuous in (False, True):
        srv = TeleRAGServer(small_index,
                            _cfg(seed=9, cache_enabled=False,
                                 buffer_pages=512), 1,
                            get_arch("llama3-8b"), micro_batch=2,
                            continuous=continuous)
        traces = make_traces("iter", 6, seed=13)
        resp = srv.serve([RagRequest(q=q[i], trace=traces[i])
                          for i in range(6)])
        assert all(r.state == RequestState.COMPLETE for r in resp)
        means[continuous] = float(np.mean([r.latency_s for r in resp]))
    assert means[True] <= means[False] * (1 + 1e-9)


# ---------------------------------------------------------------------------
# KV-slab exhaustion mid-decode: shed what fits, park the rest
# ---------------------------------------------------------------------------


def _paged_hook(kv, max_len=32, steps=4):
    """A decode hook on the real paged KV API (lease accounting only —
    no model compute): ``acquire_paged`` raises ``PoolExhausted`` when
    the wave's block tables do not fit the slab."""
    calls = []

    def hook(records, gen_tokens, rnd):
        calls.append(len(records))
        lease = kv.acquire_paged(len(records), max_len,
                                 tenant=records[0].tenant)
        try:
            for _ in range(steps):
                kv.append_paged(lease)
        finally:
            kv.release_paged(lease)
        return [DecodeEvent(request_id=r.request_id, tokens=steps,
                            seconds=0.0) for r in records]

    return hook, calls


def test_kv_slab_exhaustion_mid_decode_parks_and_rejoins(
        small_store, small_index, rng):
    """``acquire_paged`` failing at a round frontier is an admission
    decision, not a hook crash: the runtime sheds the older half of the
    wave (re-executed immediately at the smaller batch), parks the
    younger half ``PRESSURE_STALLED``, and the parked members rejoin on
    the page-free event the running half's ``release_paged`` fires —
    every request completes, nothing raises out of ``run()``."""
    eng = _engine(small_index, pool_pages=4096)
    kv = KVCacheManager(get_arch("llama3-8b").reduced(), pool=eng.pool)
    # room for exactly TWO max_len=32 block tables (4 pages each)
    kv.init_paged(num_pages=8, page_size=8)
    hook, calls = _paged_hook(kv)
    runtime = RetrievalRuntime(eng, include_tail=True, on_generate=hook)
    q = unit_queries(small_store, rng, 4)
    traces = make_traces("hyde", 4, seed=5)
    recs = [runtime.submit(q[i], traces[i]) for i in range(4)]
    runtime.run()
    assert all(r.state == RequestState.COMPLETE for r in recs)
    # the 4-wave could never fit: it shed, and everything that actually
    # decoded did so at a batch the slab can hold
    assert calls[0] == 4
    assert all(c <= 2 for c in calls[1:])
    marks = [getattr(e, "label", "") for e in runtime.recorder.events
             if getattr(e, "kind", "") == "request"]
    assert "pressure_stall" in marks, "no member ever parked"
    assert "pressure_resume" in marks, "parked members never rejoined"
    # the parked members' rounds produced results like everyone else's
    for r in recs:
        assert r.result.doc_ids


def test_kv_pool_shortfall_spills_cold_prefetch_and_retries(
        small_store, small_index, rng):
    """``PoolExhausted`` tagged with ``bytes_needed`` is a *pool-bytes*
    shortfall eviction can cure (paged KV returns its bytes between
    waves, so warm prefetch residency creeps into them): the runtime
    spills cold unpinned residency toward the failed lease and retries
    the decode hook once — same wave, no shed, no park."""
    from repro.memory.pool import PoolExhausted

    eng = _engine(small_index, pool_pages=4096)
    calls = []
    room_targets = []
    orig_make_room = eng.cache.make_room

    def spying_make_room(buffer, pages, protect=None):
        room_targets.append(pages)
        return orig_make_room(buffer, pages, protect=protect)

    eng.cache.make_room = spying_make_room

    def hook(records, gen_tokens, rnd):
        calls.append([r.request_id for r in records])
        if len(calls) == 1:
            raise PoolExhausted("kv bytes",
                                bytes_needed=3 * eng.pool.page_nbytes)
        return [DecodeEvent(request_id=r.request_id, tokens=2,
                            seconds=0.0) for r in records]

    runtime = RetrievalRuntime(eng, include_tail=True, on_generate=hook)
    q = unit_queries(small_store, rng, 2)
    traces = make_traces("hyde", 2, seed=5)
    recs = [runtime.submit(q[i], traces[i]) for i in range(2)]
    runtime.run()
    assert all(r.state == RequestState.COMPLETE for r in recs)
    # one relief spill, sized at least the lease's pages, and the retry
    # re-ran the SAME wave (no shed split)
    assert len(room_targets) == 1 and room_targets[0] >= 3
    assert calls[1] == calls[0]
    marks = [getattr(e, "label", "") for e in runtime.recorder.events
             if getattr(e, "kind", "") == "request"]
    assert "pressure_stall" not in marks


def test_kv_pool_shortfall_singleton_parks_until_pages_free(
        small_store, small_index, rng):
    """A singleton wave has no half to shed, but raising is only right
    when NO future event could free pages.  Here another in-flight
    wave's cluster pins are pending release at its completion, so the
    stuck singleton parks ``PRESSURE_STALLED`` whole and rejoins on
    that page-free event instead of raising out of ``run()``."""
    from repro.memory.pool import PoolExhausted

    eng = _engine(small_index, pool_pages=4096)
    failures = []

    def hook(records, gen_tokens, rnd):
        rids = [r.request_id for r in records]
        if rids == [1] and len(failures) < 2:
            # first attempt + the relief retry both fail: nothing cold
            # to spill (every resident cluster is pinned)
            failures.append(tuple(rids))
            raise PoolExhausted("kv bytes",
                                bytes_needed=eng.pool.page_nbytes)
        return [DecodeEvent(request_id=r.request_id, tokens=2,
                            seconds=0.0) for r in records]

    runtime = RetrievalRuntime(eng, micro_batch=1, include_tail=True,
                               on_generate=hook)
    q = unit_queries(small_store, rng, 2)
    traces = make_traces("hyde", 2, seed=5)
    recs = [runtime.submit(q[i], traces[i]) for i in range(2)]
    runtime.run()
    assert all(r.state == RequestState.COMPLETE for r in recs)
    assert len(failures) == 2
    marks = [getattr(e, "label", "") for e in runtime.recorder.events
             if getattr(e, "kind", "") == "request"]
    assert "pressure_stall" in marks, "the singleton never parked"
    assert "pressure_resume" in marks, "the parked singleton never woke"
    for r in recs:
        assert r.result.doc_ids


def test_kv_exhaustion_on_singleton_wave_still_raises(small_store,
                                                      small_index, rng):
    """With nothing left to shed (batch of one) slab exhaustion is real
    exhaustion: the legacy raise-out behavior is preserved."""
    from repro.memory.pool import PoolExhausted

    eng = _engine(small_index, pool_pages=4096)
    kv = KVCacheManager(get_arch("llama3-8b").reduced(), pool=eng.pool)
    kv.init_paged(num_pages=2, page_size=8)     # one seq of 32 needs 4
    hook, _calls = _paged_hook(kv)
    runtime = RetrievalRuntime(eng, on_generate=hook)
    runtime.submit(unit_queries(small_store, rng, 1)[0],
                   make_traces("hyde", 1, seed=5)[0])
    with pytest.raises(PoolExhausted):
        runtime.run()


def test_kv_exhaustion_in_never_reform_mode_still_raises(small_store,
                                                         small_index, rng):
    """Never-re-form cohorts cannot split, so the shed/park path must
    not engage: the exception propagates exactly as before."""
    from repro.memory.pool import PoolExhausted

    eng = _engine(small_index, pool_pages=4096)
    kv = KVCacheManager(get_arch("llama3-8b").reduced(), pool=eng.pool)
    kv.init_paged(num_pages=8, page_size=8)     # two seqs; the wave is 4
    hook, _calls = _paged_hook(kv)
    runtime = RetrievalRuntime(eng, reform=False, on_generate=hook)
    q = unit_queries(small_store, rng, 4)
    traces = make_traces("hyde", 4, seed=5)
    for i in range(4):
        runtime.submit(q[i], traces[i])
    with pytest.raises(PoolExhausted):
        runtime.run()
