"""Hypothesis property tests on system invariants."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import repro.core as core
from repro.core.datastore import build_paged_clusters, Datastore
from repro.distributed import elastic_slices, quantize_int8, dequantize_int8


@st.composite
def paged_store(draw):
    n = draw(st.integers(50, 400))
    d = draw(st.sampled_from([16, 32]))
    nc = draw(st.integers(2, 8))
    ps = draw(st.sampled_from([8, 16]))
    rng = np.random.default_rng(draw(st.integers(0, 2**16)))
    emb = rng.standard_normal((n, d)).astype(np.float32)
    emb /= np.maximum(np.linalg.norm(emb, axis=-1, keepdims=True), 1e-9)
    assign = rng.integers(0, nc, n).astype(np.int32)
    paged = build_paged_clusters(Datastore(embeddings=emb), assign, nc, ps)
    return paged, assign


@given(paged_store())
@settings(max_examples=25, deadline=None)
def test_paged_partition_is_exact(data):
    """Paging is a partition: every vector once, under its own cluster."""
    paged, assign = data
    ids = paged.page_ids.reshape(-1)
    valid = ids >= 0
    assert valid.sum() == len(assign)
    assert len(np.unique(ids[valid])) == len(assign)
    owner = np.repeat(paged.page_cluster, paged.page_size)
    assert np.all(assign[ids[valid]] == owner[valid])


@given(paged_store(), st.integers(0, 2**16), st.floats(0.0, 1.0))
@settings(max_examples=25, deadline=None)
def test_plan_prefetch_invariants(data, seed, frac):
    paged, _ = data
    rng = np.random.default_rng(seed)
    ranked = rng.permutation(paged.num_clusters)
    total = int(paged.all_cluster_bytes().sum())
    budget = int(frac * total)
    plan = core.plan_prefetch(list(ranked), paged, budget_bytes=budget,
                              resident=set(), free_pages=10**9)
    # 1. never exceeds budget
    assert plan.bytes_planned <= budget
    # 2. fetch+skip+resident covers all ranked clusters exactly once
    assert sorted(plan.fetch + plan.skipped) == sorted(int(c) for c in ranked)
    # 3. bytes accounting is exact
    assert plan.bytes_planned == sum(paged.cluster_bytes(c)
                                     for c in plan.fetch)
    # 4. greedy-prefix property: a skipped cluster never fits the budget
    #    remaining at the moment it was considered
    rem = budget
    for c in ranked:
        c = int(c)
        if c in plan.fetch:
            rem -= paged.cluster_bytes(c)
        else:
            assert paged.cluster_bytes(c) > rem


@given(st.integers(1, 64), st.integers(1, 16), st.integers(0, 1000))
@settings(max_examples=50, deadline=None)
def test_elastic_slices_partition(batch, nodes, step):
    healthy = list(range(nodes))
    sl = elastic_slices(step, healthy, batch)
    spans = sorted(sl.values())
    # exact disjoint cover of [0, batch)
    assert spans[0][0] == 0 and spans[-1][1] == batch
    for (a, b), (c, d) in zip(spans, spans[1:]):
        assert b == c
    # determinism
    assert sl == elastic_slices(step, list(reversed(healthy)), batch)


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1,
                max_size=64))
@settings(max_examples=50, deadline=None)
def test_int8_quantization_error_bound(vals):
    x = jnp.asarray(np.asarray(vals, np.float32))
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale)) - np.asarray(x))
    amax = np.max(np.abs(vals))
    assert np.all(err <= amax / 127.0 * 0.5 + 1e-6)


@given(st.integers(1, 6), st.integers(1, 5), st.integers(0, 2**16))
@settings(max_examples=25, deadline=None)
def test_merge_topk_equals_global_sort(b, k, seed):
    rng = np.random.default_rng(seed)
    ds = rng.standard_normal((b, k)).astype(np.float32)
    hs = rng.standard_normal((b, k)).astype(np.float32)
    di = rng.integers(0, 1000, (b, k)).astype(np.int32)
    hi = rng.integers(1000, 2000, (b, k)).astype(np.int32)
    s, i = core.merge_topk(jnp.asarray(np.sort(ds)[:, ::-1].copy()),
                           jnp.asarray(di),
                           jnp.asarray(np.sort(hs)[:, ::-1].copy()),
                           jnp.asarray(hi), k)
    allscores = np.concatenate([np.sort(ds)[:, ::-1], np.sort(hs)[:, ::-1]], 1)
    expect = np.sort(allscores, axis=1)[:, ::-1][:, :k]
    np.testing.assert_allclose(np.asarray(s), expect, rtol=1e-6)


@given(st.integers(2, 40), st.integers(1, 30), st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_ring_cache_position_recovery(W, pos, b):
    """slot -> absolute-position formula used by attn_decode_ring."""
    slots = np.arange(W)
    ks_pos = pos - np.mod(pos - slots, W)
    # recovered positions are exactly the last min(W, pos+1) positions
    got = sorted(p for p in ks_pos if p >= 0)
    lo = max(0, pos - W + 1)
    assert got == list(range(lo, pos + 1))
    # and each sits in its own slot
    for s, p in zip(slots, ks_pos):
        if p >= 0:
            assert p % W == s


@given(st.integers(1, 8), st.integers(1, 3), st.floats(0.1, 0.9))
@settings(max_examples=20, deadline=None)
def test_cache_hotness_monotone(rounds, used_every, frac):
    """A cluster used every round is always at least as hot as one never
    used (Eq. 6 ordering invariant)."""
    c = core.ClusterCache(core.CacheConfig(decay=1.0 / frac if False else 2.0))
    c.on_fetched([1, 2])
    for r in range(rounds):
        c.round_update([1] if r % used_every == 0 else [])
    assert c.hotness[1] >= c.hotness[2]
