"""End-to-end behaviour tests for the paper's system.

Builds a real (small) datastore + IVF index, serves full RAG pipelines
through the TeleRAG engine with real decode on a reduced LLM, and checks
the paper's headline claims at test scale:
  * retrieval results identical to the CPU-only baseline (correctness),
  * modeled latency never worse than the baseline (overlap),
  * lookahead bytes respect the Appendix-C budget,
  * multi-replica scheduling + cache raise the prefetch hit rate.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core as core
from repro.configs import get_arch
from repro.models import transformer as tf
from repro.serving import (EngineConfig, MultiReplicaOrchestrator,
                           PipelineExecutor, TeleRAGEngine, make_traces)
from tests.conftest import unit_queries


def test_end_to_end_rag_query_with_real_llm(small_store, small_index, rng):
    """One full RAG request: lookahead -> REAL decode steps (reduced llama)
    overlapping the prefetch dispatch -> hybrid retrieve -> answer decode."""
    arch = get_arch("llama3-8b").reduced()
    params = tf.init_params(arch, jax.random.PRNGKey(0))
    cache = tf.init_cache(arch, 1, 64)
    step = jax.jit(lambda p, c, i: tf.serve_step(p, c, i, arch))

    cfg = EngineConfig(nprobe=12, top_k=3, buffer_pages=128,
                       lookahead_rank=24, kernel_mode="ref")
    eng = TeleRAGEngine(small_index, cfg, get_arch("llama3-8b"))

    q_in = unit_queries(small_store, rng, 1)
    # 1) lookahead prefetch dispatched (async)
    nbytes, nfetch = eng.lookahead(q_in, gen_tokens=[8])
    assert nfetch > 0
    # 2) pre-retrieval generation: REAL decode steps run while the
    #    device_put/scatter from (1) completes
    tok = jnp.zeros((1,), jnp.int32)
    for t in range(8):
        logits, cache = step(params, cache,
                             {"token": tok, "pos": jnp.asarray([t], jnp.int32)})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    # 3) rewrite + hybrid retrieval
    q_out = core.synthetic_rewrite(q_in, 0.3, rng)
    res = eng.retrieve(q_out)
    assert res.doc_ids.shape == (1, 3) and np.all(res.doc_ids >= 0)
    assert res.hit_rate > 0  # lookahead found at least one cluster


def test_retrieval_correctness_invariant_across_systems(small_store,
                                                        small_index, rng):
    """TeleRAG accelerates retrieval; it must never change what is
    retrieved (paper's accuracy-preservation claim)."""
    q = unit_queries(small_store, rng, 5)
    ranked = core.probe(q, small_index, 10)

    cpu = [core.host_search(small_index.paged,
                            [int(c) for c in ranked[b]], q[b], 4)
           for b in range(5)]

    cfg = EngineConfig(nprobe=10, top_k=4, buffer_pages=256,
                       lookahead_rank=20, kernel_mode="ref")
    eng = TeleRAGEngine(small_index, cfg, None)
    eng.lookahead(q, gen_tokens=[64])
    res = eng.retrieve(q)
    for b in range(5):
        np.testing.assert_array_equal(np.sort(res.doc_ids[b]),
                                      np.sort(cpu[b][1]))


def test_budget_bounds_transfer(small_store, small_index, rng):
    budget = 20 * small_index.paged.page_nbytes()
    cfg = EngineConfig(nprobe=16, top_k=3, buffer_pages=512,
                       lookahead_rank=64, kernel_mode="ref",
                       prefetch_budget_bytes=budget)
    eng = TeleRAGEngine(small_index, cfg, None)
    q = unit_queries(small_store, rng, 4)
    eng.lookahead(q, gen_tokens=[32])
    assert eng.buffer.stats.bytes_h2d <= budget


def test_multi_replica_cache_hit_rate_improves(small_store, small_index, rng):
    cfg = EngineConfig(nprobe=16, top_k=3, buffer_pages=200,
                       lookahead_rank=32, kernel_mode="ref",
                       cache_enabled=True)
    orch = MultiReplicaOrchestrator(small_index, cfg, 2,
                                    get_arch("llama3-8b"))
    qs = unit_queries(small_store, rng, 8)
    r1 = orch.run_global_batch(qs, make_traces("hyde", 8, seed=1),
                               micro_batch=4)
    # second wave of similar queries: cache-aware router should place them
    # on replicas already holding their clusters
    q2 = qs + 0.02 * rng.standard_normal(qs.shape).astype(np.float32)
    q2 /= np.linalg.norm(q2, axis=-1, keepdims=True)
    r2 = orch.run_global_batch(q2, make_traces("hyde", 8, seed=2),
                               micro_batch=4)
    assert sum(a[2] for a in r2.assignments) > sum(a[2] for a in
                                                   r1.assignments)


def test_hit_rate_grows_with_budget(small_store, small_index, rng):
    """Paper Table 3's budget->hit-rate relationship at test scale."""
    rates = []
    for pages in (16, 64, 256):
        cfg = EngineConfig(nprobe=16, top_k=3, buffer_pages=pages,
                           lookahead_rank=64, kernel_mode="ref",
                           prefetch_budget_bytes=pages
                           * small_index.paged.page_nbytes())
        eng = TeleRAGEngine(small_index, cfg, None)
        q = unit_queries(small_store, rng, 4)
        eng.lookahead(q, gen_tokens=[64])
        q_out = core.synthetic_rewrite(q, 0.3, np.random.default_rng(0))
        res = eng.retrieve(q_out)
        rates.append(res.hit_rate)
    assert rates[0] <= rates[1] <= rates[2]
    assert rates[2] > 0.2
