"""Documentation contract: every public export of ``repro.serving`` and
``repro.memory`` — and every public method/property defined on an
exported class — carries a docstring (the PR-4 docs acceptance bar;
docs/TELEMETRY.md is the prose counterpart)."""

import inspect

import pytest

import repro.memory
import repro.serving

MODULES = (repro.serving, repro.memory)


def _exported_objects():
    for mod in MODULES:
        for name in mod.__all__:
            yield mod.__name__, name, getattr(mod, name)


def _public_members(cls):
    """Callables and properties defined directly in ``cls``'s body
    (inherited members are checked on the class that defines them)."""
    for name, member in vars(cls).items():
        if name.startswith("_"):
            continue
        if isinstance(member, (staticmethod, classmethod)):
            member = member.__func__
        if isinstance(member, property) or callable(member):
            yield name, member


def test_every_public_export_has_a_docstring():
    missing = []
    for mod_name, name, obj in _exported_objects():
        if isinstance(obj, (str, tuple, dict, list, int, float)):
            continue                       # constants (e.g. PIPELINE_NAMES)
        if not inspect.getdoc(obj):
            missing.append(f"{mod_name}.{name}")
    assert not missing, f"exports without docstrings: {missing}"


def test_every_public_method_of_exported_classes_has_a_docstring():
    missing = []
    for mod_name, name, obj in _exported_objects():
        if not inspect.isclass(obj):
            continue
        for mname, member in _public_members(obj):
            doc = (member.fget.__doc__ if isinstance(member, property)
                   else getattr(member, "__doc__", None))
            if not doc:
                missing.append(f"{mod_name}.{name}.{mname}")
    assert not missing, \
        f"public methods/properties without docstrings: {missing}"


def test_docstrings_name_units_on_key_surfaces():
    """Spot-check that the load-bearing quantitative surfaces state
    their units (seconds / bytes / pages), per the docs acceptance
    criterion."""
    from repro.memory import DevicePagePool, MemoryLedger
    from repro.serving import RagResponse

    assert "second" in (RagResponse.latency_s.fget.__doc__ or "").lower()
    assert "bytes" in (MemoryLedger.__doc__ or "").lower()
    assert "page" in (DevicePagePool.__doc__ or "").lower()
