"""Paged-block substrate: block-table KV leases over the shared pool,
fused one-launch retrieval on the engine path, and the launch-env
hygiene module."""

import os

import numpy as np
import jax.numpy as jnp
import pytest

import repro.core as core
from repro.configs.base import ArchConfig
from repro.core.hybrid_search import hybrid_retrieve
from repro.core.ivf import probe
from repro.core.prefetch_buffer import PrefetchBuffer
from repro.kernels import ops, ref
from repro.launch import env as launch_env
from repro.memory.pool import DevicePagePool, PoolExhausted
from repro.serving import EngineConfig, KVCacheManager, TeleRAGEngine
from tests.conftest import unit_queries


def tiny_cfg(num_layers=2, kvh=2, g=2, dh=16):
    return ArchConfig(name="tiny", family="dense", source="test",
                      d_model=kvh * g * dh, num_layers=num_layers,
                      num_heads=kvh * g, num_kv_heads=kvh, head_dim=dh,
                      vocab_size=64)


# ---------------------------------------------------------------------------
# KVCacheManager paged leases
# ---------------------------------------------------------------------------


def test_acquire_paged_block_table_and_release():
    mgr = KVCacheManager(tiny_cfg(), dtype=jnp.float32)
    slab = mgr.init_paged(num_pages=24, page_size=4)
    lease = mgr.acquire_paged(batch=3, max_len=10)
    assert lease.block_table.shape == (3, 3)          # ceil(10/4) blocks
    assert (lease.block_table >= 0).all()
    assert len(set(lease.block_table.reshape(-1).tolist())) == 9  # distinct
    assert len(slab.free) == 24 - 9
    assert (lease.lengths == 0).all()
    freed = mgr.release_paged(lease)
    assert freed == lease.nbytes
    assert len(slab.free) == 24
    assert (lease.block_table == -1).all()


def test_append_paged_then_attention_matches_dense():
    """Tokens written through the block table + flash_decode_paged ==
    dense flash_decode over the same tokens, every layer."""
    cfg = tiny_cfg()
    L, KVH, Dh = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    B, G, steps = 3, 2, 7
    mgr = KVCacheManager(cfg, dtype=jnp.float32)
    slab = mgr.init_paged(num_pages=16, page_size=4)
    lease = mgr.acquire_paged(B, steps + 1)
    rng = np.random.default_rng(5)
    ks = rng.standard_normal((steps, L, B, KVH, Dh)).astype(np.float32)
    vs = rng.standard_normal((steps, L, B, KVH, Dh)).astype(np.float32)
    for t in range(steps):
        mgr.append_paged(lease, ks[t], vs[t])
    assert (lease.lengths == steps).all()
    q = jnp.asarray(rng.standard_normal((B, KVH, G, Dh)), jnp.float32)
    bt, lens = lease.device_tables()
    for l in range(L):
        kp, vp = slab.layer(l)
        out_p = ops.flash_decode_paged(q, kp, vp, bt, lens,
                                       mode="kernel_interpret")
        dense_k = jnp.asarray(np.transpose(ks[:, l], (1, 0, 2, 3)))
        dense_v = jnp.asarray(np.transpose(vs[:, l], (1, 0, 2, 3)))
        out_d = ref.flash_decode_ref(q, dense_k, dense_v, lens - 1, 0)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d),
                                   rtol=1e-5, atol=1e-5)


def test_paged_pool_accounting_and_exhaustion(small_index):
    pool = DevicePagePool(small_index.paged, 64, jnp.float32)
    mgr = KVCacheManager(tiny_cfg(), dtype=jnp.float32, pool=pool)
    mgr.init_paged(num_pages=16, page_size=4)
    lease = mgr.acquire_paged(2, 8, tenant="acme")
    # exact bytes on the ledger, tenant-attributed
    assert lease.nbytes == 2 * 2 * mgr.paged_page_nbytes()
    assert pool.ledger.bytes_of("kv") == lease.nbytes
    assert pool.ledger.tenant_bytes("acme") == lease.nbytes
    # slab exhaustion raises, never overcommits
    with pytest.raises(PoolExhausted):
        mgr.acquire_paged(100, 1000)
    mgr.release_paged(lease)
    assert pool.ledger.bytes_of("kv") == 0


def test_paged_rejects_non_attention_archs():
    cfg = ArchConfig(name="ssm", family="ssm", source="test", d_model=32,
                     num_layers=2, num_heads=2, num_kv_heads=2,
                     vocab_size=64, attn_kind="none")
    mgr = KVCacheManager(cfg)
    with pytest.raises(ValueError):
        mgr.init_paged(8)


def test_append_paged_full_lease_raises():
    mgr = KVCacheManager(tiny_cfg(), dtype=jnp.float32)
    mgr.init_paged(num_pages=8, page_size=4)
    lease = mgr.acquire_paged(1, 4)
    cfg = tiny_cfg()
    z = np.zeros((cfg.num_layers, 1, cfg.num_kv_heads,
                  cfg.resolved_head_dim), np.float32)
    for _ in range(4):
        mgr.append_paged(lease, z, z)
    with pytest.raises(ValueError):
        mgr.append_paged(lease, z, z)


# ---------------------------------------------------------------------------
# Fused retrieval on the engine path
# ---------------------------------------------------------------------------


def test_hybrid_retrieve_fused_matches_legacy(small_store, small_index, rng):
    """One-launch probe_and_topk on the device partition returns the
    same documents as the legacy host-mask chain (same probe scores,
    tie-free data) — hit/miss telemetry identical."""
    buf = PrefetchBuffer(small_index.paged, num_pages=128)
    buf.load_clusters(range(0, 40))                  # partial residency
    q = unit_queries(small_store, rng, 5)
    ranked = probe(q, small_index, 12)
    legacy = hybrid_retrieve(buf, q, ranked, k=4, kernel_mode="ref",
                             fused=False)
    fused = hybrid_retrieve(buf, q, ranked, k=4, kernel_mode="ref",
                            fused=True, centroids=small_index.centroids)
    np.testing.assert_array_equal(fused.doc_ids, legacy.doc_ids)
    np.testing.assert_allclose(fused.scores, legacy.scores, rtol=1e-5)
    assert fused.hit_clusters == legacy.hit_clusters
    assert fused.missed_clusters == legacy.missed_clusters


def test_engine_fused_flag_equivalence(small_index, small_store, rng):
    """EngineConfig.fused_retrieval=True (the default) and False produce
    identical retrievals through the full policy path."""
    q = unit_queries(small_store, rng, 4)
    outs = {}
    for fused in (True, False):
        cfg = EngineConfig(nprobe=12, top_k=4, buffer_pages=128,
                           kernel_mode="ref", fused_retrieval=fused)
        eng = TeleRAGEngine(small_index, cfg)
        eng.lookahead(q, gen_tokens=[8] * len(q))
        outs[fused] = eng.retrieve(q)
    np.testing.assert_array_equal(outs[True].doc_ids, outs[False].doc_ids)
    np.testing.assert_allclose(outs[True].scores, outs[False].scores,
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# Launch env hygiene
# ---------------------------------------------------------------------------


def test_recommended_env_and_shell_snippet():
    env = launch_env.recommended_env(host_device_count=4)
    assert env["TF_CPP_MIN_LOG_LEVEL"] == "4"
    assert "--xla_step_marker_location=1" in env["XLA_FLAGS"]
    assert "--xla_force_host_platform_device_count=4" in env["XLA_FLAGS"]
    if "LD_PRELOAD" in env:
        assert os.path.exists(env["LD_PRELOAD"])
    snippet = launch_env.render_shell()
    for key in env:
        if key != "XLA_FLAGS":
            continue
        assert f'export {key}=' in snippet


def test_env_validate_reports_divergence(monkeypatch):
    monkeypatch.setenv("TF_CPP_MIN_LOG_LEVEL", "0")
    diffs = {k for k, _, _ in launch_env.validate()}
    assert "TF_CPP_MIN_LOG_LEVEL" in diffs
    monkeypatch.setenv("TF_CPP_MIN_LOG_LEVEL", "4")
    monkeypatch.setenv(
        "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
        str(launch_env.LARGE_ALLOC_THRESHOLD))
    # flag-wise containment: extra operator flags are not a divergence
    monkeypatch.setenv("XLA_FLAGS",
                       "--xla_dump_to=/tmp/x --xla_step_marker_location=1")
    diffs = {k for k, _, _ in launch_env.validate()}
    assert "XLA_FLAGS" not in diffs
    assert "TF_CPP_MIN_LOG_LEVEL" not in diffs


def test_decode_microbench_pinned_fixture_still_validates():
    """The committed schema fixture (tests/data) is the contract: the
    regenerated JSON itself is untracked bench output (--report-dir /
    CI artifact), so THIS is what pins the schema across PRs."""
    import json
    import os

    from benchmarks.bench_decode_microbench import validate_report
    path = os.path.join(os.path.dirname(__file__), "data",
                        "decode_microbench_pinned.json")
    with open(path) as f:
        report = json.load(f)
    validate_report(report)


def test_decode_microbench_smoke_schema():
    """The microbench JSON must validate against its schema guard."""
    from benchmarks.bench_decode_microbench import run_smoke, validate_report
    report = run_smoke()
    validate_report(report)
    assert report["schema"] == "telerag.decode_microbench/v1"
    names = {r["name"] for r in report["kernels"]}
    assert {"flash_decode_dense", "flash_decode_paged", "kv_append",
            "probe_topk_unfused", "probe_topk_fused"} <= names
