"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; only launch/dryrun.py forces 512 placeholder devices.

Every test also runs under the happens-before invariant checker: the
autouse ``_check_flight_recorders`` fixture registers each
``FlightRecorder`` a test constructs and replays its stream through
``repro.analysis.check_recorder`` at teardown — a use-before-land race
or double release anywhere in the suite fails the test that produced
it.  Tests that synthesize deliberately-corrupt streams opt out with
``@pytest.mark.trace_unchecked`` (see docs/ANALYSIS.md)."""

import numpy as np
import pytest

import repro.core as core
from repro.analysis import check_recorder
from repro.obs.recorder import FlightRecorder


@pytest.fixture(autouse=True)
def _check_flight_recorders(request, monkeypatch):
    """Invariant-check every recorder stream the test produced."""
    if request.node.get_closest_marker("trace_unchecked"):
        yield
        return
    made = []
    orig_init = FlightRecorder.__init__

    def tracking_init(self, *args, **kwargs):
        orig_init(self, *args, **kwargs)
        made.append(self)

    monkeypatch.setattr(FlightRecorder, "__init__", tracking_init)
    yield
    bad = []
    for rec in made:
        if not rec.events:
            continue
        rep = check_recorder(rec)        # skips truncated (dropped) streams
        bad.extend(v.render() for v in rep.violations)
    if bad:
        pytest.fail(
            "flight-recorder happens-before invariants violated "
            f"({len(bad)}):\n  " + "\n  ".join(bad), pytrace=False)


@pytest.fixture(scope="session")
def small_store():
    return core.synthetic_datastore(20_000, dim=128, seed=0)


@pytest.fixture(scope="session")
def small_index(small_store):
    return core.build_ivf(small_store, 64, page_size=64, kmeans_iters=4)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


def unit_queries(store, rng, n, jitter=0.1):
    q = store.embeddings[rng.choice(len(store.embeddings), n)]
    q = q + jitter * rng.standard_normal(q.shape).astype(np.float32)
    return q / np.linalg.norm(q, axis=-1, keepdims=True)
