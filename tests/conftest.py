"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see 1 device; only launch/dryrun.py forces 512 placeholder devices."""

import numpy as np
import pytest

import repro.core as core


@pytest.fixture(scope="session")
def small_store():
    return core.synthetic_datastore(20_000, dim=128, seed=0)


@pytest.fixture(scope="session")
def small_index(small_store):
    return core.build_ivf(small_store, 64, page_size=64, kmeans_iters=4)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


def unit_queries(store, rng, n, jitter=0.1):
    q = store.embeddings[rng.choice(len(store.embeddings), n)]
    q = q + jitter * rng.standard_normal(q.shape).astype(np.float32)
    return q / np.linalg.norm(q, axis=-1, keepdims=True)
