"""Differential parity: serving decode on the paged block-table KV
substrate vs the pinned dense bucket path.

Each test runs the SAME request set through two servers whose only
difference is ``EngineConfig.paged_decode`` — the ``DecodeRunner`` hook
leases block-table KV and attends through ``flash_decode_paged`` in one
run, a dense ``[B, max_len]`` bucket and ``flash_decode`` in the other —
and pins the outputs equal: retrieved doc ids exact, greedy tokens
exact per request per round, round telemetry within 1e-6, and both
runs' KV bytes fully returned to the ledger.  Shapes deliberately cross
page boundaries and leave the last block partially filled, batches are
ragged against ``micro_batch``, and the continuous-batching machinery
(mid-stream joins, stragglers, park-rejoin) runs in both modes.

Every server's flight-recorder stream is additionally replayed through
the happens-before invariant checker by the autouse conftest fixture,
so the paged lease discipline (acquire -> append* -> release, page
conservation, no append past capacity) is verified on every run here.
"""

import dataclasses
import math

import numpy as np
import jax
import pytest

from repro.analysis import check_recorder
from repro.configs import get_arch
from repro.models import transformer as tf
from repro.serving import (DecodeRunner, EngineConfig, RagRequest,
                           RequestState, TeleRAGServer, make_traces,
                           supports_paged_decode)
from repro.serving.trace import RequestTrace, StageTrace
from tests.conftest import unit_queries

ARCH = get_arch("llama3-8b")
CFG = ARCH.reduced()


@pytest.fixture(scope="module")
def params():
    return tf.init_params(CFG, jax.random.PRNGKey(0))


def _serve(small_index, q, traces, *, paged, params, micro_batch=3,
           max_len=24, max_steps=6, page_size=4, slab_seqs=None,
           arrivals=None, tenants=None):
    """One full serve run; returns (runner, server, responses)."""
    n = len(traces)
    runner = DecodeRunner(params, CFG, max_len=max_len,
                          max_steps=max_steps, page_size=page_size,
                          slab_seqs=slab_seqs if slab_seqs is not None
                          else n + 2)
    srv = TeleRAGServer(small_index, EngineConfig(
        nprobe=8, top_k=3, buffer_pages=256, pool_pages=4096,
        lookahead_rank=16, kernel_mode="ref", chips=8, seed=7,
        paged_decode=paged), 1, ARCH, micro_batch=micro_batch,
        include_tail=True, decode_hook=runner, continuous=True)
    runner.attach(srv)
    resp = srv.serve([RagRequest(
        q=q[i], trace=traces[i],
        arrival_t=0.0 if arrivals is None else arrivals[i],
        tenant="shared" if tenants is None else tenants[i])
        for i in range(n)])
    return runner, srv, resp


def _assert_token_parity(rp, rd):
    """Per-request, per-round greedy tokens must be EXACTLY equal."""
    assert set(rp.generated) == set(rd.generated)
    assert rp.generated, "no decode ran at all"
    for rid in rp.generated:
        assert rp.generated[rid] == rd.generated[rid], (
            f"request {rid}: paged tokens {rp.generated[rid]} != "
            f"dense {rd.generated[rid]}")


def _assert_full_parity(rp, respp, rd, respd):
    """Tokens exact, doc ids exact, telemetry pinned to 1e-6."""
    _assert_token_parity(rp, rd)
    assert [r.request_id for r in respp] == [r.request_id for r in respd]
    for a, b in zip(respp, respd):
        assert a.state == b.state == RequestState.COMPLETE
        assert len(a.doc_ids) == len(b.doc_ids)
        for da, db in zip(a.doc_ids, b.doc_ids):
            assert [int(x) for x in da] == [int(x) for x in db]
        assert a.latency_s == pytest.approx(b.latency_s, abs=1e-6)
        assert len(a.rounds) == len(b.rounds)
        for ta, tb in zip(a.rounds, b.rounds):
            fa = dataclasses.asdict(ta)
            fb = dataclasses.asdict(tb)
            assert fa.keys() == fb.keys()
            for key in fa:
                va, vb = fa[key], fb[key]
                if isinstance(va, float):
                    if math.isnan(va):
                        assert math.isnan(vb), (key, va, vb)
                    else:
                        assert va == pytest.approx(vb, abs=1e-6), (
                            key, va, vb)
                else:
                    assert va == vb, (key, va, vb)


def _assert_kv_drained(*runs):
    """Both runs hand every KV byte back to the pool ledger.  Paged
    leases free on release; dense buckets recycle by design, so the
    dense manager drops its recycling pool first."""
    for runner, srv in runs:
        for r, eng in enumerate(srv.engines):
            runner.kv(r).drop_all()
            assert eng.ledger.bytes_of("kv") == 0


# ---------------------------------------------------------------------------
# Acceptance: the paged serve path IS the paged substrate — and its
# output is indistinguishable from the pinned dense path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pipeline,n,micro_batch,max_steps,page_size", [
    ("hyde", 5, 3, 6, 4),     # ragged waves (3+2), partial last block (6%4)
    ("iter", 4, 2, 7, 4),     # multi-round rejoins, 7 crosses page 0->1
    ("irg", 3, 3, 5, 2),      # lengths cross two page boundaries
    ("flare", 4, 4, 4, 4),    # exactly one full page per round
])
def test_pipeline_parity_paged_vs_dense(small_store, small_index, rng,
                                        params, pipeline, n, micro_batch,
                                        max_steps, page_size):
    q = unit_queries(small_store, rng, n)
    traces = make_traces(pipeline, n, seed=11)
    rp, sp, respp = _serve(small_index, q, traces, paged=True,
                           params=params, micro_batch=micro_batch,
                           max_steps=max_steps, page_size=page_size)
    rd, sd, respd = _serve(small_index, q, traces, paged=False,
                           params=params, micro_batch=micro_batch,
                           max_steps=max_steps, page_size=page_size)
    # the paged run really ran paged (and only paged) decode
    assert rp.paged and rp.stats["paged_waves"] > 0
    assert rp.stats["dense_waves"] == 0
    assert rp.stats["paged_appends"] > 0
    assert not rd.paged and rd.stats["dense_waves"] > 0
    assert rd.stats["paged_waves"] == 0
    _assert_full_parity(rp, respp, rd, respd)
    _assert_kv_drained((rp, sp), (rd, sd))


def test_paged_run_emits_lease_events_and_drains(small_store, small_index,
                                                 rng, params):
    """The paged run's recorder stream carries the full lease lifecycle
    (kv.acquire -> kv.append* -> kv.release with lease ids and page
    counts) and satisfies the checker's drained end-state."""
    q = unit_queries(small_store, rng, 4)
    traces = make_traces("hyde", 4, seed=2)
    rp, sp, resp = _serve(small_index, q, traces, paged=True, params=params)
    assert all(r.state == RequestState.COMPLETE for r in resp)
    evs = [e for e in sp.recorder.events
           if getattr(e, "kind", "").startswith("kv.")]
    acq = [e for e in evs if e.kind == "kv.acquire"]
    app = [e for e in evs if e.kind == "kv.append"]
    rel = [e for e in evs if e.kind == "kv.release"]
    assert acq and app and rel
    lease_ids = {e.lease_id for e in acq}
    assert all(lid >= 0 for lid in lease_ids)
    assert len(lease_ids) == len(acq), "paged lease ids must be unique"
    assert {e.lease_id for e in rel} == lease_ids
    assert {e.lease_id for e in app} <= lease_ids
    # every acquire/release pair conserves its slab page count
    pages = {e.lease_id: e.pages for e in acq}
    assert all(e.pages == pages[e.lease_id] for e in rel)
    # appends never advance past the lease capacity
    assert all(0 < e.length <= e.max_len for e in app)
    rep = check_recorder(sp.recorder, drained=True, must_drain=("kv",))
    assert rep.ok, rep.summary()
    assert rep.stats["paged_leases"] == len(acq)


# ---------------------------------------------------------------------------
# Continuous-batching machinery in both modes: mid-stream joins,
# stragglers, mixed-pipeline rounds
# ---------------------------------------------------------------------------


def test_mid_stream_join_parity(small_store, small_index, rng, params):
    """Late arrivals join in-flight decode batches; wave composition is
    identical across modes (the event clock is deterministic in both),
    so parity holds through the re-forming machinery."""
    q = unit_queries(small_store, rng, 5)
    traces = make_traces("iter", 5, seed=4)
    arrivals = [0.0, 0.0, 1e-5, 2e-5, 3e-5]   # staggered mid-stream joins
    kw = dict(params=params, micro_batch=3, max_steps=5, page_size=4,
              arrivals=arrivals)
    rp, sp, respp = _serve(small_index, q, traces, paged=True, **kw)
    rd, sd, respd = _serve(small_index, q, traces, paged=False, **kw)
    _assert_full_parity(rp, respp, rd, respd)
    _assert_kv_drained((rp, sp), (rd, sd))


def test_straggler_and_mixed_round_parity(small_store, small_index, rng,
                                          params):
    """A slow request's batch-mates re-form without it (different
    per-wave batch shapes between rounds) — tokens and telemetry still
    pin across substrates, including the mixed hyde/iter rounds."""
    q = unit_queries(small_store, rng, 4)
    traces = [RequestTrace(
        pipeline="iter", request_id=0,
        stages=[StageTrace("generate", 4000), StageTrace("retrieve"),
                StageTrace("generate", 64), StageTrace("retrieve"),
                StageTrace("generate", 8)], rewrite_sigma=0.0)]
    traces += make_traces("hyde", 2, seed=6)
    traces += make_traces("iter", 2, seed=6)[1:]
    traces = [dataclasses.replace(t, request_id=i)
              for i, t in enumerate(traces)]
    kw = dict(params=params, micro_batch=4, max_steps=4, page_size=4)
    rp, sp, respp = _serve(small_index, q, traces, paged=True, **kw)
    rd, sd, respd = _serve(small_index, q, traces, paged=False, **kw)
    _assert_full_parity(rp, respp, rd, respd)
    _assert_kv_drained((rp, sp), (rd, sd))


def test_park_rejoin_token_parity_under_slab_pressure(small_store,
                                                      small_index, rng,
                                                      params):
    """A slab sized below the wave (slab_seqs=2, wave of 4) forces the
    paged run through the shed/park/rejoin path; the dense run never
    parks.  Wave compositions then differ between the runs — but the
    greedy tokens each request generates must STILL be exactly equal
    (decode is per-sequence deterministic), and everyone completes."""
    q = unit_queries(small_store, rng, 4)
    traces = make_traces("hyde", 4, seed=9)
    kw = dict(params=params, micro_batch=4, max_steps=4, page_size=4)
    rp, sp, respp = _serve(small_index, q, traces, paged=True,
                           slab_seqs=2, **kw)
    rd, sd, respd = _serve(small_index, q, traces, paged=False, **kw)
    assert all(r.state == RequestState.COMPLETE for r in respp + respd)
    # the paged run really hit pressure: someone parked AND resumed
    # (marks, not spans — on the deterministic event clock the older
    # half's decode is instantaneous, so the stall interval is empty)
    marks = [getattr(e, "label", "") for e in sp.recorder.events
             if getattr(e, "kind", "") == "request"]
    assert "pressure_stall" in marks, "slab_seqs=2 never forced a park"
    assert "pressure_resume" in marks, "parked members never rejoined"
    # the shed split the wave: more paged waves ran than dense waves
    assert rp.stats["paged_waves"] > rd.stats["dense_waves"]
    _assert_token_parity(rp, rd)
    _assert_kv_drained((rp, sp), (rd, sd))
    # doc ids are wave-composition independent too
    for a, b in zip(respp, respd):
        for da, db in zip(a.doc_ids, b.doc_ids):
            assert [int(x) for x in da] == [int(x) for x in db]


# ---------------------------------------------------------------------------
# Arch gating + randomized sweep
# ---------------------------------------------------------------------------


def test_supports_paged_decode_gates_arches():
    assert supports_paged_decode(CFG)
    assert not supports_paged_decode(
        dataclasses.replace(CFG, sliding_window=8))
    assert not supports_paged_decode(
        dataclasses.replace(CFG, attn_kind="none"))
    # an unsupported arch falls back to dense even when asked for paged
    runner = DecodeRunner(None, dataclasses.replace(CFG, sliding_window=8),
                          paged=True)
    assert not runner.paged


def test_randomized_shape_parity(small_store, small_index, params):
    """Hypothesis-driven differential sweep over batch shapes, page
    sizes and step counts (ragged batches, boundary-crossing lengths,
    partially-filled last blocks)."""
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=5, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(pipeline=st.sampled_from(["hyde", "iter", "irg", "flare"]),
           n=st.integers(2, 5), micro_batch=st.integers(2, 4),
           max_steps=st.integers(3, 7),
           page_size=st.sampled_from([2, 4, 8]),
           seed=st.integers(0, 2**16))
    def check(pipeline, n, micro_batch, max_steps, page_size, seed):
        rng = np.random.default_rng(seed)
        q = unit_queries(small_store, rng, n)
        traces = make_traces(pipeline, n, seed=seed % 97)
        kw = dict(params=params, micro_batch=micro_batch,
                  max_steps=max_steps, page_size=page_size)
        rp, sp, respp = _serve(small_index, q, traces, paged=True, **kw)
        rd, sd, respd = _serve(small_index, q, traces, paged=False, **kw)
        _assert_full_parity(rp, respp, rd, respd)
        _assert_kv_drained((rp, sp), (rd, sd))

    check()
