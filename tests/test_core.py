"""TeleRAG core unit tests: IVF, lookahead planner, buffer, cache, budget."""

import numpy as np
import jax.numpy as jnp
import pytest

import repro.core as core
from repro.configs import get_arch
from tests.conftest import unit_queries


def test_probe_matches_bruteforce(small_store, small_index, rng):
    q = unit_queries(small_store, rng, 5)
    ids = core.probe(q, small_index, 8)
    sims = q @ small_index.centroids.T
    for b in range(5):
        expect = set(np.argsort(-sims[b])[:8].tolist())
        assert set(ids[b].tolist()) == expect


def test_paged_layout_roundtrip(small_store, small_index):
    paged = small_index.paged
    # every vector appears exactly once across cluster pages
    seen = []
    for c in range(paged.num_clusters):
        ids = paged.cluster_page_ids(c).reshape(-1)
        ids = ids[ids >= 0]
        seen.append(ids)
        # vectors stored under c must be assigned to c
        assert np.all(small_index.assignments[ids] == c)
        # page content matches source embeddings
        flat = paged.cluster_pages(c).reshape(-1, paged.dim)
        np.testing.assert_allclose(flat[:len(ids)][np.argsort(ids.argsort())],
                                   flat[:len(ids)])
    allv = np.concatenate(seen)
    assert len(allv) == small_store.num_vectors
    assert len(np.unique(allv)) == small_store.num_vectors


def test_plan_prefetch_budget_and_skip_rule(small_index):
    paged = small_index.paged
    ranked = list(range(20))
    budget = int(paged.cluster_bytes(0) * 3.5)
    plan = core.plan_prefetch(ranked, paged, budget_bytes=budget,
                              resident=set(), free_pages=10_000)
    assert plan.bytes_planned <= budget
    # skip-whole-cluster rule: skipped clusters would each have overflowed
    rem = budget
    for c in ranked:
        nb = paged.cluster_bytes(c)
        if c in plan.fetch:
            rem -= nb
        elif c in plan.skipped:
            assert nb > rem or paged.cluster_num_pages[c] > 10_000
    # resident clusters are free
    plan2 = core.plan_prefetch(ranked, paged, budget_bytes=budget,
                               resident={ranked[0]}, free_pages=10_000)
    assert ranked[0] in plan2.resident_hits
    assert plan2.bytes_planned <= budget


def test_batched_plan_shares_clusters(small_index):
    paged = small_index.paged
    ranked = [[1, 2, 3], [1, 2, 4], [1, 5, 6]]
    budget = paged.cluster_bytes(1) * 6
    plan, covered = core.plan_batched_prefetch(ranked, paged,
                                               budget_bytes=budget,
                                               resident=set(),
                                               free_pages=10_000)
    assert plan.fetch.count(1) == 1           # shared cluster fetched once
    assert covered.sum() >= 3                 # all three covered cluster 1


def test_batched_plan_empty_batch(small_index):
    plan, covered = core.plan_batched_prefetch([], small_index.paged,
                                               budget_bytes=10_000,
                                               resident=set(),
                                               free_pages=100)
    assert plan.fetch == [] and plan.skipped == [] and plan.resident_hits == []
    assert plan.bytes_planned == 0 and plan.pages_planned == 0
    assert covered.shape == (0,)


def test_batched_plan_shared_cluster_charged_once(small_index):
    """A cluster every query wants is paid for by exactly one query's
    budget split; the others get it free (§4.2)."""
    paged = small_index.paged
    nb = paged.cluster_bytes(1)
    # total budget = 3 * bytes(1) => per-query split is exactly bytes(1)
    plan, covered = core.plan_batched_prefetch(
        [[1], [1], [1]], paged, budget_bytes=3 * nb,
        resident=set(), free_pages=10_000)
    assert plan.fetch == [1]
    assert plan.bytes_planned == nb              # charged once, not thrice
    assert covered.tolist() == [1, 1, 1]         # but all three covered
    assert plan.skipped == []


def test_batched_plan_skipped_is_unique(small_index):
    """Every query skipping the same over-budget cluster reports it once."""
    plan, covered = core.plan_batched_prefetch(
        [[5], [5], [5]], small_index.paged, budget_bytes=1,
        resident=set(), free_pages=10_000)
    assert plan.fetch == []
    assert plan.skipped == [5]
    assert covered.tolist() == [0, 0, 0]


def test_round_state_never_refetches_across_rounds(small_index):
    """§4.3 incremental prefetch: clusters fetched in an earlier round
    are treated as resident forever after."""
    paged = small_index.paged
    rs = core.RoundState()
    budget = int(paged.cluster_bytes(0) * 4)
    ranked = list(range(8))
    p1 = rs.incremental_plan(ranked, paged, budget_bytes=budget,
                             resident=set(), free_pages=10_000)
    assert p1.fetch                               # round one fetches
    p2 = rs.incremental_plan(ranked, paged, budget_bytes=budget,
                             resident=set(), free_pages=10_000)
    assert not set(p2.fetch) & set(p1.fetch)      # no re-fetch
    assert set(p1.fetch) <= set(p2.resident_hits)
    # a drifted ranking still tops up only the missing clusters
    fetched_before = set(rs.fetched)
    p3 = rs.incremental_plan(list(range(4, 12)), paged, budget_bytes=budget,
                             resident=set(), free_pages=10_000)
    assert not set(p3.fetch) & fetched_before
    assert rs.round == 3


def test_buffer_load_evict_consistency(small_index):
    buf = core.PrefetchBuffer(small_index.paged, num_pages=64)
    loaded, rejected = buf.load_clusters([0, 1, 2])
    assert loaded == [0, 1, 2] and not rejected
    used = buf.used_pages
    assert used == sum(int(small_index.paged.cluster_num_pages[c])
                       for c in (0, 1, 2))
    # evict then ensure the device mask excludes it after flush
    buf.evict_clusters([1])
    buf.flush_invalidations()
    pc = np.asarray(buf.page_cluster)
    assert not np.any(pc == 1)
    assert buf.free_pages() == 64 - used + int(
        small_index.paged.cluster_num_pages[1])
    # refetch into (possibly different) slots; no duplicate pages
    buf.load_clusters([1])
    pc = np.asarray(buf.page_cluster)
    assert (pc == 1).sum() == int(small_index.paged.cluster_num_pages[1])


def test_buffer_rejects_whole_cluster_when_full(small_index):
    npg0 = int(small_index.paged.cluster_num_pages[0])
    buf = core.PrefetchBuffer(small_index.paged, num_pages=npg0)
    loaded, rejected = buf.load_clusters([0])
    assert loaded == [0]
    loaded, rejected = buf.load_clusters([1])
    assert rejected == [1] and 1 not in buf.resident


def test_cache_eq6_hotness():
    cache = core.ClusterCache(core.CacheConfig(decay=2.0, h_init=1.0,
                                               h_inc=1.0))
    cache.on_fetched([1, 2])
    cache.round_update([1])            # 1 used, 2 not
    assert cache.hotness[1] == pytest.approx(1.0 / 2 + 1.0)
    assert cache.hotness[2] == pytest.approx(0.5)
    cache.round_update([])
    assert cache.hotness[1] == pytest.approx(0.75)


def test_cache_consolidate_quota(small_index):
    buf = core.PrefetchBuffer(small_index.paged, num_pages=64)
    cache = core.ClusterCache(core.CacheConfig(fraction=0.25))
    buf.load_clusters(list(range(8)))
    cache.on_fetched(range(8))
    cache.round_update([0, 1])
    cache.consolidate(buf)
    assert buf.used_pages <= cache.quota_pages(buf)
    # hottest survive
    if buf.resident:
        assert 0 in buf.resident or 1 in buf.resident


def test_cache_hotness_keys_subset_of_resident(small_index):
    """Invariant the single-pass consolidate relies on: hotness keys are
    always ⊆ resident ∪ just-fetched (every key enters via on_fetched
    and leaves with its cluster's eviction)."""
    buf = core.PrefetchBuffer(small_index.paged, num_pages=64)
    cache = core.ClusterCache(core.CacheConfig(fraction=0.25))
    rng = np.random.default_rng(4)
    for rnd in range(6):
        want = [int(c) for c in rng.choice(16, size=4, replace=False)]
        loaded, rejected = buf.load_clusters(want)
        cache.on_fetched(loaded)                 # never the rejects
        just_fetched = set(loaded)
        assert set(cache.hotness) <= (buf.resident_clusters()
                                      | just_fetched)
        cache.round_update(loaded[:2])
        if rnd % 2:
            cache.make_room(buf, pages_needed=buf.num_pages // 2)
        else:
            cache.consolidate(buf)
        assert set(cache.hotness) <= buf.resident_clusters()


def test_invalidation_only_flush_is_not_a_transfer_round(small_index):
    """flush_invalidations() scatters zero new pages — it must not count
    as an H2D transfer round (or move any bytes) in TransferStats."""
    buf = core.PrefetchBuffer(small_index.paged, num_pages=64)
    buf.load_clusters([0, 1, 2])
    assert buf.stats.rounds == 1
    bytes_before = buf.stats.bytes_h2d
    buf.evict_clusters([1])
    buf.flush_invalidations()                    # pure invalidation scatter
    assert buf.stats.rounds == 1
    assert buf.stats.bytes_h2d == bytes_before
    assert buf.stats.pages_h2d == buf.stats.bytes_h2d // buf.page_nbytes
    # device consistency still holds: evicted cluster unsearchable
    assert not np.any(np.asarray(buf.page_cluster) == 1)
    # a real load folding queued invalidations still counts exactly once
    buf.evict_clusters([2])
    buf.load_clusters([3])
    assert buf.stats.rounds == 2


def test_budget_case1_and_headroom():
    cfg = get_arch("llama3-8b")
    hw = core.TPU_V5E
    b = core.optimal_budget(cfg, hw, gen_tokens=[100], batch=1, chips=8,
                            hbm_headroom_bytes=5e9)
    t_llm = core.generation_window_seconds(cfg, hw, gen_tokens=[100],
                                           batch=1, chips=8)
    assert b == min(int(hw.host_link_bw * t_llm), int(5e9))
    # rwkv decodes faster per token => smaller window => smaller budget
    b_rwkv = core.optimal_budget(get_arch("rwkv6-3b"), hw, gen_tokens=[100],
                                 batch=1, chips=8, hbm_headroom_bytes=5e9)
    assert b_rwkv <= b


def test_budget_case2_interior_minimum():
    # a steep miss-rate curve rewards prefetching past the window
    fn = core.empirical_miss_curve([0, 1e9, 2e9, 4e9], [0.0, 0.8, 0.97, 1.0])
    b2 = core.case2_budget(fn, link_bw=64e9, nprobe=256, t_cc=2e-3,
                           b_max=4e9)
    assert b2 is not None and 0 < b2 <= 4e9


def test_hybrid_retrieve_bruteforce(small_store, small_index, rng):
    q = unit_queries(small_store, rng, 6)
    ranked = core.probe(q, small_index, 12)
    buf = core.PrefetchBuffer(small_index.paged, num_pages=256)
    plan, _ = core.plan_batched_prefetch(
        list(core.probe(q, small_index, 24)), small_index.paged,
        budget_bytes=80 * small_index.paged.page_nbytes(),
        resident=set(), free_pages=buf.free_pages())
    buf.load_clusters(plan.fetch)
    res = core.hybrid_retrieve(buf, q, ranked, k=5, kernel_mode="ref")
    for b in range(len(q)):
        allowed = set(int(c) for c in ranked[b])
        mask = np.isin(small_index.assignments, list(allowed))
        sims = small_store.embeddings[mask] @ q[b]
        ids = np.where(mask)[0]
        expect = set(ids[np.argsort(-sims)[:5]].tolist())
        got = set(int(x) for x in res.doc_ids[b] if x >= 0)
        assert got == expect, (b, got, expect)


def test_overlap_decreases_with_sigma(small_store, small_index, rng):
    q = unit_queries(small_store, rng, 16)
    covs = []
    for sigma in (0.05, 0.3, 0.8):
        qo = core.synthetic_rewrite(q, sigma, np.random.default_rng(1))
        covs.append(core.coverage(small_index, q, qo, 8))
    assert covs[0] > covs[1] > covs[2]
    assert core.coverage(small_index, q, q.copy(), 8) == 1.0
