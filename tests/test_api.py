"""Unified serving front-end: TeleRAGServer facade, typed
request/response lifecycle, cross-replica continuous dispatch, and the
legacy shims.

Pins the redesign's acceptance contract:
  * simultaneous arrivals reproduce the legacy serial
    ``run_global_batch`` drain — doc ids exactly, round telemetry to
    1e-6 — with multiple micro-batches per replica;
  * staggered arrivals interleave replica work on ONE shared event
    clock (impossible under the old one-replica-at-a-time drain);
  * per-request arrival→complete latency is monotone in offered load;
  * results come back in submission order everywhere;
  * the deprecated shims warn and agree with the server.
"""

import warnings

import numpy as np
import pytest

from repro.configs import get_arch
from repro.core.ivf import probe
from repro.core.schedulers import TeleRAGScheduler
from repro.serving import (EngineConfig, GlobalBatchReport,
                           MultiReplicaOrchestrator, PipelineExecutor,
                           RagRequest, RequestState, TeleRAGEngine,
                           TeleRAGServer, make_traces)
from repro.serving.trace import RequestTrace, StageTrace
from tests.conftest import unit_queries

TELEMETRY_FIELDS = ("round_index", "batch", "gen_tokens", "t_llm_window",
                    "bytes_prefetched", "t_prefetch", "hits", "misses",
                    "t_host_search", "t_dev_search", "t_merge")


def _cfg(seed=5, **kw):
    defaults = dict(nprobe=16, top_k=3, buffer_pages=200, lookahead_rank=32,
                    kernel_mode="ref", chips=8, cache_enabled=True,
                    seed=seed)
    defaults.update(kw)
    return EngineConfig(**defaults)


def _legacy_serial_global_batch(index, cfg, arch, n_replicas, q_in, traces,
                                micro_batch):
    """The pre-redesign ``run_global_batch``: route once, then drain one
    replica at a time through per-replica lockstep executors.  Kept here
    as the oracle the continuous dispatcher must reproduce for
    simultaneous arrivals."""
    engines = [TeleRAGEngine(index, cfg, arch) for _ in range(n_replicas)]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        execs = [PipelineExecutor(e) for e in engines]
    sched = TeleRAGScheduler()
    groups = sched.group(q_in, micro_batch)
    nprobe_sched = min(64, index.num_clusters)
    batch_clusters = []
    for g in groups:
        ranked = probe(q_in[g], index, nprobe_sched)
        batch_clusters.append(set(int(c) for r in ranked for c in r))
    caches = [e.buffer.resident_clusters() for e in engines]
    occupancy = [e.ledger.occupancy() for e in engines]
    assigns = sched.assign(batch_clusters, caches, occupancy=occupancy)
    by_id = {}
    for a in assigns:
        g = groups[a.batch_index]
        res = execs[a.replica].execute_batch(q_in[g],
                                             [traces[i] for i in g])
        for i, r in zip(g, res):
            by_id[traces[i].request_id] = (r, a.replica)
    return by_id


# ---------------------------------------------------------------------------
# Acceptance: simultaneous arrivals == legacy serial drain, to 1e-6
# ---------------------------------------------------------------------------


def test_simultaneous_arrivals_match_legacy_serial_drain(
        small_store, small_index, rng):
    """12 requests, micro-batch 2, 2 replicas => 3 micro-batches per
    replica: the continuous dispatcher serializes within each replica
    (with end_batch between batches) while replicas interleave, so doc
    ids and round telemetry must reproduce the legacy drain exactly."""
    q = unit_queries(small_store, rng, 12)
    traces = make_traces("iter", 12, seed=11)
    legacy = _legacy_serial_global_batch(
        small_index, _cfg(), get_arch("llama3-8b"), 2, q, traces, 2)

    srv = TeleRAGServer(small_index, _cfg(), 2, get_arch("llama3-8b"),
                        scheduler=TeleRAGScheduler(), micro_batch=2)
    resp = srv.serve([RagRequest(q=q[i], trace=traces[i])
                      for i in range(12)])
    assert len(resp) == 12
    assert {r.replica for r in resp} == {0, 1}
    per_replica_batches = {}
    for r in resp:
        per_replica_batches.setdefault(r.replica, set()).add(r.admit_t)
    assert max(len(v) for v in per_replica_batches.values()) >= 3

    for r in resp:
        ref, ref_replica = legacy[r.request_id]
        assert r.replica == ref_replica
        assert len(r.doc_ids) == len(ref.doc_ids)
        for got, want in zip(r.doc_ids, ref.doc_ids):
            np.testing.assert_array_equal(got, want)
        assert len(r.rounds) == len(ref.rounds)
        for got, want in zip(r.rounds, ref.rounds):
            for f in TELEMETRY_FIELDS:
                assert getattr(got, f) == pytest.approx(getattr(want, f),
                                                        abs=1e-6), f


def test_run_global_batch_shim_matches_server_and_warns(
        small_store, small_index, rng):
    q = unit_queries(small_store, rng, 8)
    traces = make_traces("hyde", 8, seed=3)
    srv = TeleRAGServer(small_index, _cfg(seed=2), 2, get_arch("llama3-8b"),
                        scheduler=TeleRAGScheduler(), micro_batch=4)
    resp = srv.serve([RagRequest(q=q[i], trace=traces[i]) for i in range(8)])

    orch = MultiReplicaOrchestrator(small_index, _cfg(seed=2), 2,
                                    get_arch("llama3-8b"))
    with pytest.warns(DeprecationWarning):
        rep = orch.run_global_batch(q, make_traces("hyde", 8, seed=3),
                                    micro_batch=4)
    results = rep.all_results()
    assert [r.request_id for r in results] == [r.request_id for r in resp]
    for a, b in zip(resp, results):
        for got, want in zip(a.doc_ids, b.doc_ids):
            np.testing.assert_array_equal(got, want)
        for got, want in zip(a.rounds, b.rounds):
            for f in TELEMETRY_FIELDS:
                assert getattr(got, f) == pytest.approx(getattr(want, f),
                                                        abs=1e-6), f
    # report bookkeeping survives the shim translation
    assert sorted(a[0] for a in rep.assignments) == [0, 1]
    assert rep.schedule_overhead_s >= 0
    assert len(rep.records) == 8


def test_shim_respects_server_level_mark_dead(small_store, small_index,
                                              rng):
    """A replica mark_dead()ed on the server stays dead through the
    legacy shim even when the call passes no dead_replicas."""
    q = unit_queries(small_store, rng, 8)
    orch = MultiReplicaOrchestrator(small_index, _cfg(seed=6), 2,
                                    get_arch("llama3-8b"))
    orch.server.mark_dead(1)
    with pytest.warns(DeprecationWarning):
        rep = orch.run_global_batch(q, make_traces("hyde", 8, seed=5),
                                    micro_batch=4)
    assert all(a[1] != 1 for a in rep.assignments)
    assert len(rep.all_results()) == 8
    assert orch.server.dead == {1}          # per-call state restored


def test_pipeline_executor_is_deprecated(small_index):
    eng = TeleRAGEngine(small_index, _cfg(), get_arch("llama3-8b"))
    with pytest.warns(DeprecationWarning):
        PipelineExecutor(eng)


# ---------------------------------------------------------------------------
# Acceptance: staggered arrivals interleave replicas on ONE clock
# ---------------------------------------------------------------------------


def _long_gen_trace(request_id, gen_tokens=4000):
    return RequestTrace(pipeline="hyde", request_id=request_id,
                        stages=[StageTrace("generate", gen_tokens),
                                StageTrace("retrieve"),
                                StageTrace("generate", 8)],
                        rewrite_sigma=0.1)


def test_staggered_arrivals_interleave_replicas_on_shared_clock(
        small_store, small_index):
    """Wave B arrives mid-way through wave A's generation window and
    runs on the OTHER replica: B's spans must lie inside A's — overlap
    on the shared clock that the old serial drain could never express
    (it admitted every wave of a call at its own replica-local zero and
    blocked between calls)."""
    cfg = _cfg(seed=7, buffer_pages=512, cache_enabled=False)
    srv = TeleRAGServer(small_index, cfg, 2, get_arch("llama3-8b"))
    cents = small_index.centroids / np.linalg.norm(
        small_index.centroids, axis=-1, keepdims=True)
    qa = cents[:2].astype(np.float32)
    qb = cents[-2:].astype(np.float32)
    t_llm = srv.engines[0].llm_window_seconds(4000, 2)
    assert t_llm > 0
    mid = 0.5 * t_llm

    reqs = ([RagRequest(q=qa[i], trace=_long_gen_trace(i)) for i in range(2)]
            + [RagRequest(q=qb[i], trace=_long_gen_trace(10 + i),
                          arrival_t=mid) for i in range(2)])
    resp = srv.serve(reqs)
    a_resp, b_resp = resp[:2], resp[2:]
    assert all(r.state == RequestState.COMPLETE for r in resp)
    # round-robin routing: the two waves land on different replicas
    assert {r.replica for r in a_resp} == {0}
    assert {r.replica for r in b_resp} == {1}
    # B admitted at its true arrival time, while A was still running
    for b in b_resp:
        assert b.admit_t == pytest.approx(mid)
        assert b.queue_s == pytest.approx(0.0, abs=1e-9)
    assert all(a.complete_t > mid for a in a_resp)
    # cross-replica overlap as interval intersection on the one clock
    a_spans = [s for a in a_resp for s in a.timeline if s.end > s.start]
    b_spans = [s for b in b_resp for s in b.timeline if s.end > s.start]
    hits = [(sa, sb) for sa in a_spans for sb in b_spans
            if sa.overlaps(sb.start, sb.end)]
    assert hits, (a_spans, b_spans)
    # and replica-B work STARTED strictly inside a replica-A span
    assert any(sa.start < sb.start < sa.end for sa, sb in hits)


def test_latency_monotone_in_offered_load(small_store, small_index, rng):
    """Same request stream at shrinking inter-arrival spacing: the data
    ops are identical (same batches, same replicas), so arrival→complete
    latency can only grow with offered load — queueing is real."""
    q = unit_queries(small_store, rng, 6)
    means = []
    for spacing in (100.0, 0.01, 0.0):
        srv = TeleRAGServer(small_index, _cfg(seed=3, cache_enabled=False),
                            2, get_arch("llama3-8b"), micro_batch=1)
        traces = make_traces("hyde", 6, seed=9)
        resp = srv.serve([RagRequest(q=q[i], trace=traces[i],
                                     arrival_t=i * spacing)
                          for i in range(6)])
        assert all(r.state == RequestState.COMPLETE for r in resp)
        means.append(float(np.mean([r.latency_s for r in resp])))
    assert means[0] <= means[1] + 1e-9 <= means[2] + 2e-9
    # saturation genuinely queues: simultaneous arrivals wait for slots
    assert means[2] > means[0]


# ---------------------------------------------------------------------------
# Submission-order guarantees
# ---------------------------------------------------------------------------


def test_global_batch_report_all_results_submission_order():
    """all_results() must not depend on replica-dict iteration order."""
    from repro.serving import RequestResult
    r = {i: RequestResult(i, "hyde") for i in range(4)}
    rep = GlobalBatchReport(
        per_replica_results={1: [r[2], r[0]], 0: [r[3], r[1]]},
        schedule_overhead_s=0.0, assignments=[],
        submission_ids=[0, 1, 2, 3])
    assert [x.request_id for x in rep.all_results()] == [0, 1, 2, 3]


def test_server_drain_returns_submission_order(small_store, small_index,
                                               rng):
    """Later-submitted requests can arrive (and finish) earlier; the
    drain still answers in submission order."""
    q = unit_queries(small_store, rng, 4)
    traces = make_traces("hyde", 4, seed=13)
    srv = TeleRAGServer(small_index, _cfg(cache_enabled=False), 2,
                        get_arch("llama3-8b"), micro_batch=1)
    # reverse arrival order: request 0 arrives last
    resp = srv.serve([RagRequest(q=q[i], trace=traces[i],
                                 arrival_t=(3 - i) * 0.5)
                      for i in range(4)])
    assert [r.request_id for r in resp] == [t.request_id for t in traces]
    assert resp[3].complete_t < resp[0].complete_t


# ---------------------------------------------------------------------------
# Decode hook + unified telemetry
# ---------------------------------------------------------------------------


def test_decode_hook_fires_per_round_and_prefetch_dispatches_once(
        small_store, small_index, rng):
    """The serve drivers' real decode runs through the hook INSIDE the
    round frontier: prefetch is dispatched exactly once (by the policy),
    so H2D bytes match a hook-less run byte for byte — the legacy driver
    double-prefetched by calling eng.lookahead() manually first."""
    q = unit_queries(small_store, rng, 3)
    traces = make_traces("iter", 3, seed=21)

    srv0 = TeleRAGServer(small_index, _cfg(seed=4), 1,
                         get_arch("llama3-8b"))
    srv0.serve([RagRequest(q=q[i], trace=traces[i]) for i in range(3)])
    baseline_h2d = srv0.engines[0].buffer.stats.bytes_h2d

    calls = []
    srv = TeleRAGServer(small_index, _cfg(seed=4), 1, get_arch("llama3-8b"),
                        decode_hook=lambda r, recs, toks, rnd:
                        calls.append((r, len(recs), tuple(toks), rnd)))
    resp = srv.serve([RagRequest(q=q[i], trace=traces[i]) for i in range(3)])
    n_frontiers = max(len(r.rounds) for r in resp)
    assert len(calls) == n_frontiers
    assert [c[3] for c in calls] == list(range(n_frontiers))
    assert srv.engines[0].buffer.stats.bytes_h2d == baseline_h2d


def test_server_telemetry_unifies_replica_counters(small_store, small_index,
                                                   rng):
    q = unit_queries(small_store, rng, 6)
    traces = make_traces("hyde", 6, seed=17)
    srv = TeleRAGServer(small_index, _cfg(), 2, get_arch("llama3-8b"),
                        scheduler=TeleRAGScheduler(), micro_batch=3)
    srv.serve([RagRequest(q=q[i], trace=traces[i]) for i in range(6)])
    tele = srv.telemetry()
    assert tele.completed == 6
    assert tele.dispatched_batches >= 2
    assert tele.bytes_h2d == sum(e.buffer.stats.bytes_h2d
                                 for e in srv.engines)
    for rt, eng in zip(tele.replicas, srv.engines):
        assert rt.ledger == eng.ledger.snapshot()
        assert rt.admission == eng.admission.stats
        assert rt.admission is not eng.admission.stats   # a snapshot copy
        assert 0.0 <= rt.occupancy <= 1.0
        assert rt.transfers == len(eng.transfer.events)
    s = tele.summary()
    assert "server:" in s and "replica 0" in s and "replica 1" in s


def test_response_lifecycle_fields(small_store, small_index, rng):
    """RagResponse decomposes arrival→complete into queue + service and
    its breakdown sums the timeline spans; deadlines are stamped."""
    q = unit_queries(small_store, rng, 2)
    traces = make_traces("hyde", 2, seed=19)
    srv = TeleRAGServer(small_index, _cfg(cache_enabled=False), 1,
                        get_arch("llama3-8b"), micro_batch=1)
    resp = srv.serve([
        RagRequest(q=q[0], trace=traces[0], deadline_s=1e-9),
        RagRequest(q=q[1], trace=traces[1], deadline_s=1e6)])
    for r in resp:
        assert r.latency_s == pytest.approx(r.queue_s + r.service_s)
        bd = r.breakdown()
        assert bd["queue"] == pytest.approx(r.queue_s)
        assert bd.get("generate", 0) > 0 and bd.get("retrieve", 0) > 0
    assert resp[0].deadline_missed and not resp[1].deadline_missed


def test_failed_drain_returns_undispatched_work_to_inbox(
        small_store, small_index, rng):
    """A drain that dies before dispatch (every replica dead) must not
    swallow the submitted requests: after recovery a retry serves them."""
    q = unit_queries(small_store, rng, 3)
    traces = make_traces("hyde", 3, seed=23)
    srv = TeleRAGServer(small_index, _cfg(), 1, get_arch("llama3-8b"))
    srv.mark_dead(0)
    for i in range(3):
        srv.submit(RagRequest(q=q[i], trace=traces[i]))
    with pytest.raises(RuntimeError, match="no healthy replicas"):
        srv.drain()
    srv.mark_alive(0)
    resp = srv.drain()
    assert [r.request_id for r in resp] == [t.request_id for t in traces]
    assert all(r.state == RequestState.COMPLETE for r in resp)


def test_pipeline_name_synthesizes_trace(small_store, small_index, rng):
    q = unit_queries(small_store, rng, 2)
    srv = TeleRAGServer(small_index, _cfg(), 1, get_arch("llama3-8b"))
    resp = srv.serve([RagRequest(q=q[i], pipeline="hyde")
                      for i in range(2)])
    assert all(r.pipeline == "hyde" and len(r.rounds) == 1 for r in resp)
    with pytest.raises(ValueError):
        RagRequest(q=q[0])
