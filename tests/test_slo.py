"""Multi-tenant SLO-aware serving: deadline-driven dispatch, per-tenant
pool reservations, and the per-tenant telemetry contract.

Pins the PR-4 acceptance criteria:
  * scheduling is demonstrably SLO-aware — the identical workload with
    swapped priorities produces a different admission/dispatch order
    AND a different deadline-miss count;
  * a tenant's guaranteed page floor is never violated by another
    tenant's burst (reservation accounting AND the spill path);
  * ``ServerTelemetry`` per-tenant deadline counters match the
    per-response ``deadline_missed`` / ``deadline_missed_in_queue``
    flags exactly;
  * a round whose members are already past deadline demotes its
    lookahead prefetch (no pool pages, no link bytes);
  * single-tenant defaults leave dispatch order unchanged (the legacy
    shim equivalence in tests/test_api.py rides on this).
"""

import numpy as np
import pytest

from repro.core.schedulers import (EdfDispatch, FifoDispatch,
                                   assign_to_replicas)
from repro.memory import AdmissionController, DevicePagePool
from repro.serving import (EngineConfig, RagRequest, RequestState,
                           TeleRAGEngine, TeleRAGServer, make_traces)
from repro.configs import get_arch
from tests.conftest import unit_queries


def _cfg(**kw):
    defaults = dict(nprobe=16, top_k=3, buffer_pages=200, lookahead_rank=32,
                    kernel_mode="ref", chips=8, cache_enabled=False, seed=5)
    defaults.update(kw)
    return EngineConfig(**defaults)


def _solo_latency(small_index, q, trace):
    srv = TeleRAGServer(small_index, _cfg(), 1, get_arch("llama3-8b"))
    return srv.serve([RagRequest(q=q, trace=trace)])[0].latency_s


# ---------------------------------------------------------------------------
# Deadline/priority-aware dispatch ordering
# ---------------------------------------------------------------------------


def test_swapped_priorities_change_dispatch_order_and_miss_count(
        small_store, small_index, rng):
    """Two simultaneous requests, one replica, micro_batch=1: request A
    carries a deadline only one of them can meet (~1.5x solo service).
    When A outranks B it dispatches first and meets its deadline; the
    identical workload with priorities swapped dispatches B first and A
    misses — same data ops, different order, different miss count."""
    q = unit_queries(small_store, rng, 2)
    traces = make_traces("hyde", 2, seed=31)
    solo_a = _solo_latency(small_index, q[0], traces[0])
    solo_b = _solo_latency(small_index, q[1], traces[1])
    # met when A serves first (~solo_a), missed when it waits behind B
    # (~solo_b + solo_a)
    deadline = solo_a + 0.5 * solo_b

    def serve(prio_a, prio_b):
        srv = TeleRAGServer(small_index, _cfg(), 1, get_arch("llama3-8b"),
                            micro_batch=1)
        resp = srv.serve([
            RagRequest(q=q[0], trace=traces[0], priority=prio_a,
                       deadline_s=deadline),
            RagRequest(q=q[1], trace=traces[1], priority=prio_b)])
        assert all(r.state == RequestState.COMPLETE for r in resp)
        return resp, srv.telemetry()

    fast, tele_fast = serve(prio_a=0, prio_b=1)       # A outranks B
    slow, tele_slow = serve(prio_a=1, prio_b=0)       # swapped

    # different dispatch (admission) order on the replica
    assert fast[0].admit_t < fast[1].admit_t
    assert slow[0].admit_t > slow[1].admit_t
    # ... and a different miss count for the identical workload
    assert not fast[0].deadline_missed
    assert slow[0].deadline_missed
    assert tele_fast.deadline_missed == 0
    assert tele_slow.deadline_missed == 1


def test_edf_orders_by_deadline_within_priority_class(
        small_store, small_index, rng):
    """Three same-priority requests in one wave, served one at a time:
    EDF dispatches tightest deadline first regardless of submission
    order; FifoDispatch preserves submission order on the same stream."""
    q = unit_queries(small_store, rng, 3)
    traces = make_traces("hyde", 3, seed=37)
    deadlines = [30.0, 10.0, 20.0]          # submission order != EDF order

    def admit_order(dispatch):
        srv = TeleRAGServer(small_index, _cfg(), 1, get_arch("llama3-8b"),
                            micro_batch=1, dispatch=dispatch)
        resp = srv.serve([RagRequest(q=q[i], trace=traces[i],
                                     deadline_s=deadlines[i])
                          for i in range(3)])
        return [r.request_id for r in sorted(resp, key=lambda r: r.admit_t)]

    ids = [t.request_id for t in traces]
    assert admit_order(EdfDispatch()) == [ids[1], ids[2], ids[0]]
    assert admit_order(FifoDispatch()) == ids


def test_default_dispatch_without_deadlines_is_legacy_order(
        small_store, small_index, rng):
    """No deadlines anywhere: the default EdfDispatch must reproduce the
    legacy (priority, FIFO) dispatch order exactly — this is what keeps
    the deprecated shims pinned equivalent."""
    q = unit_queries(small_store, rng, 4)
    traces = make_traces("hyde", 4, seed=41)
    prios = [1, 0, 1, 0]
    srv = TeleRAGServer(small_index, _cfg(), 1, get_arch("llama3-8b"),
                        micro_batch=1)
    resp = srv.serve([RagRequest(q=q[i], trace=traces[i], priority=prios[i])
                      for i in range(4)])
    got = [r.request_id for r in sorted(resp, key=lambda r: r.admit_t)]
    want = [traces[i].request_id for i in (1, 3, 0, 2)]  # prio, then FIFO
    assert got == want


# ---------------------------------------------------------------------------
# Per-tenant pool reservations
# ---------------------------------------------------------------------------


def test_tenant_floor_survives_other_tenants_burst(small_index):
    """Reservation accounting: tenant B bursting to everything it can
    see must leave tenant A's unclaimed floor reservable."""
    pool = DevicePagePool(small_index.paged, 32)
    pool.set_tenant_share("lat", floor_pages=8)
    # B can never see A's unclaimed floor
    assert pool.reservable_pages_for("batch") == 24
    assert pool.reserve(25, "burst", tenant="batch") is None
    res_b = pool.reserve(24, "burst", tenant="batch")
    assert res_b is not None
    assert pool.reservable_pages_for("batch") == 0
    # A's floor is still fully claimable, burst or no burst
    assert pool.reservable_pages_for("lat") == 8
    res_a = pool.reserve(8, "floor", tenant="lat")
    assert res_a is not None
    # with the floor claimed, nothing is withheld anymore
    assert pool.withheld_floor_pages("batch") == 0


def test_tenant_burst_cap_bounds_total_hold(small_index):
    """max_pages caps a tenant's leases+reservations even when the pool
    has free pages left."""
    pool = DevicePagePool(small_index.paged, 32)
    pool.set_tenant_share("batch", floor_pages=0, max_pages=12)
    lease = pool.lease_slots(8, "prefetch", tenant="batch")
    assert lease is not None
    assert pool.reservable_pages_for("batch") == 4
    assert pool.reserve(5, "b2", tenant="batch") is None
    assert pool.reserve(4, "b2", tenant="batch") is not None
    # an uncapped tenant still sees the remaining free pages
    assert pool.reservable_pages_for("other") == 32 - 12


def test_request_above_tenant_ceiling_caps_instead_of_parking(
        small_index):
    """A plan that exceeds what the tenant could EVER reserve (its
    burst cap) must take a capped grant immediately — parking would
    starve it on page-free retries no future free can satisfy — while
    a reachable request under the same pressure still parks."""
    pool = DevicePagePool(small_index.paged, 32)
    pool.set_tenant_share("batch", floor_pages=0, max_pages=12)
    adm = AdmissionController(pool)
    # a KV lease creates pressure AND a future page-free event
    kv = pool.lease_bytes(24 * pool.page_nbytes, "kv")
    assert kv is not None and adm.holds_pending_release()
    # reachable (10 <= cap 12) but blocked: parks as before
    assert adm.admit(10, "w1", can_wait=True, tenant="batch") is None
    # unreachable (20 > cap 12): caps NOW with everything available
    t = adm.admit(20, "w2", can_wait=True, tenant="batch")
    assert t is not None and t.capped
    assert t.pages_granted == min(pool.free_pages(), 12)
    assert adm.per_tenant["batch"].capped == 1


def test_spill_never_evicts_under_floor_tenants_residency(
        small_store, small_index):
    """The admission spill path: tenant "batch" needs room, tenant
    "lat" holds residency at/below its floor — spill must take its
    victims from the over-floor tenant only."""
    cfg = _cfg(buffer_pages=40, pool_pages=40, cache_enabled=True,
               tenant_shares={"lat": (10, None)})
    eng = TeleRAGEngine(small_index, cfg, get_arch("llama3-8b"))
    paged = small_index.paged
    # residency: "lat" holds a few clusters (<= floor), "batch" many
    lat_clusters, batch_clusters, pages = [], [], 0
    for c in range(small_index.num_clusters):
        npg = int(paged.cluster_num_pages[c])
        if pages + npg > 36:
            break
        tenant = "lat" if eng.pool.tenant_pages("lat") + npg <= 10 \
            else "batch"
        res = eng.pool.reserve(npg, f"c{c}", tenant=tenant)
        if res is None:
            break
        loaded, _ = eng.buffer.load_clusters([c], reservation=res)
        eng.pool.cancel(res)
        assert loaded == [c]
        eng.cache.on_fetched([c])
        (lat_clusters if tenant == "lat" else batch_clusters).append(c)
        pages += npg
    assert lat_clusters and batch_clusters
    lat_before = set(lat_clusters) & eng.buffer.resident_clusters()
    assert eng.pool.tenant_pages("lat") <= 10

    # batch asks for more than is free -> admission must spill
    ticket = eng.admission.admit(eng.pool.free_pages() + 4, "burst",
                                 can_wait=False, tenant="batch")
    assert ticket.spilled_pages > 0 or ticket.capped
    # every "lat" cluster is still resident; victims came from "batch"
    assert lat_before <= eng.buffer.resident_clusters()
    assert set(batch_clusters) - eng.buffer.resident_clusters()


def test_spill_stops_at_an_over_floor_tenants_floor(small_store,
                                                    small_index):
    """An over-floor tenant exposes only its excess as spill victims:
    eviction on another tenant's behalf never pulls it below its
    guaranteed floor (the protect set is per-page, not all-or-nothing)."""
    cfg = _cfg(buffer_pages=40, pool_pages=40, cache_enabled=True,
               tenant_shares={"lat": (6, None)})
    eng = TeleRAGEngine(small_index, cfg, get_arch("llama3-8b"))
    paged = small_index.paged
    # "lat" bursts OVER its 6-page floor
    pages = 0
    for c in range(small_index.num_clusters):
        npg = int(paged.cluster_num_pages[c])
        if pages + npg > 16:
            break
        res = eng.pool.reserve(npg, f"c{c}", tenant="lat")
        if res is None:
            break
        loaded, _ = eng.buffer.load_clusters([c], reservation=res)
        eng.pool.cancel(res)
        assert loaded == [c]
        eng.cache.on_fetched([c])
        pages += npg
    assert eng.pool.tenant_pages("lat") > 6
    # batch demands everything: spill may take lat's excess, not floor
    eng.admission.admit(eng.pool.num_pages, "burst", can_wait=False,
                        tenant="batch")
    assert eng.pool.tenant_pages("lat") >= 6
    # the O(1) running counters agree with a full scan after the churn
    for t in ("lat", "batch"):
        slow = (sum(l.num_pages for l in eng.pool.leases.values()
                    if l.tenant == t)
                + sum(r.pages for r in eng.pool.reservations.values()
                      if r.tenant == t))
        assert eng.pool.tenant_pages(t) == slow


def test_snapshot_restore_carries_per_tenant_admission_stats(
        small_index):
    """Replica restart must not zero the per-tenant admission slices
    (the PR-3 aggregate-stats guarantee, extended to tenants)."""
    cfg = _cfg(tenant_shares={"lat": (8, None)})
    eng = TeleRAGEngine(small_index, cfg, get_arch("llama3-8b"))
    eng.admission.admit(4, "w", can_wait=False, tenant="lat")
    before = dict(eng.admission.per_tenant)
    assert before["lat"].admitted == 1
    eng.restore(eng.snapshot())
    assert eng.admission.per_tenant == before
    assert eng.admission.per_tenant["lat"].admitted == 1


# ---------------------------------------------------------------------------
# Telemetry contract
# ---------------------------------------------------------------------------


def test_tenant_telemetry_counters_match_response_flags(
        small_store, small_index, rng):
    """Per-tenant deadline counters are exactly the sums of the
    per-response flags, and the attainment/miss-in-service identities
    hold."""
    q = unit_queries(small_store, rng, 8)
    traces = make_traces("hyde", 8, seed=47)
    srv = TeleRAGServer(small_index, _cfg(), 2, get_arch("llama3-8b"),
                        micro_batch=1)
    solo = _solo_latency(small_index, q[0], traces[0])
    reqs = []
    for i in range(8):
        tenant = "lat" if i % 2 == 0 else "batch"
        # tight deadlines on the lat tenant guarantee a mix of hits+misses
        deadline = solo * (0.5 if i in (0, 2) else 20.0) \
            if tenant == "lat" else None
        reqs.append(RagRequest(q=q[i], trace=traces[i], tenant=tenant,
                               deadline_s=deadline, arrival_t=0.001 * i))
    resp = srv.serve(reqs)
    tele = srv.telemetry()
    assert {t.tenant for t in tele.tenants} == {"lat", "batch"}
    for name in ("lat", "batch"):
        sub = [r for r in resp if r.tenant == name]
        t = tele.tenant(name)
        assert t.completed == len(sub)
        assert t.deadline_missed == sum(r.deadline_missed for r in sub)
        assert t.missed_in_queue == sum(r.deadline_missed_in_queue
                                        for r in sub)
        assert t.with_deadline == sum(r.deadline_s is not None for r in sub)
        assert t.missed_in_service == t.deadline_missed - t.missed_in_queue
        if t.with_deadline:
            assert t.attainment == pytest.approx(
                1.0 - t.deadline_missed / t.with_deadline)
    assert tele.tenant("lat").deadline_missed >= 1   # the tight ones
    assert tele.deadline_missed == sum(r.deadline_missed for r in resp)
    # a missed-in-queue response is by definition also missed overall
    for r in resp:
        if r.deadline_missed_in_queue:
            assert r.deadline_missed
    # tenant lines show up in the printable summary
    s = tele.summary()
    assert "tenant lat:" in s and "tenant batch:" in s


def test_missed_in_queue_distinguished_from_missed_in_service(
        small_store, small_index, rng):
    """A request whose deadline expires while it still waits for a
    replica slot reports missed-in-queue; one admitted in time that
    finishes late reports missed-in-service only."""
    q = unit_queries(small_store, rng, 3)
    traces = make_traces("hyde", 3, seed=53)
    solo = [_solo_latency(small_index, q[i], traces[i]) for i in range(3)]
    srv = TeleRAGServer(small_index, _cfg(), 1, get_arch("llama3-8b"),
                        micro_batch=1, dispatch=FifoDispatch())
    resp = srv.serve([
        RagRequest(q=q[0], trace=traces[0]),                 # head of line
        # admitted in time (queue ~ solo[0]) but expires mid-service
        RagRequest(q=q[1], trace=traces[1],
                   deadline_s=solo[0] + 0.5 * solo[1]),
        # expires while still queued behind requests 0 and 1
        RagRequest(q=q[2], trace=traces[2], deadline_s=0.5 * solo[0])])
    assert resp[1].deadline_missed and not resp[1].deadline_missed_in_queue
    assert resp[2].deadline_missed and resp[2].deadline_missed_in_queue
    t = srv.telemetry().tenant("shared")
    assert t.deadline_missed == 2
    assert t.missed_in_queue == 1
    assert t.missed_in_service == 1


# ---------------------------------------------------------------------------
# Slack-based prefetch demotion
# ---------------------------------------------------------------------------


def test_past_deadline_rounds_demote_prefetch(small_store, small_index,
                                              rng):
    """A multi-round request already past its deadline stops spending
    pool pages and link bytes on lookahead: later rounds demote, H2D
    drops below the no-deadline run, and results stay identical."""
    q = unit_queries(small_store, rng, 1)
    traces = make_traces("iter", 1, seed=59)         # multi-round pipeline
    assert len([s for s in traces[0].stages if s.kind == "retrieve"]) >= 2

    def serve(deadline):
        srv = TeleRAGServer(small_index, _cfg(), 1, get_arch("llama3-8b"))
        resp = srv.serve([RagRequest(q=q[0], trace=traces[0],
                                     deadline_s=deadline)])
        return resp[0], srv

    free_run, srv_free = serve(None)
    doomed, srv_doomed = serve(1e-9)                 # past-deadline at once
    assert doomed.state == RequestState.COMPLETE
    assert doomed.demoted_rounds >= 1
    assert free_run.demoted_rounds == 0
    # demoted rounds move no prefetch bytes
    assert (srv_doomed.engines[0].buffer.stats.bytes_h2d
            < srv_free.engines[0].buffer.stats.bytes_h2d)
    # retrieval results are unchanged — misses just route to host search
    for got, want in zip(doomed.doc_ids, free_run.doc_ids):
        np.testing.assert_array_equal(got, want)
    assert srv_doomed.telemetry().tenant("shared").demoted_rounds \
        == doomed.demoted_rounds


# ---------------------------------------------------------------------------
# Routing reads per-tenant occupancy
# ---------------------------------------------------------------------------


def test_assign_tie_breaks_away_from_tenant_loaded_replica():
    """Equal overlap, equal ledger occupancy: the batch routes to the
    replica where its tenant holds the least pool share."""
    out = assign_to_replicas([set()], [set(), set()],
                             occupancy=[0.5, 0.5],
                             tenant_occupancy=[[0.9, 0.1]])
    assert out[0].replica == 1
    # ledger occupancy still dominates tenant spreading
    out = assign_to_replicas([set()], [set(), set()],
                             occupancy=[0.2, 0.8],
                             tenant_occupancy=[[0.9, 0.0]])
    assert out[0].replica == 0
