"""Scan-aware cost extraction: the §Roofline methodology contract."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import (collective_bytes_corrected, jaxpr_cost,
                                   _split_computations)
from repro.launch.roofline import collective_bytes


def test_jaxpr_cost_counts_scan_trips():
    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    c = jaxpr_cost(f, w, x)
    assert c["flops"] == pytest.approx(7 * 2 * 8 * 128 * 128)
    # traffic: (A + B + O) x trips
    assert c["bytes"] == pytest.approx(7 * 4 * (8 * 128 + 128 * 128 + 8 * 128))


def test_jaxpr_cost_through_grad_checkpoint_nested_scan():
    def g(w, x):
        def layer(c, _):
            def inner(cc, __):
                return jnp.tanh(cc @ w), None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        out, _ = jax.lax.scan(jax.checkpoint(layer), x, None, length=5)
        return jnp.sum(out)

    c = jaxpr_cost(jax.grad(g), jnp.ones((64, 64)), jnp.ones((4, 64)))
    fwd = 15 * 2 * 4 * 64 * 64
    # grad-with-remat >= 2x forward (fwd replay + bwd matmuls)
    assert c["flops"] >= 2 * fwd
    assert c["flops"] <= 5 * fwd


def test_collective_trip_count_correction():
    hlo = """
HloModule test

%loop_cond (p: (s32[], f32[4])) -> pred[] {
  %gte = s32[] get-tuple-element((s32[], f32[4]) %p), index=0
  %c = s32[] constant(6)
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}

%loop_body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %gte1 = f32[4]{0} get-tuple-element((s32[], f32[4]) %p), index=1
  %ag = f32[16]{0} all-gather(f32[4]{0} %gte1), replica_groups={}
  ROOT %t = (s32[], f32[4]) tuple(%gte0, %gte1)
}

ENTRY %main (x: f32[4]) -> f32[4] {
  %init = (s32[], f32[4]) tuple(%c0, %x)
  %w = (s32[], f32[4]) while(%init), condition=%loop_cond, body=%loop_body
  %ar = f32[8]{0} all-reduce(f32[8]{0} %y), to_apply=%sum
  ROOT %out = f32[4]{0} get-tuple-element(%w), index=1
}
"""
    flat = collective_bytes(hlo)
    corr = collective_bytes_corrected(hlo)
    assert flat["all-gather"] == 64            # counted once
    assert corr["all-gather"] == 6 * 64        # x trip count
    assert corr["all-reduce"] == 32            # entry-level, x1


def test_split_computations_nested_tuple_params():
    hlo = """
%f (p: (s32[], (f32[2], f32[2]))) -> f32[2] {
  ROOT %r = f32[2]{0} get-tuple-element((s32[], (f32[2], f32[2])) %p), index=1
}

ENTRY %main (x: f32[2]) -> f32[2] {
  ROOT %out = f32[2]{0} copy(%x)
}
"""
    comps = _split_computations(hlo)
    assert set(comps) == {"f", "main"}
