"""Per-arch smoke tests (reduced configs) + decode/forward parity.

Smoke: every assigned arch instantiates its reduced-family config and runs
one forward/train step on CPU asserting shapes + no NaNs (assignment
requirement). Parity: prefill+decode must reproduce the full-sequence
forward logits — this exercises the KV cache, the absorbed-MLA decode,
the gemma2 split/ring cache, and the SSM O(1) decode paths.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch, list_archs
from repro.models import transformer as tf

ARCHS = sorted(list_archs())


def make_batch(cfg, key, B=2, S=32):
    if cfg.frontend and cfg.frontend.kind == "encodec_stub":
        toks = jax.random.randint(key, (B, S, cfg.frontend.num_codebooks), 0,
                                  cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend and cfg.frontend.kind == "vit_stub":
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.frontend.num_prefix_embeddings,
                  cfg.frontend.embed_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_decode(arch):
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = tf.init_params(cfg, key)
    B, S = 2, 32
    batch = make_batch(cfg, key, B, S)
    loss, metrics = jax.jit(
        lambda p, b: tf.loss_fn(p, b, cfg, remat=False))(params, batch)
    assert jnp.isfinite(loss), arch
    assert float(metrics["tokens"]) == B * S

    inputs = {"tokens": batch["tokens"]}
    if "image_embeds" in batch:
        inputs["image_embeds"] = batch["image_embeds"]
    logits, cache = jax.jit(lambda p, i: tf.prefill(p, i, cfg))(params, inputs)
    if cfg.frontend and cfg.frontend.kind == "encodec_stub":
        assert logits.shape == (B, cfg.frontend.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_decreases_loss(arch):
    from repro.data import DataConfig, TokenStream
    from repro.training import OptConfig, init_training, make_train_step
    cfg = get_arch(arch).reduced()
    opt = OptConfig(lr=3e-3, warmup_steps=2, total_steps=100)
    params, opt_state = init_training(cfg, opt, jax.random.PRNGKey(1))
    data = TokenStream(cfg, DataConfig(global_batch=4, seq_len=32, seed=2))
    step = jax.jit(make_train_step(cfg, opt, attn_chunk=32, loss_chunk=16))
    losses = []
    for _ in range(8):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1]), arch
    # margin absorbs optimizer numerics drift across jax releases
    # (granite-20b sits at +0.08 on jax 0.4.37)
    assert min(losses[4:]) < losses[0] + 0.1, (arch, losses)


PARITY_ARCHS = ["llama3-8b", "gemma2-27b", "minicpm3-4b", "granite-20b",
                "rwkv6-3b", "zamba2-2.7b", "musicgen-large",
                "granite-moe-3b-a800m"]


@pytest.mark.slow
@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_decode_matches_forward(arch):
    """Greedy decode after prefill == teacher-forced full forward.

    MoE: capacity dropping depends on the dispatch-group population, which
    differs between a full forward and one-token decode — parity is only
    defined in the no-drop regime, so capacity is raised to group size."""
    import dataclasses
    cfg = get_arch(arch).reduced()
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(
                cfg.moe, capacity_factor=cfg.moe.num_experts / cfg.moe.top_k))
    key = jax.random.PRNGKey(3)
    params = tf.init_params(cfg, key, dtype=jnp.float32)
    B, S, extra = 2, 24, 4
    audio = cfg.frontend and cfg.frontend.kind == "encodec_stub"
    if audio:
        toks = jax.random.randint(key, (B, S + extra, cfg.frontend.num_codebooks),
                                  0, cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, S + extra), 0, cfg.vocab_size)

    # reference: full forward logits at every position
    x, _, _ = tf.forward(params, toks, cfg)
    ref_logits = tf.unembed(params, x, cfg)             # [B, S+extra, ...]

    # prefill on S, then decode the remaining tokens one by one
    logits, cache = tf.prefill(params, {"tokens": toks[:, :S]}, cfg)
    full = tf.init_cache(cfg, B, S + extra, dtype=jnp.float32)

    def put(fc, pc):
        if fc.shape == pc.shape:
            return pc.astype(fc.dtype)
        sl = tuple(slice(0, s) for s in pc.shape)
        return fc.at[sl].set(pc.astype(fc.dtype))
    full = jax.tree.map(put, full, cache)

    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(ref_logits[:, S - 1]),
                               rtol=2e-4, atol=2e-4)

    step = jax.jit(lambda p, c, i: tf.serve_step(p, c, i, cfg))
    for t in range(extra):
        inp = {"token": toks[:, S + t], "pos": jnp.full((B,), S + t, jnp.int32)}
        lg, full = step(params, full, inp)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(ref_logits[:, S + t]),
            rtol=2e-3, atol=2e-3)


def test_gemma2_ring_cache_respects_window():
    """Ring cache must equal full-cache attention once pos > window."""
    cfg = get_arch("gemma2-27b").reduced()   # window=8, 4 layers
    key = jax.random.PRNGKey(5)
    params = tf.init_params(cfg, key, dtype=jnp.float32)
    B, S = 1, 20                             # S > 2*window
    toks = jax.random.randint(key, (B, S + 2), 0, cfg.vocab_size)
    x, _, _ = tf.forward(params, toks, cfg)
    ref_logits = tf.unembed(params, x, cfg)
    _, cache = tf.prefill(params, {"tokens": toks[:, :S]}, cfg)
    full = tf.init_cache(cfg, B, S + 2, dtype=jnp.float32)

    def put(fc, pc):
        if fc.shape == pc.shape:
            return pc.astype(fc.dtype)
        sl = tuple(slice(0, s) for s in pc.shape)
        return fc.at[sl].set(pc.astype(fc.dtype))
    full = jax.tree.map(put, full, cache)
    for t in range(2):
        inp = {"token": toks[:, S + t], "pos": jnp.full((B,), S + t, jnp.int32)}
        lg, full = tf.serve_step(params, full, inp, cfg)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(ref_logits[:, S + t]),
                                   rtol=2e-3, atol=2e-3)


def test_param_axes_structurally_match_params():
    for arch in ARCHS:
        cfg = get_arch(arch).reduced()
        shapes = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
        axes = tf.param_axes(cfg)
        s1 = jax.tree.structure(shapes)
        s2 = jax.tree.structure(axes, is_leaf=lambda x: isinstance(x, tuple))
        assert s1 == s2, arch
        # every axes tuple rank matches the leaf rank
        for ax, sh in zip(jax.tree.leaves(axes,
                                          is_leaf=lambda x: isinstance(x, tuple)),
                          jax.tree.leaves(shapes)):
            assert len(ax) == len(sh.shape), (arch, ax, sh.shape)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3-8b", "gemma2-27b"])
def test_int8_kv_decode_parity(arch):
    """Quantized KV decode: small logit error, identical argmax."""
    from repro.models.attention import quantize_heads
    cfg = get_arch(arch).reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 2), 0,
                              cfg.vocab_size)
    x, _, _ = tf.forward(params, toks, cfg)
    ref = tf.unembed(params, x, cfg)
    _, cache = tf.prefill(params, {"tokens": toks[:, :S]}, cfg)
    full = tf.init_cache(cfg, B, S + 2, dtype=jnp.float32, kv_quant=True)
    newc = dict(full)
    for key, src in cache.items():
        if newc[key].dtype == jnp.int8:
            q, sc = quantize_heads(src)
            newc[key] = newc[key].at[tuple(slice(0, d)
                                           for d in q.shape)].set(q)
            newc[key + "_scale"] = newc[key + "_scale"].at[
                tuple(slice(0, d) for d in sc.shape)].set(
                sc.astype(jnp.bfloat16))
        else:
            newc[key] = newc[key].at[tuple(slice(0, d)
                                           for d in src.shape)].set(
                src.astype(newc[key].dtype))
    for t in range(2):
        inp = {"token": toks[:, S + t], "pos": jnp.full((B,), S + t,
                                                        jnp.int32)}
        lg, newc = tf.serve_step(params, newc, inp, cfg, kv_quant=True)
        assert np.max(np.abs(np.asarray(lg)
                             - np.asarray(ref[:, S + t]))) < 0.5
        assert np.all(np.argmax(np.asarray(lg), -1)
                      == np.argmax(np.asarray(ref[:, S + t]), -1))
