"""Sharding rules, compression, elastic scaling, roofline parsing."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

import repro.distributed as dist
from repro.configs import get_arch
from repro.launch.mesh import _axis_type_kwargs
from repro.launch.roofline import collective_bytes, model_flops_for


def fake_mesh(shape=(2, 2), axes=("data", "model")):
    # abstract mesh over fake devices (no jax device init needed for specs)
    devs = np.array(jax.devices() * (int(np.prod(shape)) // len(jax.devices())
                                     + 1))[:int(np.prod(shape))]
    return Mesh(devs.reshape(shape), axes, **_axis_type_kwargs(len(axes)))


def test_spec_for_divisibility_and_duplicates():
    mesh = fake_mesh()
    rules = dist.RULES_DEFAULT
    # divisible dims shard
    assert dist.spec_for(("vocab", "embed"), (512, 64), mesh, rules) == \
        P("model")
    # non-divisible dim replicates (kv_heads=1 under TP)
    assert dist.spec_for(("embed", "kv_heads", None), (64, 1, 128), mesh,
                         rules) == P()
    # duplicate mesh axis: first dim claims it, second drops
    lc = dist.RULES_LONG_CONTEXT
    spec = dist.spec_for(("layers", "batch", "kv_seq", "kv_heads", None),
                         (4, 4, 64, 2, 16), mesh, lc)
    assert spec == P(None, "data", "model")


def test_param_shardings_cover_all_archs():
    mesh = fake_mesh()
    for arch in ("llama3-8b", "gemma2-27b", "zamba2-2.7b", "rwkv6-3b",
                 "arctic-480b", "musicgen-large"):
        cfg = get_arch(arch).reduced()
        tree = dist.param_shardings(cfg, mesh)
        from repro.models import transformer as tf
        shapes = jax.eval_shape(lambda: tf.init_params(cfg,
                                                       jax.random.PRNGKey(0)))
        assert jax.tree.structure(tree) == jax.tree.structure(shapes)


@pytest.mark.slow
def test_manual_dp_step_with_compression():
    cfg = get_arch("llama3-8b").reduced()
    from repro.training import OptConfig, init_training
    from repro.training.train_loop import make_manual_dp_train_step
    from repro.distributed import init_error_feedback
    mesh = fake_mesh((1,), ("data",))
    opt = OptConfig(lr=1e-3)
    params, opt_state = init_training(cfg, opt, jax.random.PRNGKey(0))
    err = init_error_feedback(params)
    from repro.data import DataConfig, TokenStream
    data = TokenStream(cfg, DataConfig(global_batch=2, seq_len=16, seed=0))
    batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
    step = make_manual_dp_train_step(cfg, opt, mesh, compress=True,
                                     attn_chunk=16)
    with mesh:
        p2, o2, e2, m = step(params, opt_state, err, batch)
    assert np.isfinite(float(m["loss"]))
    # error feedback is non-trivial (quantization residue exists)
    assert any(float(jnp.max(jnp.abs(l))) > 0 for l in jax.tree.leaves(e2))


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[16,4096]{1,0} all-gather(bf16[1,4096]{1,0} %p0), replica_groups={}
  %ar.1 = f32[128]{0} all-reduce(f32[128]{0} %x), to_apply=%sum
  %t = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(f32[8,8]{1,0} %a, f32[8,8]{1,0} %b)
  %rs = bf16[2,64]{1,0} reduce-scatter(bf16[16,64]{1,0} %y), dimensions={0}
  %cp-start = bf16[4]{0} collective-permute-start(bf16[4]{0} %z)
  %notacoll = f32[4]{0} add(f32[4]{0} %z, f32[4]{0} %z)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 4096 * 2
    assert out["all-reduce"] == 128 * 4
    assert out["all-to-all"] == 2 * 8 * 8 * 4
    assert out["reduce-scatter"] == 2 * 64 * 2
    assert out["collective-permute"] == 4 * 2


def test_cost_analysis_is_per_device():
    """Pin down the per-device semantics the roofline relies on."""
    mesh = fake_mesh((1, 1))
    w = jnp.ones((256, 256), jnp.float32)
    x = jnp.ones((64, 256), jnp.float32)
    from repro.compat import compiled_cost_analysis
    c = jax.jit(lambda a, b: a @ b.T).lower(x, w).compile()
    flops = compiled_cost_analysis(c)["flops"]
    assert flops == pytest.approx(2 * 64 * 256 * 256, rel=0.01)


def test_model_flops_convention():
    cfg = get_arch("llama3-8b")
    n = cfg.active_param_count()
    assert model_flops_for(cfg, "train_step", 4096, 256) == 6.0 * n * 4096 * 256
    assert model_flops_for(cfg, "serve_step", 32768, 128) == 2.0 * n * 128


def test_elastic_rerun_after_resize():
    from repro.distributed import ElasticRun
    run = ElasticRun(global_batch=32)
    s1 = run.resize(0, {0, 1, 2, 3})
    assert sum(b - a for a, b in s1.values()) == 32
    s2 = run.resize(5, {0, 1, 3})            # node 2 died
    assert set(s2) == {0, 1, 3}
    assert sum(b - a for a, b in s2.values()) == 32
    assert len(run.history) == 2
