"""Chunked-parallel SSM forms must match the exact token recurrences.

These are the TPU adaptations of RWKV6's CUDA kernel and Mamba2's SSD —
the chunked einsum forms are only valid if they reproduce the recurrence
step-for-step (the decode path uses the recurrence directly).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_arch
from repro.models import mamba2 as mm
from repro.models import rwkv6 as rw
from repro.models import transformer as tf


@pytest.mark.slow
def test_rwkv6_chunked_matches_recurrent():
    cfg = get_arch("rwkv6-3b").reduced()      # chunk_size=16
    key = jax.random.PRNGKey(0)
    mk = tf._layer_builder(cfg)
    from repro.models.layers import InitMaker
    p = mk(InitMaker(key, dtype=jnp.float32))["tm"]
    B, S, d = 2, 48, cfg.d_model              # 3 chunks of 16
    K = cfg.ssm.head_dim
    H = d // K
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32) * 0.5
    shift0 = jnp.zeros((B, d), jnp.float32)
    st0 = jnp.zeros((B, H, K, K), jnp.float32)

    y_chunk, sh_c, st_c = rw.rwkv6_time_mix(p, x, cfg, shift_in=shift0,
                                            state_in=st0)

    # exact recurrence
    ys = []
    sh, st = shift0, st0
    for t in range(S):
        y, sh, st = rw.rwkv6_time_mix_step(p, x[:, t, :], cfg,
                                           shift_in=sh, state_in=st)
        ys.append(y)
    y_rec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sh_c), np.asarray(x[:, -1, :]))


@pytest.mark.slow
def test_mamba2_chunked_matches_recurrent():
    cfg = get_arch("zamba2-2.7b").reduced()   # mamba2, chunk_size=16
    key = jax.random.PRNGKey(2)
    from repro.models.layers import InitMaker
    p = mm.mamba2_params(InitMaker(key, dtype=jnp.float32), cfg)
    B, S, d = 2, 32, cfg.d_model
    d_in, H, P, N = mm.mamba2_dims(cfg)
    cw = cfg.ssm.conv_width
    x = jax.random.normal(jax.random.PRNGKey(3), (B, S, d), jnp.float32) * 0.5
    conv0 = jnp.zeros((B, cw - 1, d_in + 2 * N), jnp.float32)
    st0 = jnp.zeros((B, H, P, N), jnp.float32)

    y_chunk, conv_c, st_c = mm.mamba2_forward(p, x, cfg, conv_in=conv0,
                                              state_in=st0)
    ys = []
    conv, st = conv0, st0
    for t in range(S):
        y, conv, st = mm.mamba2_step(p, x[:, t, :], cfg, conv_in=conv,
                                     state_in=st)
        ys.append(y)
    y_rec = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_c), np.asarray(st),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(conv_c), np.asarray(conv),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("S,chunk", [(17, 16), (16, 32), (40, 8)])
def test_rwkv6_odd_lengths(S, chunk):
    """Non-divisible sequence lengths fall back to a single chunk."""
    import dataclasses
    cfg = get_arch("rwkv6-3b").reduced()
    cfg = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm,
                                                           chunk_size=chunk))
    from repro.models.layers import InitMaker
    p = tf._layer_builder(cfg)(InitMaker(jax.random.PRNGKey(0),
                                         dtype=jnp.float32))["tm"]
    B, d = 1, cfg.d_model
    K = cfg.ssm.head_dim
    H = d // K
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32) * 0.3
    y, _, _ = rw.rwkv6_time_mix(p, x, cfg, shift_in=jnp.zeros((B, d)),
                                state_in=jnp.zeros((B, H, K, K)))
    assert y.shape == (B, S, d)
    assert np.all(np.isfinite(np.asarray(y)))
