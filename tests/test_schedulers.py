"""Prefetching scheduler + cache-aware scheduler behaviour (paper §4.2)."""

import numpy as np

import repro.core as core


def test_group_queries_exact_cover():
    rng = np.random.default_rng(0)
    emb = rng.standard_normal((13, 16)).astype(np.float32)
    groups = core.group_queries(emb, micro_batch=4)
    flat = sorted(i for g in groups for i in g)
    assert flat == list(range(13))
    assert all(len(g) <= 4 for g in groups)


def test_group_queries_groups_similar():
    rng = np.random.default_rng(1)
    a = rng.standard_normal(16).astype(np.float32)
    b = rng.standard_normal(16).astype(np.float32)
    emb = np.stack([a + 0.01 * rng.standard_normal(16) for _ in range(4)]
                   + [b + 0.01 * rng.standard_normal(16) for _ in range(4)]
                   ).astype(np.float32)
    order = rng.permutation(8)
    groups = core.group_queries(emb[order], micro_batch=4)
    for g in groups:
        fams = {int(order[i] < 4) for i in g}
        assert len(fams) == 1, (groups, order)


def test_assignment_prefers_overlap_and_caps_load():
    batches = [set(range(0, 10)), set(range(10, 20)), set(range(0, 10)),
               set(range(10, 20))]
    caches = [set(range(0, 10)), set(range(10, 20))]
    out = core.assign_to_replicas(batches, caches)
    assert len(out) == 4
    loads = {}
    for a in out:
        loads[a.replica] = loads.get(a.replica, 0) + 1
        if a.overlap > 0:
            # routed to the replica holding its clusters
            assert (a.replica == 0) == (a.batch_index in (0, 2))
    assert max(loads.values()) <= 2


def _assign_reference(batch_clusters, replica_caches, *, max_per_replica=None):
    """The pre-optimization greedy sweep (fresh deep copy + full re-mask
    per pick) — kept as the oracle for the incremental-masking version."""
    n_b, n_r = len(batch_clusters), len(replica_caches)
    if n_r == 0:
        return []
    cap = max_per_replica or -(-n_b // n_r)
    overlap = np.zeros((n_b, n_r), np.int64)
    for i, bc in enumerate(batch_clusters):
        for r, rc in enumerate(replica_caches):
            overlap[i, r] = len(bc & rc)
    load = np.zeros(n_r, np.int64)
    taken = np.zeros(n_b, bool)
    out = []
    for _ in range(n_b):
        masked = overlap.astype(np.float64).copy()
        masked[taken, :] = -1
        masked[:, load >= cap] = -1
        i, r = np.unravel_index(np.argmax(masked), masked.shape)
        if masked[i, r] < 0:
            i = int(np.argmin(taken))
            r = int(np.argmin(load))
        out.append((int(i), int(r), int(overlap[i, r])))
        taken[int(i)] = True
        load[int(r)] += 1
    out.sort()
    return out


def test_assign_incremental_masking_matches_reference():
    """The O(n_b·n_r)-masking sweep must pick identical assignments to
    the old O(n_b²·n_r) copy-per-pick loop on a fixed seed."""
    rng = np.random.default_rng(42)
    for trial in range(20):
        n_b = int(rng.integers(1, 12))
        n_r = int(rng.integers(1, 5))
        batches = [set(map(int, rng.choice(40, rng.integers(0, 15),
                                           replace=False)))
                   for _ in range(n_b)]
        caches = [set(map(int, rng.choice(40, rng.integers(0, 20),
                                          replace=False)))
                  for _ in range(n_r)]
        got = [(a.batch_index, a.replica, a.overlap)
               for a in core.assign_to_replicas(batches, caches)]
        assert got == _assign_reference(batches, caches), trial


def test_assign_occupancy_breaks_ties_toward_free_memory():
    # two replicas with identical caches: overlap ties everywhere
    batches = [set(range(5)), set(range(5))]
    caches = [set(range(5)), set(range(5))]
    out = core.assign_to_replicas(batches, caches,
                                  occupancy=[0.9, 0.1])
    assert out[0].replica == 1                  # less-loaded HBM wins the tie
    # but occupancy can never override a real overlap advantage
    caches = [set(range(5)), set(range(1))]
    out = core.assign_to_replicas([set(range(5))], caches,
                                  occupancy=[1.0, 0.0],
                                  max_per_replica=1)
    assert out[0].replica == 0


def test_straggler_requeue():
    from repro.core.schedulers import Assignment, ReplicaHealth
    h = ReplicaHealth(deadline_s=1.0)
    h.heartbeat(0, now=0.0)
    h.heartbeat(1, now=5.0)
    assert h.healthy([0, 1], now=5.5) == [1]
    assigns = [Assignment(0, 0, 3), Assignment(1, 1, 2)]
    alive, requeue = h.requeue_straggler_batches(assigns, dead={0})
    assert requeue == [0] and [a.batch_index for a in alive] == [1]


def test_scheduler_improves_hit_rate(small_store, small_index, rng):
    """End-to-end: grouping similar queries should not hurt (and usually
    helps) shared-cluster coverage under a split budget."""
    from tests.conftest import unit_queries
    base = unit_queries(small_store, rng, 4)
    emb = np.concatenate([base + 0.02 * rng.standard_normal(base.shape)
                          for _ in range(4)]).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=-1, keepdims=True)
    ranked = [core.probe(emb[i], small_index, 8)[0] for i in range(16)]
    groups = core.group_queries(emb, 4)
    gain_sched = core.grouping_shared_cluster_gain(ranked, groups, top=8)
    naive = [list(range(i, i + 4)) for i in range(0, 16, 4)]
    gain_naive = core.grouping_shared_cluster_gain(ranked, naive, top=8)
    assert gain_sched >= gain_naive - 1e-9
