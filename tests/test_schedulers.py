"""Prefetching scheduler + cache-aware scheduler behaviour (paper §4.2)."""

import numpy as np

import repro.core as core


def test_group_queries_exact_cover():
    rng = np.random.default_rng(0)
    emb = rng.standard_normal((13, 16)).astype(np.float32)
    groups = core.group_queries(emb, micro_batch=4)
    flat = sorted(i for g in groups for i in g)
    assert flat == list(range(13))
    assert all(len(g) <= 4 for g in groups)


def test_group_queries_groups_similar():
    rng = np.random.default_rng(1)
    a = rng.standard_normal(16).astype(np.float32)
    b = rng.standard_normal(16).astype(np.float32)
    emb = np.stack([a + 0.01 * rng.standard_normal(16) for _ in range(4)]
                   + [b + 0.01 * rng.standard_normal(16) for _ in range(4)]
                   ).astype(np.float32)
    order = rng.permutation(8)
    groups = core.group_queries(emb[order], micro_batch=4)
    for g in groups:
        fams = {int(order[i] < 4) for i in g}
        assert len(fams) == 1, (groups, order)


def test_assignment_prefers_overlap_and_caps_load():
    batches = [set(range(0, 10)), set(range(10, 20)), set(range(0, 10)),
               set(range(10, 20))]
    caches = [set(range(0, 10)), set(range(10, 20))]
    out = core.assign_to_replicas(batches, caches)
    assert len(out) == 4
    loads = {}
    for a in out:
        loads[a.replica] = loads.get(a.replica, 0) + 1
        if a.overlap > 0:
            # routed to the replica holding its clusters
            assert (a.replica == 0) == (a.batch_index in (0, 2))
    assert max(loads.values()) <= 2


def test_straggler_requeue():
    from repro.core.schedulers import Assignment, ReplicaHealth
    h = ReplicaHealth(deadline_s=1.0)
    h.heartbeat(0, now=0.0)
    h.heartbeat(1, now=5.0)
    assert h.healthy([0, 1], now=5.5) == [1]
    assigns = [Assignment(0, 0, 3), Assignment(1, 1, 2)]
    alive, requeue = h.requeue_straggler_batches(assigns, dead={0})
    assert requeue == [0] and [a.batch_index for a in alive] == [1]


def test_scheduler_improves_hit_rate(small_store, small_index, rng):
    """End-to-end: grouping similar queries should not hurt (and usually
    helps) shared-cluster coverage under a split budget."""
    from tests.conftest import unit_queries
    base = unit_queries(small_store, rng, 4)
    emb = np.concatenate([base + 0.02 * rng.standard_normal(base.shape)
                          for _ in range(4)]).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=-1, keepdims=True)
    ranked = [core.probe(emb[i], small_index, 8)[0] for i in range(16)]
    groups = core.group_queries(emb, 4)
    gain_sched = core.grouping_shared_cluster_gain(ranked, groups, top=8)
    naive = [list(range(i, i + 4)) for i in range(0, 16, 4)]
    gain_naive = core.grouping_shared_cluster_gain(ranked, naive, top=8)
    assert gain_sched >= gain_naive - 1e-9
