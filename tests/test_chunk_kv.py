"""Chunk-KV splice: reordered-RoPE parity against re-prefill oracles,
residency refcount discipline, and the end-to-end serve path.

Parity is layered the way the subsystem is:

* **Kernel**: ``flash_decode_spliced_ref`` against (a) the dense
  ``flash_decode_ref`` on all-fresh and on aligned multi-chunk tables
  (stored K roped chunk-locally, the oracle roped at layout positions —
  the rotation-composition claim itself), and (b) a loopy numpy softmax
  oracle that only ever *gathers live tokens*, so partial-last-page
  masking is checked against an implementation with no masks at all.
* **Model**: ``serve_step_paged_spliced`` greedy decode against full
  re-prefill (``transformer.prefill`` over chunk tokens + generated
  tokens): logits within float32 tolerance, greedy tokens EXACTLY
  equal, including two chunks spliced in both orders in one batch.
  Ragged chunks are pinned by garbage-invariance: poisoning the dead
  tail of a partial last page must not move a single logit bit.
* **Serve**: a real ``TeleRAGServer`` run with a chunk store — splice
  hits, lookahead prefetch landing pages, miss fallback, retrieval
  parity with a chunk-less run, and a fully drained ledger + recorder
  stream (``must_drain=("kv", "chunk_kv")``).

The hypothesis sweeps (skipped when hypothesis is absent) randomize
page size, chunk lengths/orderings and step counts through the same
oracles.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import check_recorder
from repro.configs import get_arch
from repro.configs.base import ArchConfig
from repro.data.chunk_kv import (ChunkKVStore, build_chunk, build_chunk_kv,
                                 chunk_tokens, cluster_map_from_assignments,
                                 pages_from_cache)
from repro.kernels import ops, ref
from repro.memory.pool import DevicePagePool
from repro.models import transformer as tf
from repro.models.layers import apply_rope
from repro.obs.recorder import FlightRecorder
from repro.serving import (ChunkKVCache, DecodeRunner, EngineConfig,
                           KVCacheManager, RagRequest, RequestState,
                           TeleRAGServer, make_traces)
from tests.conftest import unit_queries

TINY = ArchConfig(name="tiny", family="dense", source="test",
                  d_model=64, num_layers=2, num_heads=4, num_kv_heads=2,
                  head_dim=16, vocab_size=64)
ARCH = get_arch("llama3-8b")
SERVE_CFG = ARCH.reduced()


@pytest.fixture(scope="module")
def tparams():
    return tf.init_params(TINY, jax.random.PRNGKey(1), dtype=jnp.float32)


@pytest.fixture(scope="module")
def serve_params():
    return tf.init_params(SERVE_CFG, jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# Offline builder (data layer)
# ---------------------------------------------------------------------------


def test_chunk_tokens_deterministic_and_ragged():
    a = chunk_tokens(7, 64, seed=3)
    b = chunk_tokens(7, 64, seed=3)
    np.testing.assert_array_equal(a, b)          # pure fn of (seed, doc)
    assert 8 <= len(a) <= 24
    assert (a >= 0).all() and (a < 64).all()
    assert not np.array_equal(a, chunk_tokens(8, 64, seed=3)[:len(a)]) \
        or len(a) != len(chunk_tokens(8, 64, seed=3))
    assert not np.array_equal(chunk_tokens(7, 64, seed=4), a) \
        or len(chunk_tokens(7, 64, seed=4)) != len(a)
    lengths = {len(chunk_tokens(d, 64, seed=0)) for d in range(32)}
    assert len(lengths) > 3, "lengths must be ragged across docs"


def test_pages_from_cache_pads_and_bounds():
    rng = np.random.default_rng(0)
    k = rng.standard_normal((2, 16, 2, 8)).astype(np.float32)
    v = rng.standard_normal((2, 16, 2, 8)).astype(np.float32)
    kp, vp = pages_from_cache(k, v, length=9, page_size=4)
    assert kp.shape == (2, 3, 4, 2, 8)           # ceil(9/4) pages
    np.testing.assert_array_equal(kp.reshape(2, 12, 2, 8)[:, :9], k[:, :9])
    assert (kp[:, 2, 1:] == 0).all(), "dead tail must be zero padding"
    assert (vp[:, 2, 1:] == 0).all()
    with pytest.raises(ValueError):
        pages_from_cache(k, v, length=17, page_size=4)


def test_build_chunk_matches_prefill_and_store_roundtrip(tparams, tmp_path):
    """The builder's pages are exactly one chunk-local prefill, cut to
    page geometry — and survive the .npz artifact roundtrip."""
    chunk = build_chunk(tparams, TINY, 5, page_size=4, seed=2)
    toks = chunk_tokens(5, TINY.vocab_size, seed=2)
    _, cache = tf.prefill(tparams, {"tokens": np.asarray(toks)[None]}, TINY)
    kp, vp = pages_from_cache(np.asarray(cache["k"][:, 0], np.float32),
                              np.asarray(cache["v"][:, 0], np.float32),
                              len(toks), 4)
    np.testing.assert_allclose(chunk.k, kp, rtol=1e-6)
    np.testing.assert_allclose(chunk.v, vp, rtol=1e-6)
    assert chunk.length == len(toks)

    store = build_chunk_kv(tparams, TINY, [5, 9], page_size=4, seed=2,
                           cluster_of=lambda d: d % 3)
    path = str(tmp_path / "chunks.npz")
    store.save(path)
    loaded = ChunkKVStore.load(path)
    assert loaded.page_size == 4 and len(loaded) == 2
    for d in (5, 9):
        np.testing.assert_array_equal(loaded.get(d).k, store.get(d).k)
        assert loaded.get(d).length == store.get(d).length
        assert loaded.get(d).cluster == d % 3
    assert loaded.docs_in_cluster(2) == [5]
    assert loaded.docs_in_cluster(0) == [9]


# ---------------------------------------------------------------------------
# RoPE composition + kernel-level splice parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fraction", [1.0, 0.5])
def test_rope_rotations_compose(fraction):
    """R(p + d) x == R(d) R(p) x — the identity the whole reordered-RoPE
    splice rests on (chunk-local K + one constant per-page delta)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((5, 2, 16)), jnp.float32)
    p = jnp.asarray([0, 3, 4, 9, 17])
    d = jnp.asarray([8, 8, 8, 8, 8])
    once = apply_rope(x, p + d, fraction=fraction)
    twice = apply_rope(apply_rope(x, p, fraction=fraction), d,
                       fraction=fraction)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                               rtol=1e-5, atol=1e-5)


def _rand_qkv(rng, B, S, KVH, G, Dh):
    q = jnp.asarray(rng.standard_normal((B, KVH, G, Dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((S, KVH, Dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((S, KVH, Dh)), jnp.float32)
    return q, k, v


def test_spliced_all_fresh_equals_dense_ref():
    """delta=0 / valid=ps degenerates to plain paged attention — and the
    ops entry point resolves modes but runs the same oracle."""
    rng = np.random.default_rng(4)
    B, S, KVH, G, Dh, ps = 2, 12, 2, 2, 16, 4
    q, k1, v1 = _rand_qkv(rng, B, S, KVH, G, Dh)
    k = jnp.stack([k1, k1[::-1]])                     # [B, S, KVH, Dh]
    v = jnp.stack([v1, v1[::-1]])
    kp = k.reshape(B * 3, ps, KVH, Dh)                # 3 pages per row
    vp = v.reshape(B * 3, ps, KVH, Dh)
    bt = jnp.arange(B * 3, dtype=jnp.int32).reshape(B, 3)
    lengths = jnp.asarray([S, S - 2], jnp.int32)
    delta = jnp.zeros((B, 3), jnp.int32)
    valid = jnp.full((B, 3), ps, jnp.int32)
    out = ref.flash_decode_spliced_ref(q, kp, vp, bt, lengths, delta, valid)
    want = ref.flash_decode_ref(q, k, v, lengths - 1, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    out2 = ops.flash_decode_spliced(q, kp, vp, bt, lengths, delta, valid,
                                    mode="ref")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
    with pytest.raises(ValueError):
        ops.flash_decode_spliced(q, kp, vp, bt, lengths, delta, valid,
                                 mode="not_a_mode")


def test_spliced_multi_chunk_delta_equals_layout_rope():
    """Two aligned chunks + fresh tokens: pages stored with CHUNK-LOCAL
    rope and reindexed by per-page delta must equal the dense oracle
    whose K was roped at layout positions outright."""
    rng = np.random.default_rng(5)
    KVH, G, Dh, ps = 2, 2, 16, 4
    lens = [8, 4]                        # chunk A: pages 0-1, B: page 2
    fresh = 2                            # 2 generated tokens on page 3
    S = sum(lens) + fresh                # layout positions 0..13
    q, raw_k, raw_v = _rand_qkv(rng, 1, S, KVH, G, Dh)
    layout = jnp.arange(S)
    dense_k = apply_rope(raw_k, layout)  # the re-prefill-at-layout oracle
    pages_k, pages_v, deltas = [], [], []
    base = 0
    for ln in lens:
        local = apply_rope(raw_k[base:base + ln], jnp.arange(ln))
        for p in range(ln // ps):
            pages_k.append(local[p * ps:(p + 1) * ps])
            pages_v.append(raw_v[base + p * ps:base + (p + 1) * ps])
            deltas.append(base)          # b0 * ps: constant per chunk
        base += ln
    pad = jnp.zeros((ps - fresh, KVH, Dh), jnp.float32)
    tail = apply_rope(raw_k[base:], layout[base:])    # fresh page, delta 0
    pages_k.append(jnp.concatenate([tail, pad]))
    pages_v.append(jnp.concatenate([raw_v[base:], pad]))
    deltas.append(0)
    kp, vp = jnp.stack(pages_k), jnp.stack(pages_v)
    bt = jnp.arange(4, dtype=jnp.int32)[None]
    out = ref.flash_decode_spliced_ref(
        q, kp, vp, bt, jnp.asarray([S], jnp.int32),
        jnp.asarray(deltas, jnp.int32)[None],
        jnp.full((1, 4), ps, jnp.int32))
    want = ref.flash_decode_ref(q, dense_k[None], raw_v[None],
                                jnp.asarray([S - 1]), 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def _loopy_spliced_oracle(q, k_pages, v_pages, bt, lengths, delta, valid,
                          ps):
    """Mask-free oracle: gather ONLY the live tokens per row, rotate by
    the page delta, plain softmax.  [B, KVH, G, Dh] fp32."""
    q = np.asarray(q, np.float32)
    B, KVH, G, Dh = q.shape
    out = np.zeros_like(q)
    for b in range(B):
        ks, vs = [], []
        for blk in range(bt.shape[1]):
            pg = int(bt[b, blk])
            if pg < 0:
                continue
            for off in range(int(valid[b, blk])):
                if blk * ps + off > int(lengths[b]) - 1:
                    continue
                kro = apply_rope(jnp.asarray(k_pages[pg, off])[None],
                                 jnp.asarray([int(delta[b, blk])]))
                ks.append(np.asarray(kro, np.float32)[0])
                vs.append(np.asarray(v_pages[pg, off], np.float32))
        K, V = np.stack(ks), np.stack(vs)             # [N, KVH, Dh]
        for h in range(KVH):
            s = q[b, h] @ K[:, h].T / np.sqrt(Dh)     # [G, N]
            w = np.exp(s - s.max(-1, keepdims=True))
            w /= w.sum(-1, keepdims=True)
            out[b, h] = w @ V[:, h]
    return out


def _ragged_case(rng, lens, ps, fresh, KVH=2, G=2, Dh=16):
    """Build a spliced table for ragged chunk ``lens`` + ``fresh``
    generated tokens; returns (q, kp, vp, bt, lengths, delta, valid)."""
    n_pages = [-(-ln // ps) for ln in lens]
    MB = sum(n_pages) + max(1, -(-fresh // ps))
    q = jnp.asarray(rng.standard_normal((1, KVH, G, Dh)), jnp.float32)
    pages_k, pages_v, delta, valid = [], [], [], []
    b0 = 0
    for ln, npg in zip(lens, n_pages):
        raw = jnp.asarray(rng.standard_normal((npg * ps, KVH, Dh)),
                          jnp.float32)
        local = apply_rope(raw, jnp.arange(npg * ps))
        for p in range(npg):
            pages_k.append(local[p * ps:(p + 1) * ps])
            pages_v.append(raw[p * ps:(p + 1) * ps])
            delta.append(b0 * ps)
            valid.append(ps if p < npg - 1 else ln - (npg - 1) * ps)
        b0 += npg
    layout0 = b0 * ps                                 # generation resumes
    for p in range(MB - sum(n_pages)):
        raw = jnp.asarray(rng.standard_normal((ps, KVH, Dh)), jnp.float32)
        pos = jnp.arange(layout0 + p * ps, layout0 + (p + 1) * ps)
        pages_k.append(apply_rope(raw, pos))
        pages_v.append(raw)
        delta.append(0)
        valid.append(ps)
    kp, vp = jnp.stack(pages_k), jnp.stack(pages_v)
    bt = np.arange(MB, dtype=np.int32)[None]
    lengths = np.asarray([layout0 + fresh], np.int32)
    return (q, kp, vp, bt, lengths, np.asarray(delta, np.int32)[None],
            np.asarray(valid, np.int32)[None])


@pytest.mark.parametrize("lens,ps,fresh", [
    ([5], 4, 3),            # partial last page, holes at layout 5..7
    ([9, 3], 4, 1),         # two ragged chunks, two partial pages
    ([3, 5, 2], 2, 2),      # three chunks crossing page_size=2 oddly
])
def test_spliced_ragged_vs_loopy_oracle(lens, ps, fresh):
    rng = np.random.default_rng(sum(lens) * 31 + ps)
    q, kp, vp, bt, lengths, delta, valid = _ragged_case(rng, lens, ps, fresh)
    out = ref.flash_decode_spliced_ref(
        q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths),
        jnp.asarray(delta), jnp.asarray(valid))
    want = _loopy_spliced_oracle(q, kp, vp, bt, lengths, delta, valid, ps)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-5, atol=1e-5)


def test_spliced_hole_slots_are_garbage_invariant():
    """Poisoning the dead tail of a partial last page (and padding
    columns) must not move any output bit — the masks, not luck."""
    rng = np.random.default_rng(9)
    q, kp, vp, bt, lengths, delta, valid = _ragged_case(rng, [5], 4, 3)
    clean = ref.flash_decode_spliced_ref(
        q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths),
        jnp.asarray(delta), jnp.asarray(valid))
    kp2 = kp.at[1, 1:].set(1e9)          # chunk's page 1 holds 1 live token
    vp2 = vp.at[1, 1:].set(-1e9)
    dirty = ref.flash_decode_spliced_ref(
        q, kp2, vp2, jnp.asarray(bt), jnp.asarray(lengths),
        jnp.asarray(delta), jnp.asarray(valid))
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(dirty))


def test_hypothesis_spliced_kernel_vs_loopy_oracle():
    """Randomized ragged sweep of the kernel oracle pair."""
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=20, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(ps=st.sampled_from([2, 4]),
           lens=st.lists(st.integers(1, 9), min_size=0, max_size=3),
           fresh=st.integers(1, 5), seed=st.integers(0, 2**16))
    def check(ps, lens, fresh, seed):
        rng = np.random.default_rng(seed)
        q, kp, vp, bt, lengths, delta, valid = _ragged_case(
            rng, lens, ps, fresh)
        out = ref.flash_decode_spliced_ref(
            q, kp, vp, jnp.asarray(bt), jnp.asarray(lengths),
            jnp.asarray(delta), jnp.asarray(valid))
        want = _loopy_spliced_oracle(q, kp, vp, bt, lengths, delta, valid,
                                     ps)
        np.testing.assert_allclose(np.asarray(out), want, rtol=1e-4,
                                   atol=1e-4)

    check()


# ---------------------------------------------------------------------------
# Model-level: spliced decode vs full re-prefill oracle
# ---------------------------------------------------------------------------


def _splice_env(tparams, row_docs, doc_lens, *, ps=4, max_len=8,
                num_pages=64, seed=0):
    """Manager + store + a spliced lease over explicit per-doc lengths
    (min_len == max_len pins each doc's chunk_tokens length)."""
    mgr = KVCacheManager(TINY, dtype=jnp.float32)
    mgr.init_paged(num_pages=num_pages, page_size=ps)
    store = ChunkKVStore(page_size=ps, seed=seed)
    for d, ln in doc_lens.items():
        store.add(d, build_chunk(tparams, TINY, d, page_size=ps, seed=seed,
                                 min_len=ln, max_len=ln))
    cache = ChunkKVCache(mgr, store)
    row_chunks, pinned, misses = cache.acquire_rows(row_docs)
    lease = mgr.acquire_paged(len(row_docs), max_len)
    mgr.splice_paged(lease, row_chunks)
    return mgr, cache, lease, pinned, misses


def _spliced_greedy(params, cfg, mgr, lease, steps):
    """Greedy decode through serve_step_paged_spliced; returns
    (per-step logits [B, V], per-step tokens [B])."""
    logits_seq, toks = [], []
    tok = jnp.zeros((lease.batch,), jnp.int32)
    for _ in range(steps):
        bt, lens, dl, vd = lease.device_splice_tables()
        logits, mgr.slab.k, mgr.slab.v = tf.serve_step_paged_spliced(
            params, mgr.slab.k, mgr.slab.v, bt, lens, dl, vd,
            {"token": tok}, cfg)
        mgr.append_paged(lease)
        logits_seq.append(np.asarray(logits))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(np.asarray(tok))
    return logits_seq, toks


def _prefill_oracle(params, cfg, ctx_tokens, steps):
    """Full re-prefill greedy oracle: each step re-prefills context +
    everything generated so far and reads the last-position logits."""
    seq = [int(t) for t in ctx_tokens] + [0]   # BOS-like 0 = first token
    logits_seq, toks = [], []
    for _ in range(steps):
        lg, _ = tf.prefill(params, {"tokens": np.asarray(seq, np.int32)[None]},
                           cfg)
        last = np.asarray(lg)                  # [1, V] last-token logits
        nxt = int(np.argmax(last, -1)[0])
        logits_seq.append(last)
        toks.append(nxt)
        seq.append(nxt)
    return logits_seq, toks


def test_spliced_decode_matches_full_reprefill_single_chunk(tparams):
    """One page-aligned chunk spliced at layout 0: greedy tokens EXACT,
    logits within float32 tolerance of re-prefilling everything."""
    mgr, cache, lease, pinned, _ = _splice_env(tparams, [[7]], {7: 8})
    assert lease.spliced_pages == 2 and list(lease.lengths) == [8]
    got_logits, got_toks = _spliced_greedy(tparams, TINY, mgr, lease, 4)
    ctx = chunk_tokens(7, TINY.vocab_size, seed=0, min_len=8, max_len=8)
    want_logits, want_toks = _prefill_oracle(tparams, TINY, ctx, 4)
    assert [int(t[0]) for t in got_toks] == want_toks
    for g, w in zip(got_logits, want_logits):
        np.testing.assert_allclose(g, w, rtol=2e-4, atol=2e-4)
    mgr.release_paged(lease)
    cache.release_rows(pinned)
    assert cache.resident_pages() == 2 and cache.pinned_pages() == 0


def _assembled_dense_oracle(params, cfg, store, order, steps, *, ps=4):
    """Exact multi-chunk oracle: a DENSE cache assembled from each
    chunk's own independent prefill pages, rotated to their layout
    offset (rope composition), then plain ``serve_step`` greedy decode.
    This is the semantic contract of the splice — for several chunks it
    deliberately differs from re-prefilling the concatenation, whose
    layer>0 hidden states mix the chunks (the TurboRAG independent-
    chunk approximation); for ONE chunk the two oracles coincide."""
    L, KVH, Dh = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    ks, vs = [], []
    base = 0
    for d in order:
        c = store.get(d)
        assert c.length % ps == 0, "aligned chunks only (no holes)"
        k = jnp.asarray(c.k.reshape(L, -1, KVH, Dh), jnp.float32)
        v = jnp.asarray(c.v.reshape(L, -1, KVH, Dh), jnp.float32)
        ks.append(apply_rope(k, jnp.full((k.shape[1],), base)))
        vs.append(v)
        base += c.length
    cache = tf.init_cache(cfg, 1, base + steps, jnp.float32)
    cache["k"] = cache["k"].at[:, 0, :base].set(jnp.concatenate(ks, 1))
    cache["v"] = cache["v"].at[:, 0, :base].set(jnp.concatenate(vs, 1))
    logits_seq, toks = [], []
    tok = jnp.zeros((1,), jnp.int32)
    for t in range(steps):
        logits, cache = tf.serve_step(
            params, cache, {"token": tok,
                            "pos": jnp.full((1,), base + t, jnp.int32)}, cfg)
        logits_seq.append(np.asarray(logits))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(int(tok[0]))
    return logits_seq, toks


def test_spliced_decode_multi_chunk_orderings_match_oracle(tparams):
    """Two chunks spliced [A, B] in row 0 and [B, A] in row 1 of ONE
    batch: each row must match the assembled-rotated-cache oracle for
    its own order (order changes the context, deltas differ per row,
    parity must hold for both rows simultaneously)."""
    mgr, cache, lease, pinned, _ = _splice_env(
        tparams, [[3, 5], [5, 3]], {3: 8, 5: 4})
    assert lease.spliced_pages == 6 and list(lease.lengths) == [12, 12]
    # row 0: chunk 5 sits at base block 2 -> delta 8; row 1: chunk 3 at
    # base block 1 -> delta 4
    assert list(lease.page_delta[0][:3]) == [0, 0, 8]
    assert list(lease.page_delta[1][:3]) == [0, 4, 4]
    got_logits, got_toks = _spliced_greedy(tparams, TINY, mgr, lease, 3)
    for row, order in enumerate(([3, 5], [5, 3])):
        want_logits, want_toks = _assembled_dense_oracle(
            tparams, TINY, cache.store, order, 3)
        assert [int(t[row]) for t in got_toks] == want_toks, f"row {row}"
        for g, w in zip(got_logits, want_logits):
            np.testing.assert_allclose(g[row][None], w, rtol=2e-4,
                                       atol=2e-4)
    # the two orders are genuinely different contexts
    assert not np.allclose(got_logits[0][0], got_logits[0][1], atol=1e-3)
    mgr.release_paged(lease)
    cache.release_rows(pinned)


def test_spliced_decode_ragged_chunk_garbage_invariant(tparams):
    """A ragged chunk (partial last page) decoded end-to-end: poisoning
    the page's dead tail in the slab changes nothing."""
    mgr, cache, lease, pinned, _ = _splice_env(tparams, [[11]], {11: 5})
    assert lease.spliced_pages == 2
    assert list(lease.lengths) == [8], "resume at next page boundary"
    assert lease.page_valid[0][1] == 1
    hole_slot = int(lease.block_table[0, 1])
    k0, v0 = mgr.slab.k, mgr.slab.v
    bt, lens, dl, vd = lease.device_splice_tables()
    tok = jnp.zeros((1,), jnp.int32)
    clean, _, _ = tf.serve_step_paged_spliced(
        tparams, k0, v0, bt, lens, dl, vd, {"token": tok}, TINY)
    dirty, _, _ = tf.serve_step_paged_spliced(
        tparams, k0.at[:, hole_slot, 1:].set(1e9),
        v0.at[:, hole_slot, 1:].set(-1e9), bt, lens, dl, vd,
        {"token": tok}, TINY)
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(dirty))
    mgr.release_paged(lease)
    cache.release_rows(pinned)


def test_hypothesis_spliced_decode_vs_oracles(tparams):
    """Randomized aligned multi-chunk orderings: greedy tokens exact
    and logits within tolerance of the assembled-cache oracle (which
    for a single chunk IS full re-prefill)."""
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(max_examples=8, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(n_pages=st.lists(st.integers(1, 3), min_size=1, max_size=3),
           perm_seed=st.integers(0, 5), steps=st.integers(1, 3))
    def check(n_pages, perm_seed, steps):
        ps = 4
        doc_lens = {10 + i: n * ps for i, n in enumerate(n_pages)}
        order = list(doc_lens)
        np.random.default_rng(perm_seed).shuffle(order)
        mgr, cache, lease, pinned, _ = _splice_env(
            tparams, [order], doc_lens, ps=ps)
        got_logits, got_toks = _spliced_greedy(tparams, TINY, mgr, lease,
                                               steps)
        want_logits, want_toks = _assembled_dense_oracle(
            tparams, TINY, cache.store, order, steps, ps=ps)
        assert [int(t[0]) for t in got_toks] == want_toks
        for g, w in zip(got_logits, want_logits):
            np.testing.assert_allclose(g, w, rtol=5e-4, atol=5e-4)
        if len(order) == 1:
            ctx = chunk_tokens(order[0], TINY.vocab_size, seed=0,
                               min_len=doc_lens[order[0]],
                               max_len=doc_lens[order[0]])
            rl, rt = _prefill_oracle(tparams, TINY, ctx, steps)
            assert rt == want_toks       # one chunk: oracles coincide
        mgr.release_paged(lease)
        cache.release_rows(pinned)

    check()


# ---------------------------------------------------------------------------
# Splice mechanics on the manager (block-table edit discipline)
# ---------------------------------------------------------------------------


def test_splice_paged_edits_table_and_keeps_ownership(tparams):
    mgr, cache, lease, pinned, _ = _splice_env(tparams, [[7], []], {7: 8})
    free_before = set(mgr.slab.free)
    owned = set(lease.owned_slots)
    chunk_slots = set(int(s) for s in lease.block_table[0, :2])
    assert chunk_slots.isdisjoint(owned)
    assert lease.max_len == 8 + 8                 # lead pages widen bounds
    assert list(lease.lengths) == [8, 0]          # row 1 spliced nothing
    assert (lease.page_valid[0, :2] == [4, 4]).all()
    assert (lease.page_valid[lease.block_table < 0] == 0).all()
    mgr.release_paged(lease)
    # ONLY the owned slots return to the free list; the chunk's pages
    # stay with the residency (freeing them would alias live pages)
    assert set(mgr.slab.free) == free_before | owned
    assert chunk_slots.isdisjoint(mgr.slab.free)
    cache.release_rows(pinned)
    assert cache.resident_pages() == 2            # warm, not freed


def test_splice_paged_rejects_bad_rows(tparams):
    mgr = KVCacheManager(TINY, dtype=jnp.float32)
    mgr.init_paged(num_pages=16, page_size=4)
    lease = mgr.acquire_paged(2, 8)
    with pytest.raises(ValueError):               # row count mismatch
        mgr.splice_paged(lease, [[]])
    with pytest.raises(ValueError):               # page count vs length
        mgr.splice_paged(lease, [[((1, 2), 3)], []])
    assert mgr.splice_paged(lease, [[], []]) == 0
    assert lease.spliced_pages == 0 and lease.page_delta is None
    z = np.zeros((TINY.num_layers, 2, TINY.num_kv_heads,
                  TINY.resolved_head_dim), np.float32)
    mgr.append_paged(lease, z, z)
    with pytest.raises(ValueError):               # not fresh anymore
        mgr.splice_paged(lease, [[((1,), 4)], []])
    mgr.release_paged(lease)


# ---------------------------------------------------------------------------
# ChunkKVCache residency: refcounts, LRU, accounting
# ---------------------------------------------------------------------------


def _pool_cache(small_index, tparams, *, slab_pages=32, pool_pages=128,
                docs=(1, 2, 3), lens=(5, 8, 9), cluster_of=None):
    pool = DevicePagePool(small_index.paged, pool_pages, jnp.float32)
    pool.recorder = FlightRecorder()
    pool.replica_id = 0
    mgr = KVCacheManager(TINY, dtype=jnp.float32, pool=pool)
    mgr.init_paged(num_pages=slab_pages, page_size=4)
    store = ChunkKVStore(page_size=4)
    for d, ln in zip(docs, lens):
        store.add(d, build_chunk(tparams, TINY, d, page_size=4,
                                 min_len=ln, max_len=ln,
                                 cluster=(-1 if cluster_of is None
                                          else cluster_of(d))))
    return pool, mgr, ChunkKVCache(mgr, store)


def test_residency_lifecycle_refcounts_and_ledger(small_index, tparams):
    pool, mgr, cache = _pool_cache(small_index, tparams)
    free0 = len(mgr.slab.free)
    res = cache.load(1, tenant="acme")            # 5 tokens -> 2 pages
    assert res.slots and len(mgr.slab.free) == free0 - 2
    assert pool.ledger.bytes_of("chunk_kv") == 2 * mgr.paged_page_nbytes()
    assert pool.tenant_bytes("acme", owner="chunk_kv") > 0
    assert cache.load(1, tenant="acme") is res    # idempotent re-load
    assert cache.stats.loads == 1
    cache.pin(1)
    cache.pin(1)
    with pytest.raises(ValueError):
        cache.evict(1)                            # pinned -> protected
    with pytest.raises(RuntimeError):
        cache.drain()
    cache.unpin(1)
    cache.unpin(1)
    with pytest.raises(ValueError):
        cache.unpin(1)                            # not pinned anymore
    with pytest.raises(KeyError):
        cache.pin(99)                             # pin-before-load
    assert cache.evict(1) == 2
    assert len(mgr.slab.free) == free0
    assert pool.ledger.bytes_of("chunk_kv") == 0
    assert cache.load(77) is None                 # store miss -> fallback
    rep = check_recorder(pool.recorder, drained=True,
                         must_drain=("chunk_kv",))
    assert rep.ok, rep.summary()
    assert rep.stats["chunk_loads"] == 1


def test_evict_cold_is_lru_and_skips_pinned(small_index, tparams):
    _, mgr, cache = _pool_cache(small_index, tparams)
    for d in (1, 2, 3):
        cache.load(d)
    cache.load(1)                                 # refresh 1 -> 2 is LRU
    cache.pin(2)                                  # ... but 2 is pinned
    cache.evict_cold(pages_hint=1)
    assert 3 not in cache.resident                # next-coldest unpinned
    assert 1 in cache.resident and 2 in cache.resident
    cache.unpin(2)
    assert cache.drain() == 2 + 2                 # docs 1 and 2, 2 pages each
    assert not cache.resident and cache.stats.evictions == 3


def test_page_size_mismatch_rejected(tparams):
    mgr = KVCacheManager(TINY, dtype=jnp.float32)
    mgr.init_paged(num_pages=8, page_size=4)
    with pytest.raises(ValueError):
        ChunkKVCache(mgr, ChunkKVStore(page_size=8))


def test_acquire_rows_stats_and_backfill(small_index, tparams):
    _, mgr, cache = _pool_cache(small_index, tparams)
    rows, pinned, misses = cache.acquire_rows([[1, 99], [2]])
    assert [len(r) for r in rows] == [1, 1] and misses == [[99], []]
    assert cache.stats.hits == 2 and cache.stats.misses == 1
    assert cache.stats.prefill_tokens_avoided == 5 + 8
    assert cache.stats.spliced_pages == 2 + 2
    assert sorted(pinned) == [1, 2] and cache.pinned_pages() == 4
    cache.release_rows(pinned)
    assert cache.pinned_pages() == 0
    # miss-path backfill: prefill once now, hit forever after
    assert cache.backfill(99, tparams, TINY, min_len=6, max_len=6)
    assert cache.backfill(99, tparams, TINY) is None   # already there
    assert cache.stats.backfills == 1
    rows2, pinned2, misses2 = cache.acquire_rows([[99]])
    assert misses2 == [[]] and len(rows2[0]) == 1
    cache.release_rows(pinned2)
    cache.drain()


def test_prefetch_clusters_budget_and_room(small_index, tparams):
    _, mgr, cache = _pool_cache(small_index, tparams,
                                cluster_of=lambda d: d % 2)
    landed = cache.prefetch_clusters([1], budget_pages=2)   # docs 1, 3
    assert landed == 2                       # doc 1 (2 pages) hits budget
    assert cache.stats.prefetched_pages == 2
    assert 1 in cache.resident and 3 not in cache.resident
    assert cache.prefetch_clusters([0]) == 2                # doc 2
    cache.drain()
    # a slab too small for the chunk stops the burst instead of raising
    _, mgr2, cache2 = _pool_cache(small_index, tparams, slab_pages=1,
                                  cluster_of=lambda d: d % 2)
    assert cache2.prefetch_clusters([1]) == 0


def test_chunk_load_under_pool_pressure_evicts_cold(small_index, tparams):
    """When the POOL (not the slab) is the constraint, loading spills
    cold residency first and only then reports no-room."""
    pool, mgr, cache = _pool_cache(small_index, tparams, slab_pages=32,
                                   pool_pages=1)
    assert cache.load(1) is not None         # the one pool page
    assert pool.ledger.bytes_of("chunk_kv") > 0
    assert cache.load(2) is not None         # evicts 1 to make room
    assert 1 not in cache.resident and cache.stats.evictions == 1
    cache.pin(2)
    assert cache.load(3) is None             # pinned -> nothing to spill
    cache.unpin(2)
    cache.drain()
    assert pool.ledger.bytes_of("chunk_kv") == 0


# ---------------------------------------------------------------------------
# End-to-end serve: splice + lookahead prefetch on a real server
# ---------------------------------------------------------------------------


def _serve_chunk(small_index, q, traces, *, params, store, micro_batch=3,
                 max_steps=4, page_size=4, slab_seqs=None):
    n = len(traces)
    runner = DecodeRunner(params, SERVE_CFG, max_len=24,
                          max_steps=max_steps, page_size=page_size,
                          slab_seqs=slab_seqs if slab_seqs is not None
                          else n + 8, chunk_store=store)
    srv = TeleRAGServer(small_index, EngineConfig(
        nprobe=8, top_k=3, buffer_pages=256, pool_pages=4096,
        lookahead_rank=16, kernel_mode="ref", chips=8, seed=7,
        paged_decode=True, chunk_kv=store is not None), 1, ARCH,
        micro_batch=micro_batch, include_tail=True, decode_hook=runner,
        continuous=True)
    runner.attach(srv)
    resp = srv.serve([RagRequest(q=q[i], trace=traces[i], arrival_t=0.0)
                      for i in range(n)])
    return runner, srv, resp


def _round_docs(resp):
    return [[sorted(int(x) for x in d) for d in r.doc_ids] for r in resp]


@pytest.mark.slow
def test_serve_splices_prefetches_and_drains(small_store, small_index, rng,
                                             serve_params):
    """The whole tentpole on a live server: retrieval docs resolve to
    precomputed pages, waves decode through the spliced step, lookahead
    lands pages ahead of the splice, retrieval is unchanged vs the
    chunk-less run, and everything drains to zero."""
    q = unit_queries(small_store, rng, 4)
    traces = make_traces("iter", 4, seed=11)
    r0, s0, resp0 = _serve_chunk(small_index, q, traces,
                                 params=serve_params, store=None)
    assert all(r.state == RequestState.COMPLETE for r in resp0)
    docs = sorted({int(d) for r in resp0 for rd in r.doc_ids for d in rd})
    assert docs, "no retrieval rounds ran"
    store = build_chunk_kv(
        serve_params, SERVE_CFG, docs, page_size=4, seed=3, min_len=6,
        max_len=8,
        cluster_of=cluster_map_from_assignments(small_index.assignments))
    r1, s1, resp1 = _serve_chunk(small_index, q, traces,
                                 params=serve_params, store=store)
    assert all(r.state == RequestState.COMPLETE for r in resp1)
    ck = r1.chunk(0)
    assert ck is not None, "chunk cache never attached"
    st = ck.stats
    assert st.hits > 0 and st.spliced_pages > 0
    assert st.prefill_tokens_avoided >= st.hits * 6
    assert r1.stats["spliced_waves"] > 0
    assert st.hits / (st.hits + st.misses) == 1.0, \
        "every retrieved doc was built offline; all splices must hit"
    # lookahead prefetch landed pages ahead of the splice
    assert st.prefetched_pages > 0
    # retrieval itself is untouched by splicing
    assert _round_docs(resp1) == _round_docs(resp0)
    # teardown: warm residency + kv buckets drain to a zero ledger
    for runner, srv in ((r0, s0), (r1, s1)):
        chunk = runner.chunk(0)
        if chunk is not None:
            chunk.drain()
        runner.kv(0).drop_all()
        eng = srv.engines[0]
        assert eng.ledger.bytes_of("kv") == 0
        assert eng.ledger.bytes_of("chunk_kv") == 0
    rep = check_recorder(s1.recorder, drained=True,
                         must_drain=("kv", "chunk_kv"))
    assert rep.ok, rep.summary()
    assert rep.stats["chunk_loads"] > 0
    kinds = {getattr(e, "kind", "") for e in s1.recorder.events}
    assert "kv.splice" in kinds and "chunk.pin" in kinds


@pytest.mark.slow
def test_serve_partial_store_mixes_hits_and_misses(small_store, small_index,
                                                   rng, serve_params):
    """Half-coverage store: misses fall back to the plain path (no
    crash, requests complete) and the hit-rate telemetry reflects it."""
    q = unit_queries(small_store, rng, 3)
    traces = make_traces("iter", 3, seed=5)
    r0, _, resp0 = _serve_chunk(small_index, q, traces,
                                params=serve_params, store=None)
    docs = sorted({int(d) for r in resp0 for rd in r.doc_ids for d in rd})
    store = build_chunk_kv(serve_params, SERVE_CFG, docs[:len(docs) // 2],
                           page_size=4, seed=3, min_len=6, max_len=8)
    r1, s1, resp1 = _serve_chunk(small_index, q, traces,
                                 params=serve_params, store=store)
    assert all(r.state == RequestState.COMPLETE for r in resp1)
    st = r1.chunk(0).stats
    assert st.misses > 0, "half the docs are not in the store"
    if st.hits:
        assert r1.stats["spliced_waves"] > 0
    tel = s1.telemetry()
    ch = tel.replicas[0].chunk_kv
    assert ch and ch["misses"] == st.misses
    r1.chunk(0).drain()
    r1.kv(0).drop_all()
    r0.kv(0).drop_all()
