"""Serving engine integration: pipelines, modes, fault tolerance."""

import numpy as np
import pytest

import repro.core as core
from repro.configs import get_arch
from repro.serving import (EngineConfig, MultiReplicaOrchestrator,
                           PipelineExecutor, TeleRAGEngine, make_traces,
                           calibration_windows, PIPELINES)
from tests.conftest import unit_queries


@pytest.fixture()
def engine(small_index):
    cfg = EngineConfig(nprobe=16, top_k=3, buffer_pages=160,
                       lookahead_rank=32, kernel_mode="ref", chips=8)
    return TeleRAGEngine(small_index, cfg, get_arch("llama3-8b"))


@pytest.mark.parametrize("pipe", PIPELINES)
def test_pipeline_executes_and_speedup_model(small_store, small_index, rng,
                                             engine, pipe):
    ex = PipelineExecutor(engine)
    q = unit_queries(small_store, rng, 4)
    traces = make_traces(pipe, 4, seed=1)
    res = ex.execute_batch(q, traces)
    assert len(res) == 4
    for r, t in zip(res, traces):
        assert len(r.rounds) == t.rounds
        assert all(d.shape == (3,) for d in r.doc_ids)
        tele = r.latency("telerag", t_cc=engine.effective_tcc(),
                         cluster_bytes=1e6, link_bw=32e9)
        cpu = r.latency("cpu_baseline", t_cc=engine.effective_tcc(),
                        cluster_bytes=1e6, link_bw=32e9)
        assert tele <= cpu + 1e-9         # overlap can only help the model


def test_modes_agree_on_results(small_store, small_index, rng):
    """All three modes must return identical retrieval results — they
    differ in WHERE the search runs, never in WHAT it returns."""
    q = unit_queries(small_store, rng, 3)
    traces = make_traces("hyde", 3, seed=9)
    outs = {}
    for mode in ("telerag", "cpu_baseline", "runtime_fetch"):
        cfg = EngineConfig(nprobe=12, top_k=3, buffer_pages=256,
                           lookahead_rank=24, kernel_mode="ref", mode=mode,
                           seed=5)
        eng = TeleRAGEngine(small_index, cfg, get_arch("llama3-8b"))
        ex = PipelineExecutor(eng)
        res = ex.execute_batch(q.copy(), [t for t in traces])
        outs[mode] = np.stack([np.sort(np.concatenate(r.doc_ids))
                               for r in res])
    np.testing.assert_array_equal(outs["telerag"], outs["cpu_baseline"])
    np.testing.assert_array_equal(outs["telerag"], outs["runtime_fetch"])


def test_multi_round_incremental_prefetch(small_store, small_index, rng,
                                          engine):
    """IRG does 3 rounds on drifting queries: later rounds should reuse
    earlier fetches (bytes decrease or stay flat per round)."""
    ex = PipelineExecutor(engine)
    q = unit_queries(small_store, rng, 2)
    traces = make_traces("irg", 2, seed=3)
    res = ex.execute_batch(q, traces)
    per_round = {}
    for r in res:
        for rt in r.rounds:
            per_round.setdefault(rt.round_index, 0)
            per_round[rt.round_index] += rt.bytes_prefetched
    # IRG round 0 retrieves before any generation window -> no lookahead
    # budget (t_LLM = 0); rounds 1,2 prefetch and later rounds reuse
    # earlier fetches on the drifting query (incremental top-up, §4.3)
    assert per_round[0] == 0
    assert per_round[1] > 0
    assert per_round[2] <= per_round[1] * 1.5 + 1


def test_cache_improves_second_batch(small_store, small_index, rng):
    cfg = EngineConfig(nprobe=16, top_k=3, buffer_pages=200,
                       lookahead_rank=32, kernel_mode="ref",
                       cache_enabled=True, seed=2)
    eng = TeleRAGEngine(small_index, cfg, get_arch("llama3-8b"))
    ex = PipelineExecutor(eng)
    q = unit_queries(small_store, rng, 4)
    ex.execute_batch(q, make_traces("hyde", 4, seed=4))
    h2d_first = eng.buffer.stats.bytes_h2d
    # same neighbourhood of queries again: cached clusters cut transfers
    q2 = q + 0.02 * rng.standard_normal(q.shape).astype(np.float32)
    q2 /= np.linalg.norm(q2, axis=-1, keepdims=True)
    ex.execute_batch(q2, make_traces("hyde", 4, seed=5))
    h2d_second = eng.buffer.stats.bytes_h2d - h2d_first
    assert h2d_second <= h2d_first


def test_engine_snapshot_restore_roundtrip(small_store, small_index, rng,
                                           engine):
    ex = PipelineExecutor(engine)
    q = unit_queries(small_store, rng, 2)
    engine.cfg.cache_enabled = True
    ex.execute_batch(q, make_traces("iter", 2, seed=6))
    snap = engine.snapshot()
    eng2 = TeleRAGEngine(small_index, engine.cfg, get_arch("llama3-8b"))
    eng2.restore(snap)
    assert eng2.buffer.resident_clusters() == engine.buffer.resident_clusters()
    assert eng2.cache.hotness == engine.cache.hotness
    # restored replica serves correctly
    res = PipelineExecutor(eng2).execute_batch(q, make_traces("hyde", 2, seed=7))
    assert all(len(r.doc_ids) > 0 for r in res)


def test_restore_rebinds_subscribers_and_keeps_admission_stats(
        small_store, small_index, rng, engine):
    """Replica restart must (a) carry page-free listeners onto the
    replacement pool through the public rebind API — long-lived runtimes
    keep waking on pressure events — and (b) restore admission telemetry
    instead of silently zeroing it."""
    ex = PipelineExecutor(engine)
    q = unit_queries(small_store, rng, 2)
    engine.cfg.cache_enabled = True
    ex.execute_batch(q, make_traces("iter", 2, seed=6))
    freed = []
    engine.pool.subscribe(freed.append)
    snap = engine.snapshot()
    assert snap["admission"]["admitted"] > 0
    stats_before = engine.admission.stats

    engine.restore(snap)
    assert engine.admission.stats == stats_before
    # the pre-restore listener still hears the REPLACEMENT pool
    lease = engine.pool.lease_slots(2)
    engine.pool.release(lease)
    assert freed and freed[-1] == 2
    # restoring into a fresh replica carries the stats too; snapshots
    # from before the admission key keep the fresh zeros (back-compat)
    eng2 = TeleRAGEngine(small_index, engine.cfg, get_arch("llama3-8b"))
    eng2.restore(snap)
    assert eng2.admission.stats == stats_before
    del snap["admission"]
    eng3 = TeleRAGEngine(small_index, engine.cfg, get_arch("llama3-8b"))
    eng3.restore(snap)
    assert eng3.admission.stats.admitted == 0


def test_orchestrator_with_dead_replica(small_store, small_index, rng):
    cfg = EngineConfig(nprobe=12, top_k=3, buffer_pages=128,
                       lookahead_rank=24, kernel_mode="ref",
                       cache_enabled=True)
    orch = MultiReplicaOrchestrator(small_index, cfg, 3,
                                    get_arch("llama3-8b"))
    q = unit_queries(small_store, rng, 12)
    rep = orch.run_global_batch(q, make_traces("hyde", 12, seed=8),
                                micro_batch=4, dead_replicas={1})
    assert all(a[1] != 1 for a in rep.assignments)
    assert len(rep.all_results()) == 12


def test_calibration_windows_positive():
    for p in PIPELINES:
        ws = calibration_windows(p, n=16)
        assert len(ws) >= 16 and all(w >= 0 for w in ws)
        if p != "irg":                       # IRG round 1 has no window
            assert np.mean(ws) > 0
