"""End-to-end driver: multi-replica TeleRAG serving with batched requests.

Exercises the full Fig.-7 system: prefetching scheduler groups a global
batch by embedding similarity, the cache-aware scheduler routes micro-
batches to replicas, each replica runs lookahead + hybrid retrieval with
REAL decode on a reduced LLM, and a straggler is killed mid-run to show
the re-queue path.

Run: PYTHONPATH=src python examples/serve_rag.py [--requests 24]
"""

import argparse
import time

import numpy as np

import repro.core as core
from repro.configs import get_arch
from repro.core.schedulers import TeleRAGScheduler
from repro.serving import (EngineConfig, MultiReplicaOrchestrator,
                           latency_summary, make_traces)


def latency_line(rep):
    """Per-request admit->complete latency from the runtime event clock."""
    return latency_summary(rep.records)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--micro-batch", type=int, default=4)
    ap.add_argument("--pipeline", default="hyde")
    args = ap.parse_args()

    store = core.synthetic_datastore(60_000, dim=160, seed=1)
    index = core.build_ivf(store, 96, page_size=96, kmeans_iters=4)
    cfg = EngineConfig(nprobe=24, top_k=3, buffer_pages=384,
                       lookahead_rank=48, kernel_mode="ref",
                       cache_enabled=True, chips=4)
    orch = MultiReplicaOrchestrator(index, cfg, args.replicas,
                                    get_arch("llama3-8b"),
                                    scheduler=TeleRAGScheduler())

    rng = np.random.default_rng(2)

    def wave(n, seed):
        q = store.embeddings[rng.choice(store.num_vectors, n)]
        q = q + 0.05 * rng.standard_normal(q.shape).astype(np.float32)
        return q / np.linalg.norm(q, axis=-1, keepdims=True)

    print(f"== wave 1: {args.requests} requests on {args.replicas} replicas ==")
    t0 = time.time()
    rep = orch.run_global_batch(wave(args.requests, 3),
                                make_traces(args.pipeline, args.requests,
                                            seed=3),
                                micro_batch=args.micro_batch)
    hits = sum(rt.hits for r in rep.all_results() for rt in r.rounds)
    miss = sum(rt.misses for r in rep.all_results() for rt in r.rounds)
    print(f"done in {time.time()-t0:.1f}s wall; hit {hits/(hits+miss):.0%}; "
          f"sched overhead {rep.schedule_overhead_s*1e3:.0f} ms; "
          f"assignments {rep.assignments}")
    print(latency_line(rep))

    print("\n== wave 2: warm caches raise routing overlap ==")
    rep2 = orch.run_global_batch(wave(args.requests, 4),
                                 make_traces(args.pipeline, args.requests,
                                             seed=4),
                                 micro_batch=args.micro_batch)
    print(f"cache-overlap per assignment: {[a[2] for a in rep2.assignments]}")
    print(latency_line(rep2))
    # routing sees real memory state: per-replica ledger occupancy
    for i, e in enumerate(orch.replicas):
        led = e.ledger.snapshot()
        print(f"replica {i}: prefetch={led.get('prefetch', 0)/1e6:.2f}MB "
              f"peak={led['peak']/1e9:.2f}GB occ={e.ledger.occupancy():.2%} "
              f"admission(admitted={e.admission.stats.admitted} "
              f"stalled={e.admission.stats.stalled} "
              f"spilled_pages={e.admission.stats.spilled_pages})")

    print("\n== wave 3: replica 1 dies; batches re-queue ==")
    rep3 = orch.run_global_batch(wave(args.requests, 5),
                                 make_traces(args.pipeline, args.requests,
                                             seed=5),
                                 micro_batch=args.micro_batch,
                                 dead_replicas={1})
    print(f"re-queued micro-batches: {rep3.requeued}; "
          f"all {len(rep3.all_results())} requests served")
    print(latency_line(rep3))

    print("\n== replica snapshot/restore (fault tolerance) ==")
    snap = orch.replicas[0].snapshot()
    orch.replicas[0].restore(snap)
    print(f"replica 0 restored: {len(snap['resident'])} clusters resident, "
          f"{snap['stats'][0]/1e6:.1f} MB lifetime h2d")


if __name__ == "__main__":
    main()
