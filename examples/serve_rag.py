"""End-to-end driver: multi-replica TeleRAG serving through the unified
``TeleRAGServer`` front-end.

Exercises the full Fig.-7 system as ONE surface: wave 1 is closed-loop
batch replay (simultaneous arrivals), wave 2 is an OPEN-LOOP Poisson
arrival stream — the continuous dispatcher admits requests at their
arrival times, routes them per wave with the cache-aware scheduler
(reading live cache residency + ledger occupancy), and interleaves the
replica runtimes on one shared event clock, so queue wait and
latency-under-load are measured quantities.  Wave 3 kills a replica to
show the re-queue path, then a replica snapshot/restore round-trips the
admission telemetry.  Wave 4 is the multi-tenant SLO mix: a
deadline-carrying interactive tenant (with a guaranteed pool floor)
shares the fleet with a bursty batch tenant; EDF dispatch + per-tenant
reservations keep the interactive tenant's deadlines while both
complete, and the per-tenant telemetry lines show the split.  Wave 5
contrasts static-group execution with per-request continuous batching
(`continuous=True`): the dynamic wave former re-batches at every round
frontier, so heterogeneous round counts stop dragging batch-mates.

Run: PYTHONPATH=src python examples/serve_rag.py [--requests 24]
"""

import argparse
import time

import numpy as np

import repro.core as core
from repro.configs import get_arch
from repro.core.schedulers import TeleRAGScheduler
from repro.serving import (EngineConfig, RagRequest, TeleRAGServer,
                           make_traces, summarize_latency)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--micro-batch", type=int, default=4)
    ap.add_argument("--pipeline", default="hyde")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="open-loop offered load for wave 2 (modeled req/s)")
    args = ap.parse_args()

    store = core.synthetic_datastore(60_000, dim=160, seed=1)
    index = core.build_ivf(store, 96, page_size=96, kmeans_iters=4)
    cfg = EngineConfig(nprobe=24, top_k=3, buffer_pages=384,
                       lookahead_rank=48, kernel_mode="ref",
                       cache_enabled=True, chips=4)
    srv = TeleRAGServer(index, cfg, args.replicas, get_arch("llama3-8b"),
                        scheduler=TeleRAGScheduler(),
                        micro_batch=args.micro_batch)

    rng = np.random.default_rng(2)

    def wave(n):
        q = store.embeddings[rng.choice(store.num_vectors, n)]
        q = q + 0.05 * rng.standard_normal(q.shape).astype(np.float32)
        return q / np.linalg.norm(q, axis=-1, keepdims=True)

    print(f"== wave 1: {args.requests} simultaneous requests on "
          f"{args.replicas} replicas ==")
    q1 = wave(args.requests)
    traces = make_traces(args.pipeline, args.requests, seed=3)
    t0 = time.time()
    resp = srv.serve([RagRequest(q=q1[i], trace=traces[i])
                      for i in range(args.requests)])
    hits = sum(rt.hits for r in resp for rt in r.rounds)
    miss = sum(rt.misses for r in resp for rt in r.rounds)
    w = srv.wave_log[-1]
    print(f"done in {time.time()-t0:.1f}s wall; hit {hits/(hits+miss):.0%}; "
          f"sched overhead {w.sched_overhead_s*1e3:.0f} ms; "
          f"assignments {w.assignments}")
    print(summarize_latency(resp))

    print(f"\n== wave 2: open-loop Poisson arrivals at {args.rate:.0f} "
          f"modeled req/s (warm caches raise routing overlap) ==")
    q2 = wave(args.requests)
    traces2 = make_traces(args.pipeline, args.requests, seed=4)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    n_waves0 = len(srv.wave_log)
    resp2 = srv.serve([RagRequest(q=q2[i], trace=traces2[i],
                                  arrival_t=float(arrivals[i]))
                       for i in range(args.requests)])
    waves2 = srv.wave_log[n_waves0:]
    print(f"{len(waves2)} arrival waves; cache-overlap per assignment: "
          f"{[a[2] for w in waves2 for a in w.assignments]}")
    print(summarize_latency(resp2))
    by_replica = {}
    for r in resp2:
        by_replica.setdefault(r.replica, []).append(r)
    for i in sorted(by_replica):
        rs = by_replica[i]
        print(f"replica {i}: {len(rs)} requests, "
              f"mean queue {np.mean([r.queue_s for r in rs])*1e3:.1f}ms")

    print("\n== wave 3: replica 1 dies; micro-batches re-queue ==")
    srv.mark_dead(1)
    q3 = wave(args.requests)
    traces3 = make_traces(args.pipeline, args.requests, seed=5)
    n_waves0 = len(srv.wave_log)
    resp3 = srv.serve([RagRequest(q=q3[i], trace=traces3[i])
                       for i in range(args.requests)])
    requeued = [b for w in srv.wave_log[n_waves0:] for b in w.requeued]
    print(f"re-queued micro-batches: {requeued}; "
          f"all {len(resp3)} requests served "
          f"(replicas used: {sorted({r.replica for r in resp3})})")
    print(summarize_latency(resp3))
    srv.mark_alive(1)

    print("\n== wave 4: multi-tenant SLO mix (interactive floor + "
          "batch burst) ==")
    cfg_mt = EngineConfig(nprobe=24, top_k=3, buffer_pages=384,
                          lookahead_rank=48, kernel_mode="ref",
                          cache_enabled=True, chips=4,
                          tenant_shares={"interactive": (96, None),
                                         "batch": (0, 288)})
    srv_mt = TeleRAGServer(index, cfg_mt, 2, get_arch("llama3-8b"),
                           scheduler=TeleRAGScheduler(), micro_batch=2)
    n_i, n_b = max(1, args.requests // 3), args.requests
    q_i, q_b = wave(n_i), wave(n_b)
    t_i = make_traces(args.pipeline, n_i, seed=6)
    t_b = make_traces(args.pipeline, n_b, seed=7)
    # calibrate the deadline on a throwaway server so the solo run does
    # not pollute srv_mt's per-tenant telemetry
    srv_cal = TeleRAGServer(index, cfg_mt, 1, get_arch("llama3-8b"))
    solo = srv_cal.serve([RagRequest(q=q_i[0], trace=t_i[0],
                                     tenant="interactive")])[0].latency_s
    reqs = [RagRequest(q=q_b[i], trace=t_b[i], tenant="batch", priority=1)
            for i in range(n_b)]
    reqs += [RagRequest(q=q_i[i], trace=t_i[i], tenant="interactive",
                        priority=0, deadline_s=5.0 * solo,
                        arrival_t=0.01 + 0.5 * solo * i)
             for i in range(n_i)]
    resp4 = srv_mt.serve(reqs)
    tele = srv_mt.telemetry()
    for t in tele.tenants:
        print(t.line())
    missed = [r.request_id for r in resp4 if r.deadline_missed]
    print(f"all {len(resp4)} served; deadline misses: {missed or 'none'}")

    print("\n== wave 5: per-request continuous batching vs static "
          "groups (heterogeneous round counts) ==")
    n5 = args.requests
    q5 = wave(n5)
    pipes = ["hyde", "iter", "irg", "flare"]
    mixed = [make_traces(pipes[i % len(pipes)], 1, seed=8 + i)[0]
             for i in range(n5)]
    for i, t in enumerate(mixed):
        t.request_id = i
    for continuous in (False, True):
        srv5 = TeleRAGServer(index, cfg, 1, get_arch("llama3-8b"),
                             micro_batch=args.micro_batch,
                             continuous=continuous)
        resp5 = srv5.serve([
            RagRequest(q=q5[i], trace=mixed[i], arrival_t=0.002 * i)
            for i in range(n5)])
        label = "per-request" if continuous else "static-groups"
        n_waves = sum(len(rt.wave_log) for rt in srv5.runtimes)
        print(f"{label:>14}: {summarize_latency(resp5)} "
              f"({n_waves} waves executed)")

    print("\n== unified telemetry snapshot ==")
    print(srv.telemetry().summary())

    print("\n== replica snapshot/restore (fault tolerance) ==")
    snap = srv.engines[0].snapshot()
    srv.engines[0].restore(snap)
    print(f"replica 0 restored: {len(snap['resident'])} clusters resident, "
          f"{snap['stats'][0]/1e6:.1f} MB lifetime h2d, admission stats "
          f"carried (admitted={snap['admission']['admitted']})")


if __name__ == "__main__":
    main()
