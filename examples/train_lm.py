"""Train a ~100M-parameter llama-family model for a few hundred steps.

Thin wrapper over the production driver (repro.launch.train) — same code
path the cluster uses, scaled to one host. Demonstrates checkpoint/resume:
the run is interrupted halfway and resumed from the latest checkpoint.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import subprocess
import sys
import tempfile


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3-8b")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        half = args.steps // 2
        base = [sys.executable, "-m", "repro.launch.train",
                "--arch", args.arch, "--preset", "100m",
                "--batch", "8", "--seq", "256",
                "--ckpt-dir", ckpt, "--ckpt-every", "25"]
        print(f"== phase 1: steps 0..{half} (then 'crash') ==")
        subprocess.run(base + ["--steps", str(half)], check=True)
        print(f"== phase 2: resume from checkpoint -> {args.steps} ==")
        subprocess.run(base + ["--steps", str(args.steps)], check=True)


if __name__ == "__main__":
    main()
