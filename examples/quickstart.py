"""Quickstart: one RAG query through TeleRAG's lookahead retrieval.

Builds a small synthetic datastore + IVF index, then runs the paper's
§4.1 flow end to end:
  1. probe the *input* query and prefetch its clusters (async dispatch)
  2. run real LLM decode steps (reduced llama) — the generation window
     that hides the transfer
  3. rewrite -> probe -> hybrid search (device hits + host misses)
  4. merge on device and show the retrieved documents

Run: PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

import repro.core as core
from repro.configs import get_arch
from repro.models import transformer as tf
from repro.serving import EngineConfig, TeleRAGEngine, sample


def main():
    print("== building datastore ==")
    store = core.synthetic_datastore(40_000, dim=128, seed=0)
    index = core.build_ivf(store, 64, page_size=64, kmeans_iters=4)
    print(f"{store.num_vectors} vectors, {index.num_clusters} clusters, "
          f"{store.nbytes()/1e6:.0f} MB host-resident")

    eng = TeleRAGEngine(index, EngineConfig(
        nprobe=16, top_k=3, buffer_pages=192, lookahead_rank=32,
        kernel_mode="ref"), get_arch("llama3-8b"))

    # the user query (embedding) — q_in
    rng = np.random.default_rng(7)
    q_in = store.embeddings[rng.choice(store.num_vectors, 1)]
    q_in += 0.05 * rng.standard_normal(q_in.shape).astype(np.float32)
    q_in /= np.linalg.norm(q_in, axis=-1, keepdims=True)

    print("\n== 1. lookahead prefetch (async dispatch) ==")
    t0 = time.time()
    nbytes, nfetch = eng.lookahead(q_in, gen_tokens=[24])
    print(f"planned {nfetch} clusters / {nbytes/1e6:.2f} MB "
          f"(dispatch {1e3*(time.time()-t0):.1f} ms — returns immediately)")

    print("\n== 2. pre-retrieval generation overlaps the transfer ==")
    cfg = get_arch("llama3-8b").reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    cache = tf.init_cache(cfg, 1, 64)
    step = jax.jit(lambda p, c, i: tf.serve_step(p, c, i, cfg))
    tok = jnp.zeros((1,), jnp.int32)
    t0 = time.time()
    for t in range(24):
        logits, cache = step(params, cache,
                             {"token": tok,
                              "pos": jnp.asarray([t], jnp.int32)})
        tok = sample(logits)
    print(f"generated 24 tokens in {time.time()-t0:.2f}s (reduced llama)")

    print("\n== 3./4. rewrite -> hybrid retrieval -> merge ==")
    q_out = core.synthetic_rewrite(q_in, 0.04, rng)
    res = eng.retrieve(q_out)
    print(f"cluster hit rate: {res.hit_rate:.0%} "
          f"(device searched {len(res.hit_clusters[0])} clusters, "
          f"host searched {len(res.missed_clusters[0])})")
    print(f"top-3 documents: {res.doc_ids[0].tolist()} "
          f"scores {np.round(res.scores[0], 3).tolist()}")

    # verify against exhaustive search over the probed clusters
    ranked = core.probe(q_out, index, 16)[0]
    mask = np.isin(index.assignments, ranked)
    sims = store.embeddings[mask] @ q_out[0]
    ids = np.where(mask)[0]
    expect = ids[np.argsort(-sims)[:3]]
    assert set(expect.tolist()) == set(res.doc_ids[0].tolist())
    print("verified: identical to exhaustive search over probed clusters")


if __name__ == "__main__":
    main()
