"""Beyond-paper: datastore-SHARDED distributed retrieval (paper §7).

The paper's default multi-GPU mode is data parallelism with per-replica
prefetch buffers. §7 sketches the alternative — shard the datastore
across devices — which we implement with shard_map: each shard computes
a local top-k over its slab shard and only the k candidates are
all-gathered (never raw vectors). This example runs it on the host
devices and checks it against the single-device search.

Run: XLA_FLAGS=--xla_force_host_platform_device_count=4 \
     PYTHONPATH=src python examples/sharded_retrieval.py
"""

import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=4")

import numpy as np
import jax
import jax.numpy as jnp

import repro.core as core
from repro.kernels import ref


def main():
    print(f"devices: {jax.device_count()}")
    mesh = jax.make_mesh((jax.device_count(),), ("model",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    store = core.synthetic_datastore(32_000, dim=128, seed=0)
    index = core.build_ivf(store, 32, page_size=64, kmeans_iters=4)
    paged = index.paged

    # slab = the whole paged store, sharded over devices on the page dim
    P = (paged.total_pages // jax.device_count()) * jax.device_count()
    pages = jnp.asarray(paged.pages[:P])
    ids = jnp.asarray(paged.page_ids[:P])
    mask = jnp.ones((P,), bool)

    rng = np.random.default_rng(1)
    q = store.embeddings[rng.choice(store.num_vectors, 4)]
    q = jnp.asarray(q / np.linalg.norm(q, axis=-1, keepdims=True))

    s, i = core.sharded_device_search(mesh, q, pages, ids, mask, k=5)
    s_ref, i_ref = ref.ivf_topk_ref(pages, ids, mask, q, 5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-5)
    print("sharded top-k == single-device top-k ✓")
    print("candidate bytes all-gathered per query:",
          2 * 5 * 8 * jax.device_count(), "B (vs",
          pages.size * 2 // jax.device_count(), "B of raw vectors per shard)")


if __name__ == "__main__":
    main()
