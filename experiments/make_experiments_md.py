"""Compose EXPERIMENTS.md from dry-run JSONs, hillclimb JSONs, bench CSVs.

Run: PYTHONPATH=src python experiments/make_experiments_md.py
"""

import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)

import sys
sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.launch.report import (load_cells, roofline_table, summary,
                                 worst_cells, most_collective_bound)


def csv_table(path, title):
    if not os.path.exists(path):
        return f"*(missing: {os.path.basename(path)})*\n"
    lines = open(path).read().strip().splitlines()
    hdr = lines[0].split(",")
    out = ["| " + " | ".join(hdr) + " |",
           "|" + "---|" * len(hdr)]
    for l in lines[1:]:
        out.append("| " + " | ".join(l.split(",")) + " |")
    return "\n".join(out) + "\n"


def hillclimb_section():
    rows = []
    for f in sorted(glob.glob(os.path.join(HERE, "hillclimb", "*.json"))):
        d = json.load(open(f))
        if d.get("status") != "ok":
            rows.append((d.get("variant_name", f), d, None))
            continue
        rows.append((d["variant_name"], d, d["roofline"]))
    cells = {}
    for name, d, r in rows:
        cell = os.path.basename(
            [f for f in glob.glob(os.path.join(HERE, "hillclimb", "*.json"))
             if json.load(open(f)).get("variant_name") == name][0]
        ).split("__")[0]
        cells.setdefault(cell, []).append((name, d, r))
    out = []
    for cell, variants in cells.items():
        out.append(f"\n### {cell}\n")
        out.append("| variant | t_compute | t_memory | t_collective | bound "
                   "| peak GB | Δ dominant vs prev | verdict |")
        out.append("|---|---|---|---|---|---|---|---|")
        prev = None
        for name, d, r in variants:
            if r is None:
                out.append(f"| {name} | — | — | — | — | — | — | failed |")
                continue
            dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
            delta = ""
            verdict = "baseline"
            if prev is not None:
                prev_dom = max(prev["t_compute_s"], prev["t_memory_s"],
                               prev["t_collective_s"])
                delta = f"{(dom - prev_dom) / prev_dom * 100:+.0f}%"
                verdict = "confirmed" if dom < prev_dom * 0.95 else (
                    "refuted" if dom > prev_dom * 1.05 else "neutral")
            out.append(
                f"| {name} | {r['t_compute_s']*1e3:.0f}ms "
                f"| {r['t_memory_s']*1e3:.0f}ms "
                f"| {r['t_collective_s']*1e3:.0f}ms | {r['bottleneck']} "
                f"| {d['memory']['peak_bytes']/1e9:.1f} | {delta} "
                f"| {verdict} |")
            out.append(f"|  | *hypothesis: {d.get('hypothesis','')}* |||||||")
            prev = r
        out.append("")
    return "\n".join(out)


def main():
    parts = []
    parts.append(open(os.path.join(HERE, "EXPERIMENTS_header.md")).read())

    parts.append("\n## §Dry-run\n")
    for mesh in ("pod16x16", "pod2x16x16"):
        cells = load_cells(os.path.join(HERE, "dryrun"), mesh)
        s = summary(cells)
        parts.append(
            f"\n**{mesh}** ({'256' if mesh=='pod16x16' else '512'} chips): "
            f"{s['ok']} cells compiled OK, {s['skipped']} recorded skips, "
            f"{s['failed']} failures; {s['fits']}/{s['ok']} fit 16 GB/chip; "
            f"total compile wall {s['compile_s']:.0f}s on one CPU core.\n")
    parts.append(open(os.path.join(HERE, "EXPERIMENTS_dryrun_notes.md")).read()
                 if os.path.exists(os.path.join(HERE,
                                                "EXPERIMENTS_dryrun_notes.md"))
                 else "")

    parts.append("\n## §Roofline\n")
    parts.append(open(os.path.join(HERE,
                                   "EXPERIMENTS_roofline_notes.md")).read()
                 if os.path.exists(os.path.join(
                     HERE, "EXPERIMENTS_roofline_notes.md")) else "")
    for mesh in ("pod16x16", "pod2x16x16"):
        cells = load_cells(os.path.join(HERE, "dryrun"), mesh)
        parts.append(f"\n### {mesh}\n")
        parts.append(roofline_table(cells))
        parts.append(f"\nworst roofline fractions: {worst_cells(cells, 3)}\n")
        parts.append(f"most collective-bound: "
                     f"{most_collective_bound(cells, 3)}\n")

    parts.append("\n## §Perf — hillclimb log\n")
    parts.append(open(os.path.join(HERE, "EXPERIMENTS_perf_notes.md")).read()
                 if os.path.exists(os.path.join(HERE,
                                                "EXPERIMENTS_perf_notes.md"))
                 else "")
    parts.append(hillclimb_section())

    parts.append("\n## Benchmark results (paper tables/figures)\n")
    bench = os.path.join(HERE, "bench")
    for name, title in [
        ("table1_overlap", "Table 1 — IVF cluster overlap (measured)"),
        ("table3_hitrate", "Table 3 — budgets & hit rates (measured)"),
        ("fig9_latency", "Fig. 9 — single-query latency (modeled @ paper scale)"),
        ("fig10_throughput", "Fig. 10/12 — batched throughput"),
        ("fig11_13_scaling", "Fig. 11/13 — multi-replica scaling & cache"),
        ("fig14_sched", "Fig. 14 — scheduler overhead/benefit"),
        ("fig15_nprobe", "Fig. 15 — retrieval speedup vs nprobe"),
        ("fig4_5_breakdown", "Fig. 4/5 — latency breakdown"),
        ("appC_budget", "Appendix C — budget model"),
        ("kernel_ivf_topk", "Kernel — fused ivf_topk roofline"),
    ]:
        parts.append(f"\n### {title}\n")
        parts.append(csv_table(os.path.join(bench, f"{name}.csv"), title))

    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write("\n".join(parts))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
