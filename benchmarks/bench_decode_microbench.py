"""Per-kernel decode microbenchmark (MaxText-style).

Times each kernel on the decode hot path *in isolation*, one timed call
per decode step, and emits a JSON report — the per-kernel complement to
the end-to-end benches: when a serving number moves, this pins which
kernel moved it.

Kernels timed per step:

  * ``flash_decode``        — dense decode attention over [B, S] KV
  * ``flash_decode_paged``  — block-table decode attention over the
                              paged KV slab (same tokens, paged layout)
  * ``kv_append``           — one decode step's K/V scatter through the
                              block table (``KVCacheManager.append_paged``)
  * ``probe_topk_unfused``  — legacy retrieval chain: centroid probe ->
                              host-built page mask -> ``ivf_topk``
  * ``probe_topk_fused``    — the one-launch ``probe_and_topk`` kernel
  * ``serve_path_paged`` / ``serve_path_dense`` — the ACTUAL engine
    decode step: a ``serving.DecodeRunner`` wave (lease + full
    transformer step + sample) on the paged block-table substrate vs
    the dense bucket path, per decode step.  The paged row is verified
    to execute the paged kernels (``flash_decode_paged`` traced,
    ``append_paged`` accounted) — the row cannot silently fall back to
    dense.

Wall times are honest for the mode they ran in (ref on CPU is the
default; interpret mode is a correctness tool, not a perf proxy — the
report records the mode so downstream tooling never compares across
modes).  ``modeled_bytes`` is the analytic HBM traffic of each kernel
at the benched shapes, which IS comparable across modes and is what the
fused-vs-unfused assertions check.

Run:  PYTHONPATH=src python -m benchmarks.bench_decode_microbench [--smoke]
JSON: experiments/bench/decode_microbench.json
      (schema "telerag.decode_microbench/v1"; fields documented in
      docs/TELEMETRY.md and checked by ``validate_report``)
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Callable, Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from types import SimpleNamespace

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import transformer as tf
from repro.obs import SystemClock
from repro.serving import DecodeRunner, EngineConfig
from repro.serving.kv_cache import KVCacheManager
from benchmarks.common import (emit, report_path, summarize_rows,
                               write_report)

SCHEMA = "telerag.decode_microbench/v1"

# every per-kernel record carries exactly these timing fields (us)
TIMING_FIELDS = ("wall_us_mean", "wall_us_p50", "wall_us_p99")


def _time_steps(fn: Callable[[int], jax.Array], steps: int,
                warmup: int = 1) -> List[float]:
    """One timed call per decode step; returns per-step seconds."""
    for s in range(warmup):
        jax.block_until_ready(fn(s))
    out = []
    for s in range(steps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(s))
        out.append(time.perf_counter() - t0)
    return out


def _record(name: str, secs: List[float], modeled_bytes: int) -> Dict:
    us = np.asarray(secs) * 1e6
    return {
        "name": name,
        "steps": len(secs),
        "wall_us_mean": round(float(us.mean()), 2),
        "wall_us_p50": round(float(np.percentile(us, 50)), 2),
        "wall_us_p99": round(float(np.percentile(us, 99)), 2),
        "modeled_bytes": int(modeled_bytes),
    }


def validate_report(report: Dict) -> None:
    """Schema guard for the JSON report (run by --smoke and by CI so the
    emitted fields cannot silently drift from docs/TELEMETRY.md)."""
    assert report.get("schema") == SCHEMA, report.get("schema")
    for key in ("mode", "backend", "steps", "shapes", "kernels"):
        assert key in report, f"missing {key}"
    assert isinstance(report["kernels"], list) and report["kernels"]
    names = set()
    for rec in report["kernels"]:
        for key in ("name", "steps", "modeled_bytes", *TIMING_FIELDS):
            assert key in rec, f"kernel record missing {key}: {rec}"
        for key in TIMING_FIELDS:
            assert rec[key] >= 0.0, (rec["name"], key, rec[key])
        assert rec["modeled_bytes"] > 0, rec["name"]
        names.add(rec["name"])
    fused = {r["name"]: r for r in report["kernels"]}
    if {"probe_topk_fused", "probe_topk_unfused"} <= names:
        assert (fused["probe_topk_fused"]["modeled_bytes"]
                <= fused["probe_topk_unfused"]["modeled_bytes"]), \
            "fused retrieval must not model more HBM traffic than unfused"


def _serve_path_records(*, B: int, steps: int, page_size: int,
                        mode: str) -> List[Dict]:
    """Time the ACTUAL engine decode step — a ``DecodeRunner`` wave
    (KV lease + full transformer serve step + sample per token) — in
    both modes, and assert the paged row really executed the paged
    substrate: ``flash_decode_paged`` must be traced by the paged
    runner's jit (and never by the dense one), and every paged step
    must have gone through ``append_paged`` accounting."""
    L, KVH, G, Dh = 2, 2, 2, 16
    cfg = ArchConfig(name="microbench-serve", family="dense",
                     source="bench", d_model=KVH * G * Dh, num_layers=L,
                     num_heads=KVH * G, num_kv_heads=KVH, head_dim=Dh,
                     d_ff=64, vocab_size=64)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    members = [SimpleNamespace(request_id=i, tenant="shared")
               for i in range(B)]
    max_len = max(steps, page_size) + 1
    waves = 4

    traced = {"paged": 0}
    orig_paged = ops.flash_decode_paged

    def counting_paged(*a, **kw):
        traced["paged"] += 1
        return orig_paged(*a, **kw)

    records = []
    ops.flash_decode_paged = counting_paged
    try:
        for name, paged in (("serve_path_paged", True),
                            ("serve_path_dense", False)):
            runner = DecodeRunner(params, cfg, max_len=max_len,
                                  max_steps=steps, page_size=page_size,
                                  slab_seqs=B, paged=paged)
            runner.attach(SimpleNamespace(
                wall=SystemClock(),
                engines=[SimpleNamespace(
                    cfg=EngineConfig(paged_decode=paged, kernel_mode=mode),
                    pool=None)]))
            before = traced["paged"]
            secs: List[float] = []
            for w in range(waves + 1):          # wave 0 is jit warmup
                t0 = time.perf_counter()
                runner(0, members, [steps] * B, w)
                dt = time.perf_counter() - t0
                if w:
                    secs.append(dt / max(steps, 1))
            if paged:
                assert traced["paged"] > before, \
                    "paged serve path never traced flash_decode_paged"
                assert runner.stats["paged_appends"] == (waves + 1) * steps
                assert runner.stats["dense_waves"] == 0
            else:
                assert traced["paged"] == before, \
                    "dense serve path traced the paged kernel"
                assert runner.stats["paged_waves"] == 0
                assert runner.stats["dense_steps"] == (waves + 1) * steps
            # per-step modeled traffic: k+v append write + full-capacity
            # KV read for attention, all layers (bf16 slab width)
            modeled = (2 * L * B * KVH * Dh * 2
                       + 2 * L * B * max_len * KVH * Dh * 2)
            records.append(_record(name, secs, modeled))
    finally:
        ops.flash_decode_paged = orig_paged
    return records


def run(*, B: int = 8, S: int = 1024, KVH: int = 8, G: int = 4,
        Dh: int = 128, page_size: int = 64, d: int = 256, Nc: int = 256,
        P: int = 256, ps_ret: int = 128, nprobe: int = 64, k: int = 8,
        steps: int = 16, mode: str = "auto", out: str = None) -> Dict:
    """Bench every decode-path kernel for ``steps`` decode steps at the
    given shapes and write the JSON report.  Attention shapes follow the
    serving defaults (GQA, fp32 math over bf16-width traffic); retrieval
    shapes follow benchmarks/common.py's 1/64-scale index."""
    resolved = ops.resolve_mode(mode)
    rng = np.random.default_rng(0)
    itemsize = 2                                     # bf16 KV / slab traffic

    # ---- attention operands (dense and paged views of the same tokens)
    q = jnp.asarray(rng.standard_normal((B, KVH, G, Dh)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, S, KVH, Dh)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, S, KVH, Dh)), jnp.float32)
    pos = jnp.full((B,), S - 1, jnp.int32)
    mb = S // page_size
    kp = kc.reshape(B * mb, page_size, KVH, Dh)      # request-major pages
    vp = vc.reshape(B * mb, page_size, KVH, Dh)
    bt = jnp.arange(B * mb, dtype=jnp.int32).reshape(B, mb)
    lengths = jnp.full((B,), S, jnp.int32)

    # ---- retrieval operands (pool slab + centroids)
    qs = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    cents = jnp.asarray(rng.standard_normal((Nc, d)), jnp.float32)
    pages = jnp.asarray(rng.standard_normal((P, ps_ret, d)), jnp.float32)
    pids = jnp.arange(P * ps_ret, dtype=jnp.int32).reshape(P, ps_ret)
    page_cluster = jnp.asarray(rng.integers(0, Nc, P), jnp.int32)
    pc_host = np.asarray(page_cluster)

    # ---- paged KV manager for the append kernel (2 layers is enough to
    # exercise the stacked-layer scatter; bytes scale linearly in L)
    L = 2
    cfg = ArchConfig(name="microbench", family="dense", source="bench",
                     d_model=KVH * G * Dh, num_layers=L, num_heads=KVH * G,
                     num_kv_heads=KVH, head_dim=Dh, vocab_size=32)
    mgr = KVCacheManager(cfg, dtype=jnp.bfloat16)
    mgr.init_paged(num_pages=B * (steps // page_size + 2),
                   page_size=page_size)
    lease = mgr.acquire_paged(B, steps + 1)
    knew = jnp.asarray(rng.standard_normal((L, B, KVH, Dh)), jnp.bfloat16)
    vnew = jnp.asarray(rng.standard_normal((L, B, KVH, Dh)), jnp.bfloat16)

    def unfused(step):
        ps_, pi_ = ops.centroid_probe(cents, qs, nprobe, mode=mode)
        lut = np.zeros((B, Nc), bool)
        pi_h = np.asarray(pi_)
        fin = np.isfinite(np.asarray(ps_))
        for b in range(B):
            lut[b, pi_h[b][fin[b]]] = True
        mask = lut[:, pc_host]                       # [B, P] host-built
        return ops.ivf_topk(pages, pids, jnp.asarray(mask), qs, k, mode=mode)

    def append(step):
        mgr.append_paged(lease, knew, vnew)
        return mgr.slab.k

    kernels = [
        ("flash_decode_dense",
         lambda s: ops.flash_decode(q, kc, vc, pos, mode=mode),
         2 * B * S * KVH * Dh * itemsize + 2 * B * KVH * G * Dh * 4),
        ("flash_decode_paged",
         lambda s: ops.flash_decode_paged(q, kp, vp, bt, lengths, mode=mode),
         2 * B * S * KVH * Dh * itemsize + 2 * B * KVH * G * Dh * 4
         + B * mb * 4),                              # + block table
        ("kv_append", append,
         2 * 2 * L * B * KVH * Dh * itemsize),       # k+v write+readback
        ("probe_topk_unfused", unfused,
         # slab + centroids once, PLUS the [B, Nc] score round trip, the
         # host-built [B, P] mask upload, and the compacted-slab copy the
         # legacy path pays before ivf_topk can run
         P * ps_ret * d * itemsize + Nc * d * 4
         + 2 * 4 * B * Nc + B * P + 2 * P * ps_ret * d * itemsize),
        ("probe_topk_fused",
         lambda s: ops.probe_and_topk(qs, cents, pages, pids, page_cluster,
                                      nprobe=nprobe, k=k, mode=mode),
         P * ps_ret * d * itemsize + Nc * d * 4 + 2 * B * k * 8),
    ]

    records = []
    for name, fn, modeled in kernels:
        secs = _time_steps(fn, steps)
        rec = _record(name, secs, modeled)
        records.append(rec)
        emit(f"decode_microbench/{name}", rec["wall_us_mean"],
             f"p99={rec['wall_us_p99']};modeled_MB="
             f"{modeled / 1e6:.2f};mode={resolved}")

    # the end-to-end engine decode step (DecodeRunner wave), both modes
    for rec in _serve_path_records(B=B, steps=steps, page_size=page_size,
                                   mode=mode):
        records.append(rec)
        emit(f"decode_microbench/{rec['name']}", rec["wall_us_mean"],
             f"p99={rec['wall_us_p99']};modeled_MB="
             f"{rec['modeled_bytes'] / 1e6:.2f};mode={resolved}")

    report = {
        "schema": SCHEMA,
        "mode": resolved,
        "backend": jax.default_backend(),
        "steps": steps,
        "shapes": {"B": B, "S": S, "KVH": KVH, "G": G, "Dh": Dh,
                   "page_size": page_size, "d": d, "Nc": Nc, "P": P,
                   "ps_ret": ps_ret, "nprobe": nprobe, "k": k,
                   "num_layers": L},
        "kernels": records,
    }
    validate_report(report)
    # report-dir routed (untracked): regenerated timing JSON is a CI
    # artifact, never a commit — the schema itself is pinned by
    # tests/data/decode_microbench_pinned.json
    path = out or report_path("decode_microbench.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
    # the uniform telerag.bench/v1 report alongside the detailed one
    write_report("decode_microbench", metrics=summarize_rows(records),
                 rows=records, meta={"mode": resolved, "steps": steps})
    return report


def run_smoke() -> Dict:
    """CI-sized run: tiny shapes, ref mode, schema-validated."""
    return run(B=2, S=64, KVH=2, G=2, Dh=16, page_size=16, d=32, Nc=16,
               P=12, ps_ret=8, nprobe=4, k=3, steps=3, mode="ref")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + schema check (CI guard)")
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--mode", default="auto",
                    help="kernel mode (auto|ref|kernel|kernel_interpret)")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        run_smoke()
    else:
        run(steps=args.steps, mode=args.mode, out=args.out)


if __name__ == "__main__":
    main()
