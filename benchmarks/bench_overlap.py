"""Table 1: IVF cluster overlap between pre-retrieval input and output.

Measured quantity (hardware-independent). The paper reports 61.6–100%
coverage at nprobe 256 on wiki_dpr; our synthetic rewrites are calibrated
(core/overlap.py PIPELINE_SIGMA) to land in the same band at the scaled
nprobe, which this bench verifies.
"""

import time

import numpy as np

import repro.core as core
from benchmarks.common import NPROBE, bench_index, bench_queries, emit, write_csv, summarize_rows, write_report

# paper Table 1 (NQ row) for reference
PAPER_NQ = {"hyde": 0.731, "subq": 0.632, "iter": 0.915, "irg": 0.838,
            "flare": 0.791, "self_rag": 1.0}


def run(n_queries: int = 256):
    idx = bench_index()
    q = bench_queries(n_queries)
    rows = []
    t0 = time.time()
    for pipe, sigma in core.PIPELINE_SIGMA.items():
        q_in, q_out = core.pipeline_pairs(q, pipe, seed=3)[0]
        cov = core.coverage(idx, q_in, q_out, NPROBE)
        rows.append({"pipeline": pipe, "coverage": round(cov, 4),
                     "paper_nq": PAPER_NQ[pipe], "sigma": sigma,
                     "nprobe": NPROBE,
                     "in_band": abs(cov - PAPER_NQ[pipe]) < 0.12})
    wall = (time.time() - t0) / len(rows) * 1e6
    write_csv("table1_overlap", rows)
    write_report("overlap", metrics=summarize_rows(rows), rows=rows)
    for r in rows:
        emit(f"overlap/{r['pipeline']}", wall,
             f"coverage={r['coverage']:.3f};paper={r['paper_nq']}")
    return rows


if __name__ == "__main__":
    run()
