"""Fig. 15 (App. F): retrieval speedup across nprobe at fixed budget.

Paper: speedups peak at nprobe 256 (7.2–7.4x) and shrink as nprobe grows
past the fixed prefetch budget (more missed clusters land on the CPU).
"""

import numpy as np

import repro.core as core
from repro.serving import EngineConfig, TeleRAGEngine
from repro.configs import get_arch
from benchmarks.common import (N_CLUSTERS, bench_index, bench_queries, emit,
                               paper_scale_tcc, write_csv, PAPER_CLUSTER_BYTES,
                               summarize_rows, write_report)


def run(nprobes=(16, 32, 64, 128), budget_pages: int = 640,
        n_queries: int = 16):
    idx = bench_index()
    rows = []
    for np_ in nprobes:
        cfg = EngineConfig(nprobe=np_, top_k=3, buffer_pages=1024,
                           lookahead_rank=min(4 * np_, N_CLUSTERS),
                           kernel_mode="ref",
                           prefetch_budget_bytes=budget_pages
                           * idx.paged.page_nbytes(), chips=4)
        eng = TeleRAGEngine(idx, cfg, get_arch("llama3-8b"))
        q = bench_queries(n_queries, seed=61)
        eng.lookahead(q, gen_tokens=[128] * n_queries)
        q_out = core.synthetic_rewrite(q, 0.3, np.random.default_rng(62))
        res = eng.retrieve(q_out)
        hits = sum(len(h) for h in res.hit_clusters)
        miss = sum(len(m) for m in res.missed_clusters)
        t_cc = paper_scale_tcc(cfg.hw)
        t_cpu = (hits + miss) / n_queries * t_cc
        t_tel = max(miss / n_queries * t_cc,
                    hits / n_queries * PAPER_CLUSTER_BYTES
                    / (cfg.hw.hbm_bw * cfg.chips)) + 2e-5
        rows.append({"nprobe": np_, "hit_rate": round(res.hit_rate, 4),
                     "retrieval_speedup": round(t_cpu / t_tel, 2),
                     "t_cpu_ms": round(t_cpu * 1e3, 2),
                     "t_telerag_ms": round(t_tel * 1e3, 2)})
        emit(f"nprobe/{np_}", t_tel * 1e6,
             f"speedup={rows[-1]['retrieval_speedup']};hit={res.hit_rate:.3f}")
    write_csv("fig15_nprobe", rows)
    write_report("nprobe", metrics=summarize_rows(rows), rows=rows)
    return rows


if __name__ == "__main__":
    run()
