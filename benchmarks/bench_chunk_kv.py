"""Chunk-KV splice vs re-prefill: how many prefill tokens does the
precomputed chunk-KV path remove from the serve critical path?

One run per (pipeline, coverage) cell: a baseline serve pass (no chunk
store) records which documents each wave retrieves; the chunk store is
then built offline (``data.chunk_kv.build_chunk_kv``) over a
``coverage`` fraction of those docs — mapped to their real IVF clusters
so lookahead prefetch can resolve predicted clusters to pages — and the
same requests are served again with splicing enabled.  The headline
metric is ``prefill_tokens_avoided``: every hit chunk's full token
count that the baseline would have had to prefill is instead attached
to the wave's lease by block-table edit.

The bench is also a CI guard (``run_smoke``): each cell asserts the
splice reduction is at least hit-rate-proportional —
``prefill_tokens_avoided >= hit_rate * chunk_requests * min_len`` (a
hit can never avoid fewer tokens than the shortest chunk) — that waves
actually decoded through the spliced step when coverage > 0, and that
zero coverage avoids exactly zero.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from benchmarks.common import (NPROBE, bench_index, bench_queries, emit,
                               summarize_rows, write_report)
from repro.configs import get_arch
from repro.data.chunk_kv import (ChunkKVStore, build_chunk_kv,
                                 cluster_map_from_assignments)
from repro.models import transformer as tf
from repro.serving import (DecodeRunner, EngineConfig, RagRequest,
                           TeleRAGServer, make_traces)

ARCH = get_arch("llama3-8b")
CFG = ARCH.reduced()

PAGE_SIZE = 4          # KV page size (tokens) — the splice granularity
MIN_LEN, MAX_LEN = 6, 10   # chunk token lengths (ragged on purpose)
SEED = 3


def _params():
    return tf.init_params(CFG, jax.random.PRNGKey(0), dtype=jnp.float32)


def _serve(params, q, traces, *, store: Optional[ChunkKVStore],
           micro_batch: int, max_steps: int, slab_seqs: int):
    """One serve pass; returns (runner, server, responses)."""
    runner = DecodeRunner(params, CFG, max_len=24, max_steps=max_steps,
                          page_size=PAGE_SIZE, slab_seqs=slab_seqs,
                          chunk_store=store)
    srv = TeleRAGServer(bench_index(), EngineConfig(
        nprobe=NPROBE, top_k=3, buffer_pages=640, pool_pages=8192,
        lookahead_rank=2 * NPROBE, kernel_mode="ref", chips=8, seed=7,
        paged_decode=True, chunk_kv=store is not None), 1, ARCH,
        micro_batch=micro_batch, include_tail=True, decode_hook=runner,
        continuous=True)
    runner.attach(srv)
    resp = srv.serve([RagRequest(q=q[i], trace=traces[i], arrival_t=0.0)
                      for i in range(len(traces))])
    return runner, srv, resp


def _retrieved_docs(resp) -> List[int]:
    """Unique doc ids across every response round, first-seen order."""
    seen: Dict[int, None] = {}
    for r in resp:
        for round_docs in r.doc_ids:
            for d in round_docs:
                seen.setdefault(int(d), None)
    return list(seen)


def run(n_requests: int = 6,
        pipelines: Sequence[str] = ("iter", "irg", "flare"),
        coverages: Sequence[float] = (0.0, 0.5, 1.0),
        max_steps: int = 4, micro_batch: int = 3) -> Dict:
    """The splice-vs-re-prefill table; returns the written report."""
    params = _params()
    cluster_of = cluster_map_from_assignments(bench_index().assignments)
    rows: List[Dict] = []
    for pipeline in pipelines:
        q = bench_queries(n_requests, seed=5)
        traces = make_traces(pipeline, n_requests, seed=11)
        t0 = time.time()
        _, _, resp = _serve(params, q, traces, store=None,
                            micro_batch=micro_batch, max_steps=max_steps,
                            slab_seqs=n_requests + 2)
        base_s = time.time() - t0
        docs = _retrieved_docs(resp)
        full = build_chunk_kv(params, CFG, docs, page_size=PAGE_SIZE,
                              seed=SEED, min_len=MIN_LEN, max_len=MAX_LEN,
                              cluster_of=cluster_of)
        for coverage in coverages:
            subset = docs[:round(coverage * len(docs))]
            store = ChunkKVStore(page_size=PAGE_SIZE, seed=SEED)
            for d in subset:
                store.add(d, full.get(d))
            # slab headroom: wave leases + every built chunk resident
            slab_seqs = n_requests + 2 + (-(-store.total_pages()
                                            // (24 // PAGE_SIZE)) + 1)
            t0 = time.time()
            runner, srv, resp2 = _serve(params, q, traces, store=store,
                                        micro_batch=micro_batch,
                                        max_steps=max_steps,
                                        slab_seqs=slab_seqs)
            spliced_s = time.time() - t0
            st = runner.chunk(0).stats
            requests = st.hits + st.misses
            row = {"pipeline": pipeline, "coverage": coverage,
                   "docs_built": len(store), "chunk_requests": requests,
                   "hit_rate": st.hits / max(requests, 1),
                   "spliced_pages": st.spliced_pages,
                   "prefill_tokens_avoided": st.prefill_tokens_avoided,
                   "spliced_waves": runner.stats["spliced_waves"],
                   "prefetched_pages": st.prefetched_pages,
                   "baseline_s": base_s, "spliced_s": spliced_s}
            rows.append(row)
            # CI guard: the splice must deliver at least a
            # hit-rate-proportional prefill-token reduction
            assert row["prefill_tokens_avoided"] >= (
                row["hit_rate"] * requests * MIN_LEN), row
            if coverage > 0 and requests:
                assert row["hit_rate"] > 0, row
                assert row["spliced_pages"] > 0, row
                assert row["spliced_waves"] > 0, row
            if coverage == 0:
                assert row["prefill_tokens_avoided"] == 0, row
                assert row["spliced_pages"] == 0, row
            emit(f"chunk_kv/{pipeline}/cov{coverage:.2f}",
                 1e6 * spliced_s,
                 f"hit_rate={row['hit_rate']:.2f} "
                 f"avoided={row['prefill_tokens_avoided']}")
    full_cov = [r for r in rows if r["coverage"] == 1.0]
    metrics = dict(summarize_rows(rows),
                   total_prefill_tokens_avoided=float(
                       sum(r["prefill_tokens_avoided"] for r in rows)),
                   full_coverage_hit_rate=float(
                       sum(r["hit_rate"] for r in full_cov)
                       / max(len(full_cov), 1)))
    path = write_report("chunk_kv", metrics=metrics, rows=rows,
                        meta={"page_size": PAGE_SIZE, "min_len": MIN_LEN,
                              "max_len": MAX_LEN, "seed": SEED,
                              "arch": CFG.name})
    return {"rows": rows, "metrics": metrics, "path": path}


def run_smoke() -> Dict:
    """CI smoke cell: one pipeline, full coverage, asserts included."""
    return run(n_requests=4, pipelines=("iter",), coverages=(0.0, 1.0),
               max_steps=3, micro_batch=2)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: one small cell with assertions")
    a = ap.parse_args()
    run_smoke() if a.smoke else run()
