"""Fig. 10/12: batched throughput + per-stage latency breakdown.

Paper: TeleRAG's advantage GROWS with batch (1.32x -> 1.98x at batch 8 on
H100/Llama-8B) because CPU retrieval scales linearly with batch while the
hybrid path amortizes. Same composition here with measured hit rates.
"""

import time

import numpy as np

import repro.core as core
from repro.serving import make_traces
from benchmarks.common import (bench_index, bench_queries, emit, make_server,
                               paper_scale_tcc, serve_requests, write_csv,
                               summarize_rows, write_report)
from benchmarks.bench_latency import modeled_latency, PAPER_CLUSTER_BYTES


def run(batches=(1, 2, 4, 8), pipelines=("hyde", "subq", "irg")):
    rows = []
    for pipe in pipelines:
        for bs in batches:
            srv = make_server(buffer_pages=1024)
            eng = srv.engines[0]
            q = bench_queries(bs, seed=31)
            traces = make_traces(pipe, bs, seed=32)
            res = serve_requests(srv, q, traces)
            tele_lat = max(modeled_latency(r, eng, "telerag") for r in res)
            cpu_lat = max(modeled_latency(r, eng, "cpu_baseline")
                          for r in res)
            # breakdown (Fig 12): llm vs retrieval share per system
            t_llm = sum(rt.t_llm_window for r in res for rt in r.rounds) / bs
            t_cc = paper_scale_tcc(eng.cfg.hw)
            t_cpu_ret = sum((rt.hits + rt.misses) * t_cc
                            for r in res for rt in r.rounds) / bs
            t_tel_ret = sum(max(rt.misses * t_cc,
                                rt.hits * PAPER_CLUSTER_BYTES
                                / (eng.cfg.hw.hbm_bw * eng.cfg.chips))
                            for r in res for rt in r.rounds) / bs
            rows.append({
                "pipeline": pipe, "batch": bs,
                "telerag_qps": round(bs / tele_lat, 3),
                "cpu_qps": round(bs / cpu_lat, 3),
                "speedup": round(cpu_lat / tele_lat, 3),
                "t_llm_ms": round(t_llm * 1e3, 2),
                "t_retrieval_cpu_ms": round(t_cpu_ret * 1e3, 2),
                "t_retrieval_telerag_ms": round(t_tel_ret * 1e3, 2),
            })
            emit(f"throughput/{pipe}/b{bs}", tele_lat * 1e6,
                 f"qps={rows[-1]['telerag_qps']};speedup={rows[-1]['speedup']}")
    write_csv("fig10_throughput", rows)
    write_report("throughput", metrics=summarize_rows(rows), rows=rows)
    # Fig 12 check: speedup should not decrease with batch
    for pipe in pipelines:
        sp = [r["speedup"] for r in rows if r["pipeline"] == pipe]
        if len(sp) > 1 and sp[-1] < sp[0] * 0.9:
            print(f"# WARN {pipe}: speedup fell with batch {sp}")
    return rows


if __name__ == "__main__":
    run()
