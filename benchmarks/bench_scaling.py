"""Fig. 11/13: multi-replica scaling and the cache ablation.

Scheduling quality and cache hit rates are MEASURED; per-replica latency
is modeled and the slowest replica bounds the batch (the paper's
"long tail of higher-latency micro-batches" shows up the same way).
"""

import time

import numpy as np

from repro.serving import MultiReplicaOrchestrator, make_traces
from repro.configs import get_arch
from repro.serving import EngineConfig
from benchmarks.common import (NPROBE, N_CLUSTERS, bench_index, bench_queries,
                               emit, write_csv)
from benchmarks.bench_latency import modeled_latency


def _orch(n, cache):
    cfg = EngineConfig(nprobe=NPROBE, top_k=3, buffer_pages=768,
                       lookahead_rank=min(2 * NPROBE, N_CLUSTERS),
                       kernel_mode="ref", cache_enabled=cache, chips=4)
    return MultiReplicaOrchestrator(bench_index(), cfg, n,
                                    get_arch("llama3-8b"))


def run(replica_counts=(1, 2, 4, 8), global_batch: int = 32,
        micro_batch: int = 4, pipeline: str = "hyde"):
    rows = []
    base_qps = None
    for cache in (False, True):
        for n in replica_counts:
            orch = _orch(n, cache)
            q = bench_queries(global_batch, seed=41)
            traces = make_traces(pipeline, global_batch, seed=42)
            # warm round for the cache (paper uses 512 warm queries)
            if cache:
                orch.run_global_batch(q, traces, micro_batch=micro_batch)
            t0 = time.time()
            rep = orch.run_global_batch(
                bench_queries(global_batch, seed=43),
                make_traces(pipeline, global_batch, seed=44),
                micro_batch=micro_batch)
            wall = time.time() - t0
            # modeled: replicas run their micro-batches serially; the batch
            # completes when the slowest replica finishes
            per_replica = {}
            for rid, results in rep.per_replica_results.items():
                eng = orch.replicas[rid]
                per_replica[rid] = sum(modeled_latency(r, eng, "telerag")
                                       for r in results) / micro_batch
            lat = max(per_replica.values()) + rep.schedule_overhead_s
            qps = global_batch / lat
            if not cache and n == replica_counts[0]:
                base_qps = qps
            hits = sum(rt.hits for r in rep.all_results() for rt in r.rounds)
            miss = sum(rt.misses for r in rep.all_results()
                       for rt in r.rounds)
            rows.append({
                "replicas": n, "cache": cache,
                "qps": round(qps, 3),
                "scaling_vs_1": round(qps / base_qps, 3),
                "hit_rate": round(hits / max(hits + miss, 1), 4),
                "sched_overhead_ms": round(rep.schedule_overhead_s * 1e3, 2),
                "wall_s": round(wall, 2),
            })
            emit(f"scaling/{'cache' if cache else 'nocache'}/r{n}",
                 lat * 1e6 / global_batch,
                 f"qps={rows[-1]['qps']};scale={rows[-1]['scaling_vs_1']}")
    write_csv("fig11_13_scaling", rows)
    return rows


if __name__ == "__main__":
    run()
