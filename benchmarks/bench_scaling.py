"""Fig. 11/13: multi-replica scaling and the cache ablation.

Scheduling quality and cache hit rates are MEASURED (through the unified
``TeleRAGServer`` front-end); per-replica latency is modeled and the
slowest replica bounds the batch (the paper's "long tail of
higher-latency micro-batches" shows up the same way).
"""

import time

import numpy as np

from repro.core.schedulers import TeleRAGScheduler
from repro.serving import make_traces
from repro.configs import get_arch
from benchmarks.common import (NPROBE, N_CLUSTERS, bench_queries, emit,
                               make_server, serve_requests,
                               slowest_replica_latency, write_csv,
                               summarize_rows, write_report)
from benchmarks.bench_latency import modeled_latency


def run(replica_counts=(1, 2, 4, 8), global_batch: int = 32,
        micro_batch: int = 4, pipeline: str = "hyde"):
    rows = []
    base_qps = None
    for cache in (False, True):
        for n in replica_counts:
            srv = make_server(replicas=n, cache=cache, buffer_pages=768,
                              scheduler=TeleRAGScheduler(),
                              micro_batch=micro_batch)
            # warm round for the cache (paper uses 512 warm queries)
            if cache:
                serve_requests(srv, bench_queries(global_batch, seed=41),
                               make_traces(pipeline, global_batch, seed=42))
            n_waves0 = len(srv.wave_log)
            t0 = time.time()
            resp = serve_requests(srv, bench_queries(global_batch, seed=43),
                                  make_traces(pipeline, global_batch,
                                              seed=44))
            wall = time.time() - t0
            sched_s = sum(w.sched_overhead_s
                          for w in srv.wave_log[n_waves0:])
            lat = slowest_replica_latency(resp, srv, micro_batch, sched_s,
                                          modeled_latency)
            qps = global_batch / lat
            if not cache and n == replica_counts[0]:
                base_qps = qps
            hits = sum(rt.hits for r in resp for rt in r.rounds)
            miss = sum(rt.misses for r in resp for rt in r.rounds)
            rows.append({
                "replicas": n, "cache": cache,
                "qps": round(qps, 3),
                "scaling_vs_1": round(qps / base_qps, 3),
                "hit_rate": round(hits / max(hits + miss, 1), 4),
                "sched_overhead_ms": round(sched_s * 1e3, 2),
                "wall_s": round(wall, 2),
            })
            emit(f"scaling/{'cache' if cache else 'nocache'}/r{n}",
                 lat * 1e6 / global_batch,
                 f"qps={rows[-1]['qps']};scale={rows[-1]['scaling_vs_1']}")
    write_csv("fig11_13_scaling", rows)
    write_report("scaling", metrics=summarize_rows(rows), rows=rows)
    return rows


if __name__ == "__main__":
    run()
