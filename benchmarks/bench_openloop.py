"""Open-loop serving: Poisson arrivals through the ``TeleRAGServer``
continuous dispatcher (the regime Shen et al. 2024 show flips RAG
serving conclusions vs closed-loop batch replay).

Requests arrive on a seeded Poisson process; the server groups each
arrival wave, routes it with the cache-aware scheduler, and interleaves
the replica runtimes on one shared event clock — so the reported
latencies decompose into queue wait + service and respond to offered
load.  Runs a low/high load pair and asserts every request completes
and that mean latency is monotone in load; ``--smoke`` is the CI guard.
"""

import argparse

import numpy as np

import os

from repro.core.schedulers import TeleRAGScheduler
from repro.obs import analyze, write_jsonl, write_trace
from repro.serving import make_traces, summarize_latency
from benchmarks.common import (bench_queries, emit, make_server,
                               serve_requests, write_csv,
                               summarize_rows, write_report)


def _run_load(n_requests, replicas, rate_rps, pipeline, micro_batch, seed):
    srv = make_server(replicas=replicas, cache=True, buffer_pages=768,
                      scheduler=TeleRAGScheduler(),
                      micro_batch=micro_batch)
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, n_requests))
    q = bench_queries(n_requests, seed=seed + 1)
    traces = make_traces(pipeline, n_requests, seed=seed + 2)
    # gather arrivals so a wave holds ~2 micro-batches (the cache-aware
    # scheduler's per-wave load cap then spreads them across replicas),
    # capped so a lightly-loaded stream still dispatches per arrival
    srv.batch_window_s = min(2.0 * micro_batch / rate_rps, 0.05)
    resp = serve_requests(srv, q, traces, arrivals)
    assert len(resp) == n_requests
    assert all(r.state.value == "complete" for r in resp), \
        [r.state for r in resp if r.state.value != "complete"]
    assert [r.request_id for r in resp] == [t.request_id for t in traces], \
        "drain() must return responses in submission order"
    return srv, resp


def run(n_requests: int = 48, replicas: int = 2,
        rates=(1.0, 100.0), pipeline: str = "hyde",
        micro_batch: int = 4, seed: int = 61,
        trace_out: str = None):
    rows = []
    mean_lats = []
    srv = None
    for rate in rates:
        srv, resp = _run_load(n_requests, replicas, rate, pipeline,
                              micro_batch, seed)
        lats = np.array([r.latency_s for r in resp])
        queue = np.array([r.queue_s for r in resp])
        mean_lats.append(float(lats.mean()))
        rows.append({
            "rate_rps": rate, "replicas": replicas,
            "requests": n_requests,
            "p50_ms": round(float(np.percentile(lats, 50)) * 1e3, 2),
            "p95_ms": round(float(np.percentile(lats, 95)) * 1e3, 2),
            "mean_ms": round(float(lats.mean()) * 1e3, 2),
            "queue_mean_ms": round(float(queue.mean()) * 1e3, 2),
            "waves": len(srv.wave_log),
            "batches": srv.telemetry().dispatched_batches,
        })
        emit(f"openloop/r{replicas}/rps{rate:.0f}", lats.mean() * 1e6,
             f"p95_ms={rows[-1]['p95_ms']};queue_ms="
             f"{rows[-1]['queue_mean_ms']}")
        print(f"# openloop rate={rate:.0f}rps {summarize_latency(resp)}")
        print(srv.telemetry().summary())
    # offered load up => arrival->complete latency up (queueing is real)
    if len(mean_lats) > 1:
        assert mean_lats[-1] >= mean_lats[0] - 1e-9, mean_lats
    write_csv("openloop_latency", rows)
    write_report("openloop", metrics=summarize_rows(rows), rows=rows)
    if trace_out and srv is not None:
        # the last load point's full flight-recorder stream as
        # Perfetto-loadable JSON (validated by tools/check_trace.py)
        # plus the lossless JSONL sibling the happens-before invariant
        # checker replays (tools/telint.py --trace)
        write_trace(srv.recorder, trace_out)
        write_jsonl(srv.recorder, os.path.splitext(trace_out)[0] + ".jsonl")
        print(f"# trace: {trace_out} ({len(srv.recorder.events)} events)")
        print(analyze(srv.recorder).summary())
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: small fast open-loop pass")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the last load point's trace as "
                         "Chrome/Perfetto trace-event JSON")
    args = ap.parse_args()
    if args.smoke:
        run(n_requests=16, replicas=2, trace_out=args.trace_out)
    else:
        run(trace_out=args.trace_out)
