"""Kernel-level benchmark: ivf_topk fused vs unfused, the paged-block
kernels, and the roofline arithmetic for the retrieval hot path.

On CPU we measure the REF path wall time (the kernel itself targets TPU;
interpret mode is a correctness tool, not a perf proxy) and report the
analytic TPU roofline: the fused kernel reads the slab once (memory-bound,
N·d·2 bytes) while the unfused matmul+top_k round-trips the [B, N] score
matrix through HBM (extra 2·4·B·N bytes).

The paged section actually EXECUTES the block-table decode kernel and
the one-launch ``probe_and_topk`` in interpret mode at small shapes —
checking outputs against the dense/unfused paths while reporting the
modeled bytes each fusion removes (score-matrix round trip, mask
upload, compacted-slab copy) — so the perf claims stay attached to
running code, not just arithmetic.
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.budget import TPU_V5E
from repro.kernels import ops, ref
from benchmarks.common import emit, write_csv, summarize_rows, write_report


def run(P: int = 2048, ps: int = 128, d: int = 768, B: int = 8, k: int = 8):
    rng = np.random.default_rng(0)
    pages = jnp.asarray(rng.standard_normal((P, ps, d)), jnp.bfloat16)
    ids = jnp.arange(P * ps, dtype=jnp.int32).reshape(P, ps)
    mask = jnp.ones((B, P), bool)
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.bfloat16)

    # measured (CPU ref path)
    f = jax.jit(lambda: ops.ivf_topk(pages, ids, mask, q, k, mode="ref"))
    f()[0].block_until_ready()
    t0 = time.time()
    reps = 5
    for _ in range(reps):
        f()[0].block_until_ready()
    wall = (time.time() - t0) / reps

    # analytic TPU v5e roofline
    N = P * ps
    slab_bytes = N * d * 2
    flops = 2 * B * N * d
    t_mem_fused = slab_bytes / TPU_V5E.hbm_bw
    t_mem_unfused = (slab_bytes + 2 * 4 * B * N) / TPU_V5E.hbm_bw
    t_compute = flops / TPU_V5E.peak_flops
    rows = [{
        "N_vectors": N, "B": B, "d": d, "k": k,
        "cpu_ref_wall_ms": round(wall * 1e3, 2),
        "tpu_t_mem_fused_us": round(t_mem_fused * 1e6, 1),
        "tpu_t_mem_unfused_us": round(t_mem_unfused * 1e6, 1),
        "tpu_t_compute_us": round(t_compute * 1e6, 1),
        "fusion_gain": round(t_mem_unfused / t_mem_fused, 3),
        "arithmetic_intensity": round(flops / slab_bytes, 2),
        "bound": "memory" if t_mem_fused > t_compute else "compute",
    }]
    write_csv("kernel_ivf_topk", rows)
    write_report("kernels", metrics=summarize_rows(rows), rows=rows)
    emit("kernel/ivf_topk", wall * 1e6,
         f"fusion_gain={rows[0]['fusion_gain']};AI={rows[0]['arithmetic_intensity']}")
    rows += run_paged()
    return rows


def run_paged(*, B: int = 2, KVH: int = 2, G: int = 2, Dh: int = 32,
              ps_kv: int = 16, MB: int = 4, d: int = 64, Nc: int = 16,
              P: int = 12, ps_ret: int = 8, nprobe: int = 4, k: int = 4):
    """Execute the paged-block kernels (interpret mode, small shapes):
    block-table decode attention vs the dense kernel on the same tokens,
    and one-launch ``probe_and_topk`` vs the unfused probe->mask->topk
    chain — outputs must match, and the fused path must model strictly
    less HBM traffic than the unfused one."""
    rng = np.random.default_rng(1)

    # --- paged decode attention vs dense over the same tokens
    S = MB * ps_kv
    q = jnp.asarray(rng.standard_normal((B, KVH, G, Dh)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, S, KVH, Dh)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, S, KVH, Dh)), jnp.float32)
    kp = kc.reshape(B * MB, ps_kv, KVH, Dh)
    vp = vc.reshape(B * MB, ps_kv, KVH, Dh)
    bt = jnp.arange(B * MB, dtype=jnp.int32).reshape(B, MB)
    lengths = jnp.asarray(rng.integers(1, S + 1, B), jnp.int32)

    t0 = time.time()
    out_p = ops.flash_decode_paged(q, kp, vp, bt, lengths,
                                   mode="kernel_interpret")
    jax.block_until_ready(out_p)
    wall_paged = time.time() - t0
    out_d = ops.flash_decode(q, kc, vc, lengths - 1, mode="kernel_interpret")
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_d),
                               rtol=1e-5, atol=1e-5)

    # --- fused probe_and_topk vs the unfused chain
    qs = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
    cents = jnp.asarray(rng.standard_normal((Nc, d)), jnp.float32)
    pages = jnp.asarray(rng.standard_normal((P, ps_ret, d)), jnp.float32)
    pids = jnp.arange(P * ps_ret, dtype=jnp.int32).reshape(P, ps_ret)
    pc = jnp.asarray(rng.integers(0, Nc, P), jnp.int32)

    t0 = time.time()
    fs, fi = ops.probe_and_topk(qs, cents, pages, pids, pc, nprobe=nprobe,
                                k=k, cent_tile=Nc, page_tile=4,
                                mode="kernel_interpret")
    jax.block_until_ready(fi)
    wall_fused = time.time() - t0
    us, ui = ref.probe_and_topk_ref(qs, cents, jnp.ones((Nc,), bool), pages,
                                    pids, pc, nprobe, k)
    np.testing.assert_array_equal(np.asarray(fi), np.asarray(ui))

    # modeled HBM traffic: both read the slab + centroids once; the
    # unfused chain additionally round-trips the [B, Nc] score matrix,
    # uploads the host-built [B, P] page mask, and pays the
    # compacted-slab copy before ivf_topk can launch
    slab = P * ps_ret * d * 2 + Nc * d * 4
    fused_bytes = slab + 2 * B * k * 8
    unfused_bytes = slab + 2 * 4 * B * Nc + B * P + 2 * P * ps_ret * d * 2
    assert fused_bytes < unfused_bytes, (fused_bytes, unfused_bytes)

    rows = [{
        "paged_attn_wall_ms": round(wall_paged * 1e3, 2),
        "fused_retrieval_wall_ms": round(wall_fused * 1e3, 2),
        "fused_modeled_bytes": fused_bytes,
        "unfused_modeled_bytes": unfused_bytes,
        "bytes_removed": unfused_bytes - fused_bytes,
        "parity": "ok",
    }]
    write_csv("kernel_paged", rows)
    write_report("kernels_paged", metrics=summarize_rows(rows), rows=rows)
    emit("kernel/flash_decode_paged", wall_paged * 1e6, "parity=ok")
    emit("kernel/probe_and_topk", wall_fused * 1e6,
         f"bytes_removed={rows[0]['bytes_removed']}")
    return rows


if __name__ == "__main__":
    run()
