"""Kernel-level benchmark: ivf_topk fused vs unfused, and the roofline
arithmetic for the retrieval hot path.

On CPU we measure the REF path wall time (the kernel itself targets TPU;
interpret mode is a correctness tool, not a perf proxy) and report the
analytic TPU roofline: the fused kernel reads the slab once (memory-bound,
N·d·2 bytes) while the unfused matmul+top_k round-trips the [B, N] score
matrix through HBM (extra 2·4·B·N bytes).
"""

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.budget import TPU_V5E
from repro.kernels import ops
from benchmarks.common import emit, write_csv


def run(P: int = 2048, ps: int = 128, d: int = 768, B: int = 8, k: int = 8):
    rng = np.random.default_rng(0)
    pages = jnp.asarray(rng.standard_normal((P, ps, d)), jnp.bfloat16)
    ids = jnp.arange(P * ps, dtype=jnp.int32).reshape(P, ps)
    mask = jnp.ones((B, P), bool)
    q = jnp.asarray(rng.standard_normal((B, d)), jnp.bfloat16)

    # measured (CPU ref path)
    f = jax.jit(lambda: ops.ivf_topk(pages, ids, mask, q, k, mode="ref"))
    f()[0].block_until_ready()
    t0 = time.time()
    reps = 5
    for _ in range(reps):
        f()[0].block_until_ready()
    wall = (time.time() - t0) / reps

    # analytic TPU v5e roofline
    N = P * ps
    slab_bytes = N * d * 2
    flops = 2 * B * N * d
    t_mem_fused = slab_bytes / TPU_V5E.hbm_bw
    t_mem_unfused = (slab_bytes + 2 * 4 * B * N) / TPU_V5E.hbm_bw
    t_compute = flops / TPU_V5E.peak_flops
    rows = [{
        "N_vectors": N, "B": B, "d": d, "k": k,
        "cpu_ref_wall_ms": round(wall * 1e3, 2),
        "tpu_t_mem_fused_us": round(t_mem_fused * 1e6, 1),
        "tpu_t_mem_unfused_us": round(t_mem_unfused * 1e6, 1),
        "tpu_t_compute_us": round(t_compute * 1e6, 1),
        "fusion_gain": round(t_mem_unfused / t_mem_fused, 3),
        "arithmetic_intensity": round(flops / slab_bytes, 2),
        "bound": "memory" if t_mem_fused > t_compute else "compute",
    }]
    write_csv("kernel_ivf_topk", rows)
    emit("kernel/ivf_topk", wall * 1e6,
         f"fusion_gain={rows[0]['fusion_gain']};AI={rows[0]['arithmetic_intensity']}")
    return rows


if __name__ == "__main__":
    run()
