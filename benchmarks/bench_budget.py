"""Appendix C: the prefetch-budget model — t1+t2 curve and the optimum.

Empirically builds r_miss(b) by sweeping budgets on the bench index, then
checks Appendix C's conclusion: on realistic link speeds the optimum sits
at b* = B·t̄_LLM (case 1), not at an interior case-2 point.
"""

import numpy as np

import repro.core as core
from repro.configs import get_arch
from repro.serving import calibration_windows
from benchmarks.common import (NPROBE, bench_index, bench_queries, emit,
                               make_engine, paper_scale_tcc, write_csv)


def run(pipeline: str = "hyde", n_queries: int = 16):
    idx = bench_index()
    total = float(idx.paged.all_cluster_bytes().sum())
    budgets = [total * f for f in (0.02, 0.05, 0.1, 0.2, 0.4, 0.7)]
    hit_rates = []
    for b in budgets:
        eng = make_engine(budget_bytes=int(b), buffer_pages=4096)
        q = bench_queries(n_queries, seed=81)
        eng.lookahead(q, gen_tokens=[128] * n_queries)
        q_out = core.synthetic_rewrite(q, core.PIPELINE_SIGMA[pipeline],
                                       np.random.default_rng(82))
        res = eng.retrieve(q_out)
        hit_rates.append(res.hit_rate)

    miss_fn = core.empirical_miss_curve(budgets, hit_rates)
    hw = core.TPU_V5E
    t_cc = paper_scale_tcc(hw)
    wins = calibration_windows(pipeline, 64)
    cfg = get_arch("llama3-8b")
    t_llm = core.generation_window_seconds(cfg, hw, gen_tokens=wins, batch=1,
                                           chips=4)
    b_case1 = core.case1_budget(t_llm, hw.host_link_bw)
    b_case2 = core.case2_budget(miss_fn, link_bw=hw.host_link_bw,
                                nprobe=NPROBE, t_cc=t_cc, b_max=total)
    rows = [{"budget_frac": round(b / total, 3),
             "hit_rate": round(h, 4),
             "t_total_ms": round((max(t_llm, b / hw.host_link_bw)
                                  + miss_fn(b) * NPROBE * t_cc) * 1e3, 3)}
            for b, h in zip(budgets, hit_rates)]
    write_csv("appC_budget", rows)
    emit("budget/case1", t_llm * 1e6,
         f"b1_frac={b_case1/total:.3f};case2={'none' if b_case2 is None else round(b_case2/total,3)}")
    # hit rate must be monotone in budget
    assert all(a <= b + 0.02 for a, b in zip(hit_rates, hit_rates[1:])), \
        hit_rates
    return rows


if __name__ == "__main__":
    run()
