"""Appendix C: the prefetch-budget model — t1+t2 curve and the optimum —
plus the admission-control path of the shared device page pool.

Empirically builds r_miss(b) by sweeping budgets on the bench index, then
checks Appendix C's conclusion: on realistic link speeds the optimum sits
at b* = B·t̄_LLM (case 1), not at an interior case-2 point.

``run_admission`` (the ``--smoke`` entry CI exercises) serves two query
waves through a pool sized below their combined lookahead plans and
checks the reserve/stall/resume path end to end: the second wave must
park PRESSURE_STALLED and still complete once the first wave's pins
release — no silent plan truncation, no rejected-cluster leaks.
"""

import argparse

import numpy as np

import repro.core as core
from repro.configs import get_arch
from repro.serving import calibration_windows
from benchmarks.common import (NPROBE, bench_index, bench_queries, emit,
                               make_engine, paper_scale_tcc, write_csv,
                               summarize_rows, write_report)


def run(pipeline: str = "hyde", n_queries: int = 16):
    idx = bench_index()
    total = float(idx.paged.all_cluster_bytes().sum())
    budgets = [total * f for f in (0.02, 0.05, 0.1, 0.2, 0.4, 0.7)]
    hit_rates = []
    for b in budgets:
        eng = make_engine(budget_bytes=int(b), buffer_pages=4096)
        q = bench_queries(n_queries, seed=81)
        eng.lookahead(q, gen_tokens=[128] * n_queries)
        q_out = core.synthetic_rewrite(q, core.PIPELINE_SIGMA[pipeline],
                                       np.random.default_rng(82))
        res = eng.retrieve(q_out)
        hit_rates.append(res.hit_rate)

    miss_fn = core.empirical_miss_curve(budgets, hit_rates)
    hw = core.TPU_V5E
    t_cc = paper_scale_tcc(hw)
    wins = calibration_windows(pipeline, 64)
    cfg = get_arch("llama3-8b")
    t_llm = core.generation_window_seconds(cfg, hw, gen_tokens=wins, batch=1,
                                           chips=4)
    b_case1 = core.case1_budget(t_llm, hw.host_link_bw)
    b_case2 = core.case2_budget(miss_fn, link_bw=hw.host_link_bw,
                                nprobe=NPROBE, t_cc=t_cc, b_max=total)
    rows = [{"budget_frac": round(b / total, 3),
             "hit_rate": round(h, 4),
             "t_total_ms": round((max(t_llm, b / hw.host_link_bw)
                                  + miss_fn(b) * NPROBE * t_cc) * 1e3, 3)}
            for b, h in zip(budgets, hit_rates)]
    write_csv("appC_budget", rows)
    write_report("budget", metrics=summarize_rows(rows), rows=rows)
    emit("budget/case1", t_llm * 1e6,
         f"b1_frac={b_case1/total:.3f};case2={'none' if b_case2 is None else round(b_case2/total,3)}")
    # hit rate must be monotone in budget
    assert all(a <= b + 0.02 for a, b in zip(hit_rates, hit_rates[1:])), \
        hit_rates
    return rows


def run_admission(n_queries: int = 8):
    """Serve disjoint-neighbourhood waves through a pool too small for
    all plans at once; report stall/resume/spill admission stats.  Runs
    the default per-request (reform) runtime: queries are ordered so the
    EDF wave former's FIFO chunks of ``micro_batch`` are the disjoint
    neighbourhoods by construction, and parked requests rejoin waves as
    completions free pages."""
    from repro.serving import (EngineConfig, RequestState, RetrievalRuntime,
                               TeleRAGEngine, make_traces)

    store = core.synthetic_datastore(24_000, dim=96, seed=7, num_topics=48)
    index = core.build_ivf(store, 48, page_size=64, kmeans_iters=3)
    # pool sized below one wave's combined plan => admission must arbitrate
    pages_per_cluster = float(np.mean(index.paged.cluster_num_pages))
    pool_pages = int(10 * pages_per_cluster)
    eng = TeleRAGEngine(index, EngineConfig(
        nprobe=12, top_k=3, buffer_pages=pool_pages, lookahead_rank=16,
        kernel_mode="ref", chips=4, seed=3), get_arch("llama3-8b"))
    runtime = RetrievalRuntime(eng, micro_batch=2)

    cents = index.centroids / np.linalg.norm(index.centroids, axis=-1,
                                             keepdims=True)
    half = max(2, n_queries // 2)
    q = np.concatenate([cents[:half], cents[-half:]]).astype(np.float32)
    traces = make_traces("hyde", len(q), seed=5)
    recs = [runtime.submit(q[i], traces[i]) for i in range(len(q))]
    runtime.run()
    adm = eng.admission.stats
    assert all(r.state == RequestState.COMPLETE for r in recs)
    assert not eng.admission.parked, "parked waves leaked past the drain"
    # the whole point of this smoke: the pressure path actually ran
    assert adm.stalled > 0 and adm.resumed > 0, adm
    stalls = [rid for _, label, rid in runtime.event_log
              if label == "pressure_stall"]
    rows = [{"pool_pages": pool_pages,
             "admitted": adm.admitted, "stalled": adm.stalled,
             "resumed": adm.resumed, "capped": adm.capped,
             "spilled_pages": adm.spilled_pages,
             "stalled_requests": len(set(stalls)),
             "ledger_peak_mb": round(eng.ledger.peak_bytes / 1e6, 3)}]
    write_csv("admission_smoke", rows)
    write_report("admission", metrics=summarize_rows(rows), rows=rows)
    emit("budget/admission", adm.stalled,
         f"resumed={adm.resumed};capped={adm.capped};"
         f"spill_pages={adm.spilled_pages}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: exercise the admission path only")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        run_admission()
    else:
        run()
        run_admission()
