"""Table 3: prefetch budget (Appendix-C policy) and cluster hit rate.

Budgets come from the real §4.1 calibration (64-trace profile of each
pipeline's generation windows × modeled v5e decode latency × host link
bw); hit rates are MEASURED by running the engine.
"""

import time

import numpy as np

import repro.core as core
from repro.configs import get_arch
from repro.serving import PipelineExecutor, calibration_windows, make_traces
from benchmarks.common import (bench_index, bench_queries, emit, make_engine,
                               write_csv,
                               summarize_rows, write_report)

PAPER_H100_8B = {"hyde": 0.932, "subq": 0.791, "iter": 0.937, "irg": 0.591,
                 "flare": 0.878, "self_rag": 0.726}


def run(n_queries: int = 32, arch: str = "llama3-8b"):
    idx = bench_index()
    cfg = get_arch(arch)
    rows = []
    for pipe in core.PIPELINE_SIGMA:
        # §4.1 budget: B_link * t̄_LLM from the 64-sample calibration
        wins = calibration_windows(pipe, 64)
        budget = core.optimal_budget(
            cfg, core.TPU_V5E, gen_tokens=wins, batch=1, chips=4,
            hbm_headroom_bytes=idx.paged.all_cluster_bytes().sum() * 0.35)
        eng = make_engine(budget_bytes=int(budget), buffer_pages=2048)
        ex = PipelineExecutor(eng)
        qs = bench_queries(n_queries, seed=11)
        traces = make_traces(pipe, n_queries, seed=12)
        t0 = time.time()
        res = []
        for i in range(n_queries):      # Table 3 is single-query serving
            res.extend(ex.execute_batch(qs[i:i + 1], traces[i:i + 1]))
        wall = (time.time() - t0) * 1e6 / n_queries
        hits = sum(rt.hits for r in res for rt in r.rounds)
        miss = sum(rt.misses for r in res for rt in r.rounds)
        hr = hits / max(hits + miss, 1)
        frac = budget / idx.paged.all_cluster_bytes().sum()
        rows.append({"pipeline": pipe, "budget_frac_of_store": round(frac, 4),
                     "hit_rate": round(hr, 4),
                     "paper_h100_8b": PAPER_H100_8B[pipe],
                     "wall_us_per_query": round(wall, 1)})
        emit(f"hitrate/{pipe}", wall,
             f"hit={hr:.3f};budget_frac={frac:.3f}")
    write_csv("table3_hitrate", rows)
    write_report("hitrate", metrics=summarize_rows(rows), rows=rows)
    return rows


if __name__ == "__main__":
    run()
