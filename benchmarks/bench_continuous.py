"""Per-request continuous batching vs static groups on a heterogeneous
round-count workload.

The workload mixes the §5.1 pipelines (hyde: 1 retrieval round, iter:
2-3, irg: 3, flare: 2-4) with staggered arrivals, so round frontiers
desynchronize immediately — exactly the regime where a static group's
lockstep drags every member at the pace of its slowest.  The same
request stream runs through both dispatch disciplines of one
``TeleRAGServer``:

  * **static** (``continuous=False``): admission groups stay batched
    for every round; one micro-batch in flight per replica, later
    batches queue behind the drain (the legacy, shim-pinned path);
  * **per-request** (``continuous=True``): the dynamic wave former
    re-batches whichever requests are ready at each round frontier,
    arrivals join in-flight waves mid-stream, and completions are
    consumed per request.

Asserts (the CI guard): the per-request mode completes every request,
its mean arrival→complete latency is no worse than static groups, and
its throughput (completions per event-clock second) does not regress.
"""

import argparse
import itertools

import numpy as np

from repro.serving import make_traces, summarize_latency
from benchmarks.common import (bench_queries, emit, make_server,
                               serve_requests, write_csv,
                               summarize_rows, write_report)

PIPELINE_MIX = ("hyde", "iter", "irg", "flare")


def heterogeneous_traces(n: int, seed: int = 0):
    """``n`` traces cycling through the pipeline mix (heterogeneous
    round counts: 1 to ~4 retrieval rounds side by side)."""
    per = -(-n // len(PIPELINE_MIX))
    pools = [make_traces(p, per, seed=seed + i)
             for i, p in enumerate(PIPELINE_MIX)]
    out = list(itertools.islice(
        itertools.chain.from_iterable(zip(*pools)), n))
    # re-id in submission order so responses map 1:1
    for i, t in enumerate(out):
        t.request_id = i
    return out


def _run(continuous: bool, n_requests: int, replicas: int,
         micro_batch: int, seed: int):
    srv = make_server(replicas=replicas, micro_batch=micro_batch,
                      buffer_pages=1024, continuous=continuous, seed=seed)
    q = bench_queries(n_requests, seed=seed + 1)
    traces = heterogeneous_traces(n_requests, seed=seed + 2)
    rng = np.random.default_rng(seed + 3)
    arrivals = np.cumsum(rng.exponential(0.02, n_requests))
    resp = serve_requests(srv, q, traces, arrivals)
    assert len(resp) == n_requests
    assert all(r.state.value == "complete" for r in resp), \
        [r.state for r in resp if r.state.value != "complete"]
    lats = np.array([r.latency_s for r in resp])
    clock = srv.telemetry().clock_s
    return srv, resp, float(lats.mean()), n_requests / max(clock, 1e-12)


def run(n_requests: int = 32, replicas: int = 2, micro_batch: int = 4,
        seed: int = 71):
    rows = []
    stats = {}
    for continuous in (False, True):
        srv, resp, mean_lat, tput = _run(continuous, n_requests, replicas,
                                         micro_batch, seed)
        name = "per_request" if continuous else "static_groups"
        stats[continuous] = (mean_lat, tput)
        lats = np.array([r.latency_s for r in resp])
        rows.append({
            "mode": name, "requests": n_requests, "replicas": replicas,
            "micro_batch": micro_batch,
            "mean_ms": round(mean_lat * 1e3, 2),
            "p50_ms": round(float(np.percentile(lats, 50)) * 1e3, 2),
            "p95_ms": round(float(np.percentile(lats, 95)) * 1e3, 2),
            "throughput_rps": round(tput, 2),
            "waves_executed": sum(len(rt.wave_log) for rt in srv.runtimes),
            "stall_ms": round(sum(r.stall_s for r in resp) * 1e3, 2),
        })
        emit(f"continuous/{name}", mean_lat * 1e6,
             f"tput_rps={rows[-1]['throughput_rps']};"
             f"p95_ms={rows[-1]['p95_ms']}")
        print(f"# {name}: {summarize_latency(resp)} "
              f"tput={tput:.2f} req/s")
    # the point of the refactor: re-forming waves per request must not
    # cost latency OR throughput on heterogeneous round counts
    assert stats[True][0] <= stats[False][0] * (1 + 1e-9), \
        f"per-request mean latency regressed: {stats}"
    assert stats[True][1] >= stats[False][1] * (1 - 1e-9), \
        f"per-request throughput regressed: {stats}"
    write_csv("continuous_vs_static", rows)
    write_report("continuous", metrics=summarize_rows(rows), rows=rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: small fast pass")
    args = ap.parse_args()
    if args.smoke:
        run(n_requests=12, replicas=2, micro_batch=2)
    else:
        run()
