"""Multi-tenant SLO serving: a latency-sensitive tenant sharing
replicas with a throughput batch tenant.

Two open-loop tenant mixes hit the same fleet:

  * **latency** — low-rate trickle of requests with a tight
    arrival→complete deadline and priority class 0;
  * **batch** — a simultaneous burst of deadline-free requests at
    priority class 1 (throughput traffic that should soak up whatever
    the fleet has left).

The SLO-aware configuration (the server default: ``EdfDispatch``
priority classes + earliest-deadline-first, plus per-tenant pool
reservations giving the latency tenant a guaranteed page floor and
capping the batch tenant's burst) is compared against a mixed baseline
(``FifoDispatch`` — strict arrival order, no reservations) on the
*identical* workload.  Asserts every request of BOTH tenants completes
under both configurations and that the latency tenant's deadline-miss
rate under the SLO configuration is no worse than under the baseline;
``--smoke`` is the CI guard.

Prints per-tenant ``TenantTelemetry`` lines and writes
``experiments/bench/tenant_slo.csv``.
"""

import argparse
import dataclasses

import numpy as np

from repro.configs import get_arch
from repro.core.schedulers import EdfDispatch, FifoDispatch, TeleRAGScheduler
from repro.serving import RagRequest, TeleRAGServer, make_traces
from benchmarks.common import (bench_cfg, bench_index, bench_queries, emit,
                               write_csv,
                               summarize_rows, write_report)


def _server(dispatch, tenant_shares, replicas, micro_batch, seed):
    cfg = dataclasses.replace(bench_cfg(seed=seed, buffer_pages=512),
                              tenant_shares=tenant_shares)
    return TeleRAGServer(bench_index(), cfg, replicas, get_arch("llama3-8b"),
                         scheduler=TeleRAGScheduler(),
                         micro_batch=micro_batch, dispatch=dispatch)


def _workload(n_latency, n_batch, deadline_s, spacing_s, seed):
    """The identical two-tenant request stream both configurations
    serve: a batch-tenant burst at t=0 plus a latency-tenant trickle
    arriving while the burst is queued."""
    q_lat = bench_queries(n_latency, seed=seed)
    q_bat = bench_queries(n_batch, seed=seed + 1)
    t_lat = make_traces("hyde", n_latency, seed=seed + 2)
    t_bat = make_traces("hyde", n_batch, seed=seed + 3)
    reqs = [RagRequest(q=q_bat[i], trace=t_bat[i], tenant="batch",
                       priority=1) for i in range(n_batch)]
    reqs += [RagRequest(q=q_lat[i], trace=t_lat[i], tenant="latency",
                        priority=0, deadline_s=deadline_s,
                        arrival_t=0.005 + i * spacing_s)
             for i in range(n_latency)]
    return reqs


def _solo_latency(micro_batch, seed):
    """One latency request on an idle fleet: the no-contention service
    time the deadline is calibrated from."""
    srv = _server(EdfDispatch(), None, 1, micro_batch, seed)
    q = bench_queries(1, seed=seed + 9)
    trace = make_traces("hyde", 1, seed=seed + 9)[0]
    resp = srv.serve([RagRequest(q=q[0], trace=trace, tenant="latency")])
    return resp[0].latency_s


def run(n_latency: int = 8, n_batch: int = 24, replicas: int = 2,
        micro_batch: int = 2, seed: int = 71):
    solo = _solo_latency(micro_batch, seed)
    deadline_s = 3.0 * solo              # met when served promptly,
    spacing_s = 0.5 * solo               # missed when parked behind the burst
    pool_pages = 512
    shares = {"latency": (pool_pages // 4, None),
              "batch": (0, 3 * pool_pages // 4)}

    rows = []
    miss_rate = {}
    for label, dispatch, tenant_shares in (
            ("slo", EdfDispatch(), shares),
            ("fifo_baseline", FifoDispatch(), None)):
        srv = _server(dispatch, tenant_shares, replicas, micro_batch, seed)
        resp = srv.serve(_workload(n_latency, n_batch, deadline_s,
                                   spacing_s, seed))
        assert len(resp) == n_latency + n_batch
        assert all(r.state.value == "complete" for r in resp), \
            f"{label}: both tenants must fully complete"
        tele = srv.telemetry()
        lat = tele.tenant("latency")
        bat = tele.tenant("batch")
        assert lat.completed == n_latency and bat.completed == n_batch
        # telemetry counters must agree with the per-response flags
        assert lat.deadline_missed == sum(r.deadline_missed for r in resp
                                          if r.tenant == "latency")
        miss_rate[label] = lat.deadline_missed / max(1, lat.with_deadline)
        rows.append({
            "config": label, "replicas": replicas,
            "n_latency": n_latency, "n_batch": n_batch,
            "deadline_ms": round(deadline_s * 1e3, 2),
            "lat_p50_ms": round(lat.p50_latency_s * 1e3, 2),
            "lat_p99_ms": round(lat.p99_latency_s * 1e3, 2),
            "lat_miss": lat.deadline_missed,
            "lat_miss_queue": lat.missed_in_queue,
            "lat_attain": round(lat.attainment, 3),
            "bat_p50_ms": round(bat.p50_latency_s * 1e3, 2),
            "bat_completed": bat.completed,
            "demoted_rounds": lat.demoted_rounds + bat.demoted_rounds,
        })
        emit(f"tenants/{label}", lat.p99_latency_s * 1e6,
             f"attain={lat.attainment:.2f};miss={lat.deadline_missed}/"
             f"{lat.with_deadline}")
        print(f"# tenants config={label} deadline={deadline_s*1e3:.0f}ms")
        print("#   " + lat.line())
        print("#   " + bat.line())

    # the acceptance bar: SLO-aware serving never makes the
    # latency-sensitive tenant's miss rate worse than the mixed baseline
    assert miss_rate["slo"] <= miss_rate["fifo_baseline"] + 1e-12, miss_rate
    write_csv("tenant_slo", rows)
    write_report("tenants", metrics=summarize_rows(rows), rows=rows)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: small fast two-tenant pass")
    args = ap.parse_args()
    if args.smoke:
        run(n_latency=4, n_batch=10)
    else:
        run()
