"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV to stdout and writes detailed
CSVs to experiments/bench/. Run: PYTHONPATH=src python -m benchmarks.run
"""

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: run a small fast subset at --quick "
                         "sizes so the perf scripts cannot silently rot")
    ap.add_argument("--report-dir", default=None, metavar="DIR",
                    help="directory for the machine-readable "
                         "BENCH_<name>.json reports (default "
                         "experiments/bench)")
    args = ap.parse_args()
    if args.smoke:
        args.quick = True
        if args.only is None:
            args.only = ("overlap,overlap_trace,sched,admission,openloop,"
                         "tenants,continuous,decode_microbench,chunk_kv")

    from benchmarks import (bench_breakdown, bench_budget, bench_chunk_kv,
                            bench_continuous, bench_decode_microbench,
                            bench_hitrate, bench_kernels, bench_latency,
                            bench_nprobe, bench_openloop, bench_overlap,
                            bench_overlap_trace, bench_sched, bench_scaling,
                            bench_tenants, bench_throughput)
    from benchmarks.common import set_report_dir

    if args.report_dir:
        set_report_dir(args.report_dir)

    benches = {
        "overlap": lambda: bench_overlap.run(64 if args.quick else 256),
        "overlap_trace": lambda: bench_overlap_trace.run(
            n_requests=12 if args.quick else 24),
        "hitrate": lambda: bench_hitrate.run(8 if args.quick else 32),
        "latency": lambda: bench_latency.run(4 if args.quick else 16),
        "throughput": lambda: bench_throughput.run(
            (1, 4) if args.quick else (1, 2, 4, 8)),
        "scaling": lambda: bench_scaling.run(
            (1, 2) if args.quick else (1, 2, 4, 8),
            global_batch=8 if args.quick else 32),
        "sched": lambda: bench_sched.run(
            global_batch=8 if args.quick else 32),
        "nprobe": lambda: bench_nprobe.run(
            (16, 64) if args.quick else (16, 32, 64, 128)),
        "breakdown": lambda: bench_breakdown.run(4 if args.quick else 8),
        "budget": lambda: bench_budget.run(
            n_queries=4 if args.quick else 16),
        "admission": lambda: bench_budget.run_admission(
            n_queries=4 if args.quick else 8),
        "kernels": lambda: bench_kernels.run(
            P=512 if args.quick else 2048),
        "decode_microbench": lambda: (
            bench_decode_microbench.run_smoke() if args.quick
            else bench_decode_microbench.run()),
        "chunk_kv": lambda: (
            bench_chunk_kv.run_smoke() if args.quick
            else bench_chunk_kv.run()),
        "openloop": lambda: bench_openloop.run(
            n_requests=16 if args.quick else 48),
        "tenants": lambda: bench_tenants.run(
            n_latency=4 if args.quick else 8,
            n_batch=10 if args.quick else 24),
        "continuous": lambda: bench_continuous.run(
            n_requests=12 if args.quick else 32,
            micro_batch=2 if args.quick else 4),
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    t0 = time.time()
    for name, fn in benches.items():
        if only and name not in only:
            continue
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name},0,FAILED:{type(e).__name__}:{e}", file=sys.stderr)
            raise
    print(f"# total wall {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
