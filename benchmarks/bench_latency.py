"""Fig. 9: single-query end-to-end latency — TeleRAG vs CPU-offload.

Hit rates / cluster sets are MEASURED by the engine on the bench index;
wall-clock is MODELED at paper datastore scale (61 GB/4096 clusters) on
the v5e profile via the §4.1/App-C composition. Both numbers per pipeline
(paper reports 1.2–2.1× on RTX4090; regime differs but the mechanism —
overlap + hybrid split — is identical).
"""

import time

import numpy as np

import repro.core as core
from repro.serving import make_traces
from benchmarks.common import (NPROBE, PAPER_CLUSTER_BYTES, bench_index,
                               bench_queries, emit, make_server,
                               paper_scale_tcc, serve_requests, write_csv,
                               summarize_rows, write_report)

PAPER_4090_3B = {"hyde": 1.3, "subq": 1.85, "iter": 1.4, "irg": 2.11,
                 "flare": 1.5, "self_rag": 1.35}


PAPER_NPROBE = 256


def modeled_latency(result, eng, mode: str) -> float:
    """Recompose round telemetry at paper scale.

    Measured hit/miss *rates* transfer; absolute cluster counts scale by
    PAPER_NPROBE / bench nprobe (the bench index probes 64 = 4*sqrt(256)
    clusters, the paper probes 256 = 4*sqrt(4096)), and cluster bytes
    scale to the paper's 61 GB / 4096 = 15 MB clusters.
    """
    t_cc = paper_scale_tcc(eng.cfg.hw)
    link = eng.cfg.hw.host_link_bw
    scale = PAPER_NPROBE / max(eng.cfg.nprobe, 1)
    total = 0.0
    for rt in result.rounds:
        # rescale byte-dependent terms to paper cluster count and size
        n_pref_clusters = (rt.bytes_prefetched / max(
            np.mean(eng.index.paged.all_cluster_bytes()), 1)) * scale
        t_prefetch = n_pref_clusters * PAPER_CLUSTER_BYTES / link
        hits, misses = rt.hits * scale, rt.misses * scale
        t_host = misses * t_cc
        t_dev = (hits * PAPER_CLUSTER_BYTES
                 / (eng.cfg.hw.hbm_bw * eng.cfg.chips))
        if mode == "telerag":
            total += max(rt.t_llm_window, t_prefetch)
            total += max(t_host, t_dev) + rt.t_merge
        elif mode == "cpu_baseline":
            total += rt.t_llm_window + (hits + misses) * t_cc
        elif mode == "runtime_fetch":
            nb = (hits + misses) * PAPER_CLUSTER_BYTES
            total += rt.t_llm_window + nb / link + t_dev + rt.t_merge
        elif mode == "gpu_resident":  # datastore fully in HBM (infeasible)
            total += rt.t_llm_window + (hits + misses) * PAPER_CLUSTER_BYTES \
                / (eng.cfg.hw.hbm_bw * eng.cfg.chips)
    return total


def run(n_queries: int = 16):
    rows = []
    for pipe in core.PIPELINE_SIGMA:
        srv = make_server(buffer_pages=1024)
        eng = srv.engines[0]
        q = bench_queries(n_queries, seed=21)
        traces = make_traces(pipe, n_queries, seed=22)
        t0 = time.time()
        res = serve_requests(srv, q, traces)
        wall = (time.time() - t0) * 1e6 / n_queries
        tele = np.mean([modeled_latency(r, eng, "telerag") for r in res])
        cpu = np.mean([modeled_latency(r, eng, "cpu_baseline") for r in res])
        fetch = np.mean([modeled_latency(r, eng, "runtime_fetch")
                         for r in res])
        rows.append({
            "pipeline": pipe,
            "telerag_ms": round(tele * 1e3, 2),
            "cpu_baseline_ms": round(cpu * 1e3, 2),
            "runtime_fetch_ms": round(fetch * 1e3, 2),
            "speedup_vs_cpu": round(cpu / max(tele, 1e-12), 3),
            "speedup_vs_fetch": round(fetch / max(tele, 1e-12), 3),
            "paper_4090_speedup": PAPER_4090_3B[pipe],
        })
        emit(f"latency/{pipe}", wall,
             f"speedup={rows[-1]['speedup_vs_cpu']};paper~{PAPER_4090_3B[pipe]}")
    write_csv("fig9_latency", rows)
    write_report("latency", metrics=summarize_rows(rows), rows=rows)
    return rows


if __name__ == "__main__":
    run()
