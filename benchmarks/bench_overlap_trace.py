"""Overlap efficiency from the flight-recorder trace (the paper's
central claim, measured off the event timeline).

Drives a hyde/iter request mix through the continuous-batching server,
then runs ``repro.obs.analyze`` over the recorded trace: per-round
lookahead overlap ratio (the fraction of each member's modeled H2D copy
hidden under its generation window), stall-time attribution (link vs
pressure vs queue), and wave-fragmentation stats.  Asserts the TeleRAG
property the whole repo exists to reproduce — the mean overlap ratio on
a prefetching mix is strictly positive — and that every admitted
request's lifecycle events are well-ordered in the trace.

``--smoke`` is the CI-sized guard (also in ``run.py --smoke``).
"""

import argparse
import dataclasses

import numpy as np

from repro.core.schedulers import TeleRAGScheduler
from repro.obs import analyze
from repro.serving import make_traces
from benchmarks.common import (bench_queries, emit, make_server,
                               serve_requests, write_csv,
                               summarize_rows, write_report)


def run(n_requests: int = 24, replicas: int = 2, micro_batch: int = 4,
        seed: int = 71):
    srv = make_server(replicas=replicas, cache=True, buffer_pages=768,
                      scheduler=TeleRAGScheduler(),
                      micro_batch=micro_batch, continuous=True)
    # hyde/iter mix: both pipelines prefetch, with different round
    # shapes; re-id so the mix's request ids stay unique (make_traces
    # numbers 0..n-1 per call and the recorder correlates by id)
    half = n_requests // 2
    traces = [dataclasses.replace(t, request_id=i) for i, t in enumerate(
        make_traces("hyde", half, seed=seed)
        + make_traces("iter", n_requests - half, seed=seed + 1))]
    q = bench_queries(n_requests, seed=seed + 2)
    rng = np.random.default_rng(seed + 3)
    arrivals = np.cumsum(rng.exponential(0.02, n_requests))
    resp = serve_requests(srv, q, traces, arrivals)
    assert len(resp) == n_requests

    rec = srv.recorder
    report = analyze(rec)
    print(report.summary())

    # the TeleRAG claim: on a prefetching mix, part of the copy hides
    # under generation — the trace must show a positive overlap ratio
    assert report.prefetched_rounds, "no prefetched rounds in the trace"
    assert report.mean_overlap_ratio > 0.0, report.mean_overlap_ratio

    # lifecycle sanity straight off the trace: admit <= first generate
    # <= complete for every admitted request
    marks = {}
    for r in resp:
        m = rec.request_marks(r.request_id)
        assert "admit" in m and "complete" in m, m
        assert m["admit"] <= m.get("generate", m["complete"]) + 1e-12
        assert m.get("generate", m["admit"]) <= m["complete"] + 1e-12
        marks[r.request_id] = m

    rows = [{
        "requests": n_requests, "replicas": replicas,
        "prefetched_rounds": len(report.prefetched_rounds),
        "rounds": len(report.rounds),
        "mean_overlap_ratio": round(report.mean_overlap_ratio, 4),
        "fully_hidden_frac": round(report.fully_hidden_frac, 4),
        "mean_wave_size": round(report.mean_wave_size, 3),
        "singleton_wave_frac": round(report.singleton_wave_frac, 4),
        "link_stall_ms": round(report.stall.get("link_s", 0.0) * 1e3, 3),
        "pressure_stall_ms": round(
            report.stall.get("pressure_s", 0.0) * 1e3, 3),
        "queue_ms": round(report.stall.get("queue_s", 0.0) * 1e3, 3),
        "trace_events": len(rec.events),
    }]
    write_csv("overlap_trace", rows)
    write_report("overlap_trace", metrics=summarize_rows(rows), rows=rows)
    emit("overlap_trace", report.mean_overlap_ratio * 1e6,
         f"hidden={report.mean_overlap_ratio:.3f};"
         f"waves={len(report.wave_sizes)}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: small fast trace-analysis pass")
    args = ap.parse_args()
    if args.smoke:
        run(n_requests=12, replicas=2)
    else:
        run()
