"""Fig. 4/5: where RAG latency goes — CPU retrieval vs GPU retrieval vs
runtime-fetch. Also measures REAL host-search wall time on this machine
(the one hardware-honest latency we can measure) for t_cc calibration.
"""

import time

import numpy as np

import repro.core as core
from repro.serving import PipelineExecutor, make_traces
from benchmarks.common import (bench_index, bench_queries, emit, make_engine,
                               paper_scale_tcc, write_csv,
                               summarize_rows, write_report)
from benchmarks.bench_latency import modeled_latency


def run(n_queries: int = 8):
    idx = bench_index()
    rows = []

    # measured t_cc on this container (real wall time of numpy host search)
    eng = make_engine()
    t_cc_measured = eng.calibrate_tcc(32)
    emit("breakdown/t_cc_measured_this_host", t_cc_measured * 1e6,
         f"paper_scale_model={paper_scale_tcc()*1e6:.0f}us")

    for pipe in ("hyde", "iter", "irg"):
        eng = make_engine(buffer_pages=1024)
        ex = PipelineExecutor(eng)
        res = ex.execute_batch(bench_queries(n_queries, seed=71),
                               make_traces(pipe, n_queries, seed=72))
        t_cc = paper_scale_tcc(eng.cfg.hw)
        llm = np.mean([sum(rt.t_llm_window for rt in r.rounds) for r in res])
        cpu_ret = np.mean([sum((rt.hits + rt.misses) * t_cc
                               for rt in r.rounds) for r in res])
        tele = np.mean([modeled_latency(r, eng, "telerag") for r in res])
        cpu = np.mean([modeled_latency(r, eng, "cpu_baseline") for r in res])
        fetch = np.mean([modeled_latency(r, eng, "runtime_fetch")
                         for r in res])
        rows.append({
            "pipeline": pipe,
            "llm_ms": round(llm * 1e3, 2),
            "cpu_retrieval_ms": round(cpu_ret * 1e3, 2),
            "retrieval_frac_cpu_system": round(cpu_ret / (llm + cpu_ret), 3),
            "e2e_cpu_ms": round(cpu * 1e3, 2),
            "e2e_runtime_fetch_ms": round(fetch * 1e3, 2),
            "e2e_telerag_ms": round(tele * 1e3, 2),
        })
        emit(f"breakdown/{pipe}", tele * 1e6,
             f"ret_frac={rows[-1]['retrieval_frac_cpu_system']}")
    write_csv("fig4_5_breakdown", rows)
    write_report("breakdown", metrics=summarize_rows(rows), rows=rows)
    return rows


if __name__ == "__main__":
    run()
