"""Shared benchmark fixtures: a mid-scale datastore + engines.

Scale model: the paper's index is 21M vectors × 768d in 4096 clusters
(61 GB, nprobe 256 = 4√Nc). The CPU-budget version here keeps the same
*shape ratios* at 1/64 scale: 320k × 256d in 256 clusters, nprobe 64
(= 4√256), and the latency MODEL uses the paper-scale byte counts so
modeled numbers are paper-comparable (measured quantities — hit rates,
coverage, bytes moved, scheduling quality — are scale-honest).
"""

from __future__ import annotations

import functools
import json
import os
import platform
import time
from typing import Dict, List, Optional

import numpy as np

import repro.core as core
from repro.configs import get_arch
from repro.serving import (EngineConfig, RagRequest, TeleRAGEngine,
                           TeleRAGServer)

BENCH_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                         "bench")

NPROBE = 64           # 4 * sqrt(256)
TOP_K = 3
DIM = 256
N_VECTORS = 320_000
N_CLUSTERS = 256
PAGE_SIZE = 128

# paper-scale constants for the latency model (61 GB / 4096 clusters)
PAPER_CLUSTER_BYTES = 61e9 / 4096


@functools.lru_cache(maxsize=1)
def bench_store():
    return core.synthetic_datastore(N_VECTORS, dim=DIM, seed=0,
                                    num_topics=192)


@functools.lru_cache(maxsize=1)
def bench_index():
    t0 = time.time()
    idx = core.build_ivf(bench_store(), N_CLUSTERS, page_size=PAGE_SIZE,
                         kmeans_iters=5, train_sample=80_000)
    print(f"# built bench index in {time.time()-t0:.1f}s "
          f"(avg cluster {idx.paged.cluster_sizes.mean():.0f} vecs)")
    return idx


def bench_queries(n: int, seed: int = 1, jitter: float = 0.08) -> np.ndarray:
    store = bench_store()
    rng = np.random.default_rng(seed)
    q = store.embeddings[rng.choice(store.num_vectors, n)]
    q = q + jitter * rng.standard_normal(q.shape).astype(np.float32)
    return q / np.linalg.norm(q, axis=-1, keepdims=True)


def bench_cfg(mode: str = "telerag", *, buffer_pages: int = 640,
              budget_bytes=None, cache: bool = False,
              chips: int = 4, seed: int = 0) -> EngineConfig:
    return EngineConfig(
        nprobe=NPROBE, top_k=TOP_K, buffer_pages=buffer_pages,
        lookahead_rank=min(2 * NPROBE, N_CLUSTERS), mode=mode,
        kernel_mode="ref", cache_enabled=cache,
        prefetch_budget_bytes=budget_bytes, chips=chips, seed=seed)


def make_engine(mode: str = "telerag", *, buffer_pages: int = 640,
                budget_bytes=None, cache: bool = False, arch="llama3-8b",
                chips: int = 4, seed: int = 0) -> TeleRAGEngine:
    cfg = bench_cfg(mode, buffer_pages=buffer_pages,
                    budget_bytes=budget_bytes, cache=cache, chips=chips,
                    seed=seed)
    return TeleRAGEngine(bench_index(), cfg, get_arch(arch))


def make_server(mode: str = "telerag", *, replicas: int = 1,
                scheduler=None, micro_batch=None, buffer_pages: int = 640,
                budget_bytes=None, cache: bool = False, arch="llama3-8b",
                chips: int = 4, seed: int = 0,
                continuous: bool = False) -> TeleRAGServer:
    """A TeleRAGServer over the shared bench index (the serving
    front-end the benches drive instead of raw executors).
    ``continuous=True`` enables per-request continuous batching."""
    cfg = bench_cfg(mode, buffer_pages=buffer_pages,
                    budget_bytes=budget_bytes, cache=cache, chips=chips,
                    seed=seed)
    return TeleRAGServer(bench_index(), cfg, replicas, get_arch(arch),
                         scheduler=scheduler, micro_batch=micro_batch,
                         continuous=continuous)


def serve_requests(srv: TeleRAGServer, q, traces, arrivals=None):
    """Submit one request per (q row, trace) and drain the server."""
    return srv.serve([RagRequest(q=q[i], trace=traces[i],
                                 arrival_t=(0.0 if arrivals is None
                                            else float(arrivals[i])))
                      for i in range(len(traces))])


def slowest_replica_latency(resp, srv, micro_batch: int,
                            sched_s: float, modeled) -> float:
    """Modeled global-batch latency: replicas run their micro-batches
    serially, the slowest replica bounds the batch (Fig. 11/13/14)."""
    per_replica: Dict[int, float] = {}
    for r in resp:
        eng = srv.engines[r.replica]
        per_replica[r.replica] = (per_replica.get(r.replica, 0.0)
                                  + modeled(r, eng, "telerag") / micro_batch)
    return max(per_replica.values()) + sched_s


def paper_scale_tcc(hw=core.TPU_V5E) -> float:
    """Host per-cluster search time at PAPER datastore scale."""
    return core.host_cluster_search_seconds(PAPER_CLUSTER_BYTES, hw)


def write_csv(name: str, rows: List[Dict]) -> str:
    os.makedirs(BENCH_DIR, exist_ok=True)
    path = os.path.join(BENCH_DIR, f"{name}.csv")
    if rows:
        keys = list(rows[0].keys())
        with open(path, "w") as f:
            f.write(",".join(keys) + "\n")
            for r in rows:
                f.write(",".join(str(r[k]) for k in keys) + "\n")
    return path


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


# ---------------------------------------------------------------------------
# Machine-readable bench reports (schema "telerag.bench/v1")
# ---------------------------------------------------------------------------

REPORT_SCHEMA = "telerag.bench/v1"
_report_dir: Optional[str] = None


def set_report_dir(path: Optional[str]) -> None:
    """Redirect ``write_report`` output (``benchmarks/run.py
    --report-dir``); None restores the default ``experiments/bench``."""
    global _report_dir
    _report_dir = path


def report_path(filename: str) -> str:
    """Resolve a bench output file against the active report dir
    (``--report-dir``, else ``experiments/bench`` — untracked either
    way: regenerated bench output is a CI artifact, not a commit)."""
    out_dir = _report_dir or BENCH_DIR
    os.makedirs(out_dir, exist_ok=True)
    return os.path.join(out_dir, filename)


def validate_report(report: Dict) -> None:
    """Schema guard for a ``telerag.bench/v1`` report (asserted by the
    bench smokes and tests/test_obs.py so the emitted JSON stays
    machine-consumable)."""
    assert report.get("schema") == REPORT_SCHEMA, report.get("schema")
    for key in ("bench", "host", "metrics", "rows"):
        assert key in report, f"missing {key}"
    assert isinstance(report["bench"], str) and report["bench"]
    assert isinstance(report["metrics"], dict)
    for k, v in report["metrics"].items():
        assert isinstance(k, str)
        assert isinstance(v, (int, float, str, bool)), (k, type(v))
    assert isinstance(report["rows"], list)
    for row in report["rows"]:
        assert isinstance(row, dict)


def summarize_rows(rows: List[Dict]) -> Dict:
    """Headline metrics from a bench's row table: the mean of every
    numeric column (``mean_<col>``) plus the row count — a uniform
    machine-readable summary for ``write_report``."""
    out: Dict = {"n_rows": len(rows)}
    if not rows:
        return out
    for k in rows[0]:
        vals = [r[k] for r in rows
                if isinstance(r.get(k), (int, float))
                and not isinstance(r.get(k), bool)]
        if len(vals) == len(rows):
            out[f"mean_{k}"] = float(np.mean(vals))
    return out


def write_report(name: str, *, metrics: Dict, rows: List[Dict] = (),
                 meta: Optional[Dict] = None) -> str:
    """Write one bench's machine-readable result as
    ``BENCH_<name>.json`` (schema ``telerag.bench/v1``): ``metrics`` is
    the bench's headline scalars, ``rows`` its per-configuration table
    (usually the same rows as ``write_csv``), ``meta`` free-form
    provenance.  Returns the path."""
    report = {
        "schema": REPORT_SCHEMA,
        "bench": name,
        "host": {"platform": platform.platform(),
                 "python": platform.python_version()},
        "metrics": {k: (float(v) if isinstance(v, (int, float))
                        and not isinstance(v, bool) else v)
                    for k, v in metrics.items()},
        "rows": [dict(r) for r in rows],
        "meta": dict(meta or {}),
    }
    validate_report(report)
    out_dir = _report_dir or BENCH_DIR
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1, default=float)
    print(f"# report: {path}")
    return path
