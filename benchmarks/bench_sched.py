"""Fig. 14: scheduler benefit vs overhead (both MEASURED).

Compares {both schedulers} / {prefetch scheduler only} / {neither} on the
same global batch: overhead = wall clock of scheduling; benefit = shared-
cluster gain within micro-batches + cache-overlap of assignments.
"""

import time

import numpy as np

import repro.core as core
from repro.configs import get_arch
from repro.serving import EngineConfig, MultiReplicaOrchestrator, make_traces
from benchmarks.common import (NPROBE, N_CLUSTERS, bench_index, bench_queries,
                               emit, write_csv)
from benchmarks.bench_latency import modeled_latency


def run(global_batch: int = 32, micro_batch: int = 4, replicas: int = 4):
    rows = []
    for pre_s, cache_s in ((True, True), (True, False), (False, False)):
        cfg = EngineConfig(nprobe=NPROBE, top_k=3, buffer_pages=768,
                           lookahead_rank=min(2 * NPROBE, N_CLUSTERS),
                           kernel_mode="ref", cache_enabled=True, chips=4)
        orch = MultiReplicaOrchestrator(bench_index(), cfg, replicas,
                                        get_arch("llama3-8b"),
                                        use_prefetch_sched=pre_s,
                                        use_cache_sched=cache_s)
        # warm caches
        orch.run_global_batch(bench_queries(global_batch, seed=51),
                              make_traces("hyde", global_batch, seed=52),
                              micro_batch=micro_batch)
        rep = orch.run_global_batch(bench_queries(global_batch, seed=53),
                                    make_traces("hyde", global_batch, seed=54),
                                    micro_batch=micro_batch)
        per_replica = {}
        for rid, results in rep.per_replica_results.items():
            eng = orch.replicas[rid]
            per_replica[rid] = sum(modeled_latency(r, eng, "telerag")
                                   for r in results) / micro_batch
        lat = max(per_replica.values()) + rep.schedule_overhead_s
        hits = sum(rt.hits for r in rep.all_results() for rt in r.rounds)
        miss = sum(rt.misses for r in rep.all_results() for rt in r.rounds)
        tag = ("both" if cache_s else ("prefetch_only" if pre_s else "none"))
        rows.append({
            "schedulers": tag,
            "latency_ms": round(lat * 1e3, 2),
            "sched_overhead_ms": round(rep.schedule_overhead_s * 1e3, 3),
            "hit_rate": round(hits / max(hits + miss, 1), 4),
            "cache_overlap": sum(a[2] for a in rep.assignments),
        })
        emit(f"sched/{tag}", rep.schedule_overhead_s * 1e6,
             f"lat_ms={rows[-1]['latency_ms']};hit={rows[-1]['hit_rate']}")
    write_csv("fig14_sched", rows)
    return rows


if __name__ == "__main__":
    run()
