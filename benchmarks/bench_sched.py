"""Fig. 14: scheduler benefit vs overhead (both MEASURED).

Compares {both schedulers} / {prefetch scheduler only} / {neither} on the
same global batch through the ``TeleRAGServer`` front-end: overhead =
wall clock of wave scheduling; benefit = shared-cluster gain within
micro-batches + cache-overlap of assignments.
"""

from repro.core.schedulers import TeleRAGScheduler
from repro.serving import make_traces
from benchmarks.common import (NPROBE, N_CLUSTERS, bench_queries, emit,
                               make_server, serve_requests,
                               slowest_replica_latency, write_csv,
                               summarize_rows, write_report)
from benchmarks.bench_latency import modeled_latency


def run(global_batch: int = 32, micro_batch: int = 4, replicas: int = 4):
    rows = []
    for pre_s, cache_s in ((True, True), (True, False), (False, False)):
        srv = make_server(replicas=replicas, cache=True, buffer_pages=768,
                          scheduler=TeleRAGScheduler(
                              similarity_grouping=pre_s,
                              cache_aware=cache_s),
                          micro_batch=micro_batch)

        def serve(qseed, tseed):
            return serve_requests(
                srv, bench_queries(global_batch, seed=qseed),
                make_traces("hyde", global_batch, seed=tseed))

        serve(51, 52)                               # warm caches
        n_waves0 = len(srv.wave_log)
        resp = serve(53, 54)
        waves = srv.wave_log[n_waves0:]
        sched_s = sum(w.sched_overhead_s for w in waves)
        lat = slowest_replica_latency(resp, srv, micro_batch, sched_s,
                                      modeled_latency)
        hits = sum(rt.hits for r in resp for rt in r.rounds)
        miss = sum(rt.misses for r in resp for rt in r.rounds)
        tag = ("both" if cache_s else ("prefetch_only" if pre_s else "none"))
        rows.append({
            "schedulers": tag,
            "latency_ms": round(lat * 1e3, 2),
            "sched_overhead_ms": round(sched_s * 1e3, 3),
            "hit_rate": round(hits / max(hits + miss, 1), 4),
            "cache_overlap": sum(a[2] for w in waves
                                 for a in w.assignments),
        })
        emit(f"sched/{tag}", sched_s * 1e6,
             f"lat_ms={rows[-1]['latency_ms']};hit={rows[-1]['hit_rate']}")
    write_csv("fig14_sched", rows)
    write_report("sched", metrics=summarize_rows(rows), rows=rows)
    return rows


if __name__ == "__main__":
    run()
