"""Generic decoder assembler: one code path drives all eleven archs.

Layers are *stacked* along a leading ``layers`` dim and driven by
``jax.lax.scan`` so the HLO stays O(1) in depth (compile-time critical for
the 80-cell dry-run sweep). Per-layer heterogeneity (gemma2's local/global
alternation) is expressed as scanned per-layer scalars, not Python
branches. Zamba2's shared attention block lives outside the scan and is
applied between groups with per-group LoRA deltas.

Entry points (all pure functions of pytrees — pjit-ready):
  loss_fn(params, batch)                -> (loss, metrics)
  prefill(params, inputs)               -> (last_logits, cache)
  serve_step(params, cache, inputs)     -> (logits, new_cache)
Param/axes/shape trees are built through the same builders (see
``layers.Maker``) so sharding specs always match the param structure.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import mamba2 as mamba_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models.layers import (AxesMaker, InitMaker, Maker, apply_rope,
                                 cross_entropy_loss, mlp_forward, mlp_params,
                                 rms_norm, softcap)

Params = Dict[str, Any]


def family_kind(cfg: ArchConfig) -> str:
    if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        return "rwkv6"
    if cfg.shared_attn_every:
        return "zamba2"
    return "attn"


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------


def _layer_builder(cfg: ArchConfig):
    kind = family_kind(cfg)

    def build(mk: Maker) -> Params:
        d = cfg.d_model
        if kind == "rwkv6":
            return {
                "tm_norm": mk("tm_norm", (d,), ("embed",)),
                "tm": rwkv_mod.rwkv6_params(mk, cfg),
                "cm_norm": mk("cm_norm", (d,), ("embed",)),
            }
        if kind == "zamba2":
            return {
                "norm": mk("norm", (d,), ("embed",)),
                "mamba": mamba_mod.mamba2_params(mk, cfg),
            }
        p: Params = {"attn_norm": mk("attn_norm", (d,), ("embed",))}
        if cfg.attn_kind == "mla":
            p["attn"] = mla_mod.mla_params(mk, cfg)
        else:
            p["attn"] = attn_mod.attn_params(mk, cfg)
        p["mlp_norm"] = mk("mlp_norm", (d,), ("embed",))
        if cfg.moe is not None:
            p["mlp"] = moe_mod.moe_params(mk, cfg)
        else:
            p["mlp"] = mlp_params(mk, d, cfg.d_ff, cfg.mlp_gated)
        return p

    return build


def _shared_block_builder(cfg: ArchConfig):
    """Zamba2 shared attention(+MLP) block and per-group LoRA deltas."""

    def build_shared(mk: Maker) -> Params:
        d = cfg.d_model
        return {
            "attn_norm": mk("shared.attn_norm", (d,), ("embed",)),
            "attn": attn_mod.attn_params(mk, cfg, prefix="shared.attn"),
            "mlp_norm": mk("shared.mlp_norm", (d,), ("embed",)),
            "mlp": mlp_params(mk, d, cfg.d_ff, cfg.mlp_gated, prefix="shared.mlp"),
        }

    def build_lora(mk: Maker) -> Params:
        d, r = cfg.d_model, cfg.shared_attn_lora_rank
        H, KVH, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
        return {
            "qa": mk("lora.qa", (d, r), ("embed", None)),
            "qb": mk("lora.qb", (r, H * Dh), (None, "heads_flat"), scale=0.01),
            "va": mk("lora.va", (d, r), ("embed", None)),
            "vb": mk("lora.vb", (r, KVH * Dh), (None, "heads_flat"), scale=0.01),
        }

    return build_shared, build_lora


def _top_builder(cfg: ArchConfig):
    def build(mk: Maker) -> Params:
        d, V = cfg.d_model, cfg.vocab_size
        p: Params = {"final_norm": mk("final_norm", (d,), ("embed",))}
        if cfg.frontend is not None and cfg.frontend.kind == "encodec_stub":
            nc = cfg.frontend.num_codebooks
            p["embed"] = mk("embed", (nc, V, d), (None, "vocab", "embed"), scale=0.02)
            p["unembed"] = mk("unembed", (nc, d, V), (None, "embed", "vocab"))
        else:
            p["embed"] = mk("embed", (V, d), ("vocab", "embed"), scale=0.02)
            if not cfg.tie_embeddings:
                p["unembed"] = mk("unembed", (d, V), ("embed", "vocab"))
        if cfg.frontend is not None and cfg.frontend.kind == "vit_stub":
            p["vit_proj"] = mk("vit_proj", (cfg.frontend.embed_dim, d),
                               (None, "embed"))
        return p

    return build


def zamba2_groups(cfg: ArchConfig) -> Tuple[int, int]:
    per = cfg.shared_attn_every
    assert cfg.num_layers % per == 0, "zamba2 layers must divide group size"
    return cfg.num_layers // per, per


def init_params(cfg: ArchConfig, key: jax.Array,
                dtype=jnp.bfloat16) -> Params:
    """Materialize random-init params (use under jax.eval_shape for AOT)."""
    layer_build = _layer_builder(cfg)
    kind = family_kind(cfg)
    mk = lambda k: InitMaker(k, dtype=dtype)
    top = _top_builder(cfg)(mk(jax.random.fold_in(key, 0)))

    if kind == "zamba2":
        G, per = zamba2_groups(cfg)
        keys = jax.random.split(jax.random.fold_in(key, 1), G * per)
        layers = jax.vmap(lambda k: layer_build(mk(k)))(keys)
        layers = jax.tree.map(lambda a: a.reshape((G, per) + a.shape[1:]), layers)
        build_shared, build_lora = _shared_block_builder(cfg)
        top["shared"] = build_shared(mk(jax.random.fold_in(key, 2)))
        lkeys = jax.random.split(jax.random.fold_in(key, 3), G)
        top["lora"] = jax.vmap(lambda k: build_lora(mk(k)))(lkeys)
    else:
        keys = jax.random.split(jax.random.fold_in(key, 1), cfg.num_layers)
        layers = jax.vmap(lambda k: layer_build(mk(k)))(keys)
    top["layers"] = layers
    return top


def param_axes(cfg: ArchConfig) -> Params:
    """Logical-axis tree structurally matching ``init_params`` output."""
    mk = AxesMaker()
    layer_axes = _layer_builder(cfg)(mk)
    kind = family_kind(cfg)
    top = _top_builder(cfg)(mk)
    if kind == "zamba2":
        layer_axes = jax.tree.map(lambda ax: ("layers", "layers") + ax, layer_axes,
                                  is_leaf=lambda x: isinstance(x, tuple))
        build_shared, build_lora = _shared_block_builder(cfg)
        top["shared"] = build_shared(mk)
        top["lora"] = jax.tree.map(lambda ax: ("layers",) + ax, build_lora(mk),
                                   is_leaf=lambda x: isinstance(x, tuple))
    else:
        layer_axes = jax.tree.map(lambda ax: ("layers",) + ax, layer_axes,
                                  is_leaf=lambda x: isinstance(x, tuple))
    top["layers"] = layer_axes
    return top


# ---------------------------------------------------------------------------
# Per-layer static metadata (scanned alongside params)
# ---------------------------------------------------------------------------


def layer_windows(cfg: ArchConfig) -> jnp.ndarray:
    """Per-layer attention window (0 = global). gemma2 alternates local/global."""
    L = cfg.num_layers
    if cfg.local_global_pattern and cfg.sliding_window:
        w = [(cfg.sliding_window if i % 2 == 0 else 0) for i in range(L)]
    elif cfg.sliding_window:
        w = [cfg.sliding_window] * L
    else:
        w = [0] * L
    return jnp.asarray(w, jnp.int32)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_tokens(params: Params, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.frontend is not None and cfg.frontend.kind == "encodec_stub":
        # tokens: [..., num_codebooks]; sum codebook embeddings
        nc = cfg.frontend.num_codebooks
        embs = [jnp.take(params["embed"][c], tokens[..., c], axis=0)
                for c in range(nc)]
        x = functools.reduce(jnp.add, embs)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.tie_embeddings:  # gemma-style embedding scaling
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def unembed(params: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x: [..., d] -> logits [..., V] (or [..., nc, V] for audio)."""
    if cfg.frontend is not None and cfg.frontend.kind == "encodec_stub":
        logits = jnp.einsum("...d,cdv->...cv", x, params["unembed"])
    elif cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, params["embed"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, params["unembed"])
    return softcap(logits, cfg.final_logit_softcap)


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------


def _grouped(tree, g: int):
    """Reshape stacked layer params [L, ...] -> [L//g, g, ...]."""
    return jax.tree.map(lambda a: a.reshape((a.shape[0] // g, g) + a.shape[1:]),
                        tree)


def _best_group(L: int, target: int) -> int:
    g = min(target, L)
    while L % g:
        g -= 1
    return g


def forward(params: Params, tokens: jax.Array, cfg: ArchConfig, *,
            image_embeds: Optional[jax.Array] = None,
            attn_chunk: int = 1024,
            remat: bool = False,
            remat_group: int = 4,
            act_spec=None,
            want_cache: bool = False,
            ) -> Tuple[jax.Array, jax.Array, Optional[Params]]:
    """Returns (hidden [B,S,d] after final norm, aux_loss, cache|None).

    remat=True uses *grouped* rematerialization: layers are scanned in
    groups of ``remat_group`` with jax.checkpoint at group boundaries, so
    saved residuals are L/g activations instead of per-layer scan
    residuals. ``act_spec`` (a PartitionSpec) additionally shards the
    saved residual stream — Megatron-style activation TP over d_model —
    which divides saved-activation HBM by the model-axis size.
    """
    kind = family_kind(cfg)

    def constrain(h):
        if act_spec is not None:
            return jax.lax.with_sharding_constraint(h, act_spec)
        return h

    x = embed_tokens(params, tokens, cfg)
    if image_embeds is not None:
        prefix = jnp.einsum("bpe,ed->bpd", image_embeds.astype(x.dtype),
                            params["vit_proj"])
        x = jnp.concatenate([prefix, x], axis=1)
    x = constrain(x)
    B, S, _ = x.shape
    positions = jnp.arange(S, dtype=jnp.int32)

    if kind == "attn":
        windows = layer_windows(cfg)

        def body(carry, xs):
            h, aux = carry
            lp, win = xs
            a_in = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
            if cfg.attn_kind == "mla":
                a_out, kv = mla_mod.mla_forward(lp["attn"], a_in, cfg,
                                                positions=positions,
                                                attn_chunk=attn_chunk)
            else:
                a_out, kv = attn_mod.attn_forward(lp["attn"], a_in, cfg,
                                                  positions=positions, window=win,
                                                  attn_chunk=attn_chunk)
            h = h + a_out
            m_in = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
            if cfg.moe is not None:
                m_out, a = moe_mod.moe_forward(lp["mlp"], m_in, cfg)
                aux = aux + a
            else:
                m_out = mlp_forward(lp["mlp"], m_in, cfg.mlp_act, cfg.mlp_gated)
            h = constrain(h + m_out)
            return (h, aux), kv if want_cache else None

        carry0 = (x, jnp.zeros((), jnp.float32))
        if remat and not want_cache:
            g = _best_group(cfg.num_layers, remat_group)

            def group_body(carry, xs):
                glp, gwin = xs
                return jax.lax.scan(body, carry, (glp, gwin))

            (x, aux), kvs = jax.lax.scan(
                jax.checkpoint(group_body), carry0,
                (_grouped(params["layers"], g), windows.reshape(-1, g)))
        else:
            f = jax.checkpoint(body) if remat else body
            (x, aux), kvs = jax.lax.scan(f, carry0,
                                         (params["layers"], windows))
        cache = None
        if want_cache:
            if cfg.attn_kind == "mla":
                cache = {"ckv": kvs[0], "kpe": kvs[1]}
            else:
                cache = {"k": kvs[0], "v": kvs[1]}
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, aux, cache

    if kind == "rwkv6":
        K = cfg.ssm.head_dim
        H = cfg.d_model // K

        def body(h, lp):
            s1 = jnp.zeros((B, cfg.d_model), h.dtype)
            st = jnp.zeros((B, H, K, K), jnp.float32)
            tm_in = rms_norm(h, lp["tm_norm"], cfg.norm_eps)
            y, s1o, sto = rwkv_mod.rwkv6_time_mix(lp["tm"], tm_in, cfg,
                                                  shift_in=s1, state_in=st)
            h = h + y
            cm_in = rms_norm(h, lp["cm_norm"], cfg.norm_eps)
            y2, s2o = rwkv_mod.rwkv6_channel_mix(lp["tm"], cm_in,
                                                 jnp.zeros((B, cfg.d_model), h.dtype))
            h = constrain(h + y2)
            return h, (s1o, sto, s2o) if want_cache else None

        if remat and not want_cache:
            g = _best_group(cfg.num_layers, remat_group)

            def group_body(h, glp):
                return jax.lax.scan(body, h, glp)

            x, states = jax.lax.scan(jax.checkpoint(group_body), x,
                                     _grouped(params["layers"], g))
        else:
            f = jax.checkpoint(body) if remat else body
            x, states = jax.lax.scan(f, x, params["layers"])
        cache = None
        if want_cache:
            cache = {"shift1": states[0], "wkv": states[1], "shift2": states[2]}
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return x, jnp.zeros((), jnp.float32), cache

    # ---- zamba2 hybrid -----------------------------------------------------
    G, per = zamba2_groups(cfg)
    d_in, Hm, P, N = mamba_mod.mamba2_dims(cfg)
    cw = cfg.ssm.conv_width
    shared = params["shared"]

    def shared_apply(h, lora):
        dd, HH, DD = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
        KVH = cfg.num_kv_heads
        ap = dict(shared["attn"])
        ap["wq"] = ap["wq"] + jnp.einsum("dr,re->de", lora["qa"],
                                         lora["qb"]).reshape(dd, HH, DD)
        ap["wv"] = ap["wv"] + jnp.einsum("dr,re->de", lora["va"],
                                         lora["vb"]).reshape(dd, KVH, DD)
        a_in = rms_norm(h, shared["attn_norm"], cfg.norm_eps)
        a_out, kv = attn_mod.attn_forward(ap, a_in, cfg, positions=positions,
                                          window=0, attn_chunk=attn_chunk)
        h = h + a_out
        m_in = rms_norm(h, shared["mlp_norm"], cfg.norm_eps)
        h = h + mlp_forward(shared["mlp"], m_in, cfg.mlp_act, cfg.mlp_gated)
        return h, kv

    def group_body(carry, xs):
        h = carry
        glp, lora = xs
        h, kv = shared_apply(h, lora)

        def mamba_body(hh, lp):
            m_in = rms_norm(hh, lp["norm"], cfg.norm_eps)
            ci = jnp.zeros((B, cw - 1, d_in + 2 * N), hh.dtype)
            si = jnp.zeros((B, Hm, P, N), jnp.float32)
            y, co, so = mamba_mod.mamba2_forward(lp["mamba"], m_in, cfg,
                                                 conv_in=ci, state_in=si)
            return hh + y, (co, so) if want_cache else None

        h, mstates = jax.lax.scan(mamba_body, h, glp)
        return constrain(h), (kv, mstates) if want_cache else None

    f = jax.checkpoint(group_body) if remat else group_body
    x, ys = jax.lax.scan(f, x, (params["layers"], params["lora"]))
    cache = None
    if want_cache:
        (k, v), (conv, ssd) = ys
        cache = {"shared_k": k, "shared_v": v, "conv": conv, "ssd": ssd}
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, jnp.zeros((), jnp.float32), cache


# ---------------------------------------------------------------------------
# Loss (sequence-chunked to avoid materializing [B,S,V] logits)
# ---------------------------------------------------------------------------


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ArchConfig, *,
            attn_chunk: int = 1024, remat: bool = True,
            remat_group: int = 4, act_spec=None,
            loss_chunk: int = 512) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    tokens = batch["tokens"]
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    x, aux, _ = forward(params, tokens, cfg,
                        image_embeds=batch.get("image_embeds"),
                        attn_chunk=attn_chunk, remat=remat,
                        remat_group=remat_group, act_spec=act_spec)
    if batch.get("image_embeds") is not None:
        x = x[:, batch["image_embeds"].shape[1]:, :]   # loss on text positions

    B, S = x.shape[0], x.shape[1]
    nch = max(S // loss_chunk, 1)
    while S % nch:            # largest divisor <= S//loss_chunk, so the
        nch -= 1              # [B, S/nch, V] logits chunk stays bounded
    xs = jnp.moveaxis(x.reshape(B, nch, S // nch, -1), 1, 0)
    ls = jnp.moveaxis(labels.reshape((B, nch, S // nch) + labels.shape[2:]), 1, 0)
    ms = (jnp.moveaxis(mask.reshape(B, nch, S // nch), 1, 0)
          if mask is not None else None)

    def chunk_loss(carry, xs_):
        if ms is None:
            xc, lc = xs_
            mc = jnp.ones(lc.shape[:2], jnp.float32)
        else:
            xc, lc, mc = xs_
        logits = unembed(params, xc, cfg).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = logz - gold
        if nll.ndim == 3:          # audio: extra codebook dim
            nll = jnp.mean(nll, axis=-1)
        tot, cnt = carry
        return (tot + jnp.sum(nll * mc), cnt + jnp.sum(mc)), None

    args = (xs, ls) if ms is None else (xs, ls, ms)
    # checkpoint: backward recomputes each chunk's [B,chunk,V] logits
    # instead of saving them per scan step (the dominant train-memory term)
    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(chunk_loss),
                                 (jnp.zeros(()), jnp.zeros(())), args)
    loss = tot / jnp.maximum(cnt, 1.0) + aux
    return loss, {"ce": tot / jnp.maximum(cnt, 1.0), "aux": aux,
                  "tokens": cnt}


# ---------------------------------------------------------------------------
# KV / state cache
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, maker=jnp.zeros,
               kv_quant: bool = False) -> Params:
    """kv_quant=True stores attention K/V int8 with per-(token, head)
    bf16 scales — halves the decode memory-roofline term (§Perf). The
    gemma2 split cache quantizes the full-length global layers; the
    window-sized local rings stay bf16 (negligible size)."""
    kind = family_kind(cfg)
    L, B, S = cfg.num_layers, batch, max_len
    if kind == "attn":
        if cfg.attn_kind == "mla":
            m = cfg.mla
            return {"ckv": maker((L, B, S, m.kv_lora_rank), dtype),
                    "kpe": maker((L, B, S, m.qk_rope_head_dim), dtype)}
        KVH, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
        kv_dt = jnp.int8 if kv_quant else dtype
        if cfg.local_global_pattern and cfg.sliding_window:
            # split cache: local layers need only `window` ring slots
            assert L % 2 == 0, "local/global alternation expects even L"
            W = min(cfg.sliding_window, max_len)
            Lp = L // 2
            out = {"k_local": maker((Lp, B, W, KVH, Dh), dtype),
                   "v_local": maker((Lp, B, W, KVH, Dh), dtype),
                   "k_global": maker((Lp, B, S, KVH, Dh), kv_dt),
                   "v_global": maker((Lp, B, S, KVH, Dh), kv_dt)}
            if kv_quant:
                out["k_global_scale"] = maker((Lp, B, S, KVH), jnp.bfloat16)
                out["v_global_scale"] = maker((Lp, B, S, KVH), jnp.bfloat16)
            return out
        out = {"k": maker((L, B, S, KVH, Dh), kv_dt),
               "v": maker((L, B, S, KVH, Dh), kv_dt)}
        if kv_quant:
            out["k_scale"] = maker((L, B, S, KVH), jnp.bfloat16)
            out["v_scale"] = maker((L, B, S, KVH), jnp.bfloat16)
        return out
    if kind == "rwkv6":
        K = cfg.ssm.head_dim
        H = cfg.d_model // K
        return {"shift1": maker((L, B, cfg.d_model), dtype),
                "wkv": maker((L, B, H, K, K), jnp.float32),
                "shift2": maker((L, B, cfg.d_model), dtype)}
    G, per = zamba2_groups(cfg)
    d_in, Hm, P, N = mamba_mod.mamba2_dims(cfg)
    cw = cfg.ssm.conv_width
    KVH, Dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {"shared_k": maker((G, B, S, KVH, Dh), dtype),
            "shared_v": maker((G, B, S, KVH, Dh), dtype),
            "conv": maker((G, per, B, cw - 1, d_in + 2 * N), dtype),
            "ssd": maker((G, per, B, Hm, P, N), jnp.float32)}


def cache_axes(cfg: ArchConfig, kv_quant: bool = False) -> Params:
    """Logical axes for cache leaves (mirrors init_cache structure)."""
    kind = family_kind(cfg)
    if kind == "attn":
        if cfg.attn_kind == "mla":
            return {"ckv": ("layers", "batch", "kv_seq", None),
                    "kpe": ("layers", "batch", "kv_seq", None)}
        kv = ("layers", "batch", "kv_seq", "kv_heads", None)
        sc = ("layers", "batch", "kv_seq", "kv_heads")
        if cfg.local_global_pattern and cfg.sliding_window:
            out = {"k_local": kv, "v_local": kv,
                   "k_global": kv, "v_global": kv}
            if kv_quant:
                out["k_global_scale"] = sc
                out["v_global_scale"] = sc
            return out
        out = {"k": kv, "v": kv}
        if kv_quant:
            out["k_scale"] = sc
            out["v_scale"] = sc
        return out
    if kind == "rwkv6":
        return {"shift1": ("layers", "batch", "embed"),
                "wkv": ("layers", "batch", "heads_flat", None, None),
                "shift2": ("layers", "batch", "embed")}
    return {"shared_k": ("layers", "batch", "kv_seq", "kv_heads", None),
            "shared_v": ("layers", "batch", "kv_seq", "kv_heads", None),
            "conv": ("layers", "layers", "batch", None, "heads_flat"),
            "ssd": ("layers", "layers", "batch", "heads_flat", None, None)}


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(params: Params, inputs: Dict[str, jax.Array], cfg: ArchConfig, *,
            attn_chunk: int = 1024) -> Tuple[jax.Array, Params]:
    """Full-prompt forward; returns (last-token logits, cache at prompt len)."""
    x, _, cache = forward(params, inputs["tokens"], cfg,
                          image_embeds=inputs.get("image_embeds"),
                          attn_chunk=attn_chunk, want_cache=True)
    if (family_kind(cfg) == "attn" and cfg.local_global_pattern
            and cfg.sliding_window):
        # split handoff: even layers are local (ring of W slots)
        W = cfg.sliding_window
        cache = {
            "k_local": attn_mod.ring_from_full(cache["k"][0::2], W),
            "v_local": attn_mod.ring_from_full(cache["v"][0::2], W),
            "k_global": cache["k"][1::2],
            "v_global": cache["v"][1::2],
        }
    logits = unembed(params, x[:, -1, :], cfg)
    return logits, cache


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def serve_step(params: Params, cache: Params, inputs: Dict[str, jax.Array],
               cfg: ArchConfig, *, attn_chunk: int = 0,
               seq_axis: Optional[str] = None,
               kv_quant: bool = False,
               ) -> Tuple[jax.Array, Params]:
    """One decode step for the whole batch.

    inputs: token [B] (audio: [B, nc]), pos [B] — per-sequence positions
    (continuous batching). attn_chunk=0 => single-pass attention over the
    cache (best for sharded KV; chunking matters only for prefill).
    seq_axis: mesh axis the KV cache's seq dim is sharded over (long-
    context sequence-parallel decode); threads sharding constraints into
    the attention so scores stay KV-local with small psum reductions.
    """
    import jax.sharding as jsh
    kind = family_kind(cfg)
    tok = inputs["token"]
    pos = inputs["pos"]
    kv_spec5 = (jsh.PartitionSpec(None, None, None, None, seq_axis)
                if seq_axis else None)
    kv_spec3 = (jsh.PartitionSpec(None, None, seq_axis)
                if seq_axis else None)
    x = embed_tokens(params, tok[:, None] if tok.ndim == 1 else tok[:, None, :],
                     cfg)
    B = x.shape[0]

    if (kind == "attn" and cfg.local_global_pattern and cfg.sliding_window):
        # gemma2: pair scan (local ring layer + global layer), split cache
        L = cfg.num_layers
        W = cache["k_local"].shape[2]
        Smax = cache["k_global"].shape[2]
        chunk = attn_chunk or Smax
        pair_params = _grouped(params["layers"], 2)

        def mlp_apply(lp, h):
            m_in = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
            return h + mlp_forward(lp["mlp"], m_in, cfg.mlp_act,
                                   cfg.mlp_gated)

        def body(carry, xs):
            h, c = carry
            plp, pi = xs
            lp_loc = jax.tree.map(lambda a: a[0], plp)
            lp_glb = jax.tree.map(lambda a: a[1], plp)
            # local (ring) layer
            a_in = rms_norm(h, lp_loc["attn_norm"], cfg.norm_eps)
            kl = jax.lax.dynamic_index_in_dim(c["k_local"], pi, keepdims=False)
            vl = jax.lax.dynamic_index_in_dim(c["v_local"], pi, keepdims=False)
            a_out, kl, vl = attn_mod.attn_decode_ring(
                lp_loc["attn"], a_in, cfg, cache_k=kl, cache_v=vl, pos=pos,
                window=W)
            c = dict(c,
                     k_local=jax.lax.dynamic_update_index_in_dim(
                         c["k_local"], kl, pi, 0),
                     v_local=jax.lax.dynamic_update_index_in_dim(
                         c["v_local"], vl, pi, 0))
            h = mlp_apply(lp_loc, h + a_out)
            # global layer
            a_in = rms_norm(h, lp_glb["attn_norm"], cfg.norm_eps)
            kg = jax.lax.dynamic_index_in_dim(c["k_global"], pi, keepdims=False)
            vg = jax.lax.dynamic_index_in_dim(c["v_global"], pi, keepdims=False)
            if kv_quant:
                ks = jax.lax.dynamic_index_in_dim(c["k_global_scale"], pi,
                                                  keepdims=False)
                vs = jax.lax.dynamic_index_in_dim(c["v_global_scale"], pi,
                                                  keepdims=False)
                a_out, kg, vg, ks, vs = attn_mod.attn_decode_quant(
                    lp_glb["attn"], a_in, cfg, cache_k=kg, cache_v=vg,
                    k_scale=ks, v_scale=vs, pos=pos, window=0,
                    attn_chunk=chunk, kv_seq_spec=kv_spec5)
                c = dict(c,
                         k_global_scale=jax.lax.dynamic_update_index_in_dim(
                             c["k_global_scale"], ks, pi, 0),
                         v_global_scale=jax.lax.dynamic_update_index_in_dim(
                             c["v_global_scale"], vs, pi, 0))
            else:
                a_out, kg, vg = attn_mod.attn_decode(
                    lp_glb["attn"], a_in, cfg, cache_k=kg, cache_v=vg,
                    pos=pos, window=0, attn_chunk=chunk,
                    kv_seq_spec=kv_spec5)
            c = dict(c,
                     k_global=jax.lax.dynamic_update_index_in_dim(
                         c["k_global"], kg, pi, 0),
                     v_global=jax.lax.dynamic_update_index_in_dim(
                         c["v_global"], vg, pi, 0))
            h = mlp_apply(lp_glb, h + a_out)
            return (h, c), None

        (x, cache), _ = jax.lax.scan(
            body, (x, cache),
            (pair_params, jnp.arange(L // 2, dtype=jnp.int32)))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = unembed(params, x[:, 0, :], cfg)
        return logits, cache

    if kind == "attn":
        windows = layer_windows(cfg)
        Smax = (cache["ckv"] if cfg.attn_kind == "mla" else cache["k"]).shape[2]
        chunk = attn_chunk or Smax
        L = cfg.num_layers

        # Cache rides in the scan CARRY and is updated with
        # dynamic_update_index_in_dim at the layer index: XLA recognizes
        # the in-place update inside the while loop, so the (possibly
        # hundreds of GB) stacked cache is single-buffered — scanning it
        # as xs/ys would double-buffer it in temp space.
        def body(carry, xs):
            h, cache_c = carry
            lp, win, li = xs
            a_in = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
            if cfg.attn_kind == "mla":
                ckv = jax.lax.dynamic_index_in_dim(cache_c["ckv"], li,
                                                   keepdims=False)
                kpe = jax.lax.dynamic_index_in_dim(cache_c["kpe"], li,
                                                   keepdims=False)
                a_out, ckv, kpe = mla_mod.mla_decode(lp["attn"], a_in, cfg,
                                                     cache_ckv=ckv,
                                                     cache_kpe=kpe, pos=pos,
                                                     kv_seq_spec=kv_spec3)
                cache_c = {
                    "ckv": jax.lax.dynamic_update_index_in_dim(
                        cache_c["ckv"], ckv, li, 0),
                    "kpe": jax.lax.dynamic_update_index_in_dim(
                        cache_c["kpe"], kpe, li, 0),
                }
            else:
                ck = jax.lax.dynamic_index_in_dim(cache_c["k"], li,
                                                  keepdims=False)
                cv = jax.lax.dynamic_index_in_dim(cache_c["v"], li,
                                                  keepdims=False)
                if kv_quant:
                    ks = jax.lax.dynamic_index_in_dim(cache_c["k_scale"], li,
                                                      keepdims=False)
                    vs = jax.lax.dynamic_index_in_dim(cache_c["v_scale"], li,
                                                      keepdims=False)
                    a_out, ck, cv, ks, vs = attn_mod.attn_decode_quant(
                        lp["attn"], a_in, cfg, cache_k=ck, cache_v=cv,
                        k_scale=ks, v_scale=vs, pos=pos, window=win,
                        attn_chunk=chunk, kv_seq_spec=kv_spec5)
                    cache_c = dict(
                        cache_c,
                        k_scale=jax.lax.dynamic_update_index_in_dim(
                            cache_c["k_scale"], ks, li, 0),
                        v_scale=jax.lax.dynamic_update_index_in_dim(
                            cache_c["v_scale"], vs, li, 0))
                else:
                    a_out, ck, cv = attn_mod.attn_decode(
                        lp["attn"], a_in, cfg, cache_k=ck, cache_v=cv,
                        pos=pos, window=win, attn_chunk=chunk,
                        kv_seq_spec=kv_spec5)
                cache_c = dict(
                    cache_c,
                    k=jax.lax.dynamic_update_index_in_dim(
                        cache_c["k"], ck, li, 0),
                    v=jax.lax.dynamic_update_index_in_dim(
                        cache_c["v"], cv, li, 0))
            h = h + a_out
            m_in = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
            if cfg.moe is not None:
                m_out, _ = moe_mod.moe_forward(lp["mlp"], m_in, cfg)
            else:
                m_out = mlp_forward(lp["mlp"], m_in, cfg.mlp_act, cfg.mlp_gated)
            return (h + m_out, cache_c), None

        (x, cache), _ = jax.lax.scan(
            body, (x, cache),
            (params["layers"], windows, jnp.arange(L, dtype=jnp.int32)))

    elif kind == "rwkv6":
        def body(h, xs):
            lp, s1, st, s2 = xs
            h2 = h[:, 0, :]
            tm_in = rms_norm(h2, lp["tm_norm"], cfg.norm_eps)
            y, s1o, sto = rwkv_mod.rwkv6_time_mix_step(lp["tm"], tm_in, cfg,
                                                       shift_in=s1, state_in=st)
            h2 = h2 + y
            cm_in = rms_norm(h2, lp["cm_norm"], cfg.norm_eps)
            y2, s2o = rwkv_mod.rwkv6_channel_mix(lp["tm"], cm_in, s2)
            h2 = h2 + y2
            return h2[:, None, :], (s1o, sto, s2o)

        x, new = jax.lax.scan(body, x, (params["layers"], cache["shift1"],
                                        cache["wkv"], cache["shift2"]))
        cache = {"shift1": new[0], "wkv": new[1], "shift2": new[2]}

    else:  # zamba2
        G, per = zamba2_groups(cfg)
        shared = params["shared"]
        Smax = cache["shared_k"].shape[2]

        def group_body(h, xs):
            glp, lora, ck, cv, conv, ssd = xs
            dd, HH, DD = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
            KVH = cfg.num_kv_heads
            ap = dict(shared["attn"])
            ap["wq"] = ap["wq"] + jnp.einsum("dr,re->de", lora["qa"],
                                             lora["qb"]).reshape(dd, HH, DD)
            ap["wv"] = ap["wv"] + jnp.einsum("dr,re->de", lora["va"],
                                             lora["vb"]).reshape(dd, KVH, DD)
            a_in = rms_norm(h, shared["attn_norm"], cfg.norm_eps)
            a_out, ck, cv = attn_mod.attn_decode(ap, a_in, cfg, cache_k=ck,
                                                 cache_v=cv, pos=pos, window=0,
                                                 attn_chunk=attn_chunk or Smax,
                                                 kv_seq_spec=kv_spec5)
            h = h + a_out
            m_in = rms_norm(h, shared["mlp_norm"], cfg.norm_eps)
            h = h + mlp_forward(shared["mlp"], m_in, cfg.mlp_act, cfg.mlp_gated)

            def mamba_body(hh, xs2):
                lp, ci, si = xs2
                m_in2 = rms_norm(hh[:, 0, :], lp["norm"], cfg.norm_eps)
                y, co, so = mamba_mod.mamba2_step(lp["mamba"], m_in2, cfg,
                                                  conv_in=ci, state_in=si)
                return (hh[:, 0, :] + y)[:, None, :], (co, so)

            h, (co, so) = jax.lax.scan(mamba_body, h, (glp, conv, ssd))
            return h, (ck, cv, co, so)

        x, new = jax.lax.scan(group_body, x,
                              (params["layers"], params["lora"],
                               cache["shared_k"], cache["shared_v"],
                               cache["conv"], cache["ssd"]))
        cache = {"shared_k": new[0], "shared_v": new[1],
                 "conv": new[2], "ssd": new[3]}

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x[:, 0, :], cfg)
    return logits, cache


def serve_step_paged(params: Params, k_slab: jax.Array, v_slab: jax.Array,
                     block_table: jax.Array, lengths: jax.Array,
                     inputs: Dict[str, jax.Array], cfg: ArchConfig, *,
                     kernel_mode: Optional[str] = None,
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step over **paged** (block-table) KV — the serving
    path's PagedAttention form of ``serve_step``.

    k_slab/v_slab: the ``KVPageSlab`` arrays [L, NP, ps, KVH, Dh] (all
    layers stacked); block_table: [B, max_blocks] int32 slab page slots
    (a ``PagedCacheLease.device_tables()`` view); lengths: [B] int32
    tokens already written per sequence — the new token is scattered at
    position ``lengths`` through the block table (the in-jit half of
    ``KVCacheManager.append_paged``; the caller advances the lease's
    host-side lengths afterwards) and attended in place with
    ``kernels.ops.flash_decode_paged``.  inputs: token [B].

    Returns (logits [B, V], k_slab, v_slab).  Plain global-causal GQA
    attention archs only (the same restriction as
    ``KVCacheManager.init_paged``); sliding-window / split-cache / MLA /
    SSM families stay on the dense ``serve_step``.
    """
    from repro.kernels import ops as kernel_ops

    if (family_kind(cfg) != "attn" or cfg.attn_kind != "gqa"
            or cfg.local_global_pattern or cfg.sliding_window):
        raise ValueError(
            "serve_step_paged supports plain global-causal GQA archs only "
            f"(family {family_kind(cfg)!r}, attn_kind {cfg.attn_kind!r})")
    mode = kernel_ops.DEFAULT_MODE if kernel_mode is None else kernel_mode

    tok = inputs["token"]
    x = embed_tokens(params, tok[:, None], cfg)
    B = x.shape[0]
    L = cfg.num_layers
    H, KVH, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ps = k_slab.shape[2]
    positions = lengths[:, None]                       # new token's position
    slot = jnp.take_along_axis(block_table,
                               (lengths // ps)[:, None], axis=1)[:, 0]
    off = lengths % ps

    # same carry/in-place-update discipline as the dense serve_step: the
    # slab rides the scan carry and each layer's page view is updated
    # with dynamic_update_index_in_dim so XLA single-buffers it
    def body(carry, xs):
        h, ks, vs = carry
        lp, li = xs
        ap = lp["attn"]
        a_in = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", a_in, ap["wq"])
        k = jnp.einsum("bsd,dhk->bshk", a_in, ap["wk"])
        v = jnp.einsum("bsd,dhk->bshk", a_in, ap["wv"])
        q = apply_rope(q, positions, fraction=cfg.rope_fraction,
                       theta=cfg.rope_theta)
        k = apply_rope(k, positions, fraction=cfg.rope_fraction,
                       theta=cfg.rope_theta)
        kl = jax.lax.dynamic_index_in_dim(ks, li, keepdims=False)
        vl = jax.lax.dynamic_index_in_dim(vs, li, keepdims=False)
        kl = kl.at[slot, off].set(k[:, 0].astype(kl.dtype))
        vl = vl.at[slot, off].set(v[:, 0].astype(vl.dtype))
        ks = jax.lax.dynamic_update_index_in_dim(ks, kl, li, 0)
        vs = jax.lax.dynamic_update_index_in_dim(vs, vl, li, 0)
        out = kernel_ops.flash_decode_paged(
            q[:, 0].reshape(B, KVH, H // KVH, Dh), kl, vl,
            block_table, lengths + 1, mode=mode)
        out = out.reshape(B, 1, H, Dh).astype(h.dtype)
        h = h + jnp.einsum("bshk,hkd->bsd", out, ap["wo"])
        m_in = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
        if cfg.moe is not None:
            m_out, _ = moe_mod.moe_forward(lp["mlp"], m_in, cfg)
        else:
            m_out = mlp_forward(lp["mlp"], m_in, cfg.mlp_act, cfg.mlp_gated)
        return (h + m_out, ks, vs), None

    (x, k_slab, v_slab), _ = jax.lax.scan(
        body, (x, k_slab, v_slab),
        (params["layers"], jnp.arange(L, dtype=jnp.int32)))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x[:, 0, :], cfg)
    return logits, k_slab, v_slab


def serve_step_paged_spliced(params: Params, k_slab: jax.Array,
                             v_slab: jax.Array, block_table: jax.Array,
                             lengths: jax.Array, page_delta: jax.Array,
                             page_valid: jax.Array,
                             inputs: Dict[str, jax.Array], cfg: ArchConfig, *,
                             kernel_mode: Optional[str] = None,
                             ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """``serve_step_paged`` over a block table that mixes fresh pages
    with **spliced** chunk-KV pages (reordered RoPE per TurboRAG).

    Spliced pages hold K/V prefillled offline at chunk-local positions
    0..C-1 and attach by block-table edit; at attention time each page's
    stored K is rotated by its constant layout offset ``page_delta[b,
    blk]`` (chunks splice at page boundaries, so the offset is uniform
    across a page) and the dead tail of a chunk's partial last page is
    masked via ``page_valid[b, blk]`` live-token counts.  Fresh pages
    carry ``delta = 0`` and ``valid = ps`` — with an all-fresh table this
    is numerically ``serve_step_paged``.  The new token is roped and
    scattered at layout position ``lengths`` exactly as in the unspliced
    form.  Same plain global-causal GQA restriction.
    """
    from repro.kernels import ops as kernel_ops

    if (family_kind(cfg) != "attn" or cfg.attn_kind != "gqa"
            or cfg.local_global_pattern or cfg.sliding_window):
        raise ValueError(
            "serve_step_paged_spliced supports plain global-causal GQA archs "
            f"only (family {family_kind(cfg)!r}, attn_kind {cfg.attn_kind!r})")
    mode = kernel_ops.DEFAULT_MODE if kernel_mode is None else kernel_mode

    tok = inputs["token"]
    x = embed_tokens(params, tok[:, None], cfg)
    B = x.shape[0]
    L = cfg.num_layers
    H, KVH, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ps = k_slab.shape[2]
    positions = lengths[:, None]                       # new token's position
    slot = jnp.take_along_axis(block_table,
                               (lengths // ps)[:, None], axis=1)[:, 0]
    off = lengths % ps

    def body(carry, xs):
        h, ks, vs = carry
        lp, li = xs
        ap = lp["attn"]
        a_in = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", a_in, ap["wq"])
        k = jnp.einsum("bsd,dhk->bshk", a_in, ap["wk"])
        v = jnp.einsum("bsd,dhk->bshk", a_in, ap["wv"])
        q = apply_rope(q, positions, fraction=cfg.rope_fraction,
                       theta=cfg.rope_theta)
        k = apply_rope(k, positions, fraction=cfg.rope_fraction,
                       theta=cfg.rope_theta)
        kl = jax.lax.dynamic_index_in_dim(ks, li, keepdims=False)
        vl = jax.lax.dynamic_index_in_dim(vs, li, keepdims=False)
        kl = kl.at[slot, off].set(k[:, 0].astype(kl.dtype))
        vl = vl.at[slot, off].set(v[:, 0].astype(vl.dtype))
        ks = jax.lax.dynamic_update_index_in_dim(ks, kl, li, 0)
        vs = jax.lax.dynamic_update_index_in_dim(vs, vl, li, 0)
        out = kernel_ops.flash_decode_spliced(
            q[:, 0].reshape(B, KVH, H // KVH, Dh), kl, vl,
            block_table, lengths + 1, page_delta, page_valid,
            rope_fraction=cfg.rope_fraction, rope_theta=cfg.rope_theta,
            mode=mode)
        out = out.reshape(B, 1, H, Dh).astype(h.dtype)
        h = h + jnp.einsum("bshk,hkd->bsd", out, ap["wo"])
        m_in = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
        if cfg.moe is not None:
            m_out, _ = moe_mod.moe_forward(lp["mlp"], m_in, cfg)
        else:
            m_out = mlp_forward(lp["mlp"], m_in, cfg.mlp_act, cfg.mlp_gated)
        return (h + m_out, ks, vs), None

    (x, k_slab, v_slab), _ = jax.lax.scan(
        body, (x, k_slab, v_slab),
        (params["layers"], jnp.arange(L, dtype=jnp.int32)))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, x[:, 0, :], cfg)
    return logits, k_slab, v_slab
