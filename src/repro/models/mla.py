"""Multi-head latent attention (DeepSeek-V2 / MiniCPM3).

Train/prefill use the expanded formulation; decode uses the *absorbed*
formulation, caching only the compressed latent ``c_kv`` (kv_lora_rank)
and the shared rotary key ``k_pe`` (qk_rope_head_dim) per token — the KV
cache is ~an order of magnitude smaller than GQA at the same width.

Absorbed decode math (per head h):
  score(t) = (q_nope_h · W_uk_h c_t) + (q_pe_h · k_pe_t)
           = (W_uk_hᵀ q_nope_h) · c_t + q_pe_h · k_pe_t
  out_h    = Σ_t p_t (W_uv_hᵀ c_t) = W_uv_hᵀ (Σ_t p_t c_t)
so both the key expansion and value expansion are absorbed into
per-head projections of the query / the attention-weighted latent.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Maker, apply_rope, chunked_attention


def mla_params(mk: Maker, cfg: ArchConfig, prefix: str = "mla") -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": mk(f"{prefix}.w_dq", (d, m.q_lora_rank), ("embed", None)),
        "q_norm": mk(f"{prefix}.q_norm", (m.q_lora_rank,), (None,)),
        "w_uq": mk(f"{prefix}.w_uq", (m.q_lora_rank, H, qk), (None, "heads", None)),
        # down-projection emits [c_kv | k_pe]
        "w_dkv": mk(f"{prefix}.w_dkv", (d, m.kv_lora_rank + m.qk_rope_head_dim),
                    ("embed", None)),
        "kv_norm": mk(f"{prefix}.kv_norm", (m.kv_lora_rank,), (None,)),
        "w_uk": mk(f"{prefix}.w_uk", (m.kv_lora_rank, H, m.qk_nope_head_dim),
                   (None, "heads", None)),
        "w_uv": mk(f"{prefix}.w_uv", (m.kv_lora_rank, H, m.v_head_dim),
                   (None, "heads", None)),
        "wo": mk(f"{prefix}.wo", (H, m.v_head_dim, d), ("heads", None, "embed")),
    }


def _latents(p: dict, x: jax.Array, cfg: ArchConfig, positions: jax.Array):
    """Compute (q_nope, q_pe, c_kv, k_pe) for a sequence."""
    from repro.models.layers import rms_norm
    m = cfg.mla
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    q_nope = q[..., :m.qk_nope_head_dim]
    q_pe = apply_rope(q[..., m.qk_nope_head_dim:], positions, theta=cfg.rope_theta)
    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_kv = rms_norm(dkv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope(dkv[..., None, m.kv_lora_rank:], positions, theta=cfg.rope_theta)
    return q_nope, q_pe, c_kv, k_pe[..., 0, :]


def mla_forward(p: dict, x: jax.Array, cfg: ArchConfig, *, positions: jax.Array,
                attn_chunk: int = 1024) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Expanded MLA for train/prefill. Returns (out, (c_kv, k_pe)) for caching."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_pe, c_kv, k_pe = _latents(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"])
    # assemble full q/k with shared rotary part broadcast over heads
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, H, m.qk_rope_head_dim))],
        axis=-1)
    # pad v to qk dim so we can reuse the shared chunked-attention core,
    # then slice back (v_head_dim <= qk dim always holds for our configs)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk - m.v_head_dim)))
    out = chunked_attention(
        q_full[:, :, :, None, :].reshape(B, S, H, 1, qk),
        k_full, v_pad,
        q_positions=positions, kv_positions=positions,
        window=None, softcap_val=cfg.attn_logit_softcap,
        chunk=min(attn_chunk, S))
    out = out.reshape(B, S, H, qk)[..., :m.v_head_dim]
    # MLA scores use 1/sqrt(qk_dim); chunked_attention scaled by 1/sqrt(qk) already
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (c_kv, k_pe)


def mla_decode(p: dict, x: jax.Array, cfg: ArchConfig, *,
               cache_ckv: jax.Array, cache_kpe: jax.Array, pos: jax.Array,
               kv_seq_spec=None,
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Absorbed one-token decode.

    x: [B,1,d]; cache_ckv: [B,Smax,R]; cache_kpe: [B,Smax,rope_dim]; pos: [B].
    """
    m = cfg.mla
    B = x.shape[0]
    Smax = cache_ckv.shape[1]
    positions = pos[:, None]
    q_nope, q_pe, c_kv_new, k_pe_new = _latents(p, x, cfg, positions)

    def put(cache, new):
        def one(c, n, i):
            return jax.lax.dynamic_update_slice(c, n.astype(c.dtype), (i, 0))
        return jax.vmap(one)(cache, new, pos)
    cache_ckv = put(cache_ckv, c_kv_new)
    cache_kpe = put(cache_kpe, k_pe_new)

    # absorb: q_abs[b,h,r] = Σ_k q_nope[b,h,k] W_uk[r,h,k]
    q_abs = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])[:, 0]      # [B,H,R]
    q_pe0 = q_pe[:, 0]                                                  # [B,H,rope]
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = (jnp.einsum("bhr,btr->bht", q_abs.astype(jnp.float32),
                    cache_ckv.astype(jnp.float32))
         + jnp.einsum("bhk,btk->bht", q_pe0.astype(jnp.float32),
                      cache_kpe.astype(jnp.float32))) * scale
    if kv_seq_spec is not None:
        s = jax.lax.with_sharding_constraint(s, kv_seq_spec)
    t_idx = jnp.arange(Smax, dtype=jnp.int32)[None, None, :]
    mask = t_idx <= pos[:, None, None]
    s = jnp.where(mask, s, -jnp.inf)
    pattn = jax.nn.softmax(s, axis=-1)
    latent = jnp.einsum("bht,btr->bhr", pattn, cache_ckv.astype(jnp.float32))
    out = jnp.einsum("bhr,rhk->bhk", latent, p["w_uv"].astype(jnp.float32))
    out = out.astype(x.dtype)[:, None]                                  # [B,1,H,v]
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache_ckv, cache_kpe
