"""Grouped-query attention (covers MHA / GQA / MQA) with KV cache.

Three entry points share one scoring core (``layers.chunked_attention``):
  * ``attn_forward``   — full-sequence (train / prefill), returns new KV
  * ``attn_decode``    — one token against a pre-allocated KV cache
Window semantics: ``window=None/0`` is global causal; ``window=W`` is a
W-token sliding window (gemma2 local layers). ``window`` may be a traced
scalar so local/global layers share one scanned body.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Maker, apply_rope, chunked_attention, softcap


def attn_params(mk: Maker, cfg: ArchConfig, prefix: str = "attn") -> dict:
    d, H, KVH, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "wq": mk(f"{prefix}.wq", (d, H, Dh), ("embed", "heads", None)),
        "wk": mk(f"{prefix}.wk", (d, KVH, Dh), ("embed", "kv_heads", None)),
        "wv": mk(f"{prefix}.wv", (d, KVH, Dh), ("embed", "kv_heads", None)),
        "wo": mk(f"{prefix}.wo", (H, Dh, d), ("heads", None, "embed")),
    }


def _project_qkv(p: dict, x: jax.Array, cfg: ArchConfig, positions: jax.Array):
    H, KVH = cfg.num_heads, cfg.num_kv_heads
    G = H // KVH
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = apply_rope(k, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    B, S = x.shape[:2]
    q = q.reshape(B, S, KVH, G, cfg.resolved_head_dim)
    return q, k, v


def attn_forward(p: dict, x: jax.Array, cfg: ArchConfig, *,
                 positions: jax.Array,
                 window: Optional[jax.Array | int] = None,
                 attn_chunk: int = 1024,
                 ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence attention. Returns (out [B,S,d], (k, v) for caching)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions)
    out = chunked_attention(
        q, k, v,
        q_positions=positions, kv_positions=positions,
        window=window, softcap_val=cfg.attn_logit_softcap,
        chunk=min(attn_chunk, S))
    H = cfg.num_heads
    out = out.reshape(B, S, H, cfg.resolved_head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), (k, v)


def attn_decode(p: dict, x: jax.Array, cfg: ArchConfig, *,
                cache_k: jax.Array, cache_v: jax.Array,
                pos: jax.Array,
                window: Optional[jax.Array | int] = None,
                attn_chunk: int = 2048,
                kv_seq_spec=None,
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: [B,1,d]; cache_k/v: [B,Smax,KVH,Dh]; pos: [B] int32.

    Returns (out [B,1,d], new_cache_k, new_cache_v).
    """
    B, _, _ = x.shape
    Smax = cache_k.shape[1]
    positions = pos[:, None]                          # [B,1]
    H, KVH, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    G = H // KVH
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = apply_rope(k, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    q = q.reshape(B, 1, KVH, G, Dh)

    # scatter new k/v at pos (per-batch dynamic index)
    def put(cache, new):
        def one(c, n, i):
            return jax.lax.dynamic_update_slice(c, n.astype(c.dtype), (i, 0, 0))
        return jax.vmap(one)(cache, new, pos)
    cache_k = put(cache_k, k)
    cache_v = put(cache_v, v)

    out = _decode_attention(
        q, cache_k, cache_v, pos=pos, window=window,
        softcap_val=cfg.attn_logit_softcap,
        chunk=Smax if kv_seq_spec is not None else min(attn_chunk, Smax),
        kv_seq_spec=kv_seq_spec)
    out = out.reshape(B, 1, H, Dh)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache_k, cache_v


def quantize_heads(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-(token, head) symmetric int8 quantization over the head dim.

    x: [..., Dh] -> (q int8 [..., Dh], scale bf16 [...]). Halves KV-cache
    HBM (the dominant decode roofline term); dequant fuses into the
    attention matmul on TPU.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_heads(q: jax.Array, scale: jax.Array,
                     dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)


def attn_decode_quant(p: dict, x: jax.Array, cfg: ArchConfig, *,
                      cache_k: jax.Array, cache_v: jax.Array,
                      k_scale: jax.Array, v_scale: jax.Array,
                      pos: jax.Array,
                      window=None, attn_chunk: int = 0, kv_seq_spec=None):
    """attn_decode over an int8-quantized KV cache.

    cache_k/v: int8 [B,Smax,KVH,Dh]; k/v_scale: bf16 [B,Smax,KVH].
    Returns (out, ck, cv, ks, vs).
    """
    B = x.shape[0]
    Smax = cache_k.shape[1]
    KVH, H, Dh = cfg.num_kv_heads, cfg.num_heads, cfg.resolved_head_dim
    G = H // KVH
    positions = pos[:, None]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = apply_rope(k, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    q = q.reshape(B, 1, KVH, G, Dh)
    kq, ks_new = quantize_heads(k)
    vq, vs_new = quantize_heads(v)

    def put(cache, new, nd):
        def one(c, n, i):
            idx = (i,) + (0,) * (c.ndim - 1)
            return jax.lax.dynamic_update_slice(c, n.astype(c.dtype), idx)
        return jax.vmap(one)(cache, new, pos)
    cache_k = put(cache_k, kq, 3)
    cache_v = put(cache_v, vq, 3)
    k_scale = put(k_scale, ks_new, 2)
    v_scale = put(v_scale, vs_new, 2)

    kd = dequantize_heads(cache_k, k_scale)
    vd = dequantize_heads(cache_v, v_scale)
    out = _decode_attention(
        q, kd, vd, pos=pos, window=window,
        softcap_val=cfg.attn_logit_softcap,
        chunk=Smax if kv_seq_spec is not None else min(attn_chunk or Smax,
                                                       Smax),
        kv_seq_spec=kv_seq_spec)
    out = out.reshape(B, 1, H, Dh)
    return (jnp.einsum("bshk,hkd->bsd", out, p["wo"]),
            cache_k, cache_v, k_scale, v_scale)


def attn_decode_ring(p: dict, x: jax.Array, cfg: ArchConfig, *,
                     cache_k: jax.Array, cache_v: jax.Array,
                     pos: jax.Array, window: int,
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Sliding-window decode over a RING cache of ``window`` slots.

    cache_k/v: [B, W, KVH, Dh] — slot(p) = p % W holds the most recent
    token at that residue, which is exactly the last W positions: the
    sliding-window KV cache needs W slots, not seq_len (gemma2 local
    layers: 4096 instead of 524288 — the split-cache serving optimization,
    DESIGN.md §5 / EXPERIMENTS.md §Perf).
    """
    B, _, _ = x.shape
    W = cache_k.shape[1]
    KVH, H, Dh = cfg.num_kv_heads, cfg.num_heads, cfg.resolved_head_dim
    G = H // KVH
    positions = pos[:, None]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = apply_rope(q, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    k = apply_rope(k, positions, fraction=cfg.rope_fraction, theta=cfg.rope_theta)
    q = q.reshape(B, 1, KVH, G, Dh)

    slot = jnp.mod(pos, W)

    def put(cache, new):
        def one(c, n, i):
            return jax.lax.dynamic_update_slice(c, n.astype(c.dtype), (i, 0, 0))
        return jax.vmap(one)(cache, new, slot)
    cache_k = put(cache_k, k)
    cache_v = put(cache_v, v)

    # absolute position stored in slot s: pos - ((pos - s) mod W)
    slots = jnp.arange(W, dtype=jnp.int32)[None, :]
    ks_pos = pos[:, None] - jnp.mod(pos[:, None] - slots, W)      # [B, W]
    valid = ks_pos >= 0

    scale = 1.0 / math.sqrt(Dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(cache_k.dtype) * scale,
                   cache_k, preferred_element_type=jnp.float32)
    s = softcap(s, cfg.attn_logit_softcap)
    s = jnp.where(valid[:, None, None, None, :], s, -jnp.inf)
    pattn = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", pattn.astype(cache_v.dtype),
                     cache_v, preferred_element_type=jnp.float32)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).astype(x.dtype)
    out = out.reshape(B, 1, H, Dh)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache_k, cache_v


def ring_from_full(k_full: jax.Array, window: int) -> jax.Array:
    """Convert full-sequence K/V [.., B, S, KVH, Dh] (seq axis -3... axis=-3)
    to the ring layout [.., B, W, KVH, Dh] (prefill -> decode handoff)."""
    S = k_full.shape[-3]
    W = min(window, S)
    last = jax.lax.slice_in_dim(k_full, S - W, S, axis=k_full.ndim - 3)
    if W < window:
        pad = [(0, 0)] * k_full.ndim
        pad[k_full.ndim - 3] = (0, window - W)
        last = jnp.pad(last, pad)
        return last
    # position p lands in slot p % window: roll by (S - W) % W
    return jnp.roll(last, shift=(S - W) % W, axis=k_full.ndim - 3)


def _decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      pos: jax.Array, window, softcap_val,
                      chunk: int, kv_seq_spec=None) -> jax.Array:
    """Single-token attention over a [B,Smax,KVH,Dh] cache, chunked over KV.

    Unlike ``chunked_attention`` this supports *per-batch* query positions
    (continuous batching: every sequence is at a different decode offset).

    kv_seq_spec: PartitionSpec of the scores' KV axis for sequence-parallel
    decode (long-context: KV cache sharded over ``model``). Constraining
    the scores keeps each chip on its local KV shard — softmax and the
    p·V contraction then reduce with small psums instead of GSPMD
    all-gathering the multi-GB KV slice. Requires chunk == Smax.
    """
    B, _, KVH, G, Dh = q.shape
    Smax = k.shape[1]
    scale = 1.0 / math.sqrt(Dh)
    q32 = q.astype(jnp.float32) * scale              # [B,1,KVH,G,Dh]

    nchunks = max(Smax // chunk, 1)
    if Smax % nchunks:
        nchunks, chunk = 1, Smax
    else:
        chunk = Smax // nchunks
    k_c = jnp.moveaxis(k.reshape(B, nchunks, chunk, KVH, Dh), 1, 0)
    v_c = jnp.moveaxis(v.reshape(B, nchunks, chunk, KVH, Dh), 1, 0)
    base = jnp.arange(nchunks, dtype=jnp.int32) * chunk

    def body(carry, xs):
        m_prev, l_prev, acc = carry
        kc, vc, b0 = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q32.astype(kc.dtype), kc,
                       preferred_element_type=jnp.float32)
        if kv_seq_spec is not None:
            s = jax.lax.with_sharding_constraint(s, kv_seq_spec)
        s = softcap(s, softcap_val)
        kp = (b0 + jnp.arange(chunk, dtype=jnp.int32))[None, None, None, None, :]
        qp = pos[:, None, None, None, None]
        mask = kp <= qp
        if window is not None:
            w = jnp.asarray(window, jnp.int32)
            mask &= jnp.where(w > 0, kp > qp - w, True)
        s = jnp.where(mask, s, -jnp.inf)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, KVH, G, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KVH, G, 1), jnp.float32)
    a0 = jnp.zeros((B, KVH, G, 1, Dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (k_c, v_c, base))
    out = acc / jnp.maximum(l[..., None], 1e-20)
    return jnp.transpose(out, (0, 3, 1, 2, 4)).astype(q.dtype)
