from repro.models.transformer import (cache_axes, family_kind, forward,
                                      init_cache, init_params, loss_fn,
                                      param_axes, prefill, serve_step,
                                      unembed)

__all__ = [
    "cache_axes", "family_kind", "forward", "init_cache", "init_params",
    "loss_fn", "param_axes", "prefill", "serve_step", "unembed",
]
