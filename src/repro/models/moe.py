"""Mixture-of-Experts layer: GShard-style capacity dispatch via einsums.

TPU-idiomatic formulation: token→expert routing becomes two einsums with a
[groups, tokens, experts, capacity] dispatch tensor, which GSPMD shards
cleanly with experts on the ``model`` mesh axis (expert parallelism) and
groups on the ``data`` axes. Arctic's *dense residual* MLP runs in
parallel and is summed into the expert output.

Capacity semantics: each group of ``T`` tokens gets per-expert capacity
``C = ceil(T * top_k * capacity_factor / E)``; overflow tokens lose that
expert (standard GShard token dropping) but keep their other top-k picks.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Maker, activation, mlp_forward, mlp_params


def moe_params(mk: Maker, cfg: ArchConfig, prefix: str = "moe") -> dict:
    mo = cfg.moe
    d, E, F = cfg.d_model, mo.num_experts, mo.d_ff_expert
    p = {
        "router": mk(f"{prefix}.router", (d, E), ("embed", None),
                     scale=1.0 / math.sqrt(d)),
        "w_up": mk(f"{prefix}.w_up", (E, d, F), ("experts", "embed", "mlp")),
        "w_gate": mk(f"{prefix}.w_gate", (E, d, F), ("experts", "embed", "mlp")),
        "w_down": mk(f"{prefix}.w_down", (E, F, d), ("experts", "mlp", "embed")),
    }
    if mo.dense_residual_d_ff:
        p["dense"] = mlp_params(mk, d, mo.dense_residual_d_ff, gated=True,
                                prefix=f"{prefix}.dense")
    return p


def moe_forward(p: dict, x: jax.Array, cfg: ArchConfig,
                ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (out [B, S, d], aux_loss scalar).

    Tokens are dispatched in *subgroups* of ``group_size`` tokens: per-group
    capacity is C = ceil(Tg·K·cf/E), so both the dispatch tensor
    [G, Tg, E, C] (≈ T_total·E·C_g elements) and the dispatch-einsum FLOPs
    (ratio Tg·cf/(3·ff) of the expert FLOPs) are bounded by the group
    size, independent of sequence length. This keeps high-top-k/small-ff
    configs (granite-moe: K=8 of E=40, ff=512) from blowing up, where
    sequence-sized GShard groups would need C≈T/3.
    """
    mo = cfg.moe
    B, S, d = x.shape
    E, K = mo.num_experts, mo.top_k
    T = B * S
    Tg = min(mo.group_size, T)
    while T % Tg:
        Tg -= 1
    G = T // Tg
    C = max(1, math.ceil(Tg * K * mo.capacity_factor / E))
    C = min(C, Tg)

    xg = x.reshape(G, Tg, d)
    logits = jnp.einsum("gtd,de->gte", xg, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # [G,T,E]

    # top-k selection, renormalized over the selected experts
    top_p, top_e = jax.lax.top_k(probs, K)                        # [G,T,K]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)
    sel = jax.nn.one_hot(top_e, E, dtype=jnp.float32)             # [G,T,K,E]
    gate = jnp.einsum("gtk,gtke->gte", top_p, sel)                # [G,T,E]
    sel_any = jnp.max(sel, axis=2)                                # [G,T,E] 0/1

    # position of each token within each expert's capacity buffer
    pos_in_e = jnp.cumsum(sel_any, axis=1) - sel_any              # [G,T,E]
    keep = sel_any * (pos_in_e < C)
    onehot_c = jax.nn.one_hot(pos_in_e.astype(jnp.int32), C,
                              dtype=jnp.float32)                  # [G,T,E,C]
    dispatch = (keep[..., None] * onehot_c).astype(x.dtype)
    combine = (gate[..., None] * onehot_c * keep[..., None]).astype(x.dtype)

    xin = jnp.einsum("gtec,gtd->gecd", dispatch, xg)
    up = jnp.einsum("gecd,edf->gecf", xin, p["w_up"])
    gt = jnp.einsum("gecd,edf->gecf", xin, p["w_gate"])
    h = activation(cfg.mlp_act)(gt) * up
    out_e = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    out = jnp.einsum("gtec,gecd->gtd", combine, out_e).reshape(B, S, d)

    # GShard load-balancing loss
    frac_tokens = jnp.mean(sel_any, axis=(0, 1))                  # [E]
    frac_probs = jnp.mean(probs, axis=(0, 1))                     # [E]
    aux = E * jnp.sum(frac_tokens * frac_probs) * mo.aux_loss_weight

    if mo.dense_residual_d_ff:
        out = out + mlp_forward(p["dense"], x, cfg.mlp_act, gated=True)
    return out, aux
