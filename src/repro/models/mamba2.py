"""Mamba2 (SSD) block, TPU-adapted chunked form (arXiv:2405.21060 lineage).

Per-head scalar decay makes the sequence mixing 1-semiseparable: within a
chunk it is an attention-like masked einsum with decay ratios <= 1; across
chunks state is carried by a scan. Decode is the exact O(1) recurrence.

Recurrence (head h, P = head channels, N = state dim, ngroups = 1):
  a_t   = exp(dt_t * A_h)                      (A_h < 0)
  S_t   = a_t S_{t-1} + (dt_t x_t) ⊗ B_t       S: [P, N]
  y_t   = S_t C_t + D_h x_t
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Maker, rms_norm


def mamba2_dims(cfg: ArchConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    return d_in, n_heads, s.head_dim, s.state_dim


def mamba2_params(mk: Maker, cfg: ArchConfig, prefix: str = "mamba") -> dict:
    d = cfg.d_model
    d_in, H, P, N = mamba2_dims(cfg)
    cw = cfg.ssm.conv_width
    return {
        # fused in-projection: [z | x | B | C | dt]
        "w_in": mk(f"{prefix}.w_in", (d, 2 * d_in + 2 * N + H),
                   ("embed", "heads_flat")),
        "conv_w": mk(f"{prefix}.conv_w", (cw, d_in + 2 * N),
                     (None, "heads_flat"), scale=0.5),
        "conv_b": mk(f"{prefix}.conv_bias", (d_in + 2 * N,), ("heads_flat",)),
        "a_log": mk(f"{prefix}.a_log", (H,), ("heads_flat",), scale=0.5),
        "dt_bias": mk(f"{prefix}.dt_bias", (H,), ("heads_flat",), scale=0.5),
        "d_skip": mk(f"{prefix}.d_skip", (H,), ("heads_flat",), scale=1.0),
        "out_norm": mk(f"{prefix}.out_norm", (d_in,), ("heads_flat",)),
        "w_out": mk(f"{prefix}.w_out", (d_in, d), ("heads_flat", "embed")),
    }


def _split_in(cfg: ArchConfig, proj: jax.Array):
    d_in, H, P, N = mamba2_dims(cfg)
    z = proj[..., :d_in]
    xbc = proj[..., d_in:2 * d_in + 2 * N]
    dt = proj[..., 2 * d_in + 2 * N:]
    return z, xbc, dt


def _conv(p: dict, xbc: jax.Array, conv_in: jax.Array):
    """Causal depthwise conv over seq. xbc: [B,S,ch]; conv_in: [B,cw-1,ch]."""
    cw = p["conv_w"].shape[0]
    full = jnp.concatenate([conv_in.astype(xbc.dtype), xbc], axis=1)
    out = jnp.zeros_like(xbc)
    S = xbc.shape[1]
    for i in range(cw):
        out = out + full[:, i:i + S, :] * p["conv_w"][i]
    conv_out = full[:, -(cw - 1):, :] if cw > 1 else conv_in
    return jax.nn.silu(out + p["conv_b"]), conv_out


def mamba2_forward(p: dict, x: jax.Array, cfg: ArchConfig, *,
                   conv_in: jax.Array, state_in: jax.Array,
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence SSD via chunked scan.

    x: [B,S,d]; conv_in: [B,cw-1,d_in+2N]; state_in: [B,H,P,N].
    Returns (y [B,S,d], conv_out, state_out).
    """
    B, S, d = x.shape
    d_in, H, P, N = mamba2_dims(cfg)
    C = min(cfg.ssm.chunk_size, S)
    if S % C:
        C = S
    NC = S // C

    proj = jnp.einsum("bsd,de->bse", x, p["w_in"])
    z, xbc, dt = _split_in(cfg, proj)
    xbc, conv_out = _conv(p, xbc, conv_in)
    xc = xbc[..., :d_in].reshape(B, S, H, P)
    Bm = xbc[..., d_in:d_in + N]                                  # [B,S,N]
    Cm = xbc[..., d_in + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                  # [H] < 0
    la = dt * a[None, None, :]                                    # log-decay [B,S,H]

    xc32, B32, C32 = (t.astype(jnp.float32) for t in (xc, Bm, Cm))
    ch4 = lambda t: jnp.moveaxis(t.reshape(B, NC, C, *t.shape[2:]), 1, 0)
    x_c, B_c, C_c, dt_c, la_c = ch4(xc32), ch4(B32), ch4(C32), ch4(dt), ch4(la)

    def chunk_body(S_in, xs):
        xcc, Bc, Cc, dtc, lac = xs      # [B,C,H,P], [B,C,N], [B,C,N], [B,C,H], [B,C,H]
        cum = jnp.cumsum(lac, axis=1)                             # Σ_{s<=t}
        # intra: y_t = Σ_{j<=t} exp(cum_t - cum_j) dt_j (C_t·B_j) x_j
        ratio = jnp.clip(cum[:, :, None] - cum[:, None, :, :], -60.0, 0.0)
        L = jnp.exp(ratio)                                        # [B,C,C,H]
        G = jnp.einsum("btn,bjn->btj", Cc, Bc)                    # [B,C,C]
        M = G[..., None] * L * dtc[:, None, :, :]                 # [B,t,j,H]
        tri = jnp.tril(jnp.ones((C, C), bool))[None, :, :, None]
        M = jnp.where(tri, M, 0.0)
        y = jnp.einsum("btjh,bjhp->bthp", M, xcc)
        # inter: y_t += exp(cum_t) S_in C_t
        y += jnp.einsum("bth,bhpn,btn->bthp", jnp.exp(cum), S_in, Cc)
        # state update
        dec_end = jnp.exp(cum[:, -1])                             # [B,H]
        w = jnp.exp(jnp.clip(cum[:, -1][:, None] - cum, -60.0, 0.0)) * dtc
        S_out = S_in * dec_end[..., None, None] + jnp.einsum(
            "bth,bthp,btn->bhpn", w, xcc, Bc)
        return S_out, y

    # checkpoint: intra-chunk [B,C,C,H] masks recompute in backward
    state_out, y_c = jax.lax.scan(jax.checkpoint(chunk_body),
                                  state_in.astype(jnp.float32),
                                  (x_c, B_c, C_c, dt_c, la_c))
    y = jnp.moveaxis(y_c, 0, 1).reshape(B, S, H, P)
    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xc32
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return (jnp.einsum("bse,ed->bsd", y, p["w_out"]),
            conv_out, state_out.astype(state_in.dtype))


def mamba2_step(p: dict, x: jax.Array, cfg: ArchConfig, *,
                conv_in: jax.Array, state_in: jax.Array,
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Exact O(1) decode step. x: [B,d]."""
    B, d = x.shape
    d_in, H, P, N = mamba2_dims(cfg)
    proj = jnp.einsum("bd,de->be", x, p["w_in"])
    z, xbc, dt = _split_in(cfg, proj)
    # conv over (conv_in ++ xbc)
    cw = p["conv_w"].shape[0]
    full = jnp.concatenate([conv_in.astype(xbc.dtype), xbc[:, None, :]], axis=1)
    conv_val = jnp.einsum("bwc,wc->bc", full, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_val)
    conv_out = full[:, 1:, :]
    xc = xbc[..., :d_in].reshape(B, H, P)
    Bm = xbc[..., d_in:d_in + N]
    Cm = xbc[..., d_in + N:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dec = jnp.exp(dt * a[None, :])                                # [B,H]
    S = state_in.astype(jnp.float32)
    S = S * dec[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xc.astype(jnp.float32), Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", S, Cm.astype(jnp.float32))
    y = y + p["d_skip"].astype(jnp.float32)[None, :, None] * xc.astype(jnp.float32)
    y = y.reshape(B, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return jnp.einsum("be,ed->bd", y, p["w_out"]), conv_out, S.astype(state_in.dtype)
