"""Common model building blocks (pure JAX, no framework).

Parameters are plain pytrees (nested dicts of arrays). Every parameter is
created through a ``Maker`` so that the *same* builder code path can
produce (a) materialized random-init arrays, (b) ShapeDtypeStructs for
AOT lowering, or (c) logical-axis annotations for the sharding layer —
guaranteeing the three trees are structurally identical.
"""

from __future__ import annotations

import math
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Parameter maker protocol
# ---------------------------------------------------------------------------


class Maker:
    """Creates parameters; subclasses decide what a 'parameter' is."""

    def __call__(self, name: str, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
                 scale: Optional[float] = None):
        raise NotImplementedError


class InitMaker(Maker):
    """Materializes truncated-normal random parameters (fan-in scaled)."""

    def __init__(self, key: jax.Array, dtype=jnp.bfloat16):
        self._key = key
        self._dtype = dtype
        self._i = 0

    def __call__(self, name, shape, axes, scale=None):
        assert len(shape) == len(axes), (name, shape, axes)
        self._i += 1
        k = jax.random.fold_in(self._key, self._i)
        if scale is None:
            fan_in = shape[0] if len(shape) > 1 else shape[-1]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
        if name.endswith("norm") or name.endswith("scale"):
            return jnp.ones(shape, self._dtype)
        if name.endswith("bias") or name.endswith("zeros"):
            return jnp.zeros(shape, self._dtype)
        x = jax.random.truncated_normal(k, -2.0, 2.0, shape, jnp.float32) * scale
        return x.astype(self._dtype)


class AxesMaker(Maker):
    """Returns the logical-axis annotation instead of an array."""

    def __call__(self, name, shape, axes, scale=None):
        assert len(shape) == len(axes), (name, shape, axes)
        return tuple(axes)


class ShapeMaker(Maker):
    """Returns ShapeDtypeStructs (used for AOT lowering without allocation)."""

    def __init__(self, dtype=jnp.bfloat16):
        self._dtype = dtype

    def __call__(self, name, shape, axes, scale=None):
        return jax.ShapeDtypeStruct(shape, self._dtype)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


_ACTS: dict = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def activation(name: str) -> Callable[[jax.Array], jax.Array]:
    return _ACTS[name]


# ---------------------------------------------------------------------------
# Rotary position embedding (supports partial rotary + large theta)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, fraction: float, theta: float) -> jax.Array:
    rot_dim = int(head_dim * fraction) // 2 * 2
    exponents = jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / max(rot_dim, 1)
    return 1.0 / (theta ** exponents)  # [rot_dim/2]


def apply_rope(x: jax.Array, positions: jax.Array, *, fraction: float = 1.0,
               theta: float = 10_000.0) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    head_dim = x.shape[-1]
    rot_dim = int(head_dim * fraction) // 2 * 2
    if rot_dim == 0:
        return x
    freqs = rope_frequencies(head_dim, fraction, theta)          # [rot/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs    # [..., seq, rot/2]
    cos = jnp.cos(angles)[..., None, :]                          # [..., seq, 1, rot/2]
    sin = jnp.sin(angles)[..., None, :]
    x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_params(mk: Maker, d_model: int, d_ff: int, gated: bool, prefix: str = "mlp"):
    p = {"w_down": mk(f"{prefix}.w_down", (d_ff, d_model), ("mlp", "embed"))}
    p["w_up"] = mk(f"{prefix}.w_up", (d_model, d_ff), ("embed", "mlp"))
    if gated:
        p["w_gate"] = mk(f"{prefix}.w_gate", (d_model, d_ff), ("embed", "mlp"))
    return p


def mlp_forward(p: dict, x: jax.Array, act: str, gated: bool) -> jax.Array:
    up = jnp.einsum("...d,df->...f", x, p["w_up"])
    if gated:
        # conventional SwiGLU/GeGLU ordering: act(gate) * up
        h = activation(act)(jnp.einsum("...d,df->...f", x, p["w_gate"])) * up
    else:
        h = activation(act)(up)
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention in pure JAX
# ---------------------------------------------------------------------------


def _best_chunk(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (>=1)."""
    c = min(target, n)
    while n % c:
        c -= 1
    return c


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      q_positions: jax.Array, kv_positions: jax.Array,
                      window: Optional[int], softcap_val: Optional[float],
                      kv_valid_len: Optional[jax.Array] = None,
                      chunk: int = 1024, q_chunk: int = 256) -> jax.Array:
    """Flash-style causal attention, tiled over BOTH query and KV dims.

    q: [B, Sq, KVH, G, Dh] (grouped query heads); k,v: [B, Skv, KVH, Dh].
    window: sliding-window size (None/0 => global); may be a traced
    per-layer scalar (gemma2 local/global alternation in one scanned body).

    Memory discipline (the whole point of this function):
      * live scores are [B, KVH, G, q_chunk, kv_chunk] — never Sq x Skv;
      * K/V stay in their storage dtype; the MXU accumulates fp32 via
        preferred_element_type (no fp32 materialization of the cache);
      * each q-chunk body is jax.checkpoint'ed so the backward pass
        recomputes scores instead of saving them per scan step.
    """
    B, Sq, KVH, G, Dh = q.shape
    Skv = k.shape[1]
    scale = 1.0 / math.sqrt(Dh)

    kv_c = _best_chunk(Skv, chunk)
    n_kv = Skv // kv_c
    q_c = _best_chunk(Sq, q_chunk)
    n_q = Sq // q_c

    k_c = jnp.moveaxis(k.reshape(B, n_kv, kv_c, KVH, Dh), 1, 0)
    v_c = jnp.moveaxis(v.reshape(B, n_kv, kv_c, KVH, Dh), 1, 0)
    kp_c = kv_positions.reshape(n_kv, kv_c)

    def q_body(_, xs):
        qc, qpc = xs                              # [B,q_c,KVH,G,Dh], [q_c]
        q32 = qc.astype(jnp.float32) * scale

        def kv_body(carry, xs2):
            m_prev, l_prev, acc = carry
            kc, vc, kpc = xs2
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q32, kc,
                           preferred_element_type=jnp.float32)
            s = softcap(s, softcap_val)
            qp = qpc[None, None, None, :, None]
            kp = kpc[None, None, None, None, :]
            mask = kp <= qp                       # causal
            if window is not None:
                w = jnp.asarray(window, jnp.int32)
                mask &= jnp.where(w > 0, kp > qp - w, True)
            if kv_valid_len is not None:
                mask &= kp < kv_valid_len[:, None, None, None, None]
            s = jnp.where(mask, s, -jnp.inf)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_prev, m_cur)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
            corr = jnp.where(jnp.isfinite(m_prev),
                             jnp.exp(m_prev - m_safe), 0.0)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KVH, G, q_c), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, q_c), jnp.float32)
        a0 = jnp.zeros((B, KVH, G, q_c, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0), (k_c, v_c, kp_c))
        out = acc / jnp.maximum(l[..., None], 1e-20)
        return None, jnp.transpose(out, (0, 3, 1, 2, 4)).astype(q.dtype)

    q_b = jnp.moveaxis(q.reshape(B, n_q, q_c, KVH, G, Dh), 1, 0)
    qp_b = q_positions.reshape(n_q, q_c)
    _, outs = jax.lax.scan(jax.checkpoint(q_body), None, (q_b, qp_b))
    # outs: [n_q, B, q_c, KVH, G, Dh] -> [B, Sq, KVH, G, Dh]
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KVH, G, Dh)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-mean causal LM loss in fp32. logits [..., V]; labels [...]."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
