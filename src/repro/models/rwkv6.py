"""RWKV6 "Finch" time-mix + channel-mix (arXiv:2404.05892), TPU-adapted.

The CUDA WKV6 kernel is replaced by a *chunked linear-attention* form:
within a chunk of length C the recurrence is evaluated as a masked
attention-like einsum with per-channel decay ratios (always <= 1, hence
numerically safe); state is carried across chunks with a scan. Decode is
the exact O(1) recurrence. ``tests/test_models_ssm.py`` asserts the
chunked form matches the token-by-token recurrence.

Recurrence (per head, K = V = head_dim channels):
  S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
  y_t = r_t · (S_{t-1} + diag(u) (k_t ⊗ v_t))
with data-dependent decay  w_t = exp(-exp(w0 + tanh(x_w A_w) B_w)).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Maker, activation


LORA_RANK = 32


def rwkv6_params(mk: Maker, cfg: ArchConfig, prefix: str = "rwkv") -> dict:
    d = cfg.d_model
    K = cfg.ssm.head_dim
    H = d // K
    r = LORA_RANK
    return {
        # data-dependent token-shift interpolation (5 targets: w,k,v,r,g)
        "mu_x": mk(f"{prefix}.mu_x", (d,), ("embed",), scale=0.1),
        "mu": mk(f"{prefix}.mu", (5, d), (None, "embed"), scale=0.1),
        "lora_a": mk(f"{prefix}.lora_a", (5, d, r), (None, "embed", None)),
        "lora_b": mk(f"{prefix}.lora_b", (5, r, d), (None, None, "embed"),
                     scale=0.01),
        # projections
        "w_r": mk(f"{prefix}.w_r", (d, d), ("embed", "heads_flat")),
        "w_k": mk(f"{prefix}.w_k", (d, d), ("embed", "heads_flat")),
        "w_v": mk(f"{prefix}.w_v", (d, d), ("embed", "heads_flat")),
        "w_g": mk(f"{prefix}.w_g", (d, d), ("embed", "heads_flat")),
        "w_o": mk(f"{prefix}.w_o", (d, d), ("heads_flat", "embed")),
        # decay / bonus
        "w0": mk(f"{prefix}.w0", (d,), ("embed",), scale=0.5),
        "w_lora_a": mk(f"{prefix}.w_lora_a", (d, 64), ("embed", None)),
        "w_lora_b": mk(f"{prefix}.w_lora_b", (64, d), (None, "embed"), scale=0.01),
        "bonus": mk(f"{prefix}.bonus", (H, K), ("heads_flat", None), scale=0.3),
        # per-head group norm on the wkv output
        "gn_scale": mk(f"{prefix}.gn_norm", (d,), ("embed",)),
        # channel mix
        "cm_mu_k": mk(f"{prefix}.cm_mu_k", (d,), ("embed",), scale=0.1),
        "cm_mu_r": mk(f"{prefix}.cm_mu_r", (d,), ("embed",), scale=0.1),
        "cm_w_r": mk(f"{prefix}.cm_w_r", (d, d), ("embed", "embed2")),
        "cm_w_k": mk(f"{prefix}.cm_w_k", (d, cfg.d_ff), ("embed", "mlp")),
        "cm_w_v": mk(f"{prefix}.cm_w_v", (cfg.d_ff, d), ("mlp", "embed")),
    }


def _ddlerp(p: dict, x: jax.Array, x_prev: jax.Array):
    """Data-dependent token-shift mixing -> (xw, xk, xv, xr, xg)."""
    xx = x_prev - x
    xxx = x + xx * p["mu_x"].astype(x.dtype)
    lora = jnp.einsum(
        "...ir,ird->...id",
        jnp.tanh(jnp.einsum("...d,idr->...ir", xxx, p["lora_a"])),
        p["lora_b"])
    mix = p["mu"].astype(x.dtype) + lora                      # [..., 5, d]
    out = x[..., None, :] + xx[..., None, :] * mix
    return tuple(out[..., i, :] for i in range(5))


def _decay(p: dict, xw: jax.Array) -> jax.Array:
    """log w_t (per channel), guaranteed in [-8, -1e-4] for stability."""
    lw = p["w0"].astype(jnp.float32) + jnp.einsum(
        "...r,rd->...d",
        jnp.tanh(jnp.einsum("...d,dr->...r", xw.astype(jnp.float32),
                            p["w_lora_a"].astype(jnp.float32))),
        p["w_lora_b"].astype(jnp.float32))
    return -jnp.exp(jnp.clip(lw, -6.0, 2.079))  # exp(2.079) ~ 8


def rwkv6_time_mix(p: dict, x: jax.Array, cfg: ArchConfig, *,
                   shift_in: jax.Array, state_in: jax.Array,
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Full-sequence time-mix via chunked scan.

    x: [B,S,d]; shift_in: [B,d] (last token of previous segment);
    state_in: [B,H,K,K] wkv state. Returns (y, shift_out, state_out).
    """
    B, S, d = x.shape
    K = cfg.ssm.head_dim
    H = d // K
    C = min(cfg.ssm.chunk_size, S)
    if S % C:
        C = S  # fallback: single chunk (small test shapes)
    N = S // C

    x_prev = jnp.concatenate([shift_in[:, None, :], x[:, :-1, :]], axis=1)
    xw, xk, xv, xr, xg = _ddlerp(p, x, x_prev)
    r = jnp.einsum("bsd,de->bse", xr, p["w_r"]).reshape(B, S, H, K)
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"]).reshape(B, S, H, K)
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"]).reshape(B, S, H, K)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["w_g"]))
    logw = _decay(p, xw).reshape(B, S, H, K)                  # fp32, negative
    u = p["bonus"].astype(jnp.float32)                        # [H,K]

    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
    # chunk: [B,N,C,H,K] then scan over N
    ch = lambda t: jnp.moveaxis(t.reshape(B, N, C, H, K), 1, 0)
    r_c, k_c, v_c, lw_c = ch(r32), ch(k32), ch(v32), ch(logw)

    def chunk_body(S_in, xs):
        rc, kc, vc, lwc = xs                                  # [B,C,H,K]
        cum = jnp.cumsum(lwc, axis=1)                         # inclusive Σ_{s<=t}
        cum_prev = cum - lwc                                  # Σ_{s<=t-1}
        # intra-chunk scores: A[t,j] = Σ_k r_t k_j exp(cum_prev_t - cum_j), j<t
        ratio = cum_prev[:, :, None] - cum[:, None, :, :, :]   # [B,C,C,H,K]
        ratio = jnp.clip(ratio, -60.0, 0.0)
        scores = jnp.einsum("bthk,bjhk,btjhk->bhtj", rc, kc, jnp.exp(ratio))
        tri = jnp.tril(jnp.ones((C, C), bool), -1)[None, None]
        scores = jnp.where(tri, scores, 0.0)
        diag = jnp.einsum("bthk,hk,bthk->bth", rc, u, kc)     # bonus term
        y = jnp.einsum("bhtj,bjhk->bthk", scores, vc)
        y += diag[..., None] * vc
        # state contribution: r_t ⊙ exp(cum_prev_t) against S_in
        y += jnp.einsum("bthk,bhkn->bthn", rc * jnp.exp(cum_prev), S_in)
        # state update
        decay_out = jnp.exp(cum[:, -1])                       # [B,H,K]
        k_scaled = kc * jnp.exp(jnp.clip(cum[:, -1][:, None] - cum, -60.0, 0.0))
        S_out = S_in * decay_out[..., None] + jnp.einsum("bthk,bthn->bhkn",
                                                         k_scaled, vc)
        return S_out, y

    # checkpoint: the [B,C,C,H,K] decay tensor is recomputed in backward
    state_out, y_c = jax.lax.scan(jax.checkpoint(chunk_body),
                                  state_in.astype(jnp.float32),
                                  (r_c, k_c, v_c, lw_c))
    y = jnp.moveaxis(y_c, 0, 1).reshape(B, S, H, K)

    # per-head group norm, gate, output projection
    mean = jnp.mean(y, -1, keepdims=True)
    var = jnp.var(y, -1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(B, S, d) * p["gn_scale"].astype(jnp.float32)
    y = (y.astype(x.dtype) * g)
    out = jnp.einsum("bse,ed->bsd", y, p["w_o"])
    return out, x[:, -1, :], state_out.astype(state_in.dtype)


def rwkv6_time_mix_step(p: dict, x: jax.Array, cfg: ArchConfig, *,
                        shift_in: jax.Array, state_in: jax.Array,
                        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Exact O(1) single-token recurrence. x: [B,d]."""
    B, d = x.shape
    K = cfg.ssm.head_dim
    H = d // K
    xw, xk, xv, xr, xg = _ddlerp(p, x, shift_in)
    r = jnp.einsum("bd,de->be", xr, p["w_r"]).reshape(B, H, K).astype(jnp.float32)
    k = jnp.einsum("bd,de->be", xk, p["w_k"]).reshape(B, H, K).astype(jnp.float32)
    v = jnp.einsum("bd,de->be", xv, p["w_v"]).reshape(B, H, K).astype(jnp.float32)
    g = jax.nn.silu(jnp.einsum("bd,de->be", xg, p["w_g"]))
    w = jnp.exp(_decay(p, xw).reshape(B, H, K))
    u = p["bonus"].astype(jnp.float32)
    S = state_in.astype(jnp.float32)                          # [B,H,K,K]
    kv = k[..., :, None] * v[..., None, :]                    # [B,H,K,K]
    y = jnp.einsum("bhk,bhkn->bhn", r, S + u[None, :, :, None] * kv)
    S = S * w[..., None] + kv
    mean = jnp.mean(y, -1, keepdims=True)
    var = jnp.var(y, -1, keepdims=True)
    y = (y - mean) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(B, d) * p["gn_scale"].astype(jnp.float32)
    y = y.astype(x.dtype) * g
    return jnp.einsum("be,ed->bd", y, p["w_o"]), x, S.astype(state_in.dtype)


def rwkv6_channel_mix(p: dict, x: jax.Array, shift_in: jax.Array,
                      ) -> Tuple[jax.Array, jax.Array]:
    """Channel-mix (squared-relu FFN with token shift). x: [B,S,d] or [B,d]."""
    if x.ndim == 3:
        x_prev = jnp.concatenate([shift_in[:, None, :], x[:, :-1, :]], axis=1)
        shift_out = x[:, -1, :]
    else:
        x_prev, shift_out = shift_in, x
    mk_ = p["cm_mu_k"].astype(x.dtype)
    mr_ = p["cm_mu_r"].astype(x.dtype)
    xk = x + (x_prev - x) * mk_
    xr = x + (x_prev - x) * mr_
    rcv = jax.nn.sigmoid(jnp.einsum("...d,de->...e", xr, p["cm_w_r"]))
    kk = jnp.square(jax.nn.relu(jnp.einsum("...d,df->...f", xk, p["cm_w_k"])))
    return rcv * jnp.einsum("...f,fd->...d", kk, p["cm_w_v"]), shift_out
