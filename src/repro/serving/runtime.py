"""Event-driven retrieval runtime: continuous batching over a priority
event queue (§4.1/§4.2 made operational).

Replaces the lockstep ``execute_batch`` loop.  Requests are **admitted**
at arrival time, grouped into micro-batches by a ``SchedulerPolicy``, and
walked through a per-request state machine

    QUEUED -> ADMITTED -> PREFETCHING -> GENERATING -> RETRIEVING
           -> (next round | COMPLETE)
                  |  ^
                  v  | page-free event
           PRESSURE_STALLED

driven by a min-heap of timestamped events on a modeled wall clock.
A round frontier first *reserves* its lookahead plan's page headroom
with the engine's ``AdmissionController``; when the shared
``DevicePagePool`` cannot promise the pages, the wave parks
``PRESSURE_STALLED`` and resumes on the page-free event of a completing
wave's pin release — the planner never silently truncates its plan.
Prefetch copies are ``TransferEvent``s on the engine's double-buffered
link, so overlap between a transfer and a generation window is a fact of
the event timeline (two intersecting intervals), not a ``max()``.

Execution semantics:

  * Engine *data* operations (lookahead planning, device/host search,
    cache updates) run at **group granularity** when the group's round
    frontier fires — byte-for-byte the same operations, order, and RNG
    stream as the legacy executor, so retrieval results and telemetry
    are identical.
  * The *clock* is tracked **per request**: each request's round r
    starts when its own round r-1 finished; its retrieval waits on the
    later of its generation window and its view of the shared transfer
    (``TransferEngine.ready_t``).  For a static batch this reproduces
    the legacy ``RoundTelemetry`` composition to 1e-6
    (tests/test_runtime.py), while staggered arrivals yield transfers
    genuinely in flight during other requests' generation windows.

A request's admit→complete latency is read off the event clock
(``RequestRecord.latency``), which is what the serve drivers report.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.embedder import synthetic_rewrite
from repro.core.schedulers import SchedulerPolicy
from repro.serving.engine import (RequestResult, RoundTelemetry,
                                  TeleRAGEngine)
from repro.serving.policies import LatencyContext
from repro.serving.trace import RequestTrace


class RequestState(str, Enum):
    QUEUED = "queued"
    ADMITTED = "admitted"
    PRESSURE_STALLED = "pressure_stalled"   # parked: pool reservation failed
    PREFETCHING = "prefetching"
    GENERATING = "generating"
    RETRIEVING = "retrieving"
    COMPLETE = "complete"


@dataclass(frozen=True)
class Span:
    """One interval on a request's timeline ([t, t] for instant events)."""

    kind: str
    start: float
    end: float
    round_index: int = -1

    def overlaps(self, lo: float, hi: float) -> bool:
        """True iff this span intersects the open interval (lo, hi)."""
        return self.start < hi and lo < self.end


@dataclass(eq=False)                   # identity semantics: records are
class RequestRecord:                   # live state, and `q` is an ndarray
    """One request's live serving state on a replica runtime: identity,
    event-clock timestamps (seconds), state-machine position, and the
    span timeline the telemetry layer reads.  ``deadline_t`` is the
    request's *absolute* deadline on the shared event clock (``inf`` =
    no SLO); ``tenant``/``priority`` carry the SLO identity the
    dispatcher and admission control act on."""

    request_id: int
    pipeline: str
    trace: RequestTrace
    q: np.ndarray
    arrival_t: float
    result: RequestResult
    admit_t: float = float("nan")
    complete_t: float = float("nan")
    state: RequestState = RequestState.QUEUED
    timeline: List[Span] = field(default_factory=list)
    round_start: List[float] = field(default_factory=list)
    tenant: str = "shared"
    priority: int = 0
    deadline_t: float = float("inf")
    demoted_rounds: int = 0            # rounds whose prefetch was demoted

    @property
    def latency(self) -> float:
        """Admit→complete on the event clock (seconds)."""
        return self.complete_t - self.admit_t

    def spans(self, kind: str) -> List[Span]:
        """All timeline spans of one kind (e.g. ``"pressure_stall"``)."""
        return [s for s in self.timeline if s.kind == kind]


def percentile_line(latencies: Sequence[float]) -> str:
    """Nearest-rank p50/p95/mean/max of a latency sample, in ms."""
    lats = np.sort(np.asarray(latencies))
    nearest = lambda q: lats[max(0, -(-len(lats) * q // 100) - 1)]
    return (f"p50={nearest(50)*1e3:.1f}ms p95={nearest(95)*1e3:.1f}ms "
            f"mean={lats.mean()*1e3:.1f}ms max={lats[-1]*1e3:.1f}ms")


def latency_summary(records: Sequence["RequestRecord"]) -> str:
    """One-line nearest-rank p50/p95/mean of admit→complete latencies."""
    if not records:
        return "admit->complete: no completed requests"
    return f"admit->complete {percentile_line([r.latency for r in records])}"


def round_plan(trace: RequestTrace) -> List[Tuple[int, int]]:
    """[(gen_tokens_before_retrieval, num_queries), ...] per round."""
    plan: List[Tuple[int, int]] = []
    acc = 0
    for s in trace.stages:
        if s.kind == "retrieve":
            plan.append((acc, s.num_queries))
            acc = 0
        else:
            acc += s.gen_tokens
    return plan


def tail_gen_tokens(trace: RequestTrace) -> int:
    """Generation after the last retrieval (counts once per request)."""
    acc = 0
    for s in trace.stages:
        acc = 0 if s.kind == "retrieve" else acc + s.gen_tokens
    return acc


@dataclass
class _Group:
    gid: int
    members: List[RequestRecord]
    plans: List[List[Tuple[int, int]]]
    cur_q: np.ndarray                        # [B, d], drifts per round
    scheduled_rounds: set = field(default_factory=set)
    remaining: int = 0                       # members not yet COMPLETE
    tenant: str = "shared"                   # admission/ledger attribution


class RetrievalRuntime:
    """Continuous-batching executor for one engine replica."""

    def __init__(self, engine: TeleRAGEngine, *,
                 scheduler: Optional[SchedulerPolicy] = None,
                 micro_batch: Optional[int] = None,
                 ctx: Optional[LatencyContext] = None,
                 include_tail: bool = False,
                 on_generate: Optional[Callable[[List["RequestRecord"],
                                                 List[int], int],
                                                None]] = None):
        self.engine = engine
        self.scheduler = scheduler
        self.micro_batch = micro_batch
        self._ctx = ctx
        self.include_tail = include_tail
        # decode hook: called once per round frontier, right after the
        # async prefetch dispatch, with the active records and their
        # generation-window token counts — serve drivers run REAL decode
        # here so the copy is genuinely in flight underneath it (and the
        # prefetch is dispatched exactly once, by the policy)
        self.on_generate = on_generate
        self._rng = np.random.default_rng(engine.cfg.seed + 1)
        self._now = 0.0                      # drained clock across run()s
        self._seq = itertools.count()
        self._gid = itertools.count()
        self._heap: List[Tuple[float, int, str, tuple]] = []
        self._pending: List[RequestRecord] = []
        self._batch: List[RequestRecord] = []
        self._group_of: Dict[int, _Group] = {}     # id(record) -> group
        self._retry_scheduled = False
        self.event_log: List[Tuple[float, str, int]] = []
        # page-free events wake PRESSURE_STALLED waves
        engine.pool.subscribe(self._on_pages_freed)

    @property
    def ctx(self) -> LatencyContext:
        """The timing-plane constants (lazily built from the engine)."""
        if self._ctx is None:
            self._ctx = LatencyContext.from_engine(self.engine)
        return self._ctx

    # ---- submission --------------------------------------------------------
    def submit(self, q: np.ndarray, trace: RequestTrace,
               arrival_t: float = 0.0, *, tenant: str = "shared",
               priority: int = 0,
               deadline_t: float = float("inf")) -> RequestRecord:
        """Queue one request. ``arrival_t`` is relative to this run's
        start (the clock is monotonic across run() calls);
        ``deadline_t`` is the request's absolute event-clock deadline in
        seconds (``inf`` = no SLO) and ``tenant``/``priority`` tag it
        for tenant-scoped admission and SLO accounting."""
        rec = RequestRecord(
            request_id=trace.request_id, pipeline=trace.pipeline,
            trace=trace, q=np.asarray(q), arrival_t=float(arrival_t),
            result=RequestResult(trace.request_id, trace.pipeline),
            tenant=tenant, priority=int(priority),
            deadline_t=float(deadline_t))
        self._pending.append(rec)
        self._batch.append(rec)
        return rec

    # ---- event loop --------------------------------------------------------
    def _push(self, t: float, kind: str, payload: tuple) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    @property
    def now(self) -> float:
        """Current position on the (monotonic) event clock."""
        return self._now

    def begin(self, *, rebase: bool = True) -> None:
        """Seed admit events for everything submitted since the last
        wave.  ``rebase=True`` (the legacy ``run()`` path) offsets the
        pending arrival times by the current clock; ``rebase=False``
        treats them as *absolute* event-clock times — the
        ``TeleRAGServer`` dispatches on one shared global clock and has
        already placed the wave on it (clamped monotone as a guard)."""
        if rebase:
            base = self._now
            for rec in self._pending:
                rec.arrival_t += base
        else:
            for rec in self._pending:
                rec.arrival_t = max(rec.arrival_t, self._now)
        for t in sorted({r.arrival_t for r in self._pending}):
            self._push(t, "admit", ())

    def has_work(self) -> bool:
        """True while events remain or waves are parked on pressure."""
        return bool(self._heap) or bool(self.engine.admission.parked)

    def next_event_t(self) -> Optional[float]:
        """Clock time of the next event this runtime would process (the
        server's merge key across replicas); None when drained."""
        if self._heap:
            return self._heap[0][0]
        if self.engine.admission.parked:
            return self._now
        return None

    def step(self) -> float:
        """Process exactly one event; returns the clock after it.  The
        ``TeleRAGServer`` interleaves replicas by always stepping the
        runtime with the globally-earliest ``next_event_t``."""
        if not self._heap:
            # every waker has fired and waves are still parked (the
            # pressure came from holders outside the event loop, e.g.
            # recycled KV buckets): force a capped admission so the
            # drain terminates — the shortfall lands on admission
            # stats, never on silently dropped work
            self._retry_parked(self._now, force=True)
            return self._now
        t, _, kind, payload = heapq.heappop(self._heap)
        self._now = max(self._now, t)
        if kind == "admit":
            self._on_admit(t)
        elif kind == "round":
            self._on_round(*payload, now=t)
        elif kind == "retry":
            self._retry_scheduled = False
            self._retry_parked(t)
        elif kind == "mark":
            rec, state, label = payload
            if state is not None:
                rec.state = state
            self.event_log.append((t, label, rec.request_id))
            if state is RequestState.COMPLETE:
                self._on_member_complete(rec, t)
        return self._now

    def collect(self) -> List[RequestRecord]:
        """Post-drain consolidation: end_batch the engine and hand back
        the records submitted since the last collect (submission order)."""
        self.engine.end_batch()
        out, self._batch = self._batch, []
        return out

    def run(self) -> List[RequestRecord]:
        """Drain all submitted requests; return their records (submission
        order).  Consolidates the engine (end_batch) once drained."""
        self.begin()
        while self.has_work():
            self.step()
        return self.collect()

    # ---- handlers ----------------------------------------------------------
    def _on_admit(self, now: float) -> None:
        ready = [r for r in self._pending if r.arrival_t <= now + 1e-12]
        if not ready:
            return
        self._pending = [r for r in self._pending if r not in ready]
        q = np.stack([r.q for r in ready])
        if self.scheduler is None:
            groups_idx = [list(range(len(ready)))]
        else:
            groups_idx = self.scheduler.group(
                q, self.micro_batch or len(ready))
        for gi in groups_idx:
            members = [ready[i] for i in gi]
            plans = [round_plan(m.trace) for m in members]
            g = _Group(gid=next(self._gid), members=members, plans=plans,
                       cur_q=np.stack([m.q for m in members]).copy(),
                       tenant=members[0].tenant)
            for m, p in zip(members, plans):
                m.admit_t = now
                m.state = RequestState.ADMITTED
                m.round_start = [now] + [float("nan")] * (len(p) - 1)
                m.timeline.append(Span("admit", now, now))
                self.event_log.append((now, "admit", m.request_id))
                if not p:                    # trace with no retrieval round
                    m.complete_t = now
                    m.state = RequestState.COMPLETE
                    m.timeline.append(Span("complete", now, now))
                else:
                    g.remaining += 1
                    self._group_of[id(m)] = g
            g.scheduled_rounds.add(0)
            self._push(now, "round", (g, 0))

    def _on_round(self, g: _Group, rnd: int, force: bool = False, *,
                  now: float) -> None:
        """Group round frontier: reserve the round's pool headroom (or
        park PRESSURE_STALLED), then run the engine data ops for every
        member still active in round ``rnd`` and schedule each member's
        per-request events from its own round-start."""
        eng = self.engine
        policy = eng.policy
        active = [i for i in range(len(g.members))
                  if rnd < len(g.plans[i])]
        if not active:
            return
        batch = len(active)
        gen_tokens = [g.plans[i][rnd][0] for i in active]
        act_q = g.cur_q[active]

        # 0a) slack-based demotion: a round whose every active member is
        #     already past its deadline cannot make its SLO no matter
        #     how fast retrieval runs — spending pool pages and link
        #     bandwidth on its lookahead only starves requests that CAN
        #     still meet theirs.  The round executes (misses go to host
        #     search) but its prefetch is demoted to nothing.
        demoted = (policy.prefetches and bool(active)
                   and all(now > g.members[i].deadline_t + 1e-12
                           for i in active))
        if demoted:
            for i in active:
                req = g.members[i]
                req.demoted_rounds += 1
                self.event_log.append((now, "prefetch_demoted",
                                       req.request_id))

        # 0) admission: the wave's lookahead plan reserves its headroom
        #    up front; if the pool cannot promise the pages, the whole
        #    round parks and resumes on a page-free event — the planner
        #    never silently truncates under someone else's pressure
        plan = ticket = None
        if policy.prefetches and not demoted:
            plan = eng.plan_lookahead(act_q, gen_tokens, wave_key=g.gid)
            # pin the plan's resident hits BEFORE admission: the spill
            # that makes room for this wave's reservation must not evict
            # the clusters the plan counts on finding on-device
            hit_pins = eng.buffer.pin_clusters(g.gid, plan.resident_hits)
            # stalling is only sound if someone ELSE will free pages —
            # the wave's own pins must not make it wait on itself
            waitable = (eng.buffer.pages_pinned_by_others(g.gid) > 0
                        or bool(eng.pool.reservations)
                        or any(l.owner != "prefetch"
                               for l in eng.pool.leases.values()))
            ticket = eng.admission.admit(plan.pages_planned,
                                         owner=f"g{g.gid}r{rnd}",
                                         can_wait=waitable and not force,
                                         tenant=g.tenant)
            if ticket is None:
                # a parked wave holds nothing: keeping tentative hit pins
                # would make other parked waves mutually wait on them —
                # the plan is recomputed from scratch on resume anyway
                eng.buffer.release_pins(g.gid, hit_pins)
                eng.admission.park((g, rnd), plan.pages_planned,
                                   tenant=g.tenant)
                for i in active:
                    req = g.members[i]
                    req.state = RequestState.PRESSURE_STALLED
                    self.event_log.append((now, "pressure_stall",
                                           req.request_id))
                return

        # 1) lookahead prefetch keyed on the *current* query, dispatched
        #    (async) at the frontier — in flight during generation.  A
        #    demoted round moves nothing (it only flushes any queued
        #    device invalidations so the search LUT stays consistent).
        if demoted:
            nbytes, nfetch, ev = 0, 0, None
            eng.buffer.flush_invalidations()
        else:
            nbytes, nfetch, ev = eng.lookahead_ex(act_q, gen_tokens, now=now,
                                                  plan=plan, ticket=ticket)
        if plan is not None:
            # the wave owns its fetched set too until its completion event
            eng.buffer.pin_clusters(g.gid, plan.fetch)

        # 1b) real decode (serve drivers): the copy dispatched above is
        #     in flight while the hook's device steps run
        if self.on_generate is not None:
            self.on_generate([g.members[i] for i in active], gen_tokens,
                             rnd)

        # 2) rewrite -> q_out (SubQ expands to num_queries rewrites)
        q_out_rows: List[np.ndarray] = []
        owners: List[int] = []
        for j, i in enumerate(active):
            sigma = g.members[i].trace.rewrite_sigma
            nq = g.plans[i][rnd][1]
            for _ in range(nq):
                q_out_rows.append(
                    synthetic_rewrite(act_q[j][None, :], sigma,
                                      self._rng)[0]
                    if sigma > 0 else act_q[j])
                owners.append(i)
        q_out = np.stack(q_out_rows)

        # 3) hybrid retrieval (device hits + host misses + merge)
        res = eng.retrieve(q_out, now=now, tenant=g.tenant)

        # 4) per-request telemetry + event-clock scheduling
        t_transfer = nbytes / eng.cfg.hw.host_link_bw
        mean_pages = float(np.mean(eng.index.paged.cluster_num_pages))
        continuing: List[float] = []
        for j, i in enumerate(active):
            req = g.members[i]
            rows = [r for r, o in enumerate(owners) if o == i]
            hits = sum(len(res.hit_clusters[r]) for r in rows)
            misses = sum(len(res.missed_clusters[r]) for r in rows)
            rt = RoundTelemetry(
                round_index=rnd, batch=batch, gen_tokens=gen_tokens[j],
                t_llm_window=eng.llm_window_seconds(gen_tokens[j], batch),
                bytes_prefetched=nbytes // max(batch, 1),
                t_prefetch=t_transfer,
                hits=hits, misses=misses,
                t_host_search=misses * eng.effective_tcc(),
                t_dev_search=eng._dev_search_seconds(
                    int(hits * mean_pages)),
                t_merge=2e-5)
            req.result.rounds.append(rt)
            req.result.doc_ids.extend(res.doc_ids[r] for r in rows)

            rs = req.round_start[rnd]
            gen_end = rs + rt.t_llm_window
            ready = None
            if policy.prefetches and ev is not None:
                ready = eng.transfer.ready_t(ev, rs)
            retrieve_start = (gen_end if ready is None
                              else max(gen_end, ready))
            round_end = retrieve_start + policy.search_seconds(rt, self.ctx)

            if policy.prefetches and not demoted:
                req.timeline.append(Span("prefetch_dispatch", rs, rs, rnd))
                self._push(rs, "mark",
                           (req, RequestState.PREFETCHING, "prefetch"))
            req.timeline.append(Span("generate", rs, gen_end, rnd))
            self._push(rs, "mark", (req, RequestState.GENERATING, "generate"))
            if retrieve_start > gen_end:
                req.timeline.append(
                    Span("transfer_wait", gen_end, retrieve_start, rnd))
            req.timeline.append(
                Span("retrieve", retrieve_start, round_end, rnd))
            self._push(retrieve_start, "mark",
                       (req, RequestState.RETRIEVING, "retrieve"))

            if rnd + 1 < len(g.plans[i]):
                req.round_start[rnd + 1] = round_end
                continuing.append(round_end)
            else:
                complete_t = round_end
                if self.include_tail:
                    tail_s = eng.llm_window_seconds(
                        tail_gen_tokens(req.trace), batch)
                    if tail_s > 0:
                        req.timeline.append(
                            Span("generate_tail", round_end,
                                 round_end + tail_s, rnd))
                    complete_t = round_end + tail_s
                req.complete_t = complete_t
                req.timeline.append(Span("complete", complete_t, complete_t))
                self._push(complete_t, "mark",
                           (req, RequestState.COMPLETE, "complete"))

        # 5) next round's query drifts from this round's rewrite
        for j, i in enumerate(active):
            rows = [r for r, o in enumerate(owners) if o == i]
            g.cur_q[i] = q_out[rows[0]]

        # 6) the earliest finisher opens the next round frontier
        if continuing and (rnd + 1) not in g.scheduled_rounds:
            g.scheduled_rounds.add(rnd + 1)
            self._push(min(continuing), "round", (g, rnd + 1))

    # ---- admission / memory-pressure plumbing ------------------------------
    def _on_pages_freed(self, pages: int) -> None:
        """Pool subscriber: pages returned to the free list wake parked
        waves (runs inside whichever event handler freed them)."""
        if self.engine.admission.parked and not self._retry_scheduled:
            self._retry_scheduled = True
            self._push(self._now, "retry", ())

    def _retry_parked(self, now: float, force: bool = False) -> None:
        """Re-admit every parked wave.  The stall interval becomes a
        ``pressure_stall`` span and the round restarts from the resume
        time, so admission delay shows up in admit→complete latency."""
        for (g, rnd), _npages in self.engine.admission.unpark_all():
            for i in range(len(g.members)):
                if rnd >= len(g.plans[i]):
                    continue
                req = g.members[i]
                rs = req.round_start[rnd]
                if now > rs + 1e-15:
                    req.timeline.append(Span("pressure_stall", rs, now, rnd))
                    req.round_start[rnd] = now
                req.state = RequestState.ADMITTED
                self.event_log.append((now, "pressure_resume",
                                       req.request_id))
            self._push(now, "round", (g, rnd, force))

    def _on_member_complete(self, rec: RequestRecord, t: float) -> None:
        """Completion event: the last member out releases the group's
        cluster pins, making its pages evictable for parked waves."""
        g = self._group_of.pop(id(rec), None)
        if g is None:
            return
        g.remaining -= 1
        if g.remaining == 0:
            self.engine.buffer.unpin(g.gid)
            if self.engine.admission.parked and not self._retry_scheduled:
                self._retry_scheduled = True
                self._push(t, "retry", ())
