"""Event-driven retrieval runtime: per-request continuous batching over
a priority event queue (§4.1/§4.2 made operational).

Replaces the lockstep ``execute_batch`` loop.  Requests are **admitted**
at arrival time and walked through a per-request state machine

    QUEUED -> ADMITTED -> PREFETCHING -> GENERATING -> RETRIEVING
           ->  (ready: next round | COMPLETE)
                  |  ^
                  v  | page-free event
           PRESSURE_STALLED

driven by a min-heap of timestamped events on a modeled wall clock.

Execution is **wave-formed**: there is no static batch.  Whenever one
or more requests become *ready* (admitted, resumed from a pressure
park, or finishing a retrieval round), a **round frontier** fires and
the dynamic wave former re-batches whichever requests are ready *right
now* — same replica, tenant-pure, honoring the ``micro_batch`` cap —
into fresh micro-batches (``_Wave``s).  A slow request therefore never
drags its former batch-mates: they re-form into new waves the moment
their own rounds end, newly admitted requests join mid-stream, and a
request parked ``PRESSURE_STALLED`` rejoins whatever wave forms at its
wake-up.  Wave membership (and therefore the decode batch size each
generation window is modeled at) reflects who is *actually* decoding
together.  ``SchedulerPolicy.reform_wave`` owns the ordering (default:
EDF within priority classes, FIFO among equals).

Per-request bookkeeping is keyed by the request, not the wave: buffer
pins (a request's working set stays pinned until *its* completion
event), admission parking, and round telemetry (``RoundTelemetry``
carries ``wave_id`` / ``round_start_t`` / ``round_end_t``) all hang off
``RequestRecord``.  Admission reservations are aggregated per wave (one
ticket covers the wave's batched lookahead plan) but park and resume
per request.

Decode can be **real and asynchronous**: the ``on_generate`` hook runs
actual device decode inside the round frontier (the prefetch copy
dispatched just before it is genuinely in flight underneath) and may
return per-request ``DecodeEvent``s — observed decode steps whose
measured seconds then *drive the event clock* in place of the trace's
static ``llm_window_seconds`` estimate.

A round frontier first *reserves* the wave's lookahead page headroom
with the engine's ``AdmissionController``; when the shared
``DevicePagePool`` cannot promise the pages, the wave's members park
``PRESSURE_STALLED`` and resume on the page-free event of a completing
request's pin release — the planner never silently truncates its plan.
Prefetch copies are ``TransferEvent``s on the engine's double-buffered
link, so overlap between a transfer and a generation window is a fact
of the event timeline (two intersecting intervals), not a ``max()``.

**Never-re-form mode** (``reform=False``): the degenerate setting runs
the same wave executor on *static cohorts* — the request's admission
group is its wave for every round, frontiers fire at the cohort's
earliest finisher, and each member keeps its own round start — which
reproduces the legacy group-granular executor bit-for-bit (doc ids
exact, telemetry to 1e-6; pinned by tests/test_runtime.py and
tests/test_api.py).  ``PipelineExecutor`` and ``run_global_batch`` run
in this mode.

A request's admit→complete latency is read off the event clock
(``RequestRecord.latency``), which is what the serve drivers report.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.embedder import synthetic_rewrite
from repro.core.schedulers import SchedulerPolicy
from repro.memory.pool import PoolExhausted
from repro.obs.recorder import (DecodeStep, FlightRecorder, RequestEvent,
                                SpanEvent, WaveEvent)
from repro.serving.engine import (RequestResult, RoundTelemetry,
                                  TeleRAGEngine)
from repro.serving.policies import LatencyContext
from repro.serving.trace import RequestTrace


class RequestState(str, Enum):
    QUEUED = "queued"
    ADMITTED = "admitted"                   # ready: waiting for a wave
    PRESSURE_STALLED = "pressure_stalled"   # parked: pool reservation failed
    PREFETCHING = "prefetching"
    GENERATING = "generating"
    RETRIEVING = "retrieving"
    COMPLETE = "complete"


@dataclass(frozen=True)
class Span:
    """One interval on a request's timeline ([t, t] for instant events)."""

    kind: str
    start: float
    end: float
    round_index: int = -1

    def intersects(self, lo: float, hi: float) -> bool:
        """True iff this span intersects the open interval (lo, hi):
        strict inequalities on both sides, so touching endpoints (and a
        zero-length span AT an endpoint) do not count as overlap, while
        a zero-length span strictly inside (lo, hi) does."""
        return self.start < hi and lo < self.end

    def overlaps(self, lo: float, hi: float) -> bool:
        """Back-compat alias for :meth:`intersects`."""
        return self.intersects(lo, hi)


@dataclass(frozen=True)
class DecodeEvent:
    """One request's *observed* decode outcome for a generation window.

    The ``on_generate`` hook returns one per wave member when it runs
    real decode: ``tokens`` steps were actually executed in ``seconds``
    of measured wall clock.  The runtime then models the member's full
    generation window from the observed per-step rate instead of the
    trace's static hardware estimate — real decode drives the event
    clock."""

    request_id: int
    tokens: int                   # decode steps actually executed
    seconds: float                # measured wall-clock for those steps

    def window(self, gen_tokens: int) -> float:
        """Seconds for a ``gen_tokens``-step window at the observed
        per-step rate (``seconds`` verbatim when no steps ran)."""
        if self.tokens <= 0:
            return float(self.seconds)
        return float(self.seconds) * (gen_tokens / self.tokens)


@dataclass(eq=False)                   # identity semantics: records are
class RequestRecord:                   # live state, and `q` is an ndarray
    """One request's live serving state on a replica runtime: identity,
    event-clock timestamps (seconds), state-machine position, and the
    span timeline the telemetry layer reads.

    The record IS the unit of execution: ``plan`` (its retrieval round
    shapes), ``cur_q`` (its drifting query), ``next_round`` and
    ``ready_t`` (when its next round may start) make it independently
    schedulable, and buffer pins / admission parking are keyed by the
    record itself.  ``deadline_t`` is the request's *absolute* deadline
    on the shared event clock (``inf`` = no SLO); ``tenant`` /
    ``priority`` carry the SLO identity the wave former and admission
    control act on."""

    request_id: int
    pipeline: str
    trace: RequestTrace
    q: np.ndarray
    arrival_t: float
    result: RequestResult
    admit_t: float = float("nan")
    complete_t: float = float("nan")
    state: RequestState = RequestState.QUEUED
    timeline: List[Span] = field(default_factory=list)
    round_start: List[float] = field(default_factory=list)
    tenant: str = "shared"
    priority: int = 0
    deadline_t: float = float("inf")
    demoted_rounds: int = 0            # rounds whose prefetch was demoted
    # per-request round machine (populated at admit)
    plan: List[Tuple[int, int]] = field(default_factory=list)
    cur_q: Optional[np.ndarray] = None
    next_round: int = 0
    ready_t: float = float("nan")

    @property
    def latency(self) -> float:
        """Admit→complete on the event clock (seconds)."""
        return self.complete_t - self.admit_t

    def spans(self, kind: str) -> List[Span]:
        """All timeline spans of one kind (e.g. ``"pressure_stall"``)."""
        return [s for s in self.timeline if s.kind == kind]


def percentile_line(latencies: Sequence[float]) -> str:
    """Nearest-rank p50/p95/mean/max of a latency sample, in ms."""
    lats = np.sort(np.asarray(latencies))
    nearest = lambda q: lats[max(0, -(-len(lats) * q // 100) - 1)]
    return (f"p50={nearest(50)*1e3:.1f}ms p95={nearest(95)*1e3:.1f}ms "
            f"mean={lats.mean()*1e3:.1f}ms max={lats[-1]*1e3:.1f}ms")


def latency_summary(records: Sequence["RequestRecord"]) -> str:
    """One-line nearest-rank p50/p95/mean of admit→complete latencies."""
    if not records:
        return "admit->complete: no completed requests"
    return f"admit->complete {percentile_line([r.latency for r in records])}"


def round_plan(trace: RequestTrace) -> List[Tuple[int, int]]:
    """[(gen_tokens_before_retrieval, num_queries), ...] per round."""
    plan: List[Tuple[int, int]] = []
    acc = 0
    for s in trace.stages:
        if s.kind == "retrieve":
            plan.append((acc, s.num_queries))
            acc = 0
        else:
            acc += s.gen_tokens
    return plan


def tail_gen_tokens(trace: RequestTrace) -> int:
    """Generation after the last retrieval (counts once per request;
    for a decode-only trace this is the whole trace)."""
    acc = 0
    for s in trace.stages:
        acc = 0 if s.kind == "retrieve" else acc + s.gen_tokens
    return acc


@dataclass(eq=False)
class _Cohort:
    """Never-re-form mode's static admission group: its members stay
    wave-mates for every round (the legacy ``_Group`` semantics)."""

    gid: int
    members: List[RequestRecord]
    scheduled_rounds: set = field(default_factory=set)


@dataclass(eq=False)
class _Wave:
    """One dynamically-formed micro-batch: the requests executing a
    round frontier together (mixed ``rounds`` indices are normal — a
    mid-stream admit's round 0 batches with a veteran's round 2)."""

    wid: int
    t: float                              # frontier clock time
    members: List[RequestRecord]
    rounds: List[int]                     # per-member round index
    tenant: str = "shared"
    # parked by KV-slab pressure (decode hook's acquire_paged failed):
    # on resume EVERY member re-enters the ready set — including
    # tail-only members, whose decode also never ran (an admission park
    # runs tails as their own wave before parking, so those stay
    # excluded from the wake)
    kv_parked: bool = False

    @property
    def request_ids(self) -> Tuple[int, ...]:
        """Member request ids (telemetry / test introspection)."""
        return tuple(m.request_id for m in self.members)


# forced frontiers fall back to this former: it places EVERY ready
# request, so a custom policy that keeps deferring cannot stall a drain
_BASE_FORMER = SchedulerPolicy()


class RetrievalRuntime:
    """Per-request continuous-batching executor for one engine replica."""

    def __init__(self, engine: TeleRAGEngine, *,
                 scheduler: Optional[SchedulerPolicy] = None,
                 micro_batch: Optional[int] = None,
                 ctx: Optional[LatencyContext] = None,
                 include_tail: bool = False,
                 on_generate: Optional[Callable[[List["RequestRecord"],
                                                 List[int], int],
                                                Optional[Sequence[
                                                    DecodeEvent]]]] = None,
                 reform: bool = True,
                 on_complete: Optional[Callable[["RequestRecord"],
                                                None]] = None):
        """``reform=True`` (the default) runs the dynamic wave former:
        every round frontier re-batches the currently-ready requests.
        ``reform=False`` is the degenerate never-re-form mode — the
        admission group is the wave for every round — which reproduces
        the legacy group-granular executor exactly (the deprecated
        shims run in this mode).  ``on_generate`` is the decode hook:
        called once per wave frontier, right after the async prefetch
        dispatch, with the wave's records and their generation-window
        token counts; serve drivers run REAL decode here (the copy is
        genuinely in flight underneath) and may return per-request
        ``DecodeEvent``s whose observed timing replaces the modeled
        generation window on the event clock.  ``on_complete`` fires at
        each request's completion event (the server's continuous
        dispatcher consumes these instead of waiting for batch
        drains)."""
        self.engine = engine
        self.scheduler = scheduler
        self.micro_batch = micro_batch
        self._ctx = ctx
        self.include_tail = include_tail
        self.on_generate = on_generate
        self.on_complete = on_complete
        self.reform = reform
        # the wave former: the scheduler policy when given (its
        # reform_wave hook), else the base EDF/tenant-aware default
        self._former = scheduler if scheduler is not None \
            else SchedulerPolicy()
        self._rng = np.random.default_rng(engine.cfg.seed + 1)
        self._now = 0.0                      # drained clock across run()s
        self._seq = itertools.count()
        self._gid = itertools.count()
        self._wid = itertools.count()
        self._heap: List[Tuple[float, int, str, tuple]] = []
        self._pending: List[RequestRecord] = []
        self._batch: List[RequestRecord] = []
        self._ready: List[RequestRecord] = []
        self._retry_scheduled = False
        self.wave_log: List[_Wave] = []
        # page-free events wake PRESSURE_STALLED requests
        engine.pool.subscribe(self._on_pages_freed)

    # ---- flight recorder ---------------------------------------------------
    @property
    def recorder(self) -> FlightRecorder:
        """The replica's trace stream (engine-owned; the server rebinds
        every replica onto one shared recorder)."""
        return self.engine.recorder

    @property
    def replica_id(self) -> int:
        """This runtime's lane in the shared recorder (the engine's
        replica id; -1 for a standalone engine)."""
        return self.engine.replica_id

    @property
    def event_log(self) -> List[Tuple[float, str, int]]:
        """Legacy view of the request-lifecycle stream: ``(t, label,
        request_id)`` tuples in emission order, exactly what the old
        ad-hoc list recorded.  The typed events are the source of
        truth; this is a compatibility shim."""
        return self.recorder.legacy_tuples(self.replica_id)

    def _emit_req(self, t: float, label: str, rec: RequestRecord, *,
                  round_index: int = -1, wave_id: int = -1) -> None:
        """One request-lifecycle event into the flight recorder."""
        self.recorder.emit(RequestEvent(
            t=t, kind="request", replica=self.replica_id,
            request_id=rec.request_id, tenant=rec.tenant,
            wave_id=wave_id, label=label, round_index=round_index))

    def _span(self, req: RequestRecord, kind: str, start: float,
              end: float, rnd: int = -1, *, wave_id: int = -1) -> None:
        """Append to the request's timeline AND trace the same interval
        as a typed ``SpanEvent`` (the exporters' track content)."""
        req.timeline.append(Span(kind, start, end, rnd))
        self.recorder.emit(SpanEvent(
            t=start, kind="span", replica=self.replica_id,
            request_id=req.request_id, tenant=req.tenant, wave_id=wave_id,
            name=kind, dur=end - start, round_index=rnd))

    @property
    def ctx(self) -> LatencyContext:
        """The timing-plane constants (lazily built from the engine)."""
        if self._ctx is None:
            self._ctx = LatencyContext.from_engine(self.engine)
        return self._ctx

    # ---- submission --------------------------------------------------------
    def submit(self, q: np.ndarray, trace: RequestTrace,
               arrival_t: float = 0.0, *, tenant: str = "shared",
               priority: int = 0,
               deadline_t: float = float("inf")) -> RequestRecord:
        """Queue one request. ``arrival_t`` is relative to this run's
        start (the clock is monotonic across run() calls);
        ``deadline_t`` is the request's absolute event-clock deadline in
        seconds (``inf`` = no SLO) and ``tenant``/``priority`` tag it
        for tenant-scoped admission and SLO accounting."""
        rec = RequestRecord(
            request_id=trace.request_id, pipeline=trace.pipeline,
            trace=trace, q=np.asarray(q), arrival_t=float(arrival_t),
            result=RequestResult(trace.request_id, trace.pipeline),
            tenant=tenant, priority=int(priority),
            deadline_t=float(deadline_t))
        self._pending.append(rec)
        self._batch.append(rec)
        return rec

    # ---- event loop --------------------------------------------------------
    def _push(self, t: float, kind: str, payload: tuple) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), kind, payload))

    @property
    def now(self) -> float:
        """Current position on the (monotonic) event clock."""
        return self._now

    def begin(self, *, rebase: bool = True) -> None:
        """Seed admit events for everything submitted since the last
        wave.  ``rebase=True`` (the legacy ``run()`` path) offsets the
        pending arrival times by the current clock; ``rebase=False``
        treats them as *absolute* event-clock times — the
        ``TeleRAGServer`` dispatches on one shared global clock and has
        already placed the wave on it (clamped monotone as a guard)."""
        if rebase:
            base = self._now
            for rec in self._pending:
                rec.arrival_t += base
        else:
            for rec in self._pending:
                rec.arrival_t = max(rec.arrival_t, self._now)
        for t in sorted({r.arrival_t for r in self._pending}):
            self._push(t, "admit", ())

    def has_work(self) -> bool:
        """True while events remain, requests are ready for a wave, or
        requests are parked on pressure."""
        return (bool(self._heap) or bool(self._ready)
                or bool(self.engine.admission.parked))

    def next_event_t(self) -> Optional[float]:
        """Clock time of the next event this runtime would process (the
        server's merge key across replicas); None when drained."""
        if self._heap:
            return self._heap[0][0]
        if self._ready or self.engine.admission.parked:
            return self._now
        return None

    def step(self) -> float:
        """Process exactly one event; returns the clock after it.  The
        ``TeleRAGServer`` interleaves replicas by always stepping the
        runtime with the globally-earliest ``next_event_t``."""
        if not self._heap:
            if self._ready:
                # a custom former deferred requests and nothing else is
                # coming: force a frontier so the drain terminates
                self._on_frontier(True, now=self._now)
                return self._now
            # every waker has fired and requests are still parked (the
            # pressure came from holders outside the event loop, e.g.
            # recycled KV buckets): force a capped admission so the
            # drain terminates — the shortfall lands on admission
            # stats, never on silently dropped work
            self._retry_parked(self._now, force=True)
            return self._now
        t, _, kind, payload = heapq.heappop(self._heap)
        self._now = max(self._now, t)
        # deep components (pool, admission, KV) stamp at recorder.now —
        # the event loop owns the clock, so it advances it
        self.recorder.tick(self._now)
        if kind == "admit":
            self._on_admit(t)
        elif kind == "round":
            self._on_round(*payload, now=t)
        elif kind == "frontier":
            self._on_frontier(*payload, now=t)
        elif kind == "ready":
            self._on_ready(*payload, now=t)
        elif kind == "retry":
            self._retry_scheduled = False
            self._retry_parked(t)
        elif kind == "mark":
            rec, state, label = payload
            if state is not None:
                rec.state = state
            self._emit_req(t, label, rec)
            if state is RequestState.COMPLETE:
                self._on_member_complete(rec, t)
        return self._now

    def collect(self) -> List[RequestRecord]:
        """Post-drain consolidation: end_batch the engine and hand back
        the records submitted since the last collect (submission order)."""
        self.engine.end_batch()
        out, self._batch = self._batch, []
        return out

    def run(self) -> List[RequestRecord]:
        """Drain all submitted requests; return their records (submission
        order).  Consolidates the engine (end_batch) once drained."""
        self.begin()
        while self.has_work():
            self.step()
        return self.collect()

    # ---- admission of arrivals ---------------------------------------------
    def _admit_record(self, m: RequestRecord, now: float) -> None:
        """Common per-request admission bookkeeping (both modes)."""
        m.admit_t = now
        m.state = RequestState.ADMITTED
        m.plan = round_plan(m.trace)
        m.cur_q = np.array(m.q, copy=True)
        m.next_round = 0
        m.ready_t = now
        m.round_start = [now] + [float("nan")] * max(0, len(m.plan) - 1)
        self._span(m, "admit", now, now)
        self._emit_req(now, "admit", m)

    def _on_admit(self, now: float) -> None:
        ready = [r for r in self._pending if r.arrival_t <= now + 1e-12]
        if not ready:
            return
        self._pending = [r for r in self._pending if r not in ready]
        if self.reform:
            # per-request admission: every arrival is individually ready
            # and joins whatever wave the next frontier forms (mid-stream
            # admission into an in-flight replica is the normal path —
            # decode-only requests included)
            for m in ready:
                self._admit_record(m, now)
            self._ready.extend(ready)
            self._push(now, "frontier", (False,))
            return
        # never-re-form mode: the admission group IS the wave for every
        # round (legacy semantics, pinned equivalent)
        q = np.stack([r.q for r in ready])
        if self.scheduler is None:
            groups_idx = [list(range(len(ready)))]
        else:
            groups_idx = self.scheduler.group(
                q, self.micro_batch or len(ready))
        for gi in groups_idx:
            members = [ready[i] for i in gi]
            for m in members:
                self._admit_record(m, now)
            # decode-only traces ride the normal per-request path as
            # tail-only singleton waves (no special-case completion)
            with_rounds = [m for m in members if m.plan]
            for m in members:
                if not m.plan:
                    self._exec_wave(
                        _Wave(wid=next(self._wid), t=now, members=[m],
                              rounds=[0], tenant=m.tenant),
                        now=now, starts=[now])
            if with_rounds:
                g = _Cohort(gid=next(self._gid), members=with_rounds)
                g.scheduled_rounds.add(0)
                self._push(now, "round", (g, 0))

    # ---- frontiers ---------------------------------------------------------
    def _on_round(self, g: _Cohort, rnd: int, force: bool = False, *,
                  now: float) -> None:
        """Never-re-form frontier: the cohort's active members execute
        round ``rnd`` as one wave, each from its own round start."""
        members = [m for m in g.members if rnd < len(m.plan)]
        if not members:
            return
        wave = _Wave(wid=next(self._wid), t=now, members=members,
                     rounds=[rnd] * len(members), tenant=members[0].tenant)
        self._exec_wave(wave, now=now,
                        starts=[m.round_start[rnd] for m in members],
                        force=force, cohort=g)

    def _on_frontier(self, force: bool = False, *, now: float) -> None:
        """Dynamic round frontier: re-batch whichever requests are ready
        *now* into fresh waves (the former orders/partitions; members a
        custom former defers stay ready for the next frontier).  A
        *forced* frontier (the event queue would otherwise drain) uses
        the base former, which places every ready request — a custom
        former that keeps deferring cannot livelock the drain."""
        ready = [r for r in self._ready
                 if r.state == RequestState.ADMITTED]
        self._ready = []
        if not ready:
            return
        former = _BASE_FORMER if force else self._former
        waves_idx = former.reform_wave(ready,
                                       micro_batch=self.micro_batch,
                                       now=now)
        placed = set()
        for wi in waves_idx:
            members = [ready[i] for i in wi]
            placed.update(wi)
            wave = _Wave(wid=next(self._wid), t=now, members=members,
                         rounds=[m.next_round for m in members],
                         tenant=members[0].tenant)
            self._exec_wave(wave, now=now, starts=[now] * len(members),
                            force=force)
        self._ready.extend(r for i, r in enumerate(ready)
                           if i not in placed)

    def _on_ready(self, rec: RequestRecord, *, now: float) -> None:
        """A request's round ended: it is ready for the next frontier."""
        if rec.state in (RequestState.COMPLETE,
                         RequestState.PRESSURE_STALLED):
            return
        rec.state = RequestState.ADMITTED
        rec.ready_t = now
        self._ready.append(rec)
        self._push(now, "frontier", (False,))

    # ---- the wave executor -------------------------------------------------
    @staticmethod
    def _member_cluster_sets(plan, n_members: int, *, wave_level: bool,
                             ) -> Tuple[List[List[int]], List[List[int]]]:
        """Per-member (resident-hit, fetch) cluster lists for pinning.
        ``wave_level=True`` (never-re-form mode) gives every member the
        wave's full sets — the legacy release timing, where a shared
        working set frees only when the LAST group member completes.
        Otherwise each member gets the clusters its own ranked row
        contributed, so its exclusive pages free at its own completion."""
        if wave_level or plan.ranked is None:
            return ([list(plan.resident_hits)] * n_members,
                    [list(plan.fetch)] * n_members)
        hits_all = set(map(int, plan.resident_hits))
        fetch_all = set(map(int, plan.fetch))
        hit_sets, fetch_sets = [], []
        for k in range(n_members):
            row = set(map(int, plan.ranked[k]))
            hit_sets.append(sorted(row & hits_all))
            fetch_sets.append(sorted(row & fetch_all))
        return hit_sets, fetch_sets

    def _exec_wave(self, wave: _Wave, *, now: float,
                   starts: Sequence[float], force: bool = False,
                   cohort: Optional[_Cohort] = None) -> None:
        """Execute one wave's round frontier: reserve the wave's pool
        headroom (or park its members ``PRESSURE_STALLED``), run the
        engine data ops for the whole wave, and schedule each member's
        per-request events from its own round start.  ``starts`` is the
        per-member round start (== ``now`` for dynamically formed
        waves; the member's own round clock in never-re-form mode,
        where a cohort frontier fires at its earliest finisher)."""
        eng = self.engine
        policy = eng.policy
        members, rounds = wave.members, wave.rounds
        batch = len(members)
        self.recorder.emit(WaveEvent(
            t=now, kind="wave.form", replica=self.replica_id,
            wave_id=wave.wid, tenant=wave.tenant, size=batch,
            request_ids=wave.request_ids, rounds=tuple(rounds)))
        # members still retrieving vs. decode-only / tail-only members
        ret = [j for j in range(batch) if rounds[j] < len(members[j].plan)]
        gen_tokens = [
            members[j].plan[rounds[j]][0] if rounds[j] < len(members[j].plan)
            else (tail_gen_tokens(members[j].trace)
                  if self.include_tail else 0)
            for j in range(batch)]

        # 0a) slack-based demotion: a round whose every retrieving member
        #     is already past its deadline cannot make its SLO no matter
        #     how fast retrieval runs — spending pool pages and link
        #     bandwidth on its lookahead only starves requests that CAN
        #     still meet theirs.  The round executes (misses go to host
        #     search) but its prefetch is demoted to nothing.
        demoted = (policy.prefetches and bool(ret)
                   and all(now > members[j].deadline_t + 1e-12
                           for j in ret))
        if demoted:
            for j in ret:
                req = members[j]
                req.demoted_rounds += 1
                self._emit_req(now, "prefetch_demoted", req,
                               round_index=rounds[j], wave_id=wave.wid)

        # 0) admission: the wave's lookahead plan reserves its headroom
        #    up front (ONE reservation aggregated over the wave); if the
        #    pool cannot promise the pages, every member parks and
        #    resumes on a page-free event — the planner never silently
        #    truncates under someone else's pressure.  Pins are keyed
        #    per REQUEST: each member holds the wave's working set until
        #    its own completion event.
        plan = ticket = None
        act_q = None
        hit_pins: List[object] = []
        fetch_pins: List[object] = []
        keys = tuple(members[j] for j in ret)
        if ret:
            act_q = np.stack([members[j].cur_q for j in ret])
        if ret and policy.prefetches and not demoted:
            plan = eng.plan_lookahead(act_q, [gen_tokens[j] for j in ret],
                                      wave_key=keys)
            # per-request working sets: in reform mode each member pins
            # only the clusters ITS OWN ranked row needs, so a finished
            # request's exclusive pages free immediately instead of
            # waiting for the whole wave (never-re-form mode keeps
            # wave-level sets — the legacy group release timing)
            hit_sets, fetch_sets = self._member_cluster_sets(
                plan, len(ret), wave_level=cohort is not None)
            # pin the plan's resident hits BEFORE admission: the spill
            # that makes room for this wave's reservation must not evict
            # the clusters the plan counts on finding on-device
            hit_pins = [eng.buffer.pin_clusters(m, cs)
                        for m, cs in zip(keys, hit_sets)]
            # stalling is only sound if someone ELSE will free pages —
            # the wave's own pins must not make it wait on itself
            waitable = (eng.buffer.pages_pinned_by_others(keys) > 0
                        or bool(eng.pool.reservations)
                        or any(l.owner != "prefetch"
                               for l in eng.pool.leases.values()))
            ticket = eng.admission.admit(plan.pages_planned,
                                         owner=f"w{wave.wid}",
                                         can_wait=waitable and not force,
                                         tenant=wave.tenant,
                                         wave_id=wave.wid)
            if ticket is None:
                # a parked wave holds nothing: keeping tentative hit pins
                # would make other parked waves mutually wait on them —
                # the plan is recomputed from scratch on resume anyway
                for m, pins in zip(keys, hit_pins):
                    eng.buffer.release_pins(m, pins)
                eng.admission.park(
                    (cohort, rounds[0]) if cohort is not None else wave,
                    plan.pages_planned, tenant=wave.tenant)
                for j in ret:
                    req = members[j]
                    req.state = RequestState.PRESSURE_STALLED
                    self._emit_req(now, "pressure_stall", req,
                                   wave_id=wave.wid)
                # decode-only wave-mates need no pool pages: they must
                # not be swallowed by the park — run them as their own
                # wave right now (only dynamic waves mix tail members)
                tails = [j for j in range(batch) if j not in set(ret)]
                if tails:
                    self._exec_wave(
                        _Wave(wid=next(self._wid), t=now,
                              members=[members[j] for j in tails],
                              rounds=[rounds[j] for j in tails],
                              tenant=wave.tenant),
                        now=now, starts=[starts[j] for j in tails])
                return

        # the wave is logged only once it actually executes — a parked
        # wave dissolves and its members are re-logged with the wave
        # they eventually ride
        self.wave_log.append(wave)

        # steps 1-3 run under a release-on-exception guard: a raising
        # decode hook / transfer / retrieval must not strand the wave's
        # cluster pins or its admission reservation — the members never
        # reach their completion events (the normal release point), so
        # without the cleanup the pool shrinks forever (telint TL001;
        # regression: tests/test_analysis.py)
        try:
            # 1) lookahead prefetch keyed on the *current* queries,
            #    dispatched (async) at the frontier — in flight during
            #    generation.  A demoted round moves nothing (it only
            #    flushes queued device invalidations so the search LUT
            #    stays consistent).
            nbytes, nfetch, ev = 0, 0, None
            if ret and policy.prefetches:
                if demoted:
                    eng.buffer.flush_invalidations()
                else:
                    nbytes, nfetch, ev = eng.lookahead_ex(
                        act_q, [gen_tokens[j] for j in ret], now=now,
                        plan=plan, ticket=ticket, tenant=wave.tenant)
            self.recorder.emit(WaveEvent(
                t=now, kind="wave.dispatch", replica=self.replica_id,
                wave_id=wave.wid, tenant=wave.tenant, size=batch,
                request_ids=wave.request_ids, rounds=tuple(rounds),
                transfer_id=ev.transfer_id if ev is not None else -1,
                nbytes=nbytes))
            if plan is not None:
                # each member owns its share of the fetched set too,
                # until its own completion event
                fetch_pins = [eng.buffer.pin_clusters(m, cs)
                              for m, cs in zip(keys, fetch_sets)]

            # 1b) real decode (serve drivers): the copy dispatched above
            #     is in flight while the hook's device steps run;
            #     observed per-request DecodeEvents replace the modeled
            #     windows.  KV pressure inside the hook (acquire_paged
            #     against an exhausted slab/pool) is an admission
            #     decision, not a crash: shed what fits, park the rest
            #     PRESSURE_STALLED to rejoin on page-free.
            decode_evs: Optional[List[DecodeEvent]] = None
            if self.on_generate is not None and (ret or any(gen_tokens)):
                try:
                    evs = self._generate_with_kv_relief(
                        members, gen_tokens, rounds[0], tenant=wave.tenant)
                except PoolExhausted:
                    if cohort is not None:
                        # never-re-form mode: cohorts cannot split or
                        # dissolve, so pressure cannot shed or park
                        raise
                    self._shed_on_kv_pressure(
                        wave, keys, hit_pins, fetch_pins, ticket,
                        now=now, starts=starts)
                    return
                if evs is not None:
                    if len(evs) != batch:
                        raise ValueError(
                            f"decode hook returned {len(evs)} events for "
                            f"a wave of {batch}")
                    # match by request id, not position: a hook returning
                    # events in any order must not cross-wire the windows
                    by_id = {e.request_id: e for e in evs}
                    if len(by_id) != batch or any(m.request_id not in by_id
                                                  for m in members):
                        raise ValueError(
                            "decode events must carry exactly the wave "
                            "members' request ids")
                    decode_evs = [by_id[m.request_id] for m in members]

            # 2) rewrite -> q_out (SubQ expands to num_queries rewrites)
            res = None
            owners: List[int] = []
            q_out = None
            if ret:
                q_out_rows: List[np.ndarray] = []
                for k, j in enumerate(ret):
                    sigma = members[j].trace.rewrite_sigma
                    nq = members[j].plan[rounds[j]][1]
                    for _ in range(nq):
                        q_out_rows.append(
                            synthetic_rewrite(act_q[k][None, :], sigma,
                                              self._rng)[0]
                            if sigma > 0 else act_q[k])
                        owners.append(j)
                q_out = np.stack(q_out_rows)

                # 3) hybrid retrieval (device hits + host misses + merge)
                res = eng.retrieve(q_out, now=now, tenant=wave.tenant)
        except BaseException:
            # drop every pin the wave's members hold (hit pins taken
            # before admission, fetch pins taken above, and any earlier
            # rounds' pins — the requests are dead; their completion
            # events will never fire) and return the reservation's
            # unconsumed headroom (lookahead_ex commits on its own
            # paths; pool.cancel is idempotent so a second commit is
            # a no-op)
            for m in keys:
                eng.buffer.unpin(m)
            if ticket is not None:
                eng.admission.commit(ticket)
            raise

        # 4) per-request telemetry + event-clock scheduling
        t_transfer = nbytes / eng.cfg.hw.host_link_bw
        mean_pages = float(np.mean(eng.index.paged.cluster_num_pages))
        continuing: List[float] = []
        wave_end = now
        for j in range(batch):
            req, rnd, rs = members[j], rounds[j], starts[j]
            win = eng.llm_window_seconds(gen_tokens[j], batch)
            if decode_evs is not None and decode_evs[j].tokens > 0:
                # an event with no observed steps (the hook had nothing
                # to decode for this member) keeps the modeled window
                win = decode_evs[j].window(gen_tokens[j])
            if decode_evs is not None:
                self.recorder.emit(DecodeStep(
                    t=rs, kind="decode", replica=self.replica_id,
                    request_id=req.request_id, tenant=req.tenant,
                    wave_id=wave.wid, tokens=decode_evs[j].tokens,
                    seconds=decode_evs[j].seconds, batch=batch))
            if j not in ret:
                # decode-only / tail-only member: its "round" is one
                # generation window, then completion — the same wave
                # machinery, no special-case branch
                if win > 0:
                    self._span(req, "generate_tail", rs, rs + win,
                               wave_id=wave.wid)
                    self._push(rs, "mark", (req, RequestState.GENERATING,
                                            "generate"))
                req.complete_t = rs + win
                self._span(req, "complete", req.complete_t,
                           req.complete_t)
                self._push(req.complete_t, "mark",
                           (req, RequestState.COMPLETE, "complete"))
                wave_end = max(wave_end, req.complete_t)
                continue
            rows = [r for r, o in enumerate(owners) if o == j]
            hits = sum(len(res.hit_clusters[r]) for r in rows)
            misses = sum(len(res.missed_clusters[r]) for r in rows)
            rt = RoundTelemetry(
                round_index=rnd, batch=batch, gen_tokens=gen_tokens[j],
                t_llm_window=win,
                bytes_prefetched=nbytes // max(len(ret), 1),
                t_prefetch=t_transfer,
                hits=hits, misses=misses,
                t_host_search=misses * eng.effective_tcc(),
                t_dev_search=eng._dev_search_seconds(
                    int(hits * mean_pages)),
                t_merge=2e-5,
                wave_id=wave.wid, round_start_t=rs)
            req.result.rounds.append(rt)
            req.result.doc_ids.extend(res.doc_ids[r] for r in rows)

            gen_end = rs + rt.t_llm_window
            ready = None
            if policy.prefetches and ev is not None:
                ready = eng.transfer.ready_t(ev, rs)
            retrieve_start = (gen_end if ready is None
                              else max(gen_end, ready))
            round_end = retrieve_start + policy.search_seconds(rt, self.ctx)
            rt.round_end_t = round_end

            if policy.prefetches and not demoted:
                self._span(req, "prefetch_dispatch", rs, rs, rnd,
                           wave_id=wave.wid)
                self._push(rs, "mark",
                           (req, RequestState.PREFETCHING, "prefetch"))
            self._span(req, "generate", rs, gen_end, rnd,
                       wave_id=wave.wid)
            self._push(rs, "mark", (req, RequestState.GENERATING, "generate"))
            if retrieve_start > gen_end:
                self._span(req, "transfer_wait", gen_end, retrieve_start,
                           rnd, wave_id=wave.wid)
            self._span(req, "retrieve", retrieve_start, round_end, rnd,
                       wave_id=wave.wid)
            self._push(retrieve_start, "mark",
                       (req, RequestState.RETRIEVING, "retrieve"))
            wave_end = max(wave_end, round_end)

            req.next_round = rnd + 1
            if rnd + 1 < len(req.plan):
                req.round_start[rnd + 1] = round_end
                req.ready_t = round_end
                if cohort is not None:
                    continuing.append(round_end)
                else:
                    self._push(round_end, "ready", (req,))
            else:
                complete_t = round_end
                if self.include_tail:
                    tail_s = eng.llm_window_seconds(
                        tail_gen_tokens(req.trace), batch)
                    if decode_evs is not None and decode_evs[j].tokens > 0:
                        tail_s = decode_evs[j].window(
                            tail_gen_tokens(req.trace))
                    if tail_s > 0:
                        self._span(req, "generate_tail", round_end,
                                   round_end + tail_s, rnd,
                                   wave_id=wave.wid)
                    complete_t = round_end + tail_s
                req.complete_t = complete_t
                self._span(req, "complete", complete_t, complete_t)
                self._push(complete_t, "mark",
                           (req, RequestState.COMPLETE, "complete"))
                wave_end = max(wave_end, complete_t)

        # the wave's modeled footprint on the clock ends at its slowest
        # member's round end (future-stamped; consumers sort by t)
        self.recorder.emit(WaveEvent(
            t=wave_end, kind="wave.complete", replica=self.replica_id,
            wave_id=wave.wid, tenant=wave.tenant, size=batch,
            request_ids=wave.request_ids, rounds=tuple(rounds),
            nbytes=nbytes))

        # 5) next round's query drifts from this round's rewrite
        for j in ret:
            rows = [r for r, o in enumerate(owners) if o == j]
            members[j].cur_q = q_out[rows[0]]

        # 6) never-re-form mode: the cohort's earliest finisher opens the
        #    shared next-round frontier (dynamic waves instead schedule
        #    per-request "ready" events above)
        if cohort is not None and continuing \
                and (rounds[0] + 1) not in cohort.scheduled_rounds:
            cohort.scheduled_rounds.add(rounds[0] + 1)
            self._push(min(continuing), "round", (cohort, rounds[0] + 1))

    def _generate_with_kv_relief(self, members, gen_tokens, rnd: int, *,
                                 tenant: str):
        """Run the decode hook; on a *pool-bytes* shortfall
        (``PoolExhausted.bytes_needed > 0``) evict cold unpinned
        prefetch residency toward the failed lease's size and retry
        once.  With paged decode the KV bytes return to the pool
        between waves, so warm prefetch residency physically creeps
        into them (the dense bucket held its pages forever and never
        exposed this) — the cold tail is exactly what ``plannable_pages``
        already promised generation state could reclaim.  Slab
        free-list exhaustion (``bytes_needed == 0``) is not curable by
        eviction and propagates to the shed/park path, as does a
        second failure after the spill."""
        try:
            return self.on_generate(list(members), list(gen_tokens), rnd)
        except PoolExhausted as exc:
            needed = getattr(exc, "bytes_needed", 0)
            if needed <= 0:
                raise
            eng = self.engine
            # the lease draws on *reservable* pages (free minus in-flight
            # admission reservations), so spill until the free list
            # covers the lease on top of everything already reserved
            pages = (-(-needed // eng.pool.page_nbytes)
                     + eng.pool.reserved_pages())
            eng.cache.make_room(eng.buffer, pages,
                                protect=eng.admission.spill_protect(tenant))
            return self.on_generate(list(members), list(gen_tokens), rnd)

    def _shed_on_kv_pressure(self, wave: _Wave, keys, hit_pins, fetch_pins,
                             ticket, *, now: float,
                             starts: Sequence[float]) -> None:
        """The decode hook's ``acquire_paged`` failed at this wave's
        round frontier: the KV slab/pool cannot hold the whole batch's
        block tables.  Shed half — the older half re-executes right now
        as its own smaller wave (re-planned from scratch; still too big
        and it sheds again, down to one), the younger half parks
        ``PRESSURE_STALLED`` and rejoins on the page-free event the
        running half's ``release_paged`` fires.  A singleton wave has
        no half to run: it parks whole — sound exactly when some OTHER
        holder will free pages through a future event (another wave's
        pins, an open KV lease, an outstanding reservation; checked
        after dropping this wave's own holds so they don't count as
        their own rescue).  With no such holder the exhaustion is
        structural and the original ``PoolExhausted`` propagates.  The
        original wave dissolves exactly like an admission park: this
        round's tentative pins are dropped, the reservation's remainder
        is returned, and the wave leaves the log (it never executed)."""
        eng = self.engine
        for m, pins in zip(keys, hit_pins):
            eng.buffer.release_pins(m, pins)
        for m, pins in zip(keys, fetch_pins):
            eng.buffer.release_pins(m, pins)
        if ticket is not None:
            # lookahead_ex commits on its own paths; pool.cancel is
            # idempotent so a second commit is a no-op
            eng.admission.commit(ticket)
        self.wave_log.remove(wave)
        keep = len(wave.members) // 2
        if keep == 0 and not eng.admission.holds_pending_release():
            raise       # re-raises the in-flight PoolExhausted
        parked = _Wave(wid=wave.wid, t=now, members=wave.members[keep:],
                       rounds=wave.rounds[keep:], tenant=wave.tenant,
                       kv_parked=True)
        eng.admission.park(parked, len(parked.members), tenant=wave.tenant)
        for m in parked.members:
            m.state = RequestState.PRESSURE_STALLED
            self._emit_req(now, "pressure_stall", m, wave_id=parked.wid)
        if keep:
            self._exec_wave(
                _Wave(wid=next(self._wid), t=now,
                      members=wave.members[:keep],
                      rounds=wave.rounds[:keep], tenant=wave.tenant),
                now=now, starts=list(starts[:keep]))

    # ---- admission / memory-pressure plumbing ------------------------------
    def _on_pages_freed(self, pages: int) -> None:
        """Pool subscriber: pages returned to the free list wake parked
        requests (runs inside whichever event handler freed them)."""
        if self.engine.admission.parked and not self._retry_scheduled:
            self._retry_scheduled = True
            self._push(self._now, "retry", ())

    def _retry_parked(self, now: float, force: bool = False) -> None:
        """Wake every parked request.  The stall interval becomes a
        ``pressure_stall`` span and the round restarts from the resume
        time, so admission delay shows up in admit→complete latency.
        Dynamically-formed waves dissolve on wake: their members rejoin
        whatever wave the resume frontier forms (possibly alongside
        requests admitted while they slept)."""
        woke_ready = False
        for key, _npages in self.engine.admission.unpark_all():
            if isinstance(key, _Wave):
                for j, m in enumerate(key.members):
                    # KV-parked waves wake EVERY member: their decode
                    # (tail members included) never ran.  Admission
                    # parks ran tail members as their own wave before
                    # parking, so those stay skipped.
                    if not key.kv_parked and key.rounds[j] >= len(m.plan):
                        continue
                    rs = m.ready_t
                    if now > rs + 1e-15:
                        self._span(m, "pressure_stall", rs, now,
                                   key.rounds[j], wave_id=key.wid)
                    m.ready_t = now
                    m.state = RequestState.ADMITTED
                    self._emit_req(now, "pressure_resume", m)
                    self._ready.append(m)
                    woke_ready = True
            else:
                g, rnd = key
                for m in g.members:
                    if rnd >= len(m.plan):
                        continue
                    rs = m.round_start[rnd]
                    if now > rs + 1e-15:
                        self._span(m, "pressure_stall", rs, now, rnd)
                        m.round_start[rnd] = now
                    m.state = RequestState.ADMITTED
                    self._emit_req(now, "pressure_resume", m)
                self._push(now, "round", (g, rnd, force))
        if woke_ready:
            self._push(now, "frontier", (force,))

    def _on_member_complete(self, rec: RequestRecord, t: float) -> None:
        """Completion event: the request releases its own cluster pins
        (re-keyed from wave-id to request-id — pages a whole wave
        shared become evictable when their LAST holder completes), and
        the per-request completion hook fires."""
        freed = self.engine.buffer.unpin(rec)
        if self.on_complete is not None:
            self.on_complete(rec)
        # wake parked requests only when this release actually made
        # pages evictable (the LAST pin holder of a shared working set
        # dropping out) — an intermediate wave-mate's completion frees
        # nothing and must not thrash park/re-park cycles
        if freed and self.engine.admission.parked \
                and not self._retry_scheduled:
            self._retry_scheduled = True
            self._push(t, "retry", ())
