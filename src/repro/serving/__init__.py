from repro.serving.api import (RagRequest, RagResponse, ReplicaTelemetry,
                               ServerTelemetry, TeleRAGServer,
                               TenantTelemetry, WaveDispatch,
                               summarize_latency)
from repro.serving.engine import (EngineConfig, RequestResult, RoundTelemetry,
                                  TeleRAGEngine)
from repro.serving.chunk_kv import (ChunkKVCache, ChunkKVStats,
                                    ChunkResidency)
from repro.serving.decode import DecodeRunner, supports_paged_decode
from repro.serving.kv_cache import (CacheLease, KVCacheManager, KVPageSlab,
                                    PagedCacheLease)
from repro.serving.pipelines import (GlobalBatchReport,
                                     MultiReplicaOrchestrator,
                                     PipelineExecutor, PIPELINE_NAMES)
from repro.serving.policies import (LatencyContext, RetrievalPolicy,
                                    get_policy, policy_names,
                                    register_policy)
from repro.serving.runtime import (DecodeEvent, RequestRecord, RequestState,
                                   RetrievalRuntime, Span, latency_summary)
from repro.serving.sampler import sample
from repro.serving.trace import (PIPELINES, RequestTrace, StageTrace,
                                 calibration_windows, make_trace, make_traces)

__all__ = [
    "RagRequest", "RagResponse", "ReplicaTelemetry", "ServerTelemetry",
    "TeleRAGServer", "TenantTelemetry", "WaveDispatch", "summarize_latency",
    "EngineConfig", "RequestResult", "RoundTelemetry", "TeleRAGEngine",
    "ChunkKVCache", "ChunkKVStats", "ChunkResidency",
    "DecodeRunner", "supports_paged_decode",
    "CacheLease", "KVCacheManager", "KVPageSlab", "PagedCacheLease",
    "GlobalBatchReport", "MultiReplicaOrchestrator", "PipelineExecutor",
    "PIPELINE_NAMES",
    "LatencyContext", "RetrievalPolicy", "get_policy", "policy_names",
    "register_policy",
    "DecodeEvent", "RequestRecord", "RequestState", "RetrievalRuntime",
    "Span", "latency_summary",
    "sample",
    "PIPELINES", "RequestTrace", "StageTrace", "calibration_windows",
    "make_trace", "make_traces",
]
