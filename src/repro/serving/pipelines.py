"""Legacy serving facades, now thin DEPRECATED shims over the unified
front-end in ``serving/api.py``.

``PipelineExecutor`` admits a whole micro-batch at t=0 into an
event-driven ``RetrievalRuntime`` running in the degenerate
*never-re-form* mode (``reform=False``: the admission group stays the
wave for every round) and drains it — byte-identical results to the
pre-runtime lockstep loop.

``MultiReplicaOrchestrator.run_global_batch`` routes through
``TeleRAGServer``: one simultaneous-arrival wave, grouped and routed by
the same ``SchedulerPolicy``, executed on the server's shared global
event clock.  Because the server serializes micro-batches within a
replica (with ``end_batch`` consolidation between them, exactly like the
old serial drain) the shim reproduces the legacy doc ids and round
telemetry to 1e-6 — pinned in tests/test_api.py.  New code should call
``TeleRAGServer.submit``/``drain`` directly: it is the same machinery
minus the blocking, closed-loop shape.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ivf import IVFIndex
from repro.core.schedulers import (ReplicaHealth, SchedulerPolicy,
                                   TeleRAGScheduler)
from repro.serving.api import RagRequest, TeleRAGServer
from repro.serving.engine import EngineConfig, RequestResult, TeleRAGEngine
from repro.serving.runtime import (RequestRecord, RetrievalRuntime,
                                   round_plan, tail_gen_tokens)
from repro.serving.trace import RequestTrace

PIPELINE_NAMES = ("hyde", "subq", "iter", "irg", "flare", "self_rag")


class PipelineExecutor:
    """DEPRECATED: executes micro-batches of traced requests on a single
    engine.  Use ``TeleRAGServer`` (serving/api.py) for new code."""

    def __init__(self, engine: TeleRAGEngine):
        warnings.warn(
            "PipelineExecutor is deprecated; use TeleRAGServer "
            "(repro.serving.api) — same machinery, typed "
            "request/response lifecycle", DeprecationWarning, stacklevel=2)
        self.engine = engine
        # never-re-form mode: the admission group stays the wave for
        # every round, which pins the legacy lockstep results exactly
        self.runtime = RetrievalRuntime(engine, reform=False)
        self.last_records: List[RequestRecord] = []

    def execute_batch(self, q_in: np.ndarray, traces: Sequence[RequestTrace],
                      ) -> List[RequestResult]:
        """q_in: [B, d] initial query embeddings; one trace per query."""
        assert q_in.shape[0] == len(traces)
        recs = [self.runtime.submit(q_in[i], traces[i])
                for i in range(len(traces))]
        self.runtime.run()
        self.last_records = recs
        return [r.result for r in recs]

    @staticmethod
    def _round_plan(trace: RequestTrace) -> List[Tuple[int, int]]:
        """[(gen_tokens_before_retrieval, num_queries), ...] per round."""
        return round_plan(trace)

    @staticmethod
    def tail_gen_tokens(trace: RequestTrace) -> int:
        """Generation after the last retrieval (counts once per request)."""
        return tail_gen_tokens(trace)


# ---------------------------------------------------------------------------
# Multi-replica orchestration (Fig. 7) — legacy report + shim
# ---------------------------------------------------------------------------


@dataclass
class GlobalBatchReport:
    per_replica_results: Dict[int, List[RequestResult]]
    schedule_overhead_s: float
    assignments: List[Tuple[int, int, int]]      # (batch_idx, replica, overlap)
    requeued: List[int] = field(default_factory=list)
    records: List[RequestRecord] = field(default_factory=list)
    submission_ids: List[int] = field(default_factory=list)

    def all_results(self) -> List[RequestResult]:
        """All requests' results in *submission order* (when the report
        carries it) — never in replica-dict iteration order."""
        out: List[RequestResult] = []
        for rs in self.per_replica_results.values():
            out.extend(rs)
        if self.submission_ids:
            pos = {rid: i for i, rid in enumerate(self.submission_ids)}
            out.sort(key=lambda r: pos.get(r.request_id, len(pos)))
        return out


class MultiReplicaOrchestrator:
    """DEPRECATED facade: the Fig.-7 orchestration now lives in
    ``TeleRAGServer`` (a continuous cross-replica dispatcher on a shared
    event clock).  This class keeps the old constructor surface and a
    ``run_global_batch`` shim for closed-loop batch replay; reach the
    server itself at ``.server`` (or construct one directly)."""

    def __init__(self, index: IVFIndex, cfg: EngineConfig, num_replicas: int,
                 arch=None, *, scheduler: Optional[SchedulerPolicy] = None,
                 use_prefetch_sched: bool = True,
                 use_cache_sched: bool = True):
        self.server = TeleRAGServer(
            index, cfg, num_replicas, arch,
            scheduler=scheduler or TeleRAGScheduler(
                similarity_grouping=use_prefetch_sched,
                cache_aware=use_cache_sched))
        self.index = index
        self.health = ReplicaHealth()

    @property
    def replicas(self) -> List[TeleRAGEngine]:
        """The server's replica engines (legacy attribute name)."""
        return self.server.engines

    @property
    def scheduler(self) -> SchedulerPolicy:
        """The server's SchedulerPolicy (legacy attribute name)."""
        return self.server.scheduler

    @property
    def nprobe_for_sched(self) -> int:
        """Clusters probed per query for routing hints (legacy name)."""
        return self.server.nprobe_for_sched

    def run_global_batch(self, q_in: np.ndarray,
                         traces: Sequence[RequestTrace], *,
                         micro_batch: int = 4,
                         dead_replicas: Optional[set] = None,
                         ) -> GlobalBatchReport:
        """DEPRECATED: serve one simultaneous-arrival wave through the
        server and translate the responses back into the legacy
        ``GlobalBatchReport`` shape (doc ids exact, telemetry pinned to
        1e-6 against the old serial drain in tests/test_api.py)."""
        warnings.warn(
            "run_global_batch is deprecated; submit RagRequests to "
            "TeleRAGServer and drain() — closed-loop batch replay is one "
            "simultaneous-arrival wave", DeprecationWarning, stacklevel=2)
        srv = self.server
        prev_mb, srv.micro_batch = srv.micro_batch, micro_batch
        # the per-call argument ADDS to replicas already mark_dead()ed on
        # the server — it must never silently resurrect one of them
        prev_dead = set(srv.dead)
        srv.dead = prev_dead | set(dead_replicas or ())
        wave_start = len(srv.wave_log)
        try:
            responses = srv.serve([RagRequest(q=q_in[i], trace=traces[i])
                                   for i in range(len(traces))])
        finally:
            srv.micro_batch, srv.dead = prev_mb, prev_dead
        waves = srv.wave_log[wave_start:]
        dead = prev_dead | set(dead_replicas or ())
        per_replica: Dict[int, List[RequestResult]] = {
            i: [] for i in range(len(srv.engines)) if i not in dead}
        for resp, rec in zip(responses, srv.last_records):
            per_replica.setdefault(resp.replica, []).append(rec.result)
        return GlobalBatchReport(
            per_replica_results=per_replica,
            schedule_overhead_s=sum(w.sched_overhead_s for w in waves),
            assignments=[a for w in waves for a in w.assignments],
            requeued=[b for w in waves for b in w.requeued],
            records=list(srv.last_records),
            submission_ids=[t.request_id for t in traces])
