"""The six RAG pipelines (paper §5.1, Fig. 8) as trace-driven executors,
plus the multi-replica orchestration with both schedulers (§4.2).

``PipelineExecutor`` walks each request's stage plan against one engine
replica: generation windows advance the modeled clock AND trigger
lookahead prefetch; retrieval stages run the real hybrid search; multi-
round pipelines reuse earlier prefetches incrementally (§4.3).

``MultiReplicaOrchestrator`` implements Fig. 7: the prefetching scheduler
groups the global batch into micro-batches by embedding similarity, the
cache-aware scheduler routes micro-batches to replicas by cached-cluster
overlap, with deadline-based straggler re-queue.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.embedder import synthetic_rewrite
from repro.core.ivf import IVFIndex, probe
from repro.core.schedulers import (ReplicaHealth, assign_to_replicas,
                                   group_queries)
from repro.serving.engine import (EngineConfig, RequestResult, RoundTelemetry,
                                  TeleRAGEngine)
from repro.serving.trace import RequestTrace

PIPELINE_NAMES = ("hyde", "subq", "iter", "irg", "flare", "self_rag")


class PipelineExecutor:
    """Executes micro-batches of traced requests on a single engine."""

    def __init__(self, engine: TeleRAGEngine):
        self.engine = engine
        self._rng = np.random.default_rng(engine.cfg.seed + 1)

    def execute_batch(self, q_in: np.ndarray, traces: Sequence[RequestTrace],
                      ) -> List[RequestResult]:
        """q_in: [B, d] initial query embeddings; one trace per query."""
        B = q_in.shape[0]
        assert B == len(traces)
        results = [RequestResult(t.request_id, t.pipeline) for t in traces]
        cur_q = q_in.copy()
        max_rounds = max(t.rounds for t in traces)
        # stage cursor per request: list of (gen_tokens_before, num_queries)
        plans = [self._round_plan(t) for t in traces]

        for rnd in range(max_rounds):
            active = [b for b in range(B) if rnd < len(plans[b])]
            if not active:
                break
            gen_tokens = [plans[b][rnd][0] for b in active]
            act_q = cur_q[active]

            # 1) lookahead prefetch keyed on the *current* query (q_in of
            #    this round), dispatched before the generation window
            nbytes, nfetch = self.engine.lookahead(act_q, gen_tokens)

            # 2) pre-retrieval generation window (modeled clock; the real
            #    decode overlap is exercised by examples/serve_rag.py)
            t_llm = [self.engine.llm_window_seconds(g, len(active))
                     for g in gen_tokens]

            # 3) rewrite -> q_out (SubQ expands to num_queries rewrites)
            q_out_rows: List[np.ndarray] = []
            owners: List[int] = []
            for j, b in enumerate(active):
                sigma = traces[b].rewrite_sigma
                nq = plans[b][rnd][1]
                for _ in range(nq):
                    q_out_rows.append(
                        synthetic_rewrite(act_q[j][None, :], sigma,
                                          self._rng)[0]
                        if sigma > 0 else act_q[j])
                    owners.append(b)
            q_out = np.stack(q_out_rows)

            # 4) hybrid retrieval (device hits + host misses + merge)
            res = self.engine.retrieve(q_out)

            # 5) telemetry per request
            t_transfer = nbytes / self.engine.cfg.hw.host_link_bw
            for j, b in enumerate(active):
                rows = [i for i, o in enumerate(owners) if o == b]
                hits = sum(len(res.hit_clusters[i]) for i in rows)
                misses = sum(len(res.missed_clusters[i]) for i in rows)
                pages_hit = hits * float(np.mean(
                    self.engine.index.paged.cluster_num_pages))
                rt = RoundTelemetry(
                    round_index=rnd, batch=len(active),
                    gen_tokens=gen_tokens[j],
                    t_llm_window=t_llm[j],
                    bytes_prefetched=nbytes // max(len(active), 1),
                    t_prefetch=t_transfer,
                    hits=hits, misses=misses,
                    t_host_search=misses * self.engine.effective_tcc(),
                    t_dev_search=self.engine._dev_search_seconds(
                        int(pages_hit)),
                    t_merge=2e-5)
                results[b].rounds.append(rt)
                results[b].doc_ids.extend(res.doc_ids[i] for i in rows)

            # 6) next round's query drifts from this round's rewrite
            for j, b in enumerate(active):
                rows = [i for i, o in enumerate(owners) if o == b]
                cur_q[b] = q_out[rows[0]]

        self.engine.end_batch()
        return results

    @staticmethod
    def _round_plan(trace: RequestTrace) -> List[Tuple[int, int]]:
        """[(gen_tokens_before_retrieval, num_queries), ...] per round."""
        plan: List[Tuple[int, int]] = []
        acc = 0
        for s in trace.stages:
            if s.kind == "retrieve":
                plan.append((acc, s.num_queries))
                acc = 0
            else:
                acc += s.gen_tokens
        return plan

    @staticmethod
    def tail_gen_tokens(trace: RequestTrace) -> int:
        """Generation after the last retrieval (counts once per request)."""
        acc = 0
        for s in trace.stages:
            acc = 0 if s.kind == "retrieve" else acc + s.gen_tokens
        return acc


# ---------------------------------------------------------------------------
# Multi-replica orchestration (Fig. 7)
# ---------------------------------------------------------------------------


@dataclass
class GlobalBatchReport:
    per_replica_results: Dict[int, List[RequestResult]]
    schedule_overhead_s: float
    assignments: List[Tuple[int, int, int]]      # (batch_idx, replica, overlap)
    requeued: List[int] = field(default_factory=list)

    def all_results(self) -> List[RequestResult]:
        out: List[RequestResult] = []
        for rs in self.per_replica_results.values():
            out.extend(rs)
        return out


class MultiReplicaOrchestrator:
    def __init__(self, index: IVFIndex, cfg: EngineConfig, num_replicas: int,
                 arch=None, *, use_prefetch_sched: bool = True,
                 use_cache_sched: bool = True):
        self.index = index
        self.replicas = [TeleRAGEngine(index, cfg, arch)
                         for _ in range(num_replicas)]
        self.execs = [PipelineExecutor(e) for e in self.replicas]
        self.use_prefetch_sched = use_prefetch_sched
        self.use_cache_sched = use_cache_sched
        self.health = ReplicaHealth()
        self.nprobe_for_sched = min(64, index.num_clusters)

    def run_global_batch(self, q_in: np.ndarray,
                         traces: Sequence[RequestTrace], *,
                         micro_batch: int = 4,
                         dead_replicas: Optional[set] = None,
                         ) -> GlobalBatchReport:
        t0 = time.perf_counter()
        B = q_in.shape[0]
        if self.use_prefetch_sched:
            groups = group_queries(q_in, micro_batch)
        else:
            groups = [list(range(i, min(i + micro_batch, B)))
                      for i in range(0, B, micro_batch)]

        if self.use_cache_sched:
            batch_clusters = []
            for g in groups:
                ranked = probe(q_in[g], self.index, self.nprobe_for_sched)
                batch_clusters.append(set(int(c) for r in ranked for c in r))
            caches = [e.buffer.resident_clusters() for e in self.replicas]
            assigns = assign_to_replicas(batch_clusters, caches)
        else:
            from repro.core.schedulers import Assignment
            assigns = [Assignment(replica=i % len(self.replicas),
                                  batch_index=i, overlap=0)
                       for i in range(len(groups))]
        sched_s = time.perf_counter() - t0

        # straggler handling: re-queue micro-batches from dead replicas
        dead = dead_replicas or set()
        requeued: List[int] = []
        alive = [i for i in range(len(self.replicas)) if i not in dead]
        if not alive:
            raise RuntimeError("no healthy replicas")
        fixed = []
        for a in assigns:
            if a.replica in dead:
                requeued.append(a.batch_index)
                a = type(a)(replica=alive[a.batch_index % len(alive)],
                            batch_index=a.batch_index, overlap=0)
            fixed.append(a)

        per_replica: Dict[int, List[RequestResult]] = {i: [] for i in alive}
        for a in fixed:
            g = groups[a.batch_index]
            res = self.execs[a.replica].execute_batch(
                q_in[g], [traces[i] for i in g])
            per_replica[a.replica].extend(res)
        return GlobalBatchReport(
            per_replica_results=per_replica,
            schedule_overhead_s=sched_s,
            assignments=[(a.batch_index, a.replica, a.overlap) for a in fixed],
            requeued=requeued)
