"""The six RAG pipelines (paper §5.1, Fig. 8) as trace-driven executors,
plus the multi-replica orchestration with both schedulers (§4.2).

``PipelineExecutor`` is the legacy lockstep facade: it admits a whole
micro-batch at t=0 into an event-driven ``RetrievalRuntime`` and drains
it, which reproduces the old ``execute_batch`` results (same engine ops,
same RNG stream) while the actual execution is the continuous-batching
state machine in ``serving/runtime.py``.

``MultiReplicaOrchestrator`` implements Fig. 7 through a pluggable
``SchedulerPolicy``: micro-batch formation (similarity grouping) and
replica routing (cached-cluster overlap) are one strategy object, with
deadline-based straggler re-queue on top.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.ivf import IVFIndex, probe
from repro.core.schedulers import (ReplicaHealth, SchedulerPolicy,
                                   TeleRAGScheduler)
from repro.serving.engine import EngineConfig, RequestResult, TeleRAGEngine
from repro.serving.runtime import (RequestRecord, RetrievalRuntime,
                                   round_plan, tail_gen_tokens)
from repro.serving.trace import RequestTrace

PIPELINE_NAMES = ("hyde", "subq", "iter", "irg", "flare", "self_rag")


class PipelineExecutor:
    """Executes micro-batches of traced requests on a single engine."""

    def __init__(self, engine: TeleRAGEngine):
        self.engine = engine
        self.runtime = RetrievalRuntime(engine)
        self.last_records: List[RequestRecord] = []

    def execute_batch(self, q_in: np.ndarray, traces: Sequence[RequestTrace],
                      ) -> List[RequestResult]:
        """q_in: [B, d] initial query embeddings; one trace per query."""
        assert q_in.shape[0] == len(traces)
        recs = [self.runtime.submit(q_in[i], traces[i])
                for i in range(len(traces))]
        self.runtime.run()
        self.last_records = recs
        return [r.result for r in recs]

    @staticmethod
    def _round_plan(trace: RequestTrace) -> List[Tuple[int, int]]:
        """[(gen_tokens_before_retrieval, num_queries), ...] per round."""
        return round_plan(trace)

    @staticmethod
    def tail_gen_tokens(trace: RequestTrace) -> int:
        """Generation after the last retrieval (counts once per request)."""
        return tail_gen_tokens(trace)


# ---------------------------------------------------------------------------
# Multi-replica orchestration (Fig. 7)
# ---------------------------------------------------------------------------


@dataclass
class GlobalBatchReport:
    per_replica_results: Dict[int, List[RequestResult]]
    schedule_overhead_s: float
    assignments: List[Tuple[int, int, int]]      # (batch_idx, replica, overlap)
    requeued: List[int] = field(default_factory=list)
    records: List[RequestRecord] = field(default_factory=list)

    def all_results(self) -> List[RequestResult]:
        out: List[RequestResult] = []
        for rs in self.per_replica_results.values():
            out.extend(rs)
        return out


class MultiReplicaOrchestrator:
    def __init__(self, index: IVFIndex, cfg: EngineConfig, num_replicas: int,
                 arch=None, *, scheduler: Optional[SchedulerPolicy] = None,
                 use_prefetch_sched: bool = True,
                 use_cache_sched: bool = True):
        self.index = index
        self.replicas = [TeleRAGEngine(index, cfg, arch)
                         for _ in range(num_replicas)]
        self.execs = [PipelineExecutor(e) for e in self.replicas]
        self.scheduler = scheduler or TeleRAGScheduler(
            similarity_grouping=use_prefetch_sched,
            cache_aware=use_cache_sched)
        self.health = ReplicaHealth()
        self.nprobe_for_sched = min(64, index.num_clusters)

    def run_global_batch(self, q_in: np.ndarray,
                         traces: Sequence[RequestTrace], *,
                         micro_batch: int = 4,
                         dead_replicas: Optional[set] = None,
                         ) -> GlobalBatchReport:
        t0 = time.perf_counter()
        groups = self.scheduler.group(q_in, micro_batch)

        if self.scheduler.needs_cluster_hints:
            batch_clusters = []
            for g in groups:
                ranked = probe(q_in[g], self.index, self.nprobe_for_sched)
                batch_clusters.append(set(int(c) for r in ranked for c in r))
        else:
            batch_clusters = [set() for _ in groups]
        caches = [e.buffer.resident_clusters() for e in self.replicas]
        # routing sees real per-replica memory state: ledger occupancy
        # (weights + prefetch pages + KV leases) breaks overlap ties
        # toward the replica with the most free HBM
        occupancy = [e.ledger.occupancy() for e in self.replicas]
        assigns = self.scheduler.assign(batch_clusters, caches,
                                        occupancy=occupancy)
        sched_s = time.perf_counter() - t0

        # straggler handling: re-queue micro-batches from dead replicas
        dead = dead_replicas or set()
        requeued: List[int] = []
        alive = [i for i in range(len(self.replicas)) if i not in dead]
        if not alive:
            raise RuntimeError("no healthy replicas")
        fixed = []
        for a in assigns:
            if a.replica in dead:
                requeued.append(a.batch_index)
                a = type(a)(replica=alive[a.batch_index % len(alive)],
                            batch_index=a.batch_index, overlap=0)
            fixed.append(a)

        per_replica: Dict[int, List[RequestResult]] = {i: [] for i in alive}
        records: List[RequestRecord] = []
        for a in fixed:
            g = groups[a.batch_index]
            res = self.execs[a.replica].execute_batch(
                q_in[g], [traces[i] for i in g])
            per_replica[a.replica].extend(res)
            records.extend(self.execs[a.replica].last_records)
        return GlobalBatchReport(
            per_replica_results=per_replica,
            schedule_overhead_s=sched_s,
            assignments=[(a.batch_index, a.replica, a.overlap) for a in fixed],
            requeued=requeued,
            records=records)
