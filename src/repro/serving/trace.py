"""Trace-driven benchmarking (paper §5.2 "Benchmark methodology").

The paper records each pipeline's per-stage input/output texts once (via
GPT-3.5) and then replays *real* LLM inference stopping at the recorded
output lengths — fixing the decoding workload so systems compare fairly.
We generate equivalent synthetic traces: per pipeline, a seeded sample of
stage sequences with generation lengths drawn from pipeline-specific
distributions, plus the rewrite strength that drives q_out drift.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.overlap import PIPELINE_SIGMA

PIPELINES = ("hyde", "subq", "iter", "irg", "flare", "self_rag")


@dataclass
class StageTrace:
    kind: str                   # "generate" | "retrieve" | "judge"
    gen_tokens: int = 0         # decode steps for generate/judge stages
    num_queries: int = 1        # parallel retrievals (SubQ sub-questions)


@dataclass
class RequestTrace:
    pipeline: str
    request_id: int
    stages: List[StageTrace]
    rewrite_sigma: float
    prompt_tokens: int = 64

    @property
    def rounds(self) -> int:
        """Number of retrieval rounds in the trace."""
        return sum(1 for s in self.stages if s.kind == "retrieve")

    @property
    def total_gen_tokens(self) -> int:
        """Decode tokens summed over every generate/judge stage."""
        return sum(s.gen_tokens for s in self.stages)

    def pre_retrieval_tokens(self) -> List[int]:
        """Generation tokens in each window that precedes a retrieval —
        the lookahead windows t_LLM (used for budget calibration)."""
        wins, acc = [], 0
        for s in self.stages:
            if s.kind == "retrieve":
                wins.append(acc)
                acc = 0
            else:
                acc += s.gen_tokens
        return wins or [0]


def _geo(rng: np.random.Generator, mean: float, lo: int = 4) -> int:
    return int(max(lo, rng.geometric(1.0 / max(mean, 1.0))))


def make_trace(pipeline: str, request_id: int, rng: np.random.Generator,
               length_scale: float = 1.0) -> RequestTrace:
    """One request's stage plan, mirroring Fig. 8's pipeline structures."""
    s = lambda m: _geo(rng, m * length_scale)
    st: List[StageTrace] = []
    if pipeline == "hyde":
        # hypothetical paragraph -> retrieval -> answer
        st = [StageTrace("generate", s(128)), StageTrace("retrieve"),
              StageTrace("generate", s(96))]
    elif pipeline == "subq":
        # 3-4 sub-questions generated, batched retrieval, one answer
        nq = int(rng.integers(3, 5))
        st = [StageTrace("generate", s(24) * nq),
              StageTrace("retrieve", num_queries=nq),
              StageTrace("generate", s(128))]
    elif pipeline == "iter":
        # iterative narrowing with judge, 2-3 iterations
        for _ in range(int(rng.integers(2, 4))):
            st += [StageTrace("generate", s(32)), StageTrace("retrieve"),
                   StageTrace("generate", s(64)), StageTrace("judge", s(8))]
    elif pipeline == "irg":
        # Iter-RetGen: exactly 3 retrieve+generate rounds, short outputs
        for _ in range(3):
            st += [StageTrace("retrieve"), StageTrace("generate", s(48))]
        # first round has no preceding generation window: prefetch uses the
        # prompt embedding itself (paper: post-retrieval generation serves
        # as the lookahead window for the next round)
    elif pipeline == "flare":
        # confidence-triggered retrieval per upcoming sentence
        for _ in range(int(rng.integers(2, 5))):
            st += [StageTrace("generate", s(28)), StageTrace("retrieve")]
        st.append(StageTrace("generate", s(48)))
    elif pipeline == "self_rag":
        # judge decides to retrieve; generate; self-critique
        st = [StageTrace("judge", s(8)), StageTrace("retrieve"),
              StageTrace("generate", s(96)), StageTrace("judge", s(16))]
    else:
        raise KeyError(pipeline)
    return RequestTrace(pipeline=pipeline, request_id=request_id, stages=st,
                        rewrite_sigma=PIPELINE_SIGMA[pipeline],
                        prompt_tokens=_geo(rng, 48 * length_scale, lo=8))


def make_traces(pipeline: str, n: int, *, seed: int = 0,
                length_scale: float = 1.0) -> List[RequestTrace]:
    """``n`` seeded traces for one pipeline (request ids 0..n-1 from
    one RNG stream, so a (pipeline, seed) pair fixes the workload)."""
    rng = np.random.default_rng(seed)
    return [make_trace(pipeline, i, rng, length_scale) for i in range(n)]


def calibration_windows(pipeline: str, n: int = 64, *, seed: int = 7,
                        length_scale: float = 1.0) -> List[int]:
    """The 64-sample profile the paper uses to set per-pipeline budgets."""
    toks: List[int] = []
    for t in make_traces(pipeline, n, seed=seed, length_scale=length_scale):
        toks.extend(t.pre_retrieval_tokens())
    return toks
