"""KV/state cache manager for the serving engine.

Allocates one decode cache per (batch, max_len) bucket and recycles it
across requests (zeroed logically via position resets — stale entries are
masked by per-sequence ``pos``). For SSM archs the "cache" is the O(1)
recurrent state, which must be explicitly zeroed between requests.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as tf


@dataclass
class CacheLease:
    cache: dict
    batch: int
    max_len: int


class KVCacheManager:
    def __init__(self, cfg: ArchConfig, dtype=jnp.bfloat16):
        self.cfg = cfg
        self.dtype = dtype
        self._pool: Dict[Tuple[int, int], dict] = {}

    def acquire(self, batch: int, max_len: int, *, fresh: bool = False,
                ) -> CacheLease:
        key = (batch, max_len)
        cache = self._pool.pop(key, None)
        if cache is None:
            cache = tf.init_cache(self.cfg, batch, max_len, self.dtype)
        elif fresh or tf.family_kind(self.cfg) != "attn":
            # recurrent state must not leak across requests; attention
            # caches are masked by pos so zeroing is optional
            cache = jax.tree.map(lambda a: jnp.zeros_like(a), cache)
        return CacheLease(cache=cache, batch=batch, max_len=max_len)

    def release(self, lease: CacheLease) -> None:
        self._pool[(lease.batch, lease.max_len)] = lease.cache

    def nbytes(self, batch: int, max_len: int) -> int:
        shapes = jax.eval_shape(
            lambda: tf.init_cache(self.cfg, batch, max_len, self.dtype))
        return sum(s.size * s.dtype.itemsize for s in jax.tree.leaves(shapes))
