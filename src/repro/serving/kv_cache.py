"""KV/state cache manager for the serving engine.

Allocates one decode cache per (batch, max_len) bucket and recycles it
across requests (zeroed logically via position resets — stale entries are
masked by per-sequence ``pos``). For SSM archs the "cache" is the O(1)
recurrent state, which must be explicitly zeroed between requests.

When constructed over a ``DevicePagePool`` the manager stops being a
memory island: every live lease charges its exact tensor bytes to the
replica's ``MemoryLedger`` (category ``"kv"``) and takes page slots out
of the same pool the prefetch buffer draws from, so generation state
and retrieval state compete for — and are accounted against — the same
HBM.  A recycled bucket keeps its pool lease (the bytes stay resident);
``acquire`` of a new bucket that the pool cannot fit raises
``PoolExhausted`` rather than silently overcommitting.

Leases are **tenant-tagged**: ``acquire(..., tenant=...)`` charges the
bucket's bytes to the owning request's tenant on the ledger
(``tenant:<name>`` keys now include KV, not just prefetch pages) and in
the pool's per-tenant occupancy; a recycled bucket is re-attributed to
whichever tenant reuses it.  ``ServerTelemetry.tenants`` surfaces the
per-tenant KV footprint.

**Paged mode** (``init_paged``/``acquire_paged``) replaces the
contiguous per-bucket cache with block-table leases over one shared KV
page slab: a ``PagedCacheLease`` is a [batch, max_blocks] table of slab
page slots plus per-sequence lengths — exactly the operands
``kernels.ops.flash_decode_paged`` gathers through in place
(PagedAttention-style), so decode attention reads leased pages with no
contiguous copy and no [B, max_len] over-allocation.  The same pool
byte accounting applies per lease.
"""

from __future__ import annotations

import functools
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.memory.pool import DevicePagePool, PageLease, PoolExhausted
from repro.models import transformer as tf
from repro.obs.recorder import KVEvent


@dataclass
class CacheLease:
    """One leased decode cache: the JAX cache pytree plus its bucket
    shape, exact byte footprint, (pool-backed) page lease, and the
    tenant whose requests the decode state serves (``"shared"`` = the
    untenanted sentinel)."""

    cache: dict
    batch: int
    max_len: int
    nbytes: int = 0
    page_lease: Optional[PageLease] = None
    tenant: str = "shared"


class KVCacheManager:
    """Decode-cache allocator: one cache per (batch, max_len) bucket,
    recycled across requests, with every live bucket's exact tensor
    bytes leased from the shared ``DevicePagePool`` (category ``"kv"``)
    when a pool is given."""

    def __init__(self, cfg: ArchConfig, dtype=jnp.bfloat16, *,
                 pool: Optional[DevicePagePool] = None):
        """``pool=None`` keeps the manager a standalone allocator (no
        ledger accounting, no admission pressure)."""
        self.cfg = cfg
        self.dtype = dtype
        self.pool = pool
        self._pool_buckets: Dict[Tuple[int, int], Tuple[dict, Optional[PageLease]]] = {}
        self._nbytes_memo: Dict[Tuple[int, int], int] = {}
        self.slab: Optional["KVPageSlab"] = None   # init_paged() creates it

    def _record(self, kind: str, batch: int, max_len: int, nbytes: int,
                tenant: str, *, lease_id: int = -1, pages: int = 0,
                length: int = 0, recycled: bool = False) -> None:
        """Trace through the pool's recorder lane (the manager has no
        lane of its own — KV state belongs to the pool's replica).
        Paged lease edges carry ``lease_id``/``pages`` (and appends the
        post-write ``length``) so the invariant checker can conserve
        pages per lease and pin the acquire→append→release order;
        dense edges carry ``recycled`` (acquire reused a released
        bucket) and ``kv.drop`` (a recycled bucket's bytes returned to
        the pool) so bucket recycling stays conservation-exact too."""
        rec = self.pool.recorder if self.pool is not None else None
        if rec is not None:
            rec.emit(KVEvent(t=rec.now, kind=kind,
                             replica=self.pool.replica_id, tenant=tenant,
                             batch=batch, max_len=max_len, nbytes=nbytes,
                             lease_id=lease_id, pages=pages, length=length,
                             recycled=recycled))

    def acquire(self, batch: int, max_len: int, *, fresh: bool = False,
                tenant: str = "shared") -> CacheLease:
        """Lease a decode cache for ``batch`` sequences of ``max_len``
        (recycled bucket when available, else a fresh pool-backed
        allocation; raises ``PoolExhausted`` when the pool cannot fit
        it).  ``fresh=True`` forces zeroed state.  ``tenant`` is the
        owning request's tenant: the bucket's pool lease carries it, so
        the ledger's ``tenant:<name>`` bytes (and the pool's per-tenant
        occupancy) include KV alongside prefetch pages — a recycled
        bucket is re-attributed to whoever reuses it."""
        key = (batch, max_len)
        nbytes = self.nbytes(batch, max_len)
        cache, page_lease = self._pool_buckets.pop(key, (None, None))
        recycled = cache is not None
        if cache is None:
            if self.pool is not None:
                page_lease = self.pool.lease_bytes(nbytes, "kv", tag=key,
                                                   tenant=tenant)
                if page_lease is None and self._pool_buckets:
                    # spill our own recycled buckets before giving up
                    self.drop_all()
                    page_lease = self.pool.lease_bytes(nbytes, "kv", tag=key,
                                                       tenant=tenant)
                if page_lease is None:
                    raise PoolExhausted(
                        f"kv cache {key} needs {nbytes} bytes; pool has "
                        f"{self.pool.reservable_pages()} reservable pages "
                        f"of {self.pool.page_nbytes} bytes",
                        bytes_needed=nbytes)
            try:
                cache = tf.init_cache(self.cfg, batch, max_len, self.dtype)
            except BaseException:
                # a failed allocation must hand its pool pages back —
                # otherwise every OOM here shrinks the pool forever
                # (telint TL001)
                if page_lease is not None and self.pool is not None:
                    self.pool.release(page_lease)
                raise
        else:
            if (page_lease is not None and self.pool is not None
                    and page_lease.tenant != tenant):
                # the recycled bytes now serve a different tenant — the
                # ledger must say so, or tenant KV bytes go stale
                self.pool.reattribute(page_lease, tenant)
            if fresh or tf.family_kind(self.cfg) != "attn":
                # recurrent state must not leak across requests;
                # attention caches are masked by pos so zeroing is
                # optional
                cache = jax.tree.map(lambda a: jnp.zeros_like(a), cache)
        self._record("kv.acquire", batch, max_len, nbytes, tenant,
                     recycled=recycled)
        return CacheLease(cache=cache, batch=batch, max_len=max_len,
                          nbytes=nbytes, page_lease=page_lease,
                          tenant=tenant)

    def release(self, lease: CacheLease) -> None:
        """Return the bucket for recycling (its pool lease stays live:
        the bytes remain resident until ``drop``/``drop_all``).  When a
        same-shaped bucket is already parked, keeping both would leak
        one pool lease forever — the incoming bucket's bytes go straight
        back to the pool instead (release + immediate drop in the
        trace, so the recycle balance stays conservation-exact)."""
        self._record("kv.release", lease.batch, lease.max_len,
                     lease.nbytes, lease.tenant)
        key = (lease.batch, lease.max_len)
        if key in self._pool_buckets:
            freed = lease.nbytes
            if lease.page_lease is not None and self.pool is not None:
                freed = lease.page_lease.nbytes
                self.pool.release(lease.page_lease)
            self._record("kv.drop", lease.batch, lease.max_len, freed,
                         lease.tenant)
            return
        self._pool_buckets[key] = (lease.cache, lease.page_lease)

    def drop(self, batch: int, max_len: int) -> int:
        """Free one recycled bucket back to the pool; returns its bytes.
        Emits ``kv.drop`` so the recycle pool's byte balance stays
        conservation-exact in the trace (a dense ``kv.release`` parks
        the bytes for reuse — only the drop actually returns them)."""
        cache, page_lease = self._pool_buckets.pop((batch, max_len),
                                                   (None, None))
        if cache is None:
            return 0
        freed = self.nbytes(batch, max_len)
        tenant = "shared"
        if page_lease is not None and self.pool is not None:
            tenant = page_lease.tenant
            freed = page_lease.nbytes
            self.pool.release(page_lease)
        self._record("kv.drop", batch, max_len, freed, tenant)
        return freed

    def drop_all(self) -> int:
        """Free every recycled bucket (replica teardown / pressure spill)."""
        freed = 0
        for batch, max_len in list(self._pool_buckets):
            freed += self.drop(batch, max_len)
        return freed

    def nbytes(self, batch: int, max_len: int) -> int:
        """Exact tensor bytes of one (batch, max_len) bucket — matches
        the ledger's ``"kv"`` charge to the byte."""
        key = (batch, max_len)
        if key not in self._nbytes_memo:     # eval_shape traces init_cache;
            shapes = jax.eval_shape(         # don't re-trace per acquire
                lambda: tf.init_cache(self.cfg, batch, max_len, self.dtype))
            self._nbytes_memo[key] = sum(s.size * s.dtype.itemsize
                                         for s in jax.tree.leaves(shapes))
        return self._nbytes_memo[key]

    # -- paged KV (block-table leases over a shared KV page slab) ----------

    def init_paged(self, num_pages: int, page_size: int = 16) -> "KVPageSlab":
        """Allocate the manager's KV page slab: ``num_pages`` page slots
        of ``page_size`` tokens each, all layers stacked —
        k/v [L, num_pages, page_size, KVH, Dh].  GQA attention archs
        only (SSM state is O(1) per request; nothing to page)."""
        if (tf.family_kind(self.cfg) != "attn" or not self.cfg.has_attention
                or self.cfg.attn_kind != "gqa"):
            raise ValueError(
                "paged KV supports plain GQA attention caches only "
                f"(arch family {tf.family_kind(self.cfg)!r}, "
                f"attn_kind {self.cfg.attn_kind!r})")
        L = self.cfg.num_layers
        KVH, Dh = self.cfg.num_kv_heads, self.cfg.resolved_head_dim
        shape = (L, num_pages, page_size, KVH, Dh)
        self.slab = KVPageSlab(
            k=jnp.zeros(shape, self.dtype), v=jnp.zeros(shape, self.dtype),
            page_size=page_size, free=list(range(num_pages)))
        return self.slab

    def paged_page_nbytes(self) -> int:
        """Exact bytes of one KV page slot (k+v, all layers)."""
        slab = self._require_slab()
        per = slab.k.dtype.itemsize
        L, _, ps, KVH, Dh = slab.k.shape
        return 2 * L * ps * KVH * Dh * per

    def acquire_paged(self, batch: int, max_len: int, *,
                      tenant: str = "shared") -> "PagedCacheLease":
        """Lease a block-table decode cache: ceil(max_len/page_size)
        slab pages per sequence, handed back as a [batch, max_blocks]
        block table the paged kernels gather through — no contiguous
        [B, S] cache is ever materialized.  Bytes are charged to the
        pool ledger (category ``"kv"``, tenant-tagged) exactly like the
        dense buckets; raises ``PoolExhausted`` when either the slab's
        free list or the pool cannot cover it."""
        slab = self._require_slab()
        ps = slab.page_size
        max_blocks = -(-max_len // ps)
        need = batch * max_blocks
        if len(slab.free) < need:
            raise PoolExhausted(
                f"kv page slab exhausted: need {need} pages for "
                f"({batch}, {max_len}), {len(slab.free)} free")
        nbytes = need * self.paged_page_nbytes()
        page_lease = None
        if self.pool is not None:
            page_lease = self.pool.lease_bytes(nbytes, "kv",
                                               tag=(batch, max_len),
                                               tenant=tenant)
            if page_lease is None and self._pool_buckets:
                self.drop_all()          # spill recycled dense buckets first
                page_lease = self.pool.lease_bytes(nbytes, "kv",
                                                   tag=(batch, max_len),
                                                   tenant=tenant)
            if page_lease is None:
                raise PoolExhausted(
                    f"paged kv cache ({batch}, {max_len}) needs {nbytes} "
                    f"bytes; pool has {self.pool.reservable_pages()} "
                    f"reservable pages of {self.pool.page_nbytes} bytes",
                    bytes_needed=nbytes)
        slots = [slab.free.pop() for _ in range(need)]
        bt = np.asarray(slots, np.int32).reshape(batch, max_blocks)
        lease_id = next(_LEASE_IDS)
        self._record("kv.acquire", batch, max_len, nbytes, tenant,
                     lease_id=lease_id, pages=need)
        return PagedCacheLease(block_table=bt,
                               lengths=np.zeros(batch, np.int32),
                               batch=batch, max_len=max_len, nbytes=nbytes,
                               page_lease=page_lease, tenant=tenant,
                               lease_id=lease_id, owned_slots=tuple(slots))

    def append_paged(self, lease: "PagedCacheLease",
                     k_new: Optional[jax.Array] = None,
                     v_new: Optional[jax.Array] = None) -> None:
        """Advance the lease by one decode step.  With ``k_new``/``v_new``
        (``[L, B, KVH, Dh]``) the step's K/V is written at each
        sequence's current length through the block table (donated
        in-place scatter — the slab is never copied).  Without them the
        scatter already happened inside the fused serve step
        (``transformer.serve_step_paged`` writes through the same block
        table in-jit) and this call is the accounting half: bounds
        check, length advance, and the ``kv.append`` trace edge the
        invariant checker orders between acquire and release."""
        slab = self._require_slab()
        ps = slab.page_size
        if int(lease.lengths.max(initial=0)) >= lease.max_len:
            raise ValueError(f"paged lease full at max_len={lease.max_len}")
        if k_new is not None:
            slots = lease.block_table[np.arange(lease.batch),
                                      lease.lengths // ps]
            offs = lease.lengths % ps
            slab.k, slab.v = _append_token(
                slab.k, slab.v, jnp.asarray(k_new), jnp.asarray(v_new),
                jnp.asarray(slots), jnp.asarray(offs, np.int32))
        lease.lengths += 1
        self._record("kv.append", lease.batch, lease.max_len, 0,
                     lease.tenant, lease_id=lease.lease_id,
                     pages=lease.block_table.size,
                     length=int(lease.lengths.max(initial=0)))

    def splice_paged(self, lease: "PagedCacheLease",
                     row_chunks: List[List[Tuple[Tuple[int, ...], int]]],
                     ) -> int:
        """Attach precomputed chunk-KV pages to a fresh paged lease by
        **block-table edit** (TurboRAG-style reuse; no copy).

        ``row_chunks[i]`` lists row ``i``'s chunks as ``(slots,
        length)`` pairs — slab page slots already holding the chunk's
        K/V (written by ``ChunkKVCache.load``) and the chunk's token
        count.  Chunks splice at page boundaries, in order, AHEAD of the
        lease's own (fresh) pages: row ``i``'s table becomes ``[chunk
        pages..., fresh pages..., -1 padding]``, its length starts at
        the end of its spliced region (generation resumes at the next
        page boundary), and the lease's ``max_len`` grows by the widest
        spliced region so the append bounds check keeps holding.

        Per-page splice metadata for the reordered-RoPE attention
        (``serve_step_paged_spliced``) is materialized on the lease:
        ``page_delta[i, blk]`` — the RoPE rotation offset (the chunk's
        base layout position; stored K is roped chunk-locally, and
        rotations compose) — and ``page_valid[i, blk]`` — live tokens
        on the page (< page_size only on a chunk's partial last page;
        the dead tail is masked, and generation's own pages stay fully
        valid).

        The spliced slots are NOT added to ``owned_slots``: the lease
        only references them; ownership (and the pool's ``chunk_kv``
        byte charge) stays with the chunk residency, which the caller
        pins for the lease's lifetime.  Emits ``kv.splice`` (pages =
        spliced page count, length = post-splice max length) inside the
        lease's acquire→release window.  Returns the spliced page
        count (0 = nothing to splice; the lease is untouched)."""
        slab = self._require_slab()
        ps = slab.page_size
        if len(row_chunks) != lease.batch:
            raise ValueError(f"row_chunks has {len(row_chunks)} rows for a "
                             f"batch-{lease.batch} lease")
        if int(lease.lengths.max(initial=0)) > 0:
            raise ValueError("splice_paged must run on a fresh lease "
                             "(before any append)")
        n_blocks = [sum(len(slots) for slots, _ in row) for row in row_chunks]
        total = sum(n_blocks)
        if total == 0:
            return 0
        lead = max(n_blocks)
        B, MB = lease.block_table.shape
        bt = np.full((B, lead + MB), -1, np.int32)
        delta = np.zeros((B, lead + MB), np.int32)
        valid = np.full((B, lead + MB), ps, np.int32)
        for i, row in enumerate(row_chunks):
            b0 = 0
            for slots, length in row:
                npg = len(slots)
                if length <= 0 or npg != -(-length // ps):
                    raise ValueError(
                        f"chunk of {length} tokens needs "
                        f"{-(-max(length, 1) // ps)} pages, got {npg}")
                bt[i, b0:b0 + npg] = slots
                # stored K is roped at chunk-local positions p*ps + off;
                # the layout position is (b0 + p)*ps + off, so the
                # per-page rotation delta is the constant b0*ps
                delta[i, b0:b0 + npg] = b0 * ps
                valid[i, b0 + npg - 1] = length - (npg - 1) * ps
                b0 += npg
            bt[i, b0:b0 + MB] = lease.block_table[i]
        valid[bt < 0] = 0                  # padding columns attend nothing
        lease.block_table = bt
        lease.lengths = np.asarray([n * ps for n in n_blocks], np.int32)
        lease.page_delta = delta
        lease.page_valid = valid
        lease.spliced_pages = total
        lease.max_len = lead * ps + lease.max_len
        self._record("kv.splice", lease.batch, lease.max_len,
                     total * self.paged_page_nbytes(), lease.tenant,
                     lease_id=lease.lease_id, pages=total,
                     length=int(lease.lengths.max(initial=0)))
        return total

    def release_paged(self, lease: "PagedCacheLease") -> int:
        """Return the lease's **owned** slab pages to the free list and
        release its pool bytes; returns bytes freed.  Paged leases are
        per request batch — no recycling bucket (block tables are cheap
        to rebuild; the slab itself stays allocated).  Spliced chunk-KV
        pages in the block table are NOT owned: they belong to the
        ``ChunkKVCache``'s residency and go back to *warm* residency
        (the splicer unpins them), never to the slab free list here —
        freeing them would alias live chunk pages under future leases."""
        slab = self._require_slab()
        slab.free.extend(int(s) for s in lease.owned_slots)
        pages = len(lease.owned_slots)
        lease.owned_slots = ()
        lease.block_table = np.full_like(lease.block_table, -1)
        self._record("kv.release", lease.batch, lease.max_len,
                     lease.nbytes, lease.tenant, lease_id=lease.lease_id,
                     pages=pages)
        if lease.page_lease is not None and self.pool is not None:
            self.pool.release(lease.page_lease)
            lease.page_lease = None
        return lease.nbytes

    def _require_slab(self) -> "KVPageSlab":
        if self.slab is None:
            raise RuntimeError("call init_paged(num_pages) before using "
                               "the paged KV API")
        return self.slab


# paged lease ids are process-global (not per manager): the invariant
# checker keys page conservation on (replica, lease_id), and one replica
# may host several managers
_LEASE_IDS = itertools.count()


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _append_token(k_slab, v_slab, k_new, v_new, slots, offs):
    """One donated scatter: k/v [L, NP, ps, KVH, Dh] <- new [L, B, KVH, Dh]
    at (slots[b], offs[b]) — the paged analogue of the dense cache's
    dynamic-update-slice write."""
    k_slab = k_slab.at[:, slots, offs].set(k_new.astype(k_slab.dtype))
    v_slab = v_slab.at[:, slots, offs].set(v_new.astype(v_slab.dtype))
    return k_slab, v_slab


@dataclass
class KVPageSlab:
    """The manager-owned paged KV arrays (all layers stacked) plus the
    host-side free list of page slots.  ``k[l]`` / ``v[l]`` are exactly
    the ``[NP, page_size, KVH, Dh]`` operands ``flash_decode_paged``
    reads in place."""

    k: jax.Array
    v: jax.Array
    page_size: int
    free: List[int] = field(default_factory=list)

    @property
    def num_pages(self) -> int:
        """Total KV page slots in the slab (free + leased)."""
        return self.k.shape[1]

    def layer(self, l: int) -> Tuple[jax.Array, jax.Array]:
        """(k_pages, v_pages) for layer ``l`` — the paged-attention view."""
        return self.k[l], self.v[l]


@dataclass
class PagedCacheLease:
    """One leased block-table decode cache: ``block_table`` [B, MB]
    int32 (slab page slot per sequence block, -1 after release) and
    ``lengths`` [B] int32 (tokens written so far — what
    ``flash_decode_paged`` masks on), plus the same byte/tenant
    accounting as the dense ``CacheLease``."""

    block_table: np.ndarray
    lengths: np.ndarray
    batch: int
    max_len: int
    nbytes: int = 0
    page_lease: Optional[PageLease] = None
    tenant: str = "shared"
    lease_id: int = -1                 # globally unique (trace correlation)
    # slab slots this lease allocated (and will free): spliced chunk-KV
    # pages appear in block_table but never here — their ownership stays
    # with the ChunkKVCache residency
    owned_slots: Tuple[int, ...] = ()
    # splice metadata (None until splice_paged ran): per-block RoPE
    # rotation offset and live-token count for serve_step_paged_spliced
    page_delta: Optional[np.ndarray] = None
    page_valid: Optional[np.ndarray] = None
    spliced_pages: int = 0

    def device_tables(self) -> Tuple[jax.Array, jax.Array]:
        """(block_table, lengths) as device arrays for the kernel."""
        return jnp.asarray(self.block_table), jnp.asarray(self.lengths)

    def device_splice_tables(self) -> Tuple[jax.Array, jax.Array,
                                            jax.Array, jax.Array]:
        """(block_table, lengths, page_delta, page_valid) as device
        arrays — the ``serve_step_paged_spliced`` operands.  Requires a
        prior ``splice_paged`` (which materializes delta/valid)."""
        if self.page_delta is None or self.page_valid is None:
            raise RuntimeError("lease has no splice tables: call "
                               "KVCacheManager.splice_paged first")
        return (jnp.asarray(self.block_table), jnp.asarray(self.lengths),
                jnp.asarray(self.page_delta), jnp.asarray(self.page_valid))
