"""KV/state cache manager for the serving engine.

Allocates one decode cache per (batch, max_len) bucket and recycles it
across requests (zeroed logically via position resets — stale entries are
masked by per-sequence ``pos``). For SSM archs the "cache" is the O(1)
recurrent state, which must be explicitly zeroed between requests.

When constructed over a ``DevicePagePool`` the manager stops being a
memory island: every live lease charges its exact tensor bytes to the
replica's ``MemoryLedger`` (category ``"kv"``) and takes page slots out
of the same pool the prefetch buffer draws from, so generation state
and retrieval state compete for — and are accounted against — the same
HBM.  A recycled bucket keeps its pool lease (the bytes stay resident);
``acquire`` of a new bucket that the pool cannot fit raises
``PoolExhausted`` rather than silently overcommitting.

Leases are **tenant-tagged**: ``acquire(..., tenant=...)`` charges the
bucket's bytes to the owning request's tenant on the ledger
(``tenant:<name>`` keys now include KV, not just prefetch pages) and in
the pool's per-tenant occupancy; a recycled bucket is re-attributed to
whichever tenant reuses it.  ``ServerTelemetry.tenants`` surfaces the
per-tenant KV footprint.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.memory.pool import DevicePagePool, PageLease, PoolExhausted
from repro.models import transformer as tf


@dataclass
class CacheLease:
    """One leased decode cache: the JAX cache pytree plus its bucket
    shape, exact byte footprint, (pool-backed) page lease, and the
    tenant whose requests the decode state serves (``"shared"`` = the
    untenanted sentinel)."""

    cache: dict
    batch: int
    max_len: int
    nbytes: int = 0
    page_lease: Optional[PageLease] = None
    tenant: str = "shared"


class KVCacheManager:
    """Decode-cache allocator: one cache per (batch, max_len) bucket,
    recycled across requests, with every live bucket's exact tensor
    bytes leased from the shared ``DevicePagePool`` (category ``"kv"``)
    when a pool is given."""

    def __init__(self, cfg: ArchConfig, dtype=jnp.bfloat16, *,
                 pool: Optional[DevicePagePool] = None):
        """``pool=None`` keeps the manager a standalone allocator (no
        ledger accounting, no admission pressure)."""
        self.cfg = cfg
        self.dtype = dtype
        self.pool = pool
        self._pool_buckets: Dict[Tuple[int, int], Tuple[dict, Optional[PageLease]]] = {}
        self._nbytes_memo: Dict[Tuple[int, int], int] = {}

    def acquire(self, batch: int, max_len: int, *, fresh: bool = False,
                tenant: str = "shared") -> CacheLease:
        """Lease a decode cache for ``batch`` sequences of ``max_len``
        (recycled bucket when available, else a fresh pool-backed
        allocation; raises ``PoolExhausted`` when the pool cannot fit
        it).  ``fresh=True`` forces zeroed state.  ``tenant`` is the
        owning request's tenant: the bucket's pool lease carries it, so
        the ledger's ``tenant:<name>`` bytes (and the pool's per-tenant
        occupancy) include KV alongside prefetch pages — a recycled
        bucket is re-attributed to whoever reuses it."""
        key = (batch, max_len)
        nbytes = self.nbytes(batch, max_len)
        cache, page_lease = self._pool_buckets.pop(key, (None, None))
        if cache is None:
            if self.pool is not None:
                page_lease = self.pool.lease_bytes(nbytes, "kv", tag=key,
                                                   tenant=tenant)
                if page_lease is None and self._pool_buckets:
                    # spill our own recycled buckets before giving up
                    self.drop_all()
                    page_lease = self.pool.lease_bytes(nbytes, "kv", tag=key,
                                                       tenant=tenant)
                if page_lease is None:
                    raise PoolExhausted(
                        f"kv cache {key} needs {nbytes} bytes; pool has "
                        f"{self.pool.reservable_pages()} reservable pages "
                        f"of {self.pool.page_nbytes} bytes")
            cache = tf.init_cache(self.cfg, batch, max_len, self.dtype)
        else:
            if (page_lease is not None and self.pool is not None
                    and page_lease.tenant != tenant):
                # the recycled bytes now serve a different tenant — the
                # ledger must say so, or tenant KV bytes go stale
                self.pool.reattribute(page_lease, tenant)
            if fresh or tf.family_kind(self.cfg) != "attn":
                # recurrent state must not leak across requests;
                # attention caches are masked by pos so zeroing is
                # optional
                cache = jax.tree.map(lambda a: jnp.zeros_like(a), cache)
        return CacheLease(cache=cache, batch=batch, max_len=max_len,
                          nbytes=nbytes, page_lease=page_lease,
                          tenant=tenant)

    def release(self, lease: CacheLease) -> None:
        """Return the bucket for recycling (its pool lease stays live:
        the bytes remain resident until ``drop``/``drop_all``)."""
        self._pool_buckets[(lease.batch, lease.max_len)] = (lease.cache,
                                                            lease.page_lease)

    def drop(self, batch: int, max_len: int) -> int:
        """Free one recycled bucket back to the pool; returns its bytes."""
        cache, page_lease = self._pool_buckets.pop((batch, max_len),
                                                   (None, None))
        if cache is None:
            return 0
        if page_lease is not None and self.pool is not None:
            self.pool.release(page_lease)
            return page_lease.nbytes
        return self.nbytes(batch, max_len)

    def drop_all(self) -> int:
        """Free every recycled bucket (replica teardown / pressure spill)."""
        freed = 0
        for batch, max_len in list(self._pool_buckets):
            freed += self.drop(batch, max_len)
        return freed

    def nbytes(self, batch: int, max_len: int) -> int:
        """Exact tensor bytes of one (batch, max_len) bucket — matches
        the ledger's ``"kv"`` charge to the byte."""
        key = (batch, max_len)
        if key not in self._nbytes_memo:     # eval_shape traces init_cache;
            shapes = jax.eval_shape(         # don't re-trace per acquire
                lambda: tf.init_cache(self.cfg, batch, max_len, self.dtype))
            self._nbytes_memo[key] = sum(s.size * s.dtype.itemsize
                                         for s in jax.tree.leaves(shapes))
        return self._nbytes_memo[key]
