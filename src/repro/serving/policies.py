"""Retrieval execution policies — the paper's comparison systems as a
strategy layer (§3.2, §4.1, Fig. 5).

Each policy bundles the two planes that the legacy ``TeleRAGEngine``
scattered across ``if mode == ...`` branches:

  * **data plane** — how a round's retrieval actually executes against
    the engine's buffer/cache/index (``lookahead`` / ``retrieve``);
  * **timing plane** — how the round's measured telemetry composes into
    modeled wall-clock (``transfer_ready_offset`` / ``search_seconds``),
    which the event-driven ``RetrievalRuntime`` consumes as dependency
    edges and the legacy ``RequestResult.latency`` sums per round.

Adding a baseline is one ``@register_policy`` class, not edits to the
engine, the telemetry math, and the executor.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, Optional, Sequence, Tuple, Type

import numpy as np

from repro.core.hybrid_search import RetrievalResult, host_search, hybrid_retrieve
from repro.core.ivf import probe
from repro.core.lookahead import PrefetchPlan, plan_batched_prefetch
from repro.core.transfer import TransferEvent
from repro.memory.admission import AdmissionTicket

if TYPE_CHECKING:                                    # avoid circular import
    from repro.serving.engine import RoundTelemetry, TeleRAGEngine


@dataclass(frozen=True)
class LatencyContext:
    """Hardware constants the timing plane composes telemetry with."""

    t_cc: float                  # host per-cluster search seconds
    cluster_bytes: float         # mean cluster payload (demand-fetch model)
    link_bw: float               # H2D link bandwidth for demand fetches

    @classmethod
    def from_engine(cls, engine: "TeleRAGEngine") -> "LatencyContext":
        """Read the constants off a live engine (t_cc seconds/cluster,
        mean cluster bytes, link bytes/second)."""
        return cls(
            t_cc=engine.effective_tcc(),
            cluster_bytes=float(
                np.mean(engine.index.paged.all_cluster_bytes())),
            link_bw=float(engine.cfg.hw.host_link_bw))


class RetrievalPolicy:
    """Base strategy; concrete policies override both planes."""

    name: str = ""
    prefetches: bool = False     # does lookahead dispatch an async copy?

    # ---- data plane -------------------------------------------------------
    def plan(self, engine: "TeleRAGEngine", q_in: np.ndarray,
             gen_tokens: Sequence[int], *,
             free_pages: Optional[int] = None, ranked=None,
             wave_key: object = None) -> Optional[PrefetchPlan]:
        """The *desired* lookahead plan (what the wave wants to reserve),
        computed against the pool's full extent — transient pressure is
        the admission controller's problem, not the planner's.  None for
        non-prefetching policies.  ``wave_key`` identifies the wave's
        own buffer pins so the plan never counts them as reclaimable:
        under per-request continuous batching it is the tuple of the
        wave's member records (pins are keyed per request and released
        at each request's own completion), not a wave id."""
        return None

    def lookahead(self, engine: "TeleRAGEngine", q_in: np.ndarray,
                  gen_tokens: Sequence[int], *, now: float = 0.0,
                  plan: Optional[PrefetchPlan] = None,
                  ticket: Optional[AdmissionTicket] = None,
                  tenant: str = "shared",
                  ) -> Tuple[int, int, Optional[TransferEvent]]:
        """Plan + dispatch prefetch. Returns (bytes_planned, clusters,
        transfer event). Non-prefetching policies are a no-op.
        ``tenant`` is who a direct caller's synchronous admission (no
        precomputed ``ticket``) charges its reservation to."""
        return 0, 0, None

    def retrieve(self, engine: "TeleRAGEngine", q_out: np.ndarray, *,
                 now: float = 0.0,
                 tenant: str = "shared") -> RetrievalResult:
        """Execute the round's retrieval for the rewritten queries at
        event-clock time ``now`` (seconds).  ``tenant`` is the
        requesting wave's tenant — policies that evict to make room
        (demand fetch) must scope the eviction to its floor view."""
        raise NotImplementedError

    # ---- timing plane -----------------------------------------------------
    def transfer_ready_offset(self, rt: "RoundTelemetry",
                              ctx: LatencyContext) -> Optional[float]:
        """Seconds after round start at which prefetched data is usable;
        None when retrieval has no transfer dependency."""
        return None

    def search_seconds(self, rt: "RoundTelemetry",
                       ctx: LatencyContext) -> float:
        """Retrieval critical path once its dependencies are met."""
        raise NotImplementedError

    def round_latency(self, rt: "RoundTelemetry",
                      ctx: LatencyContext) -> float:
        """Round wall-clock from the dependency decomposition.  Identical
        to the legacy closed forms (``RoundTelemetry.t_*``) by
        construction — asserted in tests/test_runtime.py."""
        off = self.transfer_ready_offset(rt, ctx)
        start = rt.t_llm_window if off is None else max(rt.t_llm_window, off)
        return start + self.search_seconds(rt, ctx)

    # ---- shared data-plane helpers ---------------------------------------
    @staticmethod
    def _hybrid_retrieve(engine: "TeleRAGEngine", q_out: np.ndarray,
                         ranked_out: np.ndarray) -> RetrievalResult:
        res = hybrid_retrieve(engine.buffer, q_out, ranked_out,
                              k=engine.cfg.top_k,
                              kernel_mode=engine.cfg.kernel_mode,
                              fused=engine.cfg.fused_retrieval,
                              centroids=engine.index.centroids)
        used = [c for h in res.hit_clusters for c in h]
        engine.cache.record_lookup([c for r in ranked_out for c in r],
                                   engine.buffer.resident_clusters())
        engine.cache.round_update(used)
        return res


_POLICIES: Dict[str, RetrievalPolicy] = {}


def register_policy(cls: Type[RetrievalPolicy]) -> Type[RetrievalPolicy]:
    """Class decorator: instantiate and register a policy under its
    ``name`` (how a new baseline plugs in without engine edits)."""
    _POLICIES[cls.name] = cls()
    return cls


def get_policy(mode: str) -> RetrievalPolicy:
    """The registered policy instance for ``mode`` (KeyError if none)."""
    if mode not in _POLICIES:
        raise KeyError(mode)
    return _POLICIES[mode]


def policy_names() -> Tuple[str, ...]:
    """Registered policy names (the valid ``EngineConfig.mode`` values)."""
    return tuple(_POLICIES)


@register_policy
class TeleRAGPolicy(RetrievalPolicy):
    """Lookahead prefetch overlapped with generation + hybrid search."""

    name = "telerag"
    prefetches = True

    def plan(self, engine, q_in, gen_tokens, *, free_pages=None,
             ranked=None, wave_key=None):
        B = q_in.shape[0]
        bud = engine.prefetch_budget(gen_tokens, B)
        if ranked is None:
            ranked = probe(q_in, engine.index,
                           min(engine.cfg.lookahead_rank,
                               engine.index.num_clusters))
        resident = engine.buffer.resident_clusters()
        # plan against the wave's plannable extent (not transient free
        # slots): how many pages it can actually have right now is the
        # admission controller's reserve/stall/spill decision, never a
        # silent clamp inside the planner
        if free_pages is None:
            hits = {int(c) for row in ranked for c in row} & resident
            free_pages = engine.plannable_pages(wave_key,
                                                hit_clusters=hits)
        plan, _ = plan_batched_prefetch(
            list(ranked), engine.index.paged, budget_bytes=bud,
            resident=resident, free_pages=free_pages)
        plan.ranked = ranked
        return plan

    def lookahead(self, engine, q_in, gen_tokens, *, now=0.0, plan=None,
                  ticket=None, tenant="shared"):
        if plan is None:
            plan = self.plan(engine, q_in, gen_tokens)
        if ticket is None:
            # direct (non-runtime) callers cannot park on an event queue:
            # admit synchronously — spill, or cap with the shortfall on
            # the admission stats rather than dropping clusters silently
            # (tenant-attributed, so a direct caller's burst still counts
            # against its own floor/ceiling, not the shared sentinel's)
            ticket = engine.admission.admit(plan.pages_planned,
                                            owner="lookahead",
                                            can_wait=False,
                                            tenant=tenant)
        if ticket.capped and ticket.pages_granted < plan.pages_planned:
            plan = self.plan(engine, q_in, gen_tokens,
                             free_pages=ticket.pages_granted,
                             ranked=plan.ranked)
        try:
            if plan.fetch:
                # the dispatch-time fallback eviction must honor tenant
                # floors exactly like the admission spill does — otherwise
                # a full buffer at transfer time would let this wave dig
                # another tenant below its guaranteed floor
                protect = engine.admission.spill_protect(ticket.tenant)
                ev = engine.transfer.submit(
                    plan.fetch, now=now, nbytes=plan.bytes_planned,
                    reservation=ticket.reservation,
                    make_room=lambda pages: engine.cache.make_room(
                        engine.buffer, pages, protect=protect))
            else:
                # nothing to move: no link event (a 0-byte event could
                # still inherit a channel-queue wait), but fold any queued
                # device invalidations exactly as the legacy load path did
                engine.buffer.load_clusters([])
                ev = None
        finally:
            # ALWAYS return the reservation's unconsumed remainder — a
            # transfer that raises mid-submit must not leave reserved
            # pages stranded until the pool is rebuilt (telint TL001)
            engine.admission.commit(ticket)
        # only clusters that actually landed become cache-tracked — a
        # rejected cluster must not leak a hotness entry
        engine.cache.on_fetched(
            [c for c in plan.fetch if engine.buffer.is_resident(c)])
        # chunk-KV lookahead: land the predicted clusters' precomputed
        # chunk pages H2D during the same generation window, so the next
        # round's splice hits warm residency instead of re-prefilling.
        # Cold (unpinned) loads: a demoted ticket never reaches this
        # call, and pool pressure can evict them again (the engine spill
        # chain protects only pinned chunks).
        chunk = getattr(engine, "chunk_kv", None)
        if chunk is not None and engine.cfg.chunk_kv_prefetch_pages > 0:
            if plan.fetch:
                clusters = list(plan.fetch) + list(plan.resident_hits)
            elif plan.ranked is not None:
                clusters = [int(c) for c in np.asarray(plan.ranked).ravel()[:8]]
            else:
                clusters = []
            if clusters:
                chunk.prefetch_clusters(
                    clusters, tenant=ticket.tenant,
                    budget_pages=engine.cfg.chunk_kv_prefetch_pages)
        return plan.bytes_planned, len(plan.fetch), ev

    def retrieve(self, engine, q_out, *, now=0.0, tenant="shared"):
        """Hybrid retrieval: device search over resident hits + host
        search over misses (no eviction at retrieval time)."""
        ranked_out = probe(q_out, engine.index, engine.cfg.nprobe)
        return self._hybrid_retrieve(engine, q_out, ranked_out)

    def transfer_ready_offset(self, rt, ctx):
        return rt.t_prefetch

    def search_seconds(self, rt, ctx):
        return max(rt.t_host_search, rt.t_dev_search) + rt.t_merge


@register_policy
class CpuBaselinePolicy(RetrievalPolicy):
    """Retrieval entirely on host (Faiss-CPU baseline)."""

    name = "cpu_baseline"

    def retrieve(self, engine, q_out, *, now=0.0, tenant="shared"):
        """Search every probed cluster on host (no device state)."""
        ranked_out = probe(q_out, engine.index, engine.cfg.nprobe)
        res_s, res_i, miss = [], [], []
        for b in range(q_out.shape[0]):
            cs = [int(c) for c in ranked_out[b]]
            s, i = host_search(engine.index.paged, cs, q_out[b],
                               engine.cfg.top_k)
            res_s.append(s)
            res_i.append(i)
            miss.append(cs)
        return RetrievalResult(doc_ids=np.stack(res_i),
                               scores=np.stack(res_s),
                               hit_clusters=[[] for _ in miss],
                               missed_clusters=miss,
                               nprobe=engine.cfg.nprobe)

    def search_seconds(self, rt, ctx):
        return (rt.hits + rt.misses) * ctx.t_cc


@register_policy
class RuntimeFetchPolicy(RetrievalPolicy):
    """Fetch-on-demand at retrieval time — no overlap (§3.2, Fig. 5)."""

    name = "runtime_fetch"

    def retrieve(self, engine, q_out, *, now=0.0, tenant="shared"):
        """Demand-fetch every probed cluster at retrieval time, then
        run the hybrid search (no lookahead overlap).  The eviction
        that makes room honors other tenants' floors from the
        requesting ``tenant``'s view."""
        ranked_out = probe(q_out, engine.index, engine.cfg.nprobe)
        # fetch exactly the probed clusters now (not overlapped)
        need = sorted(set(int(c) for r in ranked_out for c in r))
        pages = sum(int(engine.index.paged.cluster_num_pages[c])
                    for c in need if not engine.buffer.is_resident(c))
        engine.cache.make_room(engine.buffer, pages,
                               protect=engine.admission.spill_protect(
                                   tenant))
        engine.transfer.submit(need, now=now, kind="demand",
                               nbytes=pages * engine.buffer.page_nbytes)
        return self._hybrid_retrieve(engine, q_out, ranked_out)

    def search_seconds(self, rt, ctx):
        nb = (rt.hits + rt.misses) * ctx.cluster_bytes
        return nb / ctx.link_bw + rt.t_dev_search + rt.t_merge
