"""Retrieval execution policies — the paper's comparison systems as a
strategy layer (§3.2, §4.1, Fig. 5).

Each policy bundles the two planes that the legacy ``TeleRAGEngine``
scattered across ``if mode == ...`` branches:

  * **data plane** — how a round's retrieval actually executes against
    the engine's buffer/cache/index (``lookahead`` / ``retrieve``);
  * **timing plane** — how the round's measured telemetry composes into
    modeled wall-clock (``transfer_ready_offset`` / ``search_seconds``),
    which the event-driven ``RetrievalRuntime`` consumes as dependency
    edges and the legacy ``RequestResult.latency`` sums per round.

Adding a baseline is one ``@register_policy`` class, not edits to the
engine, the telemetry math, and the executor.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, Optional, Sequence, Tuple, Type

import numpy as np

from repro.core.hybrid_search import RetrievalResult, host_search, hybrid_retrieve
from repro.core.ivf import probe
from repro.core.lookahead import plan_batched_prefetch
from repro.core.transfer import TransferEvent

if TYPE_CHECKING:                                    # avoid circular import
    from repro.serving.engine import RoundTelemetry, TeleRAGEngine


@dataclass(frozen=True)
class LatencyContext:
    """Hardware constants the timing plane composes telemetry with."""

    t_cc: float                  # host per-cluster search seconds
    cluster_bytes: float         # mean cluster payload (demand-fetch model)
    link_bw: float               # H2D link bandwidth for demand fetches

    @classmethod
    def from_engine(cls, engine: "TeleRAGEngine") -> "LatencyContext":
        return cls(
            t_cc=engine.effective_tcc(),
            cluster_bytes=float(
                np.mean(engine.index.paged.all_cluster_bytes())),
            link_bw=float(engine.cfg.hw.host_link_bw))


class RetrievalPolicy:
    """Base strategy; concrete policies override both planes."""

    name: str = ""
    prefetches: bool = False     # does lookahead dispatch an async copy?

    # ---- data plane -------------------------------------------------------
    def lookahead(self, engine: "TeleRAGEngine", q_in: np.ndarray,
                  gen_tokens: Sequence[int], *, now: float = 0.0,
                  ) -> Tuple[int, int, Optional[TransferEvent]]:
        """Plan + dispatch prefetch. Returns (bytes_planned, clusters,
        transfer event). Non-prefetching policies are a no-op."""
        return 0, 0, None

    def retrieve(self, engine: "TeleRAGEngine", q_out: np.ndarray, *,
                 now: float = 0.0) -> RetrievalResult:
        raise NotImplementedError

    # ---- timing plane -----------------------------------------------------
    def transfer_ready_offset(self, rt: "RoundTelemetry",
                              ctx: LatencyContext) -> Optional[float]:
        """Seconds after round start at which prefetched data is usable;
        None when retrieval has no transfer dependency."""
        return None

    def search_seconds(self, rt: "RoundTelemetry",
                       ctx: LatencyContext) -> float:
        """Retrieval critical path once its dependencies are met."""
        raise NotImplementedError

    def round_latency(self, rt: "RoundTelemetry",
                      ctx: LatencyContext) -> float:
        """Round wall-clock from the dependency decomposition.  Identical
        to the legacy closed forms (``RoundTelemetry.t_*``) by
        construction — asserted in tests/test_runtime.py."""
        off = self.transfer_ready_offset(rt, ctx)
        start = rt.t_llm_window if off is None else max(rt.t_llm_window, off)
        return start + self.search_seconds(rt, ctx)

    # ---- shared data-plane helpers ---------------------------------------
    @staticmethod
    def _hybrid_retrieve(engine: "TeleRAGEngine", q_out: np.ndarray,
                         ranked_out: np.ndarray) -> RetrievalResult:
        res = hybrid_retrieve(engine.buffer, q_out, ranked_out,
                              k=engine.cfg.top_k,
                              kernel_mode=engine.cfg.kernel_mode)
        used = [c for h in res.hit_clusters for c in h]
        engine.cache.record_lookup([c for r in ranked_out for c in r],
                                   engine.buffer.resident_clusters())
        engine.cache.round_update(used)
        return res


_POLICIES: Dict[str, RetrievalPolicy] = {}


def register_policy(cls: Type[RetrievalPolicy]) -> Type[RetrievalPolicy]:
    _POLICIES[cls.name] = cls()
    return cls


def get_policy(mode: str) -> RetrievalPolicy:
    if mode not in _POLICIES:
        raise KeyError(mode)
    return _POLICIES[mode]


def policy_names() -> Tuple[str, ...]:
    return tuple(_POLICIES)


@register_policy
class TeleRAGPolicy(RetrievalPolicy):
    """Lookahead prefetch overlapped with generation + hybrid search."""

    name = "telerag"
    prefetches = True

    def lookahead(self, engine, q_in, gen_tokens, *, now=0.0):
        B = q_in.shape[0]
        bud = engine.prefetch_budget(gen_tokens, B)
        ranked = probe(q_in, engine.index, min(engine.cfg.lookahead_rank,
                                               engine.index.num_clusters))
        # cache makes room first so the planner sees true free pages
        plan, _ = plan_batched_prefetch(
            list(ranked), engine.index.paged, budget_bytes=bud,
            resident=engine.buffer.resident_clusters(),
            free_pages=engine.buffer.free_pages())
        if plan.pages_planned > engine.buffer.free_pages():
            engine.cache.make_room(engine.buffer, plan.pages_planned)
        if plan.fetch:
            ev = engine.transfer.submit(
                plan.fetch, now=now, nbytes=plan.bytes_planned,
                make_room=lambda pages: engine.cache.make_room(engine.buffer,
                                                               pages))
        else:
            # nothing to move: no link event (a 0-byte event could still
            # inherit a channel-queue wait), but fold any queued device
            # invalidations exactly as the legacy load path did
            engine.buffer.load_clusters([])
            ev = None
        engine.cache.on_fetched(plan.fetch)
        return plan.bytes_planned, len(plan.fetch), ev

    def retrieve(self, engine, q_out, *, now=0.0):
        ranked_out = probe(q_out, engine.index, engine.cfg.nprobe)
        return self._hybrid_retrieve(engine, q_out, ranked_out)

    def transfer_ready_offset(self, rt, ctx):
        return rt.t_prefetch

    def search_seconds(self, rt, ctx):
        return max(rt.t_host_search, rt.t_dev_search) + rt.t_merge


@register_policy
class CpuBaselinePolicy(RetrievalPolicy):
    """Retrieval entirely on host (Faiss-CPU baseline)."""

    name = "cpu_baseline"

    def retrieve(self, engine, q_out, *, now=0.0):
        ranked_out = probe(q_out, engine.index, engine.cfg.nprobe)
        res_s, res_i, miss = [], [], []
        for b in range(q_out.shape[0]):
            cs = [int(c) for c in ranked_out[b]]
            s, i = host_search(engine.index.paged, cs, q_out[b],
                               engine.cfg.top_k)
            res_s.append(s)
            res_i.append(i)
            miss.append(cs)
        return RetrievalResult(doc_ids=np.stack(res_i),
                               scores=np.stack(res_s),
                               hit_clusters=[[] for _ in miss],
                               missed_clusters=miss,
                               nprobe=engine.cfg.nprobe)

    def search_seconds(self, rt, ctx):
        return (rt.hits + rt.misses) * ctx.t_cc


@register_policy
class RuntimeFetchPolicy(RetrievalPolicy):
    """Fetch-on-demand at retrieval time — no overlap (§3.2, Fig. 5)."""

    name = "runtime_fetch"

    def retrieve(self, engine, q_out, *, now=0.0):
        ranked_out = probe(q_out, engine.index, engine.cfg.nprobe)
        # fetch exactly the probed clusters now (not overlapped)
        need = sorted(set(int(c) for r in ranked_out for c in r))
        pages = sum(int(engine.index.paged.cluster_num_pages[c])
                    for c in need if not engine.buffer.is_resident(c))
        engine.cache.make_room(engine.buffer, pages)
        engine.transfer.submit(need, now=now, kind="demand",
                               nbytes=pages * engine.buffer.page_nbytes)
        return self._hybrid_retrieve(engine, q_out, ranked_out)

    def search_seconds(self, rt, ctx):
        nb = (rt.hits + rt.misses) * ctx.cluster_bytes
        return nb / ctx.link_bw + rt.t_dev_search + rt.t_merge
