"""Unified serving front-end: the paper's Fig. 7 system as ONE surface.

The public API used to be three disjoint layers callers had to
hand-wire — ``TeleRAGEngine`` (resources), ``RetrievalRuntime`` (one
replica's event loop), and ``MultiReplicaOrchestrator.run_global_batch``
(a *blocking* global batch that drained replicas serially in lockstep).
``TeleRAGServer`` replaces that with a client-facing facade and a
**continuous dispatcher on a shared global event clock**:

  * clients ``submit()`` typed ``RagRequest``s carrying an open-loop
    ``arrival_t`` (plus priority / SLO deadline);
  * at each arrival *wave* the prefetching scheduler groups the wave
    into micro-batches and the cache-aware scheduler routes them to
    replicas (the existing ``SchedulerPolicy``, reading live per-replica
    cache residency and ledger occupancy at the wave's clock time);
  * micro-batches queue per replica and execute on per-replica
    ``RetrievalRuntime``s that the dispatcher *merge-steps* — it always
    advances the runtime holding the globally-earliest event — so
    replica timelines interleave on one clock instead of draining one
    replica at a time.  Open-loop throughput and latency-under-load
    (queue wait + service) are measurable for the first time.

Within a replica the server runs one of two dispatch disciplines.  The
default (``continuous=False``) keeps one micro-batch in flight at a
time; queued batches dispatch the instant the runtime drains, and
``end_batch`` consolidation runs between batches exactly as the legacy
executor did — which is what pins the legacy-equivalence guarantee: for
simultaneous arrivals the server reproduces ``run_global_batch``'s doc
ids and round telemetry to 1e-6 (tests/test_api.py).

``continuous=True`` is **per-request continuous batching inside the
replica**: routed micro-batches are submitted into the live runtime
immediately, the runtime's dynamic wave former
(``SchedulerPolicy.reform_wave``) re-batches whichever requests are
ready at every round frontier — so a straggler never delays its former
batch-mates, new arrivals join in-flight work mid-stream, and the
dispatcher consumes per-request *completion events* instead of batch
drains.  See the "request lifecycle" section of docs/ARCHITECTURE.md.

``ServerTelemetry`` unifies what previously lived in four places —
``buffer.stats``, ``cache.hit_rate``, ``ledger.snapshot()``,
``admission.stats``, and the transfer-engine event list — into one
snapshot the serve drivers and smoke benches print, plus per-tenant
SLO attainment (see docs/TELEMETRY.md for the field reference).

Tenancy and SLOs are first-class: ``RagRequest.tenant`` makes waves
tenant-pure and admission tenant-scoped (per-tenant pool floors/caps
via ``EngineConfig.tenant_shares``), the default ``EdfDispatch`` orders
queued micro-batches by priority class then earliest deadline, and
responses split a deadline miss into missed-in-queue vs
missed-in-service (docs/ARCHITECTURE.md, "multi-tenant SLO-aware
serving").
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field, replace as dc_replace
from typing import (Callable, Dict, List, Optional, Sequence, Set, Tuple)

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.ivf import IVFIndex, probe
from repro.core.schedulers import (Assignment, DispatchPolicy, EdfDispatch,
                                   SchedulerPolicy)
from repro.memory.admission import AdmissionStats
from repro.obs import render as obs_render
from repro.obs.clock import EventClock
from repro.obs.metrics import MetricsRegistry
from repro.obs.recorder import CounterSample, FlightRecorder, RequestEvent
from repro.serving.engine import (EngineConfig, RoundTelemetry,
                                  TeleRAGEngine)
from repro.serving.runtime import (RequestRecord, RequestState,
                                   RetrievalRuntime, Span, percentile_line)
from repro.serving.trace import RequestTrace, make_trace


# ---------------------------------------------------------------------------
# Typed request / response lifecycle
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RagRequest:
    """One client request.

    ``pipeline`` names one of the six §5.1 pipelines (the server
    synthesizes a seeded trace); an explicit ``trace`` wins when given.
    ``arrival_t`` is seconds after the drain epoch starts (open-loop
    offered load).  ``tenant`` names who the request belongs to: waves
    are grouped tenant-pure, pool admission reserves against the
    tenant's floor/cap (``EngineConfig.tenant_shares``), and SLO
    attainment is reported per tenant.  The default ``"shared"`` is the
    untenanted sentinel used across the whole stack (no per-tenant
    ledger bytes are tracked for it).  ``priority`` is the dispatch
    priority *class* (lower dispatches first); ``deadline_s`` is an
    arrival→complete SLO bound in seconds — the default ``EdfDispatch``
    orders queued batches earliest-deadline-first within a priority
    class, and the response reports ``deadline_missed`` (split into
    missed-in-queue vs missed-in-service).
    """

    q: np.ndarray
    pipeline: Optional[str] = None
    trace: Optional[RequestTrace] = None
    arrival_t: float = 0.0
    priority: int = 0
    deadline_s: Optional[float] = None
    tenant: str = "shared"

    def __post_init__(self):
        if self.trace is None and self.pipeline is None:
            raise ValueError("RagRequest needs a pipeline name or a trace")


@dataclass(frozen=True)
class RagResponse:
    """One completed request: results + its event-clock life story.

    All timestamps are seconds on the shared global event clock.  The
    deadline flags split an SLO miss by *where* the time was lost:
    ``deadline_missed_in_queue`` means the deadline had already passed
    while the request was still waiting for a replica slot (before
    ``admit_t``) — so no amount of faster service could have saved it —
    while ``deadline_missed`` alone means service itself ran long.
    """

    request_id: int
    pipeline: str
    state: RequestState
    replica: int
    doc_ids: List[np.ndarray]
    rounds: List[RoundTelemetry]
    timeline: List[Span]
    arrival_t: float                 # absolute, on the shared event clock
    admit_t: float                   # dispatch onto the replica runtime
    complete_t: float
    deadline_missed: bool = False
    deadline_missed_in_queue: bool = False
    tenant: str = "shared"
    priority: int = 0
    deadline_s: Optional[float] = None
    demoted_rounds: int = 0          # rounds whose prefetch was demoted

    @property
    def queue_s(self) -> float:
        """Time spent waiting for a replica slot (arrival → admit, s)."""
        return self.admit_t - self.arrival_t

    @property
    def service_s(self) -> float:
        """Admit → complete on the replica's event clock (seconds)."""
        return self.complete_t - self.admit_t

    @property
    def latency_s(self) -> float:
        """End-to-end arrival → complete in seconds (what open-loop
        load inflates)."""
        return self.complete_t - self.arrival_t

    @property
    def stall_s(self) -> float:
        """Seconds parked ``PRESSURE_STALLED`` on pool admission (the
        part of service lost to memory pressure, summed over rounds)."""
        return sum(s.end - s.start for s in self.timeline
                   if s.kind == "pressure_stall")

    def breakdown(self) -> Dict[str, float]:
        """Seconds per lifecycle stage: queue wait plus the summed span
        durations (generate / transfer_wait / retrieve / pressure_stall
        / generate_tail)."""
        out: Dict[str, float] = {"queue": self.queue_s}
        for s in self.timeline:
            if s.end > s.start:
                out[s.kind] = out.get(s.kind, 0.0) + (s.end - s.start)
        return out


def summarize_latency(responses: Sequence[RagResponse]) -> str:
    """One-line nearest-rank p50/p95/mean of arrival→complete latencies
    (the open-loop analogue of ``runtime.latency_summary``)."""
    if not responses:
        return "arrival->complete: no completed requests"
    queue = float(np.mean([r.queue_s for r in responses]))
    return (f"arrival->complete "
            f"{percentile_line([r.latency_s for r in responses])} "
            f"queue_mean={queue*1e3:.1f}ms")


# ---------------------------------------------------------------------------
# Telemetry snapshot
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ReplicaTelemetry:
    """One replica's device-side counters at snapshot time."""

    replica: int
    bytes_h2d: int
    pages_h2d: int
    transfer_rounds: int
    cache_hit_rate: float
    ledger: Dict[str, int]
    occupancy: float
    admission: AdmissionStats
    transfers: int
    transfer_queued_s: float
    # chunk-KV effectiveness (empty dict when splicing is not enabled):
    # hit_rate, spliced_pages, prefill_tokens_avoided, prefetched_pages,
    # resident_pages, pinned_pages — see docs/TELEMETRY.md
    chunk_kv: Dict[str, float] = field(default_factory=dict)

    @classmethod
    def capture(cls, i: int, eng: TeleRAGEngine) -> "ReplicaTelemetry":
        """Snapshot replica ``i``'s engine counters (admission stats are
        copied, so the snapshot does not alias live state)."""
        chunk = getattr(eng, "chunk_kv", None)
        return cls(
            replica=i,
            bytes_h2d=eng.buffer.stats.bytes_h2d,
            pages_h2d=eng.buffer.stats.pages_h2d,
            transfer_rounds=eng.buffer.stats.rounds,
            cache_hit_rate=eng.cache.hit_rate,
            ledger=eng.ledger.snapshot(),
            occupancy=eng.ledger.occupancy(),
            admission=dc_replace(eng.admission.stats),
            transfers=len(eng.transfer.events),
            transfer_queued_s=sum(e.queued_s for e in eng.transfer.events),
            chunk_kv=({} if chunk is None else dict(
                chunk.stats.as_dict(),
                resident_pages=chunk.resident_pages(),
                pinned_pages=chunk.pinned_pages())))


@dataclass(frozen=True)
class TenantTelemetry:
    """One tenant's SLO attainment, accumulated over every completed
    response.  Latency percentiles are arrival→complete seconds on the
    event clock; ``stall_s`` is the summed ``PRESSURE_STALLED`` time
    attributable to pool admission; the miss counters match the
    per-response ``deadline_missed`` / ``deadline_missed_in_queue``
    flags exactly (pinned in tests/test_slo.py).  ``kv_bytes`` is the
    tenant's *live* decode-cache footprint summed across replica pools
    (tenant-tagged KV leases) at snapshot time."""

    tenant: str
    completed: int
    p50_latency_s: float
    p99_latency_s: float
    mean_queue_s: float
    stall_s: float
    with_deadline: int               # responses that carried an SLO bound
    deadline_missed: int
    missed_in_queue: int             # deadline passed before admit_t
    demoted_rounds: int              # prefetches demoted as already-missed
    kv_bytes: int = 0                # live KV-lease bytes across replicas
    chunk_kv_bytes: int = 0          # resident chunk-KV bytes attributed to
                                     # this tenant's loads across replicas

    @property
    def missed_in_service(self) -> int:
        """Misses where the request was admitted in time but service ran
        past the deadline (``deadline_missed - missed_in_queue``)."""
        return self.deadline_missed - self.missed_in_queue

    @property
    def attainment(self) -> float:
        """Fraction of deadline-carrying responses that met their SLO
        (1.0 when the tenant never set a deadline)."""
        if not self.with_deadline:
            return 1.0
        return 1.0 - self.deadline_missed / self.with_deadline

    def line(self) -> str:
        """One printable summary line for this tenant (the shared
        ``repro.obs.render`` formatter — same precision as replica
        rows)."""
        return obs_render.render_tenant_line(self)


@dataclass(frozen=True)
class ServerTelemetry:
    """One unified snapshot of the whole serving surface (previously
    scattered across buffer.stats, cache.hit_rate, ledger.snapshot(),
    admission.stats, and transfer events), plus per-tenant SLO
    attainment.  See docs/TELEMETRY.md for the field reference."""

    completed: int
    waves: int
    dispatched_batches: int
    clock_s: float
    replicas: Tuple[ReplicaTelemetry, ...]
    tenants: Tuple[TenantTelemetry, ...] = ()

    @property
    def bytes_h2d(self) -> int:
        """Lifetime H2D bytes summed across replicas."""
        return sum(r.bytes_h2d for r in self.replicas)

    @property
    def pages_h2d(self) -> int:
        """Lifetime H2D pages summed across replicas."""
        return sum(r.pages_h2d for r in self.replicas)

    @property
    def admission_stalled(self) -> int:
        """admit() refusals that parked a wave, summed across replicas."""
        return sum(r.admission.stalled for r in self.replicas)

    @property
    def admission_admitted(self) -> int:
        """Full-headroom admission tickets, summed across replicas."""
        return sum(r.admission.admitted for r in self.replicas)

    @property
    def spilled_pages(self) -> int:
        """Pages reclaimed by admission spill, summed across replicas."""
        return sum(r.admission.spilled_pages for r in self.replicas)

    @property
    def deadline_missed(self) -> int:
        """Deadline misses summed across tenants (== the number of
        completed responses whose ``deadline_missed`` flag is set)."""
        return sum(t.deadline_missed for t in self.tenants)

    def tenant(self, name: str) -> Optional["TenantTelemetry"]:
        """The named tenant's slice, or None if it never completed a
        request."""
        for t in self.tenants:
            if t.tenant == name:
                return t
        return None

    def summary(self) -> str:
        """Multi-line printable snapshot: fleet totals, one line per
        replica, one line per tenant — all through the shared
        ``repro.obs.render`` formatters (one precision everywhere)."""
        return obs_render.render_telemetry(self)


@dataclass(frozen=True)
class WaveDispatch:
    """Routing record of one arrival wave (what run_global_batch's
    report used to expose for the whole batch)."""

    t: float
    assignments: List[Tuple[int, int, int]]   # (batch_idx, replica, overlap)
    requeued: List[int]
    sched_overhead_s: float


# ---------------------------------------------------------------------------
# The server
# ---------------------------------------------------------------------------


@dataclass(eq=False)
class _Submitted:
    seq: int
    request: RagRequest
    trace: RequestTrace
    arrival_abs: float = 0.0
    replica: int = -1
    record: Optional[RequestRecord] = None


@dataclass(eq=False)
class _QueuedBatch:
    avail_t: float                   # earliest dispatch time (wave clock)
    priority: int
    order: int
    members: List[_Submitted]
    deadline_t: float = float("inf")  # earliest member deadline (absolute)
    tenant: str = "shared"


class _TenantAcc:
    """Per-tenant SLO accumulator backed by the server's metrics
    registry: every field is a first-class instrument (counter or
    histogram) keyed by tenant, and ``snapshot()`` is a *view* over
    them — numerically identical to the pre-registry list/float
    accumulator (``Histogram.percentile`` is ``np.percentile`` over
    the raw latency samples; pinned by tests/test_obs.py)."""

    def __init__(self, metrics: MetricsRegistry, tenant: str):
        self.tenant = tenant
        self._lat = metrics.histogram("request_latency_s", tenant=tenant)
        self._queue_s = metrics.counter("request_queue_s", tenant=tenant)
        self._stall_s = metrics.counter("request_stall_s", tenant=tenant)
        self._completed = metrics.counter("requests_completed",
                                          tenant=tenant)
        self._with_deadline = metrics.counter("requests_with_deadline",
                                              tenant=tenant)
        self._missed = metrics.counter("deadline_missed", tenant=tenant)
        self._missed_in_queue = metrics.counter("deadline_missed_in_queue",
                                                tenant=tenant)
        self._demoted = metrics.counter("demoted_rounds", tenant=tenant)

    @property
    def completed(self) -> int:
        return int(self._completed.value)

    def note(self, r: "RagResponse") -> None:
        self._lat.observe(r.latency_s)
        self._queue_s.inc(r.queue_s)
        self._stall_s.inc(r.stall_s)
        self._completed.inc()
        self._demoted.inc(r.demoted_rounds)
        if r.deadline_s is not None:
            self._with_deadline.inc()
            self._missed.inc(int(r.deadline_missed))
            self._missed_in_queue.inc(int(r.deadline_missed_in_queue))

    def snapshot(self, tenant: str, kv_bytes: int = 0,
                 chunk_kv_bytes: int = 0) -> TenantTelemetry:
        return TenantTelemetry(
            tenant=tenant, completed=self.completed,
            p50_latency_s=self._lat.percentile(50),
            p99_latency_s=self._lat.percentile(99),
            mean_queue_s=self._queue_s.value / max(1, self.completed),
            stall_s=self._stall_s.value,
            with_deadline=int(self._with_deadline.value),
            deadline_missed=int(self._missed.value),
            missed_in_queue=int(self._missed_in_queue.value),
            demoted_rounds=int(self._demoted.value),
            kv_bytes=int(kv_bytes), chunk_kv_bytes=int(chunk_kv_bytes))


class TeleRAGServer:
    """Client-facing facade over N replica engines + a continuous
    cross-replica dispatcher on one shared event clock."""

    def __init__(self, index: IVFIndex, cfg: EngineConfig,
                 num_replicas: int = 1,
                 arch: Optional[ArchConfig] = None, *,
                 scheduler: Optional[SchedulerPolicy] = None,
                 micro_batch: Optional[int] = None,
                 include_tail: bool = False,
                 batch_window_s: float = 0.0,
                 decode_hook: Optional[Callable] = None,
                 dispatch: Optional[DispatchPolicy] = None,
                 continuous: bool = False,
                 trace: Optional[FlightRecorder] = None,
                 wall_clock=None):
        """``scheduler=None`` forms FIFO micro-batches and routes them
        round-robin (persistent across waves); a ``SchedulerPolicy``
        enables the paper's similarity grouping + cache-aware routing.
        ``micro_batch=None`` keeps each wave whole.  ``batch_window_s``
        gathers open-loop arrivals within the window into one wave
        (0 = every distinct arrival instant is its own wave).
        ``decode_hook(replica, records, gen_tokens, round)`` runs real
        decode inside each round frontier, after the async prefetch
        dispatch — prefetch is dispatched exactly once, by the policy;
        it may return per-request ``DecodeEvent``s whose observed
        timing drives the event clock in place of the modeled window.
        ``dispatch`` orders each replica's queued micro-batches; the
        default ``EdfDispatch`` runs priority classes then earliest
        deadline first, which degrades to the legacy (priority, FIFO)
        order when no request sets a deadline.

        ``continuous=True`` enables per-request continuous batching
        inside each replica: routed micro-batches are submitted into
        the replica runtime *immediately* (no one-batch-at-a-time
        serialization), the runtime's dynamic wave former re-batches
        whichever requests are ready at every round frontier
        (``SchedulerPolicy.reform_wave``, ``micro_batch``-capped,
        tenant-pure), and the dispatcher consumes **per-request
        completion events** instead of waiting for batch drains.
        ``continuous=False`` (the default) keeps the legacy
        group-granular execution that the deprecated shims are pinned
        against: one micro-batch in flight per replica, ``end_batch``
        consolidation between batches.

        ``wall_clock`` is the injected real-time source for the few
        measurements that are genuinely about THIS machine (scheduler
        overhead, host-search calibration).  The default is the
        deterministic ``obs.clock.EventClock`` — identical inputs give
        identical traces; launch drivers that want real measurement
        pass ``obs.clock.SystemClock()``."""
        self.index = index
        self.cfg = cfg
        self.continuous = bool(continuous)
        # ONE flight recorder across the whole server: every replica's
        # runtime, pool, admission controller, transfer engine and KV
        # manager emits into the same stream, correlated by replica id
        # (pass ``trace=`` to cap capacity or share a recorder)
        self.recorder = trace if trace is not None else FlightRecorder()
        self.wall = wall_clock if wall_clock is not None \
            else EventClock(self.recorder)
        self.metrics = MetricsRegistry()
        self.engines = [TeleRAGEngine(index, cfg, arch,
                                      wall_clock=self.wall)
                        for _ in range(num_replicas)]
        for i, eng in enumerate(self.engines):
            eng.attach_recorder(self.recorder, i)
        # under continuous dispatch the runtime's wave former IS the
        # scheduler policy (its reform_wave hook); the static path keeps
        # runtimes scheduler-free because the server already grouped
        self.runtimes = [
            RetrievalRuntime(
                eng, include_tail=include_tail,
                reform=self.continuous,
                scheduler=(scheduler if self.continuous else None),
                micro_batch=(micro_batch if self.continuous else None),
                on_complete=((lambda rec, _r=r:
                              self._on_request_complete(_r, rec))
                             if self.continuous else None),
                on_generate=(None if decode_hook is None else
                             (lambda recs, toks, rnd, _r=r:
                              decode_hook(_r, recs, toks, rnd))))
            for r, eng in enumerate(self.engines)]
        self.scheduler = scheduler
        self.dispatch = dispatch if dispatch is not None else EdfDispatch()
        self.micro_batch = micro_batch
        self.batch_window_s = float(batch_window_s)
        self.dead: Set[int] = set()
        self.nprobe_for_sched = min(64, index.num_clusters)
        self.wave_log: List[WaveDispatch] = []
        self.last_records: List[RequestRecord] = []
        self.last_responses: List[RagResponse] = []
        self._seq = itertools.count()
        self._order = itertools.count()
        self._inbox: List[_Submitted] = []
        self._queues: List[List[_QueuedBatch]] = [
            [] for _ in range(num_replicas)]
        self._busy = [False] * num_replicas
        self._rr = 0                       # round-robin cursor (no scheduler)
        self._global_now = 0.0
        # lifetime counts live in the registry; telemetry() reads them
        self._c_completed = self.metrics.counter("server_completed")
        self._c_waves = self.metrics.counter("server_waves")
        self._c_batches = self.metrics.counter("server_batches")
        self._tenant_acc: Dict[str, _TenantAcc] = {}

    # ---- replica health ----------------------------------------------------
    def mark_dead(self, replica: int) -> None:
        """Exclude a replica from routing; its queued batches re-route
        on the next wave (recorded in ``WaveDispatch.requeued``)."""
        self.dead.add(int(replica))

    def mark_alive(self, replica: int) -> None:
        """Return a previously ``mark_dead``ed replica to routing."""
        self.dead.discard(int(replica))

    # ---- submission --------------------------------------------------------
    def submit(self, request: RagRequest) -> int:
        """Queue one request for the next drain; returns its request id."""
        seq = next(self._seq)
        trace = request.trace
        if trace is None:
            trace = make_trace(request.pipeline, seq,
                               np.random.default_rng(self.cfg.seed + seq))
        self._inbox.append(_Submitted(seq=seq, request=request, trace=trace))
        return trace.request_id

    def serve(self, requests: Sequence[RagRequest]) -> List[RagResponse]:
        """submit() them all, then drain()."""
        for r in requests:
            self.submit(r)
        return self.drain()

    # ---- the continuous dispatcher ----------------------------------------
    def drain(self) -> List[RagResponse]:
        """Run the dispatcher until every submitted request completes;
        responses come back in submission order.

        The loop merges two event sources on the shared clock: arrival
        waves (grouped + routed when their time comes) and the replica
        runtimes' own event heaps (always stepping the globally-earliest
        one, so replica timelines interleave)."""
        if not self._inbox:
            return []
        subs, self._inbox = self._inbox, []
        try:
            epoch = max([self._global_now]
                        + [rt.now for rt in self.runtimes])
            for s in subs:
                s.arrival_abs = epoch + max(0.0, float(s.request.arrival_t))
                # server-side arrival mark: the analyzer's queue-time
                # attribution reads submit -> (replica) admit
                self.recorder.emit(RequestEvent(
                    t=s.arrival_abs, kind="request", replica=-1,
                    request_id=s.trace.request_id,
                    tenant=s.request.tenant, label="submit"))
            waves = self._form_waves(subs)
            wi = 0
            while (wi < len(waves)
                   or any(rt.has_work() for rt in self.runtimes)):
                nxt: Optional[Tuple[float, int]] = None
                for r, rt in enumerate(self.runtimes):
                    t = rt.next_event_t()
                    if t is not None and (nxt is None or t < nxt[0]):
                        nxt = (t, r)
                if wi < len(waves) and (nxt is None
                                        or waves[wi][0] <= nxt[0]):
                    wave_t, members = waves[wi]
                    wi += 1
                    self._route_wave(wave_t, members)
                else:
                    t, r = nxt
                    rt = self.runtimes[r]
                    rt.step()
                    if not rt.has_work():
                        self._complete_batch(r)
        except BaseException:
            # a failed drain must not swallow work the caller handed us:
            # requests never dispatched to a replica go back to the inbox
            # so a retry after recovery (e.g. mark_alive) serves them;
            # ones already on a failed runtime cannot be replayed safely
            self._inbox = [s for s in subs if s.record is None] + self._inbox
            raise
        self._global_now = max([self._global_now]
                               + [rt.now for rt in self.runtimes])
        ordered = sorted(subs, key=lambda s: s.seq)
        responses = [self._response(s) for s in ordered]
        self.last_records = [s.record for s in ordered]
        self.last_responses = responses
        return responses

    def telemetry(self) -> ServerTelemetry:
        """One unified snapshot across every replica's counters, plus
        per-tenant SLO attainment accumulated over completed responses."""
        return ServerTelemetry(
            completed=int(self._c_completed.value),
            waves=int(self._c_waves.value),
            dispatched_batches=int(self._c_batches.value),
            clock_s=self._global_now,
            replicas=tuple(ReplicaTelemetry.capture(i, e)
                           for i, e in enumerate(self.engines)),
            tenants=tuple(
                acc.snapshot(t, kv_bytes=sum(
                    e.pool.tenant_bytes(t, owner="kv")
                    for e in self.engines),
                    chunk_kv_bytes=sum(
                        e.pool.tenant_bytes(t, owner="chunk_kv")
                        for e in self.engines))
                for t, acc in sorted(self._tenant_acc.items())))

    # ---- internals ---------------------------------------------------------
    def _form_waves(self, subs: List[_Submitted],
                    ) -> List[Tuple[float, List[_Submitted]]]:
        """Partition arrivals into waves.  A wave opens at its first
        arrival and closes ``batch_window_s`` later; it fires at its
        last member's arrival (== the first's when the window is 0)."""
        subs = sorted(subs, key=lambda s: (s.arrival_abs, s.seq))
        waves: List[Tuple[float, List[_Submitted]]] = []
        cur: List[_Submitted] = []
        t0 = 0.0
        for s in subs:
            if cur and s.arrival_abs - t0 > self.batch_window_s + 1e-12:
                waves.append((cur[-1].arrival_abs, cur))
                cur = []
            if not cur:
                t0 = s.arrival_abs
            cur.append(s)
        if cur:
            waves.append((cur[-1].arrival_abs, cur))
        return waves

    def _route_wave(self, wave_t: float, members: List[_Submitted]) -> None:
        """Group the wave into micro-batches and route them to replica
        queues — reading each replica's *live* cache residency, ledger
        occupancy, and per-tenant pool occupancy at the wave's clock
        time.  Micro-batches are tenant-pure: similarity grouping runs
        within each tenant's slice of the wave, so admission
        reservations and ledger attribution are well-defined per batch
        (a single-tenant wave reduces to the legacy grouping exactly)."""
        t0 = self.wall.perf()
        q = np.stack([np.asarray(s.request.q) for s in members])
        mb = self.micro_batch or len(members)
        by_tenant: Dict[str, List[int]] = {}
        for i, s in enumerate(members):
            by_tenant.setdefault(s.request.tenant, []).append(i)
        groups: List[List[int]] = []
        for idxs in by_tenant.values():
            if self.scheduler is not None:
                sub = self.scheduler.group(q[idxs], mb)
            else:
                sub = [list(range(i, min(i + mb, len(idxs))))
                       for i in range(0, len(idxs), mb)]
            groups.extend([idxs[j] for j in grp] for grp in sub)
        if self.scheduler is not None:
            if self.scheduler.needs_cluster_hints:
                batch_clusters = []
                for g in groups:
                    ranked = probe(q[g], self.index, self.nprobe_for_sched)
                    batch_clusters.append(
                        set(int(c) for r in ranked for c in r))
            else:
                batch_clusters = [set() for _ in groups]
            caches = [e.buffer.resident_clusters() for e in self.engines]
            occupancy = [e.ledger.occupancy() for e in self.engines]
            # the untenanted sentinel gets no spread penalty: legacy
            # single-tenant routing must see exactly the PR-3 scores
            tenant_occupancy = [
                [0.0 for _ in self.engines]
                if members[g[0]].request.tenant == "shared" else
                [e.pool.tenant_pages(members[g[0]].request.tenant)
                 / max(1, e.pool.num_pages) for e in self.engines]
                for g in groups]
            assigns = self.scheduler.assign(batch_clusters, caches,
                                            occupancy=occupancy,
                                            tenant_occupancy=tenant_occupancy)
        else:
            assigns = []
            for i in range(len(groups)):
                assigns.append(Assignment(
                    replica=self._rr % len(self.engines),
                    batch_index=i, overlap=0))
                self._rr += 1
        alive = [i for i in range(len(self.engines)) if i not in self.dead]
        if not alive:
            raise RuntimeError("no healthy replicas")
        requeued: List[int] = []
        fixed: List[Assignment] = []
        for a in assigns:
            if a.replica in self.dead:
                requeued.append(a.batch_index)
                a = Assignment(replica=alive[a.batch_index % len(alive)],
                               batch_index=a.batch_index, overlap=0)
            fixed.append(a)
        self.wave_log.append(WaveDispatch(
            t=wave_t,
            assignments=[(a.batch_index, a.replica, a.overlap)
                         for a in fixed],
            requeued=requeued,
            sched_overhead_s=self.wall.perf() - t0))
        self._c_waves.inc()
        # occupancy time series on the event clock: one sample per
        # replica at every routed wave (what a control loop consumes)
        for i, e in enumerate(self.engines):
            self.metrics.series("ledger_occupancy", replica=i).sample(
                wave_t, e.ledger.occupancy())
        touched = []
        for a in fixed:
            batch = [members[i] for i in groups[a.batch_index]]
            for s in batch:
                s.replica = a.replica
            self._queues[a.replica].append(_QueuedBatch(
                avail_t=wave_t,
                priority=min(s.request.priority for s in batch),
                deadline_t=min(self._deadline_abs(s) for s in batch),
                tenant=batch[0].request.tenant,
                order=next(self._order), members=batch))
            touched.append(a.replica)
        for r in dict.fromkeys(touched):
            self.recorder.emit(CounterSample(
                t=wave_t, kind="counter", replica=r,
                name="queue_depth", value=float(len(self._queues[r]))))
            self._maybe_dispatch(r)

    @staticmethod
    def _deadline_abs(s: _Submitted) -> float:
        """A submission's absolute event-clock deadline in seconds
        (``inf`` when the request carries no SLO bound)."""
        if s.request.deadline_s is None:
            return float("inf")
        return s.arrival_abs + float(s.request.deadline_s)

    def _maybe_dispatch(self, r: int) -> None:
        """Feed the replica's best queued micro-batch to its runtime the
        moment it is idle — at the later of the wave's clock time and
        the runtime's own clock.  "Best" is the ``DispatchPolicy``'s
        call: the default EDF order runs priority classes first and the
        earliest absolute deadline within a class (pure head-of-line
        FIFO when nothing carries a deadline).  Under ``continuous``
        dispatch there is no idle gate: every queued micro-batch is
        submitted into the (possibly mid-flight) runtime immediately —
        its requests join waves at the next round frontier."""
        if not self.continuous and self._busy[r]:
            return
        qr = self._queues[r]
        rt = self.runtimes[r]
        submitted = False
        while qr:
            pick = min(range(len(qr)),
                       key=lambda i: self.dispatch.key(
                           priority=qr[i].priority,
                           deadline_t=qr[i].deadline_t,
                           order=qr[i].order, now=rt.now))
            batch = qr.pop(pick)
            t_disp = max(batch.avail_t, rt.now)
            for s in batch.members:
                s.record = rt.submit(s.request.q, s.trace, arrival_t=t_disp,
                                     tenant=s.request.tenant,
                                     priority=s.request.priority,
                                     deadline_t=self._deadline_abs(s))
            submitted = True
            self._c_batches.inc()
            if not self.continuous:
                rt.begin(rebase=False)
                self._busy[r] = True
                return
        if submitted:
            # one begin() for everything this call queued: begin scans
            # ALL pending submissions, so per-batch calls would push
            # duplicate admit events (O(k^2) heap traffic per wave)
            rt.begin(rebase=False)

    def _on_request_complete(self, r: int, rec: RequestRecord) -> None:
        """Per-request completion event from a continuous replica
        runtime — the dispatcher's unit of progress under per-request
        batching (the legacy path instead counts whole batch drains in
        ``_complete_batch``)."""
        self._c_completed.inc()

    def _complete_batch(self, r: int) -> None:
        """A replica drained its in-flight work: consolidate the engine
        (end_batch, as the legacy per-group executor did) and dispatch
        the next queued batch at the replica's clock.  Under continuous
        dispatch completions were already counted per request, so this
        only consolidates."""
        recs = self.runtimes[r].collect()
        if not self.continuous:
            self._c_completed.inc(len(recs))
        self._busy[r] = False
        self._maybe_dispatch(r)

    def _response(self, s: _Submitted) -> RagResponse:
        """Fold one finished submission into a RagResponse, stamping
        the deadline verdict (split into missed-in-queue — the deadline
        had already passed before the request ever reached a replica —
        vs missed-in-service) and accumulating the tenant's SLO stats."""
        rec = s.record
        deadline_abs = self._deadline_abs(s)
        missed = rec.complete_t > deadline_abs + 1e-12
        missed_in_queue = rec.admit_t > deadline_abs + 1e-12
        resp = RagResponse(
            request_id=rec.request_id, pipeline=rec.pipeline,
            state=rec.state, replica=s.replica,
            doc_ids=list(rec.result.doc_ids),
            rounds=list(rec.result.rounds),
            timeline=list(rec.timeline),
            arrival_t=s.arrival_abs, admit_t=rec.admit_t,
            complete_t=rec.complete_t, deadline_missed=missed,
            deadline_missed_in_queue=missed_in_queue,
            tenant=s.request.tenant, priority=s.request.priority,
            deadline_s=s.request.deadline_s,
            demoted_rounds=rec.demoted_rounds)
        tenant = s.request.tenant
        if tenant not in self._tenant_acc:
            self._tenant_acc[tenant] = _TenantAcc(self.metrics, tenant)
        self._tenant_acc[tenant].note(resp)
        if s.request.deadline_s is not None:
            # attainment time series: 1/0 per deadline-carrying response
            # at its completion time (mean over a window = attainment)
            self.metrics.series("attainment", tenant=tenant).sample(
                rec.complete_t, 0.0 if missed else 1.0)
        return resp
