"""Unified serving front-end: the paper's Fig. 7 system as ONE surface.

The public API used to be three disjoint layers callers had to
hand-wire — ``TeleRAGEngine`` (resources), ``RetrievalRuntime`` (one
replica's event loop), and ``MultiReplicaOrchestrator.run_global_batch``
(a *blocking* global batch that drained replicas serially in lockstep).
``TeleRAGServer`` replaces that with a client-facing facade and a
**continuous dispatcher on a shared global event clock**:

  * clients ``submit()`` typed ``RagRequest``s carrying an open-loop
    ``arrival_t`` (plus priority / SLO deadline);
  * at each arrival *wave* the prefetching scheduler groups the wave
    into micro-batches and the cache-aware scheduler routes them to
    replicas (the existing ``SchedulerPolicy``, reading live per-replica
    cache residency and ledger occupancy at the wave's clock time);
  * micro-batches queue per replica and execute on per-replica
    ``RetrievalRuntime``s that the dispatcher *merge-steps* — it always
    advances the runtime holding the globally-earliest event — so
    replica timelines interleave on one clock instead of draining one
    replica at a time.  Open-loop throughput and latency-under-load
    (queue wait + service) are measurable for the first time.

Within a replica, one micro-batch is in flight at a time (a GPU decodes
one micro-batch's windows at a time); queued batches dispatch the
instant the runtime drains, and ``end_batch`` consolidation runs between
batches exactly as the legacy executor did — which is what pins the
legacy-equivalence guarantee: for simultaneous arrivals the server
reproduces ``run_global_batch``'s doc ids and round telemetry to 1e-6
(tests/test_api.py).  Per-request rounds *across* micro-batches on one
replica are the ROADMAP follow-up this API is shaped for.

``ServerTelemetry`` unifies what previously lived in four places —
``buffer.stats``, ``cache.hit_rate``, ``ledger.snapshot()``,
``admission.stats``, and the transfer-engine event list — into one
snapshot the serve drivers and smoke benches print.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, replace as dc_replace
from typing import (Callable, Dict, List, Optional, Sequence, Set, Tuple)

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.ivf import IVFIndex, probe
from repro.core.schedulers import Assignment, SchedulerPolicy
from repro.memory.admission import AdmissionStats
from repro.serving.engine import (EngineConfig, RoundTelemetry,
                                  TeleRAGEngine)
from repro.serving.runtime import (RequestRecord, RequestState,
                                   RetrievalRuntime, Span, percentile_line)
from repro.serving.trace import RequestTrace, make_trace


# ---------------------------------------------------------------------------
# Typed request / response lifecycle
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RagRequest:
    """One client request.

    ``pipeline`` names one of the six §5.1 pipelines (the server
    synthesizes a seeded trace); an explicit ``trace`` wins when given.
    ``arrival_t`` is seconds after the drain epoch starts (open-loop
    offered load); ``priority`` breaks dispatch ties in a replica's
    queue (lower first); ``deadline_s`` is an arrival→complete SLO bound
    stamped onto the response as ``deadline_missed``.
    """

    q: np.ndarray
    pipeline: Optional[str] = None
    trace: Optional[RequestTrace] = None
    arrival_t: float = 0.0
    priority: int = 0
    deadline_s: Optional[float] = None

    def __post_init__(self):
        if self.trace is None and self.pipeline is None:
            raise ValueError("RagRequest needs a pipeline name or a trace")


@dataclass(frozen=True)
class RagResponse:
    """One completed request: results + its event-clock life story."""

    request_id: int
    pipeline: str
    state: RequestState
    replica: int
    doc_ids: List[np.ndarray]
    rounds: List[RoundTelemetry]
    timeline: List[Span]
    arrival_t: float                 # absolute, on the shared event clock
    admit_t: float                   # dispatch onto the replica runtime
    complete_t: float
    deadline_missed: bool = False

    @property
    def queue_s(self) -> float:
        """Time spent waiting for a replica slot (arrival → admit)."""
        return self.admit_t - self.arrival_t

    @property
    def service_s(self) -> float:
        """Admit → complete on the replica's event clock."""
        return self.complete_t - self.admit_t

    @property
    def latency_s(self) -> float:
        """End-to-end arrival → complete (what open-loop load inflates)."""
        return self.complete_t - self.arrival_t

    def breakdown(self) -> Dict[str, float]:
        """Seconds per lifecycle stage: queue wait plus the summed span
        durations (generate / transfer_wait / retrieve / pressure_stall
        / generate_tail)."""
        out: Dict[str, float] = {"queue": self.queue_s}
        for s in self.timeline:
            if s.end > s.start:
                out[s.kind] = out.get(s.kind, 0.0) + (s.end - s.start)
        return out


def summarize_latency(responses: Sequence[RagResponse]) -> str:
    """One-line nearest-rank p50/p95/mean of arrival→complete latencies
    (the open-loop analogue of ``runtime.latency_summary``)."""
    if not responses:
        return "arrival->complete: no completed requests"
    queue = float(np.mean([r.queue_s for r in responses]))
    return (f"arrival->complete "
            f"{percentile_line([r.latency_s for r in responses])} "
            f"queue_mean={queue*1e3:.1f}ms")


# ---------------------------------------------------------------------------
# Telemetry snapshot
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ReplicaTelemetry:
    """One replica's device-side counters at snapshot time."""

    replica: int
    bytes_h2d: int
    pages_h2d: int
    transfer_rounds: int
    cache_hit_rate: float
    ledger: Dict[str, int]
    occupancy: float
    admission: AdmissionStats
    transfers: int
    transfer_queued_s: float

    @classmethod
    def capture(cls, i: int, eng: TeleRAGEngine) -> "ReplicaTelemetry":
        return cls(
            replica=i,
            bytes_h2d=eng.buffer.stats.bytes_h2d,
            pages_h2d=eng.buffer.stats.pages_h2d,
            transfer_rounds=eng.buffer.stats.rounds,
            cache_hit_rate=eng.cache.hit_rate,
            ledger=eng.ledger.snapshot(),
            occupancy=eng.ledger.occupancy(),
            admission=dc_replace(eng.admission.stats),
            transfers=len(eng.transfer.events),
            transfer_queued_s=sum(e.queued_s for e in eng.transfer.events))


@dataclass(frozen=True)
class ServerTelemetry:
    """One unified snapshot of the whole serving surface (previously
    scattered across buffer.stats, cache.hit_rate, ledger.snapshot(),
    admission.stats, and transfer events)."""

    completed: int
    waves: int
    dispatched_batches: int
    clock_s: float
    replicas: Tuple[ReplicaTelemetry, ...]

    @property
    def bytes_h2d(self) -> int:
        return sum(r.bytes_h2d for r in self.replicas)

    @property
    def pages_h2d(self) -> int:
        return sum(r.pages_h2d for r in self.replicas)

    @property
    def admission_stalled(self) -> int:
        return sum(r.admission.stalled for r in self.replicas)

    @property
    def admission_admitted(self) -> int:
        return sum(r.admission.admitted for r in self.replicas)

    @property
    def spilled_pages(self) -> int:
        return sum(r.admission.spilled_pages for r in self.replicas)

    def summary(self) -> str:
        lines = [
            f"server: {self.completed} completed / {self.waves} waves / "
            f"{self.dispatched_batches} micro-batches, "
            f"clock={self.clock_s*1e3:.1f}ms, "
            f"h2d={self.bytes_h2d/1e6:.1f}MB, "
            f"admission admitted={self.admission_admitted} "
            f"stalled={self.admission_stalled} "
            f"spilled_pages={self.spilled_pages}"]
        for r in self.replicas:
            led = r.ledger
            lines.append(
                f"  replica {r.replica}: h2d={r.bytes_h2d/1e6:.1f}MB "
                f"cache_hit={r.cache_hit_rate:.0%} "
                f"occ={r.occupancy:.1%} "
                f"prefetch={led.get('prefetch', 0)/1e6:.2f}MB "
                f"kv={led.get('kv', 0)/1e6:.2f}MB "
                f"peak={led.get('peak', 0)/1e9:.2f}GB "
                f"transfers={r.transfers} "
                f"(queued {r.transfer_queued_s*1e3:.1f}ms)")
        return "\n".join(lines)


@dataclass(frozen=True)
class WaveDispatch:
    """Routing record of one arrival wave (what run_global_batch's
    report used to expose for the whole batch)."""

    t: float
    assignments: List[Tuple[int, int, int]]   # (batch_idx, replica, overlap)
    requeued: List[int]
    sched_overhead_s: float


# ---------------------------------------------------------------------------
# The server
# ---------------------------------------------------------------------------


@dataclass(eq=False)
class _Submitted:
    seq: int
    request: RagRequest
    trace: RequestTrace
    arrival_abs: float = 0.0
    replica: int = -1
    record: Optional[RequestRecord] = None


@dataclass(eq=False)
class _QueuedBatch:
    avail_t: float                   # earliest dispatch time (wave clock)
    priority: int
    order: int
    members: List[_Submitted]


class TeleRAGServer:
    """Client-facing facade over N replica engines + a continuous
    cross-replica dispatcher on one shared event clock."""

    def __init__(self, index: IVFIndex, cfg: EngineConfig,
                 num_replicas: int = 1,
                 arch: Optional[ArchConfig] = None, *,
                 scheduler: Optional[SchedulerPolicy] = None,
                 micro_batch: Optional[int] = None,
                 include_tail: bool = False,
                 batch_window_s: float = 0.0,
                 decode_hook: Optional[Callable] = None):
        """``scheduler=None`` forms FIFO micro-batches and routes them
        round-robin (persistent across waves); a ``SchedulerPolicy``
        enables the paper's similarity grouping + cache-aware routing.
        ``micro_batch=None`` keeps each wave whole.  ``batch_window_s``
        gathers open-loop arrivals within the window into one wave
        (0 = every distinct arrival instant is its own wave).
        ``decode_hook(replica, records, gen_tokens, round)`` runs real
        decode inside each round frontier, after the async prefetch
        dispatch — prefetch is dispatched exactly once, by the policy."""
        self.index = index
        self.cfg = cfg
        self.engines = [TeleRAGEngine(index, cfg, arch)
                        for _ in range(num_replicas)]
        self.runtimes = [
            RetrievalRuntime(
                eng, include_tail=include_tail,
                on_generate=(None if decode_hook is None else
                             (lambda recs, toks, rnd, _r=r:
                              decode_hook(_r, recs, toks, rnd))))
            for r, eng in enumerate(self.engines)]
        self.scheduler = scheduler
        self.micro_batch = micro_batch
        self.batch_window_s = float(batch_window_s)
        self.dead: Set[int] = set()
        self.nprobe_for_sched = min(64, index.num_clusters)
        self.wave_log: List[WaveDispatch] = []
        self.last_records: List[RequestRecord] = []
        self.last_responses: List[RagResponse] = []
        self._seq = itertools.count()
        self._order = itertools.count()
        self._inbox: List[_Submitted] = []
        self._queues: List[List[_QueuedBatch]] = [
            [] for _ in range(num_replicas)]
        self._busy = [False] * num_replicas
        self._rr = 0                       # round-robin cursor (no scheduler)
        self._global_now = 0.0
        self._n_completed = 0
        self._n_waves = 0
        self._n_batches = 0

    # ---- replica health ----------------------------------------------------
    def mark_dead(self, replica: int) -> None:
        self.dead.add(int(replica))

    def mark_alive(self, replica: int) -> None:
        self.dead.discard(int(replica))

    # ---- submission --------------------------------------------------------
    def submit(self, request: RagRequest) -> int:
        """Queue one request for the next drain; returns its request id."""
        seq = next(self._seq)
        trace = request.trace
        if trace is None:
            trace = make_trace(request.pipeline, seq,
                               np.random.default_rng(self.cfg.seed + seq))
        self._inbox.append(_Submitted(seq=seq, request=request, trace=trace))
        return trace.request_id

    def serve(self, requests: Sequence[RagRequest]) -> List[RagResponse]:
        """submit() them all, then drain()."""
        for r in requests:
            self.submit(r)
        return self.drain()

    # ---- the continuous dispatcher ----------------------------------------
    def drain(self) -> List[RagResponse]:
        """Run the dispatcher until every submitted request completes;
        responses come back in submission order.

        The loop merges two event sources on the shared clock: arrival
        waves (grouped + routed when their time comes) and the replica
        runtimes' own event heaps (always stepping the globally-earliest
        one, so replica timelines interleave)."""
        if not self._inbox:
            return []
        subs, self._inbox = self._inbox, []
        try:
            epoch = max([self._global_now]
                        + [rt.now for rt in self.runtimes])
            for s in subs:
                s.arrival_abs = epoch + max(0.0, float(s.request.arrival_t))
            waves = self._form_waves(subs)
            wi = 0
            while (wi < len(waves)
                   or any(rt.has_work() for rt in self.runtimes)):
                nxt: Optional[Tuple[float, int]] = None
                for r, rt in enumerate(self.runtimes):
                    t = rt.next_event_t()
                    if t is not None and (nxt is None or t < nxt[0]):
                        nxt = (t, r)
                if wi < len(waves) and (nxt is None
                                        or waves[wi][0] <= nxt[0]):
                    wave_t, members = waves[wi]
                    wi += 1
                    self._route_wave(wave_t, members)
                else:
                    t, r = nxt
                    rt = self.runtimes[r]
                    rt.step()
                    if not rt.has_work():
                        self._complete_batch(r)
        except BaseException:
            # a failed drain must not swallow work the caller handed us:
            # requests never dispatched to a replica go back to the inbox
            # so a retry after recovery (e.g. mark_alive) serves them;
            # ones already on a failed runtime cannot be replayed safely
            self._inbox = [s for s in subs if s.record is None] + self._inbox
            raise
        self._global_now = max([self._global_now]
                               + [rt.now for rt in self.runtimes])
        ordered = sorted(subs, key=lambda s: s.seq)
        responses = [self._response(s) for s in ordered]
        self.last_records = [s.record for s in ordered]
        self.last_responses = responses
        return responses

    def telemetry(self) -> ServerTelemetry:
        """One unified snapshot across every replica's counters."""
        return ServerTelemetry(
            completed=self._n_completed, waves=self._n_waves,
            dispatched_batches=self._n_batches,
            clock_s=self._global_now,
            replicas=tuple(ReplicaTelemetry.capture(i, e)
                           for i, e in enumerate(self.engines)))

    # ---- internals ---------------------------------------------------------
    def _form_waves(self, subs: List[_Submitted],
                    ) -> List[Tuple[float, List[_Submitted]]]:
        """Partition arrivals into waves.  A wave opens at its first
        arrival and closes ``batch_window_s`` later; it fires at its
        last member's arrival (== the first's when the window is 0)."""
        subs = sorted(subs, key=lambda s: (s.arrival_abs, s.seq))
        waves: List[Tuple[float, List[_Submitted]]] = []
        cur: List[_Submitted] = []
        t0 = 0.0
        for s in subs:
            if cur and s.arrival_abs - t0 > self.batch_window_s + 1e-12:
                waves.append((cur[-1].arrival_abs, cur))
                cur = []
            if not cur:
                t0 = s.arrival_abs
            cur.append(s)
        if cur:
            waves.append((cur[-1].arrival_abs, cur))
        return waves

    def _route_wave(self, wave_t: float, members: List[_Submitted]) -> None:
        """Group the wave into micro-batches and route them to replica
        queues — reading each replica's *live* cache residency and
        ledger occupancy at the wave's clock time."""
        t0 = time.perf_counter()
        q = np.stack([np.asarray(s.request.q) for s in members])
        mb = self.micro_batch or len(members)
        if self.scheduler is not None:
            groups = self.scheduler.group(q, mb)
        else:
            groups = [list(range(i, min(i + mb, len(members))))
                      for i in range(0, len(members), mb)]
        if self.scheduler is not None:
            if self.scheduler.needs_cluster_hints:
                batch_clusters = []
                for g in groups:
                    ranked = probe(q[g], self.index, self.nprobe_for_sched)
                    batch_clusters.append(
                        set(int(c) for r in ranked for c in r))
            else:
                batch_clusters = [set() for _ in groups]
            caches = [e.buffer.resident_clusters() for e in self.engines]
            occupancy = [e.ledger.occupancy() for e in self.engines]
            assigns = self.scheduler.assign(batch_clusters, caches,
                                            occupancy=occupancy)
        else:
            assigns = []
            for i in range(len(groups)):
                assigns.append(Assignment(
                    replica=self._rr % len(self.engines),
                    batch_index=i, overlap=0))
                self._rr += 1
        alive = [i for i in range(len(self.engines)) if i not in self.dead]
        if not alive:
            raise RuntimeError("no healthy replicas")
        requeued: List[int] = []
        fixed: List[Assignment] = []
        for a in assigns:
            if a.replica in self.dead:
                requeued.append(a.batch_index)
                a = Assignment(replica=alive[a.batch_index % len(alive)],
                               batch_index=a.batch_index, overlap=0)
            fixed.append(a)
        self.wave_log.append(WaveDispatch(
            t=wave_t,
            assignments=[(a.batch_index, a.replica, a.overlap)
                         for a in fixed],
            requeued=requeued,
            sched_overhead_s=time.perf_counter() - t0))
        self._n_waves += 1
        touched = []
        for a in fixed:
            batch = [members[i] for i in groups[a.batch_index]]
            for s in batch:
                s.replica = a.replica
            self._queues[a.replica].append(_QueuedBatch(
                avail_t=wave_t,
                priority=min(s.request.priority for s in batch),
                order=next(self._order), members=batch))
            touched.append(a.replica)
        for r in dict.fromkeys(touched):
            self._maybe_dispatch(r)

    def _maybe_dispatch(self, r: int) -> None:
        """Feed the replica's next queued micro-batch to its runtime the
        moment it is idle — at the later of the wave's clock time and
        the runtime's own clock (head-of-line service)."""
        if self._busy[r] or not self._queues[r]:
            return
        qr = self._queues[r]
        pick = min(range(len(qr)), key=lambda i: (qr[i].priority,
                                                  qr[i].order))
        batch = qr.pop(pick)
        rt = self.runtimes[r]
        t_disp = max(batch.avail_t, rt.now)
        for s in batch.members:
            s.record = rt.submit(s.request.q, s.trace, arrival_t=t_disp)
        rt.begin(rebase=False)
        self._busy[r] = True
        self._n_batches += 1

    def _complete_batch(self, r: int) -> None:
        """A replica drained its in-flight micro-batch: consolidate the
        engine (end_batch, as the legacy per-group executor did) and
        dispatch the next queued batch at the replica's clock."""
        recs = self.runtimes[r].collect()
        self._n_completed += len(recs)
        self._busy[r] = False
        self._maybe_dispatch(r)

    def _response(self, s: _Submitted) -> RagResponse:
        rec = s.record
        missed = (s.request.deadline_s is not None
                  and (rec.complete_t - s.arrival_abs
                       > s.request.deadline_s + 1e-12))
        return RagResponse(
            request_id=rec.request_id, pipeline=rec.pipeline,
            state=rec.state, replica=s.replica,
            doc_ids=list(rec.result.doc_ids),
            rounds=list(rec.result.rounds),
            timeline=list(rec.timeline),
            arrival_t=s.arrival_abs, admit_t=rec.admit_t,
            complete_t=rec.complete_t, deadline_missed=missed)
