"""Token sampling for the decode loop."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, key: Optional[jax.Array] = None, *,
           temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits [..., V] -> token ids [...]. temperature 0 = greedy."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / temperature
    if top_k > 0:
        kth = jnp.sort(logits, axis=-1)[..., -top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    assert key is not None, "temperature sampling needs a PRNG key"
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
