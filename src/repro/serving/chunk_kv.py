"""Device residency manager for precomputed chunk-KV pages.

``ChunkKVCache`` sits beside ``KVCacheManager`` over the *same* KV page
slab and the *same* ``DevicePagePool``: loading a chunk pops page slots
from the slab free list, writes the chunk's precomputed K/V into them
H2D, and charges the bytes to the pool under owner ``"chunk_kv"``
(tenant-attributed, so telemetry can say whose chunks sit in HBM).

Residency is **refcounted**.  A wave that splices a chunk pins it for
the lease's lifetime (``pin`` = ``pool.retain``: the pool lease's
refcount guards the bytes, and pinned residency is protected from
spill); releasing the wave's ``PagedCacheLease`` unpins the chunk back
to *warm* residency — the pages stay loaded for the next wave that
wants the same document, they are not freed.  Only ``evict`` (LRU,
pressure-driven via ``evict_cold``, or teardown via ``drain``) returns
pages to the slab and bytes to the pool, and only at pin count zero —
evicting a pinned chunk would yank pages out from under a live block
table.

Misses (document not in the offline store, or no room even after
spilling cold residency) return None and the caller falls back to
ordinary prefill; ``backfill`` optionally prefills the chunk once and
inserts it into the store so the next wave hits.

Every transition emits a ``ChunkKVEvent`` (``chunk.load`` /
``chunk.pin`` / ``chunk.unpin`` / ``chunk.evict``) on the pool's
recorder lane; the invariant checker conserves pages per (replica,
doc), rejects pin-before-load (the splice-before-land race) and
evict-while-pinned, and requires drained traces to end with zero
residency.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.data.chunk_kv import ChunkKV, ChunkKVStore
from repro.memory.pool import PageLease
from repro.obs.recorder import ChunkKVEvent
from repro.serving.kv_cache import KVCacheManager


@dataclass
class ChunkResidency:
    """One document's chunk-KV pages on device: the slab page slots
    holding its K/V, the live token count, the pool lease charging the
    bytes (owner ``"chunk_kv"``), and the pin count (>0 = spliced into
    at least one live block table; protected from eviction)."""

    doc_id: int
    slots: Tuple[int, ...]
    length: int
    lease: Optional[PageLease] = None
    pins: int = 0
    last_used: int = 0


@dataclass
class ChunkKVStats:
    """Chunk-KV effectiveness counters (telemetry / bench report)."""

    hits: int = 0                      # docs spliced from resident pages
    misses: int = 0                    # docs that fell back to prefill
    loads: int = 0                     # H2D chunk loads (incl. prefetch)
    evictions: int = 0
    spliced_pages: int = 0             # pages attached by block-table edit
    prefetched_pages: int = 0          # pages landed by lookahead prefetch
    prefill_tokens_avoided: int = 0    # chunk tokens NOT re-prefilled
    backfills: int = 0                 # miss-path prefills inserted to store

    def as_dict(self) -> Dict[str, float]:
        """Counters plus the derived ``hit_rate`` (hits over hits+misses;
        0.0 before any splice attempt) — the telemetry/report payload."""
        d = dict(vars(self))
        total = self.hits + self.misses
        d["hit_rate"] = self.hits / total if total else 0.0
        return d


class ChunkKVCache:
    """Refcounted device residency for one replica's chunk-KV pages,
    sharing the replica's KV page slab and ``DevicePagePool``."""

    def __init__(self, kv: KVCacheManager,
                 store: Optional[ChunkKVStore] = None):
        slab = kv.slab
        if slab is None:
            raise RuntimeError("ChunkKVCache needs a paged KVCacheManager: "
                               "call init_paged() first")
        if store is not None and store.page_size != slab.page_size:
            raise ValueError(
                f"store page_size {store.page_size} != slab page_size "
                f"{slab.page_size}: chunk pages must match slab geometry")
        self.kv = kv
        self.store = store if store is not None else ChunkKVStore(
            page_size=slab.page_size)
        self.resident: Dict[int, ChunkResidency] = {}
        self.stats = ChunkKVStats()
        self._clock = 0                # LRU tick (per-replica, monotone)

    # -- tracing -------------------------------------------------------------
    def _emit(self, kind: str, doc_id: int, pages: int, nbytes: int,
              pinned: int, tenant: str) -> None:
        pool = self.kv.pool
        rec = pool.recorder if pool is not None else None
        if rec is not None:
            rec.emit(ChunkKVEvent(t=rec.now, kind=kind,
                                  replica=pool.replica_id, tenant=tenant,
                                  doc_id=doc_id, pages=pages, nbytes=nbytes,
                                  pinned=pinned))

    # -- residency -----------------------------------------------------------
    def load(self, doc_id: int, *, tenant: str = "shared",
             prefetch: bool = False) -> Optional[ChunkResidency]:
        """Land one document's chunk-KV pages on device (no-op if
        already resident).  Returns None on a store miss or when
        neither the slab free list nor the pool can fit the pages even
        after evicting cold residency — the caller falls back to
        prefill.  ``prefetch=True`` attributes the load to lookahead
        (counted separately; residency is identical)."""
        doc_id = int(doc_id)
        res = self.resident.get(doc_id)
        if res is not None:
            self._clock += 1
            res.last_used = self._clock
            return res
        chunk = self.store.get(doc_id)
        if chunk is None:
            return None
        slab = self.kv.slab
        npg = chunk.num_pages
        if len(slab.free) < npg:
            self.evict_cold(npg - len(slab.free))
        if len(slab.free) < npg:
            return None
        nbytes = npg * self.kv.paged_page_nbytes()
        lease = None
        pool = self.kv.pool
        if pool is not None:
            lease = pool.lease_bytes(nbytes, "chunk_kv",
                                     tag=("chunk", doc_id), tenant=tenant)
            if lease is None:
                need = -(-nbytes // pool.page_nbytes)
                self.evict_cold(pages_hint=need)
                lease = pool.lease_bytes(nbytes, "chunk_kv",
                                         tag=("chunk", doc_id), tenant=tenant)
            if lease is None:
                return None
        slots = tuple(slab.free.pop() for _ in range(npg))
        idx = jnp.asarray(slots)
        slab.k = slab.k.at[:, idx].set(jnp.asarray(chunk.k, slab.k.dtype))
        slab.v = slab.v.at[:, idx].set(jnp.asarray(chunk.v, slab.v.dtype))
        self._clock += 1
        res = ChunkResidency(doc_id=doc_id, slots=slots, length=chunk.length,
                             lease=lease, last_used=self._clock)
        self.resident[doc_id] = res
        self.stats.loads += 1
        if prefetch:
            self.stats.prefetched_pages += npg
        self._emit("chunk.load", doc_id, npg, nbytes, 0, tenant)
        return res

    def pin(self, doc_id: int) -> ChunkResidency:
        """Pin resident pages for a lease's lifetime (``pool.retain`` —
        no pool event; the bytes were charged at load).  Pinned
        residency is never evicted/spilled."""
        res = self.resident.get(int(doc_id))
        if res is None:
            raise KeyError(f"chunk {doc_id} not resident: load before pin")
        res.pins += 1
        self._clock += 1
        res.last_used = self._clock
        if res.lease is not None and self.kv.pool is not None:
            self.kv.pool.retain(res.lease)
        self._emit("chunk.pin", res.doc_id, len(res.slots), 0, res.pins,
                   res.lease.tenant if res.lease else "shared")
        return res

    def unpin(self, doc_id: int) -> None:
        """Release one pin back to *warm* residency (pages stay loaded;
        the paired ``pool.release`` only decrements the refcount — bytes
        return to the pool at evict, not here)."""
        res = self.resident.get(int(doc_id))
        if res is None or res.pins <= 0:
            raise ValueError(f"chunk {doc_id} is not pinned")
        res.pins -= 1
        if res.lease is not None and self.kv.pool is not None:
            self.kv.pool.release(res.lease)
        self._emit("chunk.unpin", res.doc_id, len(res.slots), 0, res.pins,
                   res.lease.tenant if res.lease else "shared")

    def evict(self, doc_id: int) -> int:
        """Return one cold (unpinned) chunk's pages to the slab and its
        bytes to the pool; returns pages freed."""
        res = self.resident.get(int(doc_id))
        if res is None:
            return 0
        if res.pins > 0:
            raise ValueError(f"chunk {doc_id} is pinned ({res.pins}); "
                             "evicting would orphan a live block table")
        del self.resident[res.doc_id]
        self.kv.slab.free.extend(int(s) for s in res.slots)
        nbytes = 0
        tenant = "shared"
        if res.lease is not None and self.kv.pool is not None:
            nbytes, tenant = res.lease.nbytes, res.lease.tenant
            self.kv.pool.release(res.lease)
        self.stats.evictions += 1
        self._emit("chunk.evict", res.doc_id, len(res.slots), nbytes, 0,
                   tenant)
        return len(res.slots)

    def evict_cold(self, pages_hint: int = 0) -> int:
        """Evict unpinned residency, LRU-first, until ``pages_hint``
        slab pages are freed (0 = evict all cold).  The engine's spill
        chain calls this under pool pressure — pinned chunks are
        protected exactly like in-flight prefetch pages."""
        freed = 0
        cold = sorted((r for r in self.resident.values() if r.pins == 0),
                      key=lambda r: r.last_used)
        for res in cold:
            if pages_hint and freed >= pages_hint:
                break
            freed += self.evict(res.doc_id)
        return freed

    def drain(self) -> int:
        """Teardown: evict everything (all pins must be released)."""
        pinned = [d for d, r in self.resident.items() if r.pins > 0]
        if pinned:
            raise RuntimeError(f"drain with pinned chunks: {pinned}")
        return self.evict_cold(0)

    # -- splice front door ---------------------------------------------------
    def acquire_rows(self, row_docs: Sequence[Sequence[int]], *,
                     tenant: str = "shared",
                     ) -> Tuple[List[List[Tuple[Tuple[int, ...], int]]],
                                List[int], List[List[int]]]:
        """Resolve each row's retrieved doc ids to spliceable pages:
        load + pin every hit, count every miss.  Returns ``(row_chunks,
        pinned, row_misses)`` — ``row_chunks`` feeds
        ``KVCacheManager.splice_paged`` directly, ``pinned`` is the doc
        list to ``unpin`` when the lease is released, ``row_misses``
        lists each row's fallback docs (prefill path / ``backfill``)."""
        row_chunks: List[List[Tuple[Tuple[int, ...], int]]] = []
        pinned: List[int] = []
        row_misses: List[List[int]] = []
        for docs in row_docs:
            chunks: List[Tuple[Tuple[int, ...], int]] = []
            misses: List[int] = []
            for d in docs:
                res = self.load(int(d), tenant=tenant)
                if res is None:
                    self.stats.misses += 1
                    misses.append(int(d))
                    continue
                self.pin(res.doc_id)
                pinned.append(res.doc_id)
                chunks.append((res.slots, res.length))
                self.stats.hits += 1
                self.stats.spliced_pages += len(res.slots)
                self.stats.prefill_tokens_avoided += res.length
            row_chunks.append(chunks)
            row_misses.append(misses)
        return row_chunks, pinned, row_misses

    def release_rows(self, pinned: Sequence[int]) -> None:
        """Unpin every chunk a released lease had spliced (back to warm
        residency — the mirror of ``acquire_rows``)."""
        for d in pinned:
            self.unpin(d)

    def backfill(self, doc_id: int, params, cfg, *, seed: Optional[int] = None,
                 min_len: int = 8, max_len: int = 24) -> Optional[ChunkKV]:
        """Miss path: prefill the chunk once NOW and insert it into the
        (host) store so the next wave hits.  Returns the built chunk
        (None if it was already in the store)."""
        from repro.data.chunk_kv import build_chunk

        doc_id = int(doc_id)
        if doc_id in self.store:
            return None
        chunk = build_chunk(params, cfg, doc_id,
                            page_size=self.store.page_size,
                            seed=self.store.seed if seed is None else seed,
                            min_len=min_len, max_len=max_len)
        self.store.add(doc_id, chunk)
        self.stats.backfills += 1
        return chunk

    # -- lookahead prefetch --------------------------------------------------
    def prefetch_clusters(self, clusters: Sequence[int], *,
                          tenant: str = "shared",
                          budget_pages: int = 0) -> int:
        """Lookahead integration: land the predicted clusters' chunk
        pages H2D during generation so the next round's splice hits
        warm residency.  ``budget_pages`` caps the burst (0 = no cap);
        returns pages landed.  Loads are cold (unpinned) — the same
        slack/demotion rules that drop a prefetch ticket simply skip
        this call, and pool pressure can evict them again."""
        landed = 0
        for c in clusters:
            for d in self.store.docs_in_cluster(int(c)):
                if d in self.resident:
                    continue
                if budget_pages and landed >= budget_pages:
                    return landed
                res = self.load(d, tenant=tenant, prefetch=True)
                if res is None:
                    return landed      # out of room — stop the burst
                landed += len(res.slots)
        return landed

    # -- introspection -------------------------------------------------------
    def resident_pages(self) -> int:
        """Slab pages held by chunk residency (warm + pinned)."""
        return sum(len(r.slots) for r in self.resident.values())

    def pinned_pages(self) -> int:
        """Slab pages held by chunks currently spliced into a live
        block table (protected from spill/evict)."""
        return sum(len(r.slots) for r in self.resident.values() if r.pins)
