"""The engine decode plumbing behind ``on_generate``: one reusable
decode-hook object for serve drivers, tests and benchmarks.

``DecodeRunner`` is the real-decode hook the serving front-end wires as
each runtime's ``on_generate``: at every round frontier it leases KV for
the wave, runs actual reduced-model decode steps while the wave's
lookahead copy is in flight, and returns per-request ``DecodeEvent``s —
async decode as the clock source.

By default (``EngineConfig.paged_decode=True``) decode runs on the
**paged substrate**: the wave's KV is a ``PagedCacheLease`` block table
over the manager's shared page slab (``acquire_paged``), every step goes
through ``transformer.serve_step_paged`` — which scatters the new K/V
through the block table in-jit and attends with
``kernels.ops.flash_decode_paged`` — and ``append_paged`` advances the
lease (emitting the ``kv.append`` trace edge the invariant checker
orders).  ``paged_decode=False`` pins the legacy dense ``[B, max_len]``
bucket path (``acquire``/``serve_step``).  Both paths release in
``finally`` (telint TL001) and tenant-tag the lease (TL004), so the
wave's decode state is pool/ledger-accounted either way.

``PoolExhausted`` from ``acquire_paged`` deliberately propagates: the
``RetrievalRuntime`` catches it at the round frontier, sheds what fits
and parks the rest ``PRESSURE_STALLED`` to rejoin on page-free — KV
pressure is an admission decision, not a hook crash.

Timing comes from an injected clock (``attach`` adopts the server's
``wall_clock``): launch drivers inject ``SystemClock`` for real
measurement; the library default is the deterministic event clock, which
is what lets tests pin paged==dense telemetry exactly.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.data.chunk_kv import ChunkKVStore
from repro.models import transformer as tf
from repro.serving.chunk_kv import ChunkKVCache
from repro.serving.kv_cache import KVCacheManager
from repro.serving.runtime import DecodeEvent
from repro.serving.sampler import sample


def supports_paged_decode(cfg: ArchConfig) -> bool:
    """True iff the arch can decode through block-table KV: plain
    global-causal GQA attention (the ``init_paged`` /
    ``serve_step_paged`` restriction) — sliding-window, split-cache,
    MLA and SSM families stay dense."""
    return (tf.family_kind(cfg) == "attn" and cfg.has_attention
            and cfg.attn_kind == "gqa" and not cfg.local_global_pattern
            and not cfg.sliding_window)


class DecodeRunner:
    """Reusable ``decode_hook(replica, records, gen_tokens, rnd)``:
    per-wave KV lease + real model decode steps, paged by default.

    Construct with the reduced arch's params, pass as the server's
    ``decode_hook``, then ``attach(server)`` so the runner can build one
    pool-backed ``KVCacheManager`` per replica engine (and adopt the
    server's wall clock and each engine's ``paged_decode`` /
    ``kernel_mode`` config)."""

    def __init__(self, params, cfg: ArchConfig, *, max_len: int = 128,
                 max_steps: int = 32, page_size: int = 16,
                 slab_seqs: int = 16,
                 paged: Optional[bool] = None,
                 chunk_store: Optional[ChunkKVStore] = None):
        """``paged=None`` defers to ``EngineConfig.paged_decode`` at
        ``attach`` time (ANDed with arch support); an explicit bool
        overrides the engine config.  ``slab_seqs`` sizes the paged KV
        slab: page slots for that many concurrent ``max_len``
        sequences.  ``chunk_store`` is the offline-built chunk-KV corpus
        (``data.chunk_kv.build_chunk_kv``): when given (and the engine
        enables ``chunk_kv``), each wave's previous-round retrieved docs
        are spliced into its paged lease from precomputed pages instead
        of being re-prefilled."""
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.max_steps = max_steps
        self.page_size = page_size
        self.slab_seqs = slab_seqs
        self._paged_override = paged
        self.paged = bool(paged) and supports_paged_decode(cfg)
        self.chunk_store = chunk_store
        self.clock = None                      # attach() adopts server.wall
        self._kv: Dict[int, KVCacheManager] = {}
        self._chunk: Dict[int, ChunkKVCache] = {}
        self._dense_step = None
        self._paged_step = None
        self._spliced_step = None
        # per-request generated tokens, per round: the differential
        # parity suite pins these exactly equal across paged/dense runs
        self.generated: Dict[int, List[Tuple[int, ...]]] = {}
        self.stats = {"paged_waves": 0, "dense_waves": 0,
                      "paged_appends": 0, "dense_steps": 0,
                      "spliced_waves": 0}

    # -- wiring --------------------------------------------------------------
    def attach(self, server) -> "DecodeRunner":
        """Bind to a constructed ``TeleRAGServer``: one pool-backed KV
        manager per replica engine (paged mode also allocates the slab),
        clock from the server's ``wall_clock`` injection point."""
        self.clock = server.wall
        eng0 = server.engines[0]
        want = (eng0.cfg.paged_decode if self._paged_override is None
                else self._paged_override)
        self.paged = bool(want) and supports_paged_decode(self.cfg)
        self._kernel_mode = eng0.cfg.kernel_mode
        want_chunk = (self.paged and eng0.cfg.chunk_kv
                      and self.chunk_store is not None)
        self.chunk_docs = eng0.cfg.chunk_kv_docs
        for r, eng in enumerate(server.engines):
            kv = KVCacheManager(self.cfg, pool=eng.pool)
            if self.paged:
                blocks = -(-self.max_len // self.page_size)
                kv.init_paged(num_pages=self.slab_seqs * blocks,
                              page_size=self.page_size)
            self._kv[r] = kv
            if want_chunk:
                cache = ChunkKVCache(kv, self.chunk_store)
                self._chunk[r] = cache
                # the engine's spill chain and the policy's lookahead
                # prefetch reach chunk residency through this attr
                eng.chunk_kv = cache
        if self.paged:
            cfg, mode = self.cfg, self._kernel_mode
            self._paged_step = jax.jit(
                lambda p, k, v, bt, lens, tok: tf.serve_step_paged(
                    p, k, v, bt, lens, {"token": tok}, cfg,
                    kernel_mode=mode),
                donate_argnums=(1, 2))
            if want_chunk:
                self._spliced_step = jax.jit(
                    lambda p, k, v, bt, lens, dl, vd, tok:
                        tf.serve_step_paged_spliced(
                            p, k, v, bt, lens, dl, vd, {"token": tok}, cfg,
                            kernel_mode=mode),
                    donate_argnums=(1, 2))
        else:
            cfg = self.cfg
            self._dense_step = jax.jit(
                lambda p, c, i: tf.serve_step(p, c, i, cfg))
        return self

    def kv(self, replica: int = 0) -> KVCacheManager:
        """The replica's KV manager (attach() must have run)."""
        return self._kv[replica]

    def chunk(self, replica: int = 0) -> Optional[ChunkKVCache]:
        """The replica's chunk-KV residency cache (None when chunk-KV
        splicing is not enabled on this runner)."""
        return self._chunk.get(replica)

    # -- the hook ------------------------------------------------------------
    def __call__(self, replica: int, records, gen_tokens, rnd: int,
                 ) -> List[DecodeEvent]:
        """Decode this wave for real: ``steps`` tokens for the whole
        batch on leased KV, measured on the injected clock.  Returns
        one ``DecodeEvent`` per member (observed steps + seconds)."""
        if self.clock is None:
            raise RuntimeError("DecodeRunner.attach(server) before serving")
        n = len(records)
        steps = min(max(gen_tokens, default=0), self.max_steps)
        kv = self._kv[replica]
        tenant = records[0].tenant
        if self.paged:
            row_docs = None
            chunk = self._chunk.get(replica)
            if chunk is not None:
                # each row's context = the docs its previous retrieval
                # round returned: splice their precomputed KV instead of
                # re-prefilling them (round 0 has nothing retrieved yet)
                row_docs = [
                    [int(d) for d in r.result.doc_ids[-1]][:self.chunk_docs]
                    if r.result.doc_ids else []
                    for r in records]
            toks, per_step = self._run_paged(kv, n, steps, tenant,
                                             chunk=chunk, row_docs=row_docs)
        else:
            toks, per_step = self._run_dense(kv, n, steps, tenant)
        for j, r in enumerate(records):
            self.generated.setdefault(r.request_id, []).append(
                tuple(int(t[j]) for t in toks))
        return [DecodeEvent(request_id=r.request_id,
                            tokens=min(g, steps) if g else 0,
                            seconds=per_step * (min(g, steps) if g else 0))
                for r, g in zip(records, gen_tokens)]

    def _run_paged(self, kv: KVCacheManager, n: int, steps: int,
                   tenant: str, *, chunk: Optional[ChunkKVCache] = None,
                   row_docs: Optional[List[List[int]]] = None):
        """Block-table decode: acquire_paged -> (serve_step_paged +
        append_paged) per step -> release_paged.  ``PoolExhausted``
        from the acquire propagates to the runtime's shed/park path.

        With a chunk cache and per-row doc ids, retrieved documents'
        precomputed KV pages are pinned and spliced into the fresh
        lease by block-table edit before the first step; the wave then
        decodes through ``serve_step_paged_spliced`` (reordered RoPE +
        partial-page masking).  Pins release back to warm residency in
        the same ``finally`` that frees the lease."""
        self.stats["paged_waves"] += 1
        lease = kv.acquire_paged(n, self.max_len, tenant=tenant)
        pinned: List[int] = []
        toks: List[jax.Array] = []
        try:
            if chunk is not None and row_docs and any(row_docs):
                row_chunks, pinned, _ = chunk.acquire_rows(row_docs,
                                                           tenant=tenant)
                if kv.splice_paged(lease, row_chunks):
                    self.stats["spliced_waves"] += 1
            tok = jnp.zeros((n,), jnp.int32)
            t0 = self.clock.perf()
            for _ in range(steps):
                if lease.spliced_pages:
                    bt, lens, dl, vd = lease.device_splice_tables()
                    logits, kv.slab.k, kv.slab.v = self._spliced_step(
                        self.params, kv.slab.k, kv.slab.v, bt, lens, dl, vd,
                        tok)
                else:
                    bt, lens = lease.device_tables()
                    logits, kv.slab.k, kv.slab.v = self._paged_step(
                        self.params, kv.slab.k, kv.slab.v, bt, lens, tok)
                kv.append_paged(lease)      # scatter was fused in-jit
                self.stats["paged_appends"] += 1
                tok = sample(logits)
                toks.append(tok)
            if toks:
                jax.block_until_ready(toks[-1])
            per_step = (self.clock.perf() - t0) / max(steps, 1)
        finally:
            # a raising decode step must still free the block table —
            # leaked paged leases shrink the slab AND the shared pool
            # until admission starves (telint TL001); spliced chunks
            # unpin AFTER the table is gone (back to warm residency)
            kv.release_paged(lease)
            if chunk is not None:
                chunk.release_rows(pinned)
        return toks, per_step

    def _run_dense(self, kv: KVCacheManager, n: int, steps: int,
                   tenant: str):
        """The pinned legacy path: one dense [n, max_len] bucket."""
        self.stats["dense_waves"] += 1
        lease = kv.acquire(n, self.max_len, fresh=True, tenant=tenant)
        toks: List[jax.Array] = []
        try:
            tok = jnp.zeros((n,), jnp.int32)
            t0 = self.clock.perf()
            for t in range(steps):
                logits, lease.cache = self._dense_step(
                    self.params, lease.cache,
                    {"token": tok, "pos": jnp.full((n,), t, jnp.int32)})
                self.stats["dense_steps"] += 1
                tok = sample(logits)
                toks.append(tok)
            if toks:
                jax.block_until_ready(toks[-1])
            per_step = (self.clock.perf() - t0) / max(steps, 1)
        finally:
            kv.release(lease)
        return toks, per_step
