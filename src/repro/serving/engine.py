"""TeleRAG serving engine (paper §4, Fig. 6/7).

Resource owner + retrieval primitives for one replica ("GPU"):
prefetch buffer, cluster cache, budget policy, LLM backend, and the
timing model that composes measured byte/hit-rate telemetry into
modeled wall-clock per the paper's overlap semantics:

    t1 = max(t_llm_window, t_prefetch)          (§4.1 / App. C)
    t2 = max(t_host_search(misses), t_dev_search(hits)) + t_merge

Three execution modes cover the paper's comparison systems:
  * "telerag"        — lookahead prefetch + hybrid search (ours)
  * "cpu_baseline"   — retrieval entirely on host (Faiss-CPU baseline)
  * "runtime_fetch"  — fetch-on-demand at retrieval time (§3.2, Fig. 5)
Mode behaviour lives in serving/policies.py (``RetrievalPolicy``); the
engine owns the resources and delegates, and async H2D copies go through
``core/transfer.py``'s ``TransferEngine`` as timestamped events.

Quantities that are *measured* on this container: bytes moved, cluster
hit/miss sets, search results, scheduler quality. Wall-clock is modeled
from the HardwareProfile (CPU-only container; see DESIGN.md §7) — except
host search, whose per-cluster cost t_cc can be measured and plugged in.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.core import budget as budget_mod
from repro.core.budget import HardwareProfile, TPU_V5E
from repro.core.cache import CacheConfig, ClusterCache
from repro.core.datastore import PagedClusters
from repro.core.hybrid_search import RetrievalResult, host_search
from repro.core.ivf import IVFIndex
from repro.core.prefetch_buffer import PrefetchBuffer
from repro.core.transfer import TransferEngine, TransferEvent
from repro.memory import (AdmissionController, AdmissionStats,
                          DevicePagePool, MemoryLedger)
from repro.obs.clock import EventClock
from repro.obs.recorder import FlightRecorder
from repro.serving.policies import (LatencyContext, RetrievalPolicy,
                                    get_policy)


@dataclass
class EngineConfig:
    nprobe: int = 256
    top_k: int = 3
    buffer_pages: int = 1024
    pool_pages: Optional[int] = None              # None => buffer_pages (one
                                                  # shared slab, legacy sizing)
    prefetch_budget_bytes: Optional[int] = None   # None => Appendix-C policy
    lookahead_rank: int = 512                     # clusters ranked by q_in
    mode: str = "telerag"                         # telerag|cpu_baseline|runtime_fetch
    kernel_mode: str = "auto"
    fused_retrieval: bool = True                  # one-launch probe+topk on the
                                                  # device partition (False =
                                                  # legacy host-mask two-launch)
    cache: CacheConfig = field(default_factory=CacheConfig)
    cache_enabled: bool = False                   # paper: off on single GPU
    paged_decode: bool = True                     # serve decode over block-
                                                  # table KV (False pins the
                                                  # legacy dense [B,S] path)
    chunk_kv: bool = False                        # splice precomputed chunk-KV
                                                  # pages into paged decode
                                                  # (needs a ChunkKVStore on
                                                  # the DecodeRunner)
    chunk_kv_docs: int = 4                        # max docs spliced per row
    chunk_kv_prefetch_pages: int = 16             # lookahead chunk-page burst
                                                  # per round (0 = no chunk
                                                  # prefetch)
    hw: HardwareProfile = TPU_V5E
    chips: int = 1
    t_cc: Optional[float] = None                  # None => bytes/host_mem_bw
    seed: int = 0
    # multi-tenant pool entitlements: tenant -> (floor_pages, max_pages)
    # (max_pages None = may burst to the whole pool); None/{} = the
    # legacy single-tenant pool
    tenant_shares: Optional[Dict[str, Tuple[int, Optional[int]]]] = None


@dataclass
class RoundTelemetry:
    round_index: int
    batch: int                        # wave size the round executed at
    gen_tokens: int
    t_llm_window: float = 0.0
    bytes_prefetched: int = 0
    t_prefetch: float = 0.0
    hits: int = 0
    misses: int = 0
    t_host_search: float = 0.0
    t_dev_search: float = 0.0
    t_merge: float = 0.0
    # per-request round identity on the event clock (continuous
    # batching: a request's rounds run in different waves, so round
    # telemetry is keyed by request, stamped with the wave it rode)
    wave_id: int = -1                 # dynamic wave that ran this round
    round_start_t: float = float("nan")   # absolute event-clock round start
    round_end_t: float = float("nan")     # absolute event-clock round end

    # composed stage latencies under each system's overlap semantics
    def t_telerag(self) -> float:
        """Round seconds under TeleRAG overlap: max(gen, prefetch) +
        max(host, device search) + merge (§4.1 / App. C)."""
        t1 = max(self.t_llm_window, self.t_prefetch)
        t2 = max(self.t_host_search, self.t_dev_search) + self.t_merge
        return t1 + t2

    def t_cpu_baseline(self, t_cc: float) -> float:
        """Round seconds with all retrieval on host at ``t_cc`` seconds
        per cluster (no overlap)."""
        return self.t_llm_window + (self.hits + self.misses) * t_cc

    def t_runtime_fetch(self, page_bytes_per_cluster: float,
                        link_bw: float) -> float:
        """Round seconds for demand-fetch at retrieval time: every
        probed cluster crosses the link before the device search."""
        nb = (self.hits + self.misses) * page_bytes_per_cluster
        return (self.t_llm_window + nb / link_bw
                + self.t_dev_search + self.t_merge)


@dataclass
class RequestResult:
    request_id: int
    pipeline: str
    doc_ids: List[np.ndarray] = field(default_factory=list)
    rounds: List[RoundTelemetry] = field(default_factory=list)

    def latency(self, mode: str, *, t_cc: float, cluster_bytes: float,
                link_bw: float, tail_gen_s: float = 0.0) -> float:
        """Legacy closed-form composition, now via the policy registry —
        a new baseline is one policy class, not another elif here."""
        policy = get_policy(mode)
        ctx = LatencyContext(t_cc=t_cc, cluster_bytes=cluster_bytes,
                             link_bw=link_bw)
        return tail_gen_s + sum(policy.round_latency(r, ctx)
                                for r in self.rounds)


class TeleRAGEngine:
    """Single-replica engine: prefetch buffer + cache + hybrid retrieval."""

    def __init__(self, index: IVFIndex, cfg: EngineConfig,
                 arch: Optional[ArchConfig] = None, *,
                 wall_clock=None):
        self.index = index
        self.cfg = cfg
        self.arch = arch
        # every engine records; a standalone engine owns its recorder,
        # a server rebinds all replicas onto one shared stream
        self.recorder = FlightRecorder()
        self.replica_id = -1
        # wall-clock discipline: real time is an injected dependency
        # (launch drivers pass obs.clock.SystemClock); the default
        # EventClock keeps runs replay-deterministic
        self.wall = wall_clock if wall_clock is not None \
            else EventClock(self.recorder)
        self._init_memory()
        self.transfer = TransferEngine(self.buffer, cfg.hw.host_link_bw)
        self.cache = ClusterCache(cfg.cache)
        self._wire_recorder()
        self._rng = np.random.default_rng(cfg.seed)
        self._measured_tcc: Optional[float] = None

    def _wire_recorder(self) -> None:
        """Point every emitting component at the engine's recorder."""
        for comp in (self.pool, self.admission, self.transfer):
            comp.recorder = self.recorder
            comp.replica_id = self.replica_id

    def attach_recorder(self, recorder: FlightRecorder,
                        replica: int = -1) -> None:
        """Rebind onto a shared flight recorder (the server attaches one
        recorder across all replicas, each with its lane id)."""
        self.recorder = recorder
        self.replica_id = replica
        if isinstance(self.wall, EventClock):
            self.wall.recorder = recorder
        self._wire_recorder()

    def _init_memory(self) -> None:
        """One HBM arbiter per replica: page pool + byte ledger +
        admission control, shared by prefetch buffer and KV cache."""
        cfg = self.cfg
        self.ledger = MemoryLedger(
            capacity_bytes=int(cfg.hw.hbm_bytes * cfg.chips))
        if self.arch is not None:
            # resident model weights compete for the same HBM (bf16)
            self.ledger.charge("weights", self.arch.param_count() * 2)
        self.pool = DevicePagePool(
            self.index.paged, cfg.pool_pages or cfg.buffer_pages,
            ledger=self.ledger)
        self.buffer = PrefetchBuffer(self.index.paged, pool=self.pool,
                                     quota_pages=cfg.buffer_pages)
        for tenant, share in (cfg.tenant_shares or {}).items():
            floor, cap = (share if isinstance(share, (tuple, list))
                          else (share, None))
            self.pool.set_tenant_share(tenant, floor, cap)
        self.admission = AdmissionController(
            self.pool,
            spill=lambda target, protect=None: self._spill(target, protect))
        # chunk-KV residency (set by DecodeRunner.attach when enabled);
        # a memory rebuild (restart) loses on-device chunk pages, so the
        # stale cache must not survive it — the hook re-attaches
        self.chunk_kv = None

    def _spill(self, target: int, protect=None) -> List[int]:
        """Admission's page-reclaim chain: evict unpinned prefetch
        residency first (existing slack rules), then cold chunk-KV
        residency — pinned chunks, like in-flight wave pins, are
        protected (evicting them would orphan live block tables).
        ``target`` is a free-page goal; the controller measures what
        actually freed, so the return (evicted clusters) is advisory."""
        evicted = self.cache.make_room(self.buffer, target, protect=protect)
        if self.chunk_kv is not None and self.pool.free_pages() < target:
            self.chunk_kv.evict_cold(target - self.pool.free_pages())
        return evicted

    @property
    def policy(self) -> RetrievalPolicy:
        """Execution strategy for cfg.mode (resolved live so tests can
        flip the mode on an existing engine)."""
        return get_policy(self.cfg.mode)

    # ---- budget -----------------------------------------------------------
    @property
    def prefetch_capacity_bytes(self) -> int:
        """The prefetch share of the pool (its quota), not the whole
        slab — budgets must not grow just because the pool also hosts
        KV leases or extra headroom."""
        return self.cfg.buffer_pages * self.buffer.page_nbytes

    def prefetch_budget(self, gen_tokens: Sequence[int], batch: int) -> int:
        """The round's lookahead byte budget: an explicit override, the
        Appendix-C optimal policy (when an arch is set), or half the
        prefetch capacity."""
        if self.cfg.prefetch_budget_bytes is not None:
            return self.cfg.prefetch_budget_bytes
        if self.arch is None:
            return self.prefetch_capacity_bytes // 2
        return budget_mod.optimal_budget(
            self.arch, self.cfg.hw, gen_tokens=list(gen_tokens) or [0],
            batch=batch, nprobe=self.cfg.nprobe, t_cc=self.effective_tcc(),
            chips=self.cfg.chips,
            hbm_headroom_bytes=float(self.prefetch_capacity_bytes))

    def effective_tcc(self) -> float:
        """Host per-cluster search seconds: measured (calibrate_tcc) >
        configured (cfg.t_cc) > modeled from host memory bandwidth."""
        if self._measured_tcc is not None:
            return self._measured_tcc
        if self.cfg.t_cc is not None:
            return self.cfg.t_cc
        avg_cluster_bytes = float(np.mean(self.index.paged.all_cluster_bytes()))
        return budget_mod.host_cluster_search_seconds(avg_cluster_bytes,
                                                      self.cfg.hw)

    def calibrate_tcc(self, n_clusters: int = 16) -> float:
        """Measure real host per-cluster search cost on this machine
        via the injected wall clock.  Under the default deterministic
        ``EventClock`` the bracketing reads are equal, so the modeled
        per-cluster cost is stored instead — calibration is then a
        deterministic no-op rather than a zero that would erase host
        search time from every latency model downstream."""
        q = self._rng.standard_normal(self.index.dim).astype(np.float32)
        cs = list(range(min(n_clusters, self.index.num_clusters)))
        t0 = self.wall.perf()
        host_search(self.index.paged, cs, q, k=8)
        elapsed = self.wall.perf() - t0
        if elapsed > 0.0:
            self._measured_tcc = elapsed / len(cs)
        else:
            self._measured_tcc = self.effective_tcc()
        return self._measured_tcc

    # ---- timing primitives --------------------------------------------------
    def llm_window_seconds(self, gen_tokens: int, batch: int,
                           kv_len: int = 1024) -> float:
        """Modeled decode seconds for one generation window of
        ``gen_tokens`` at the given batch size (0.0 with no arch)."""
        if self.arch is None or gen_tokens == 0:
            return 0.0
        per = budget_mod.decode_step_seconds(self.arch, self.cfg.hw,
                                             batch=batch, kv_len=kv_len,
                                             chips=self.cfg.chips)
        return per * gen_tokens

    def _dev_search_seconds(self, pages_searched: int) -> float:
        nb = pages_searched * self.buffer.page_nbytes
        return nb / (self.cfg.hw.hbm_bw * self.cfg.chips) + 5e-6

    # ---- primitives ---------------------------------------------------------
    def plannable_pages(self, wave_key: object = None,
                        hit_clusters: Sequence[int] = ()) -> int:
        """Pages a wave's *desired* plan may target — never a silent
        clamp to transiently-free slots.  ``wave_key`` identifies the
        wave's own pins: a single pin key, or (continuous batching) a
        tuple of the wave's per-request pin keys.  Plannable capacity
        is:

          * physically free slots, plus
          * pages pinned by *other* in-flight waves (their completion
            events release them — exactly what a PRESSURE_STALLED wave
            waits for), plus
          * unpinned residency beyond the cache's protection quota
            (cold leftovers the admission spill may evict right now).

        Excluded: KV leases (generation state is not a fetch target),
        the wave's own pinned working set (already its hits), the
        ``hit_clusters`` this very plan will count as device hits (the
        wave pins them before admission, so their pages can never be
        reclaimed for its own fetches), and the hot residency the cache
        quota protects (displacing it would defeat Appendix D's cache).
        ``cfg.buffer_pages`` additionally bounds the prefetch share of a
        pool larger than it (shared with KV) so lookahead cannot starve
        generation state; under the default sizing (pool ==
        buffer_pages) the bound equals the free+reclaimable term."""
        waitable, spillable = self.buffer.reclaimable_split(wave_key,
                                                            hit_clusters)
        protected = (min(self.cache.quota_pages(self.buffer), spillable)
                     if self.cfg.cache_enabled else 0)
        reclaimable = waitable + (spillable - protected)
        quota_left = (self.cfg.buffer_pages
                      - (self.pool.leased_pages("prefetch") - reclaimable))
        return max(0, min(self.pool.free_pages() + reclaimable, quota_left))

    def plan_lookahead(self, q_in: np.ndarray, gen_tokens: Sequence[int], *,
                       wave_key: object = None):
        """The wave's *desired* prefetch plan (None for non-prefetching
        policies) — what admission control reserves headroom for."""
        return self.policy.plan(self, q_in, gen_tokens, wave_key=wave_key)

    def lookahead_ex(self, q_in: np.ndarray, gen_tokens: Sequence[int], *,
                     now: float = 0.0, plan=None, ticket=None,
                     tenant: str = "shared",
                     ) -> Tuple[int, int, Optional[TransferEvent]]:
        """Plan + dispatch prefetch for a micro-batch of q_in embeddings.

        Returns (bytes_planned, clusters_fetched, transfer event). Async
        by construction: device_put/scatter dispatch returns before the
        copy completes, so the subsequent decode steps overlap with it
        (the real mechanism, not only the model); the event's
        [start_t, end_t) window is the modeled link occupancy the
        RetrievalRuntime orders against generation windows.  ``plan`` /
        ``ticket`` carry a precomputed plan and its granted admission
        (the runtime reserves before dispatch); direct callers omit them
        and get synchronous spill-or-cap admission."""
        return self.policy.lookahead(self, q_in, gen_tokens, now=now,
                                     plan=plan, ticket=ticket, tenant=tenant)

    def lookahead(self, q_in: np.ndarray, gen_tokens: Sequence[int], *,
                  tenant: str = "shared") -> Tuple[int, int]:
        """Legacy two-value lookahead: (bytes_planned, clusters_fetched)
        with synchronous spill-or-cap admission."""
        nbytes, nfetch, _ = self.lookahead_ex(q_in, gen_tokens, tenant=tenant)
        return nbytes, nfetch

    def retrieve(self, q_out: np.ndarray, *, now: float = 0.0,
                 tenant: str = "shared") -> RetrievalResult:
        """Run the mode policy's retrieval for the rewritten queries at
        event-clock time ``now`` (seconds); ``tenant`` scopes any
        demand-fetch eviction to the requester's floor view."""
        return self.policy.retrieve(self, q_out, now=now, tenant=tenant)

    def end_batch(self) -> None:
        """Post-batch consolidation (paper App. D reproducibility rule)."""
        if self.cfg.cache_enabled:
            self.cache.consolidate(self.buffer)
        else:
            evict = list(self.buffer.resident_clusters())
            self.buffer.evict_clusters(evict)
            self.cache.hotness.clear()

    # ---- fault tolerance ------------------------------------------------------
    def snapshot(self) -> dict:
        """Host-side state capture (residency, hotness, lifetime stats,
        ledger, admission counters) for replica restart."""
        return {
            "hotness": dict(self.cache.hotness),
            "resident": sorted(self.buffer.resident_clusters()),
            "stats": (self.buffer.stats.bytes_h2d, self.buffer.stats.pages_h2d,
                      self.buffer.stats.rounds),
            "ledger": self.ledger.snapshot(),
            "admission": dataclasses.asdict(self.admission.stats),
            "admission_per_tenant": {
                t: dataclasses.asdict(s)
                for t, s in self.admission.per_tenant.items()},
        }

    def restore(self, snap: dict) -> None:
        """Rebuild device state from a snapshot (replica restart)."""
        old_pool = self.pool
        self._init_memory()
        # long-lived runtimes subscribed to the old pool must keep
        # receiving page-free events from the replacement
        self.pool.rebind_subscribers(old_pool)
        self.transfer = TransferEngine(self.buffer, self.cfg.hw.host_link_bw)
        self.cache = ClusterCache(self.cfg.cache)
        # fresh pool/admission/transfer must keep emitting into the
        # same trace stream across the restart
        self._wire_recorder()
        self.buffer.load_clusters(snap["resident"])
        self.cache.hotness.update({int(k): v for k, v in
                                   snap["hotness"].items()})
        b, p, r = snap["stats"]
        self.buffer.stats.bytes_h2d = b
        self.buffer.stats.pages_h2d = p
        self.buffer.stats.rounds = r
        # a restarted replica must not silently zero its admission
        # telemetry — aggregate AND per-tenant slices (older snapshots
        # without the keys keep the fresh zeros)
        if "admission" in snap:
            self.admission.stats = AdmissionStats(**snap["admission"])
        for t, s in snap.get("admission_per_tenant", {}).items():
            self.admission.per_tenant[t] = AdmissionStats(**s)
