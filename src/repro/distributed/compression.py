"""Gradient compression for data-parallel all-reduce (int8 + error feedback).

In the SPMD/pjit path the gradient all-reduce is implicit, so compression
is implemented for the *explicit-collective* training path
(``train_loop.make_manual_dp_train_step``), where the psum is ours:

    q, scale = quantize(g + e)        # per-tensor symmetric int8
    q_sum    = psum(q)                # 4x fewer bytes on the wire
    g_hat    = dequantize(q_sum) / n
    e'       = (g + e) - dequantize(q) (local error feedback)

Error feedback makes the compression unbiased-in-the-limit (momentum of
the residual re-enters the next step), the standard trick from 1-bit
Adam / EF-SGD lineage.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grads: Any, err: Any, axis_name,
                    ) -> Tuple[Any, Any]:
    """Per-leaf int8 psum with error feedback. Call inside shard_map.

    Returns (averaged_grads fp32, new_error_feedback). Scales are
    max-reduced across the axis so every shard dequantizes identically.
    """
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(g32 / scale), -127, 127)
        local_deq = q * scale
        new_e = g32 - local_deq
        # int8 on the wire: psum of int32-accumulated int8 payload
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
        g_hat = (q_sum.astype(jnp.float32) * scale) / n
        return g_hat, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    g_new = jax.tree.unflatten(tdef, [o[0] for o in outs])
    e_new = jax.tree.unflatten(tdef, [o[1] for o in outs])
    return g_new, e_new


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio(params: Any) -> float:
    """Wire-byte ratio int8/bf16 per step (scales amortize to ~0)."""
    return 0.5  # int8 vs bf16 grads; vs fp32 grads it is 0.25
