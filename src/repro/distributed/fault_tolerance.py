"""Cluster fault tolerance: heartbeats, stragglers, elastic re-slicing.

What runs here vs. at scale:
  * heartbeats / straggler deadlines — real logic, tested by simulation;
  * elastic re-slicing — deterministic recomputation of the data-axis
    layout when the replica set changes: every surviving replica derives
    the identical new assignment with no coordinator round-trip (the
    re-slice is a pure function of (step, healthy_set));
  * training restart — checkpoint restore (training/checkpoint.py) plus
    TokenStream cursor; serving restart — engine.snapshot()/restore().
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple


@dataclass
class HeartbeatMonitor:
    deadline_s: float = 10.0
    _last: Dict[int, float] = field(default_factory=dict)

    def beat(self, node: int, now: Optional[float] = None) -> None:
        self._last[node] = time.monotonic() if now is None else now

    def dead(self, nodes: Sequence[int], now: Optional[float] = None,
             ) -> Set[int]:
        now = time.monotonic() if now is None else now
        return {n for n in nodes
                if now - self._last.get(n, -1e18) > self.deadline_s}


@dataclass
class StragglerPolicy:
    """Deadline = factor × median completion time of the batch's peers."""
    factor: float = 3.0
    min_deadline_s: float = 1.0

    def stragglers(self, durations: Dict[int, Optional[float]],
                   now_elapsed: float) -> Set[int]:
        done = [d for d in durations.values() if d is not None]
        if not done:
            return set()
        med = sorted(done)[len(done) // 2]
        deadline = max(self.factor * med, self.min_deadline_s)
        return {n for n, d in durations.items()
                if d is None and now_elapsed > deadline}


def elastic_slices(step: int, healthy: Sequence[int], global_batch: int,
                   ) -> Dict[int, Tuple[int, int]]:
    """Deterministic contiguous batch slices for the healthy replica set.

    Remainders go to the lowest-ranked replicas so every node computes the
    same layout independently. Returns {replica: (start, stop)}.
    """
    nodes = sorted(healthy)
    n = len(nodes)
    if n == 0:
        return {}
    base = global_batch // n
    rem = global_batch % n
    out: Dict[int, Tuple[int, int]] = {}
    start = 0
    for i, node in enumerate(nodes):
        size = base + (1 if i < rem else 0)
        out[node] = (start, start + size)
        start += size
    assert start == global_batch
    return out


@dataclass
class ElasticRun:
    """Tracks replica membership across steps; yields re-slice events."""
    global_batch: int
    members: Set[int] = field(default_factory=set)
    history: List[Tuple[int, Tuple[int, ...]]] = field(default_factory=list)

    def resize(self, step: int, healthy: Set[int],
               ) -> Dict[int, Tuple[int, int]]:
        if healthy != self.members:
            self.members = set(healthy)
            self.history.append((step, tuple(sorted(healthy))))
        return elastic_slices(step, sorted(self.members), self.global_batch)
