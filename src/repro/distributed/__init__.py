from repro.distributed.compression import (compressed_psum, compression_ratio,
                                           dequantize_int8,
                                           init_error_feedback, quantize_int8)
from repro.distributed.fault_tolerance import (ElasticRun, HeartbeatMonitor,
                                               StragglerPolicy, elastic_slices)
from repro.distributed.sharding import (RULES_DEFAULT, RULES_FSDP,
                                        RULES_FSDP_LONG,
                                        RULES_LONG_CONTEXT, cache_shardings,
                                        data_sharding, param_shardings,
                                        replicated, spec_for, tree_shardings)

__all__ = [
    "compressed_psum", "compression_ratio", "dequantize_int8",
    "init_error_feedback", "quantize_int8",
    "ElasticRun", "HeartbeatMonitor", "StragglerPolicy", "elastic_slices",
    "RULES_DEFAULT", "RULES_FSDP", "RULES_FSDP_LONG", "RULES_LONG_CONTEXT",
    "cache_shardings", "data_sharding", "param_shardings", "replicated",
    "spec_for", "tree_shardings",
]
