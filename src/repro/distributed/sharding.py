"""Logical-axis sharding rules → concrete NamedShardings.

Parameters/caches carry logical axis names (see ``layers.Maker``); this
module maps them onto mesh axes with two safety passes:
  * divisibility — a dim that doesn't divide by the mesh axis size is
    replicated instead (e.g. granite-20b's single KV head under TP=16);
  * uniqueness — a mesh axis may appear once per spec; the first logical
    dim that claims it wins (e.g. long-context KV: seq takes ``model``,
    so kv_heads drops to replicated).

Rule sets:
  RULES_DEFAULT       — TP over heads/mlp/vocab/experts, DP over batch
  RULES_LONG_CONTEXT  — additionally shards kv_seq over ``model``
                        (sequence-parallel decode for long_500k)
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

Rules = Dict[str, Any]

RULES_DEFAULT: Rules = {
    "batch": ("pod", "data"),
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "heads_flat": "model",
    "mlp": "model",
    "experts": "model",
    "embed": None,
    "embed2": None,
    "layers": None,
    "kv_seq": None,
}

RULES_LONG_CONTEXT: Rules = dict(RULES_DEFAULT, kv_seq="model")

# FSDP-style 2-D weight sharding: d_model over the data (+pod) axes on top
# of TP. Required for training big archs (arctic-480b params+optimizer do
# not fit under TP-16 alone) and for serving arctic.
RULES_FSDP: Rules = dict(RULES_DEFAULT, embed=("data", "pod"))
RULES_FSDP_LONG: Rules = dict(RULES_FSDP, kv_seq="model")


def _mesh_axes(mesh: Mesh, rule) -> Tuple[str, ...]:
    if rule is None:
        return ()
    axes = rule if isinstance(rule, tuple) else (rule,)
    return tuple(a for a in axes if a in mesh.axis_names)


def spec_for(axes: Tuple[Optional[str], ...], shape: Tuple[int, ...],
             mesh: Mesh, rules: Rules) -> P:
    used: set = set()
    out = []
    for dim, ax in zip(shape, axes):
        entry: Any = None
        if ax is not None:
            maxes = _mesh_axes(mesh, rules.get(ax))
            maxes = tuple(a for a in maxes if a not in used)
            if maxes:
                size = 1
                for a in maxes:
                    size *= mesh.shape[a]
                if dim % size == 0 and dim > 0:
                    entry = maxes if len(maxes) > 1 else maxes[0]
                    used.update(maxes)
        out.append(entry)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(axes_tree, shapes_tree, mesh: Mesh, rules: Rules):
    """Zip an axes tree with a ShapeDtypeStruct tree -> NamedSharding tree."""
    is_ax = lambda x: isinstance(x, tuple)
    flat_ax, treedef = jax.tree.flatten(axes_tree, is_leaf=is_ax)
    flat_sh = jax.tree.leaves(shapes_tree)
    assert len(flat_ax) == len(flat_sh), (len(flat_ax), len(flat_sh))
    specs = [NamedSharding(mesh, spec_for(a, s.shape, mesh, rules))
             for a, s in zip(flat_ax, flat_sh)]
    return jax.tree.unflatten(treedef, specs)


def param_shardings(cfg: ArchConfig, mesh: Mesh, rules: Rules = RULES_DEFAULT):
    """NamedSharding tree for ``transformer.init_params`` output."""
    from repro.models import transformer as tf
    axes = tf.param_axes(cfg)
    shapes = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
    return tree_shardings(axes, shapes, mesh, rules)


def cache_shardings(cfg: ArchConfig, mesh: Mesh, batch: int, max_len: int,
                    rules: Rules = RULES_DEFAULT):
    from repro.models import transformer as tf
    axes = tf.cache_axes(cfg)
    shapes = jax.eval_shape(lambda: tf.init_cache(cfg, batch, max_len))
    return tree_shardings(axes, shapes, mesh, rules)


def data_sharding(mesh: Mesh, *, extra_dims: int = 1,
                  rules: Rules = RULES_DEFAULT) -> NamedSharding:
    """[batch, ...] arrays: batch over (pod, data), rest replicated."""
    axes = _mesh_axes(mesh, rules["batch"])
    return NamedSharding(mesh, P(axes if len(axes) > 1 else
                                 (axes[0] if axes else None)))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
