"""Render the dry-run JSON cache into the EXPERIMENTS.md roofline tables."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

ARCH_ORDER = ("gemma2-27b", "minicpm3-4b", "granite-20b", "nemotron-4-15b",
              "granite-moe-3b-a800m", "arctic-480b", "rwkv6-3b",
              "zamba2-2.7b", "internvl2-1b", "musicgen-large")
SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def load_cells(dryrun_dir: str, mesh: str) -> Dict[str, dict]:
    out = {}
    for f in glob.glob(os.path.join(dryrun_dir, mesh, "*.json")):
        d = json.load(open(f))
        out[f"{d['arch']}__{d['shape']}"] = d
    return out


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(cells: Dict[str, dict]) -> str:
    hdr = ("| arch | shape | t_compute | t_memory | t_collective | bound | "
           "useful-FLOPs | roofline-frac | peak GB/chip | note |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            d = cells.get(f"{arch}__{shape}")
            if d is None:
                continue
            if d["status"] == "skipped":
                rows.append(f"| {arch} | {shape} | — | — | — | — | — | — | — "
                            f"| {d['skip_reason']} |")
                continue
            r = d["roofline"]
            note = []
            if d.get("num_waves", 1) > 1:
                note.append(f"prefill waves×{d['num_waves']}")
            if not d["memory"]["fits_16gb"]:
                note.append("OVER v5e HBM")
            rows.append(
                f"| {arch} | {shape} | {_fmt_s(r['t_compute_s'])} | "
                f"{_fmt_s(r['t_memory_s'])} | {_fmt_s(r['t_collective_s'])} | "
                f"{r['bottleneck']} | {r['useful_flops_fraction']:.2f} | "
                f"{r['roofline_fraction']:.3f} | "
                f"{d['memory']['peak_bytes']/1e9:.1f} | {';'.join(note)} |")
    return hdr + "\n".join(rows) + "\n"


def summary(cells: Dict[str, dict]) -> dict:
    ok = [d for d in cells.values() if d["status"] == "ok"]
    skipped = [d for d in cells.values() if d["status"] == "skipped"]
    failed = [d for d in cells.values() if d["status"] == "failed"]
    bounds: Dict[str, int] = {}
    for d in ok:
        bounds[d["roofline"]["bottleneck"]] = bounds.get(
            d["roofline"]["bottleneck"], 0) + 1
    return {"ok": len(ok), "skipped": len(skipped), "failed": len(failed),
            "bounds": bounds,
            "fits": sum(1 for d in ok if d["memory"]["fits_16gb"]),
            "compile_s": sum(d["t_compile_s"] for d in ok)}


def worst_cells(cells: Dict[str, dict], n: int = 5) -> List[str]:
    ok = [d for d in cells.values() if d["status"] == "ok"]
    ok.sort(key=lambda d: d["roofline"]["roofline_fraction"])
    return [f"{d['arch']}__{d['shape']}"
            f" (frac={d['roofline']['roofline_fraction']:.3f},"
            f" bound={d['roofline']['bottleneck']})" for d in ok[:n]]


def most_collective_bound(cells: Dict[str, dict], n: int = 5) -> List[str]:
    ok = [d for d in cells.values() if d["status"] == "ok"]

    def coll_share(d):
        r = d["roofline"]
        tot = r["t_compute_s"] + r["t_memory_s"] + r["t_collective_s"]
        return r["t_collective_s"] / tot if tot else 0

    ok.sort(key=coll_share, reverse=True)
    return [f"{d['arch']}__{d['shape']} (coll_share={coll_share(d):.2f})"
            for d in ok[:n]]


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    args = ap.parse_args()
    for mesh in ("pod16x16", "pod2x16x16"):
        cells = load_cells(args.dir, mesh)
        print(f"\n## {mesh}: {summary(cells)}")
        print(roofline_table(cells))
        print("worst roofline:", worst_cells(cells))
        print("most collective-bound:", most_collective_bound(cells))


if __name__ == "__main__":
    main()
