import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this builds ShapeDtypeStruct stand-ins for every input
(params, optimizer state, caches, batches — no device allocation),
pjit-lowers the entry point on the production mesh, compiles it, and
records:
  * memory_analysis()      — proves the cell fits per-chip HBM,
  * cost_analysis()        — per-chip FLOPs / bytes for §Roofline,
  * collective bytes       — parsed from the optimized HLO,
  * the roofline report row (launch/roofline.py).

Results are cached as JSON under experiments/dryrun/<mesh>/ so the
80-compile sweep is resumable (the container has one core; a full sweep
is minutes-to-hours). Failures here are bugs in the sharding config.

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] [--single-pod]
"""

import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ArchConfig, ShapeSuite, get_arch, get_shape, list_archs, SHAPE_SUITES
from repro.distributed import sharding as shd
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh, mesh_chip_count
from repro.models import transformer as tf
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_loop import make_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

ASSIGNED_ARCHS = (
    "gemma2-27b", "minicpm3-4b", "granite-20b", "nemotron-4-15b",
    "granite-moe-3b-a800m", "arctic-480b", "rwkv6-3b", "zamba2-2.7b",
    "internvl2-1b", "musicgen-large",
)


# ---------------------------------------------------------------------------
# Sharding-rule selection per cell (see DESIGN.md §5)
# ---------------------------------------------------------------------------


def rules_for(cfg: ArchConfig, suite: ShapeSuite, mesh: Mesh,
              override: Optional[str] = None) -> shd.Rules:
    if override:
        return getattr(shd, f"RULES_{override.upper()}")
    model_size = mesh.shape.get("model", 1)
    if suite.entry == "train_step":
        return shd.RULES_FSDP                      # 2-D weight sharding
    if suite.name == "long_500k":
        # 500k-token KV leaves no weight headroom: 2-D weight sharding +
        # sequence-parallel KV
        return shd.RULES_FSDP_LONG
    # serving: weight-stationary TP unless the model cannot fit under TP
    tp_bytes = cfg.param_count() * 2 / model_size
    needs_fsdp = tp_bytes > 8e9                    # > half of v5e HBM
    kv_shardable = (cfg.num_kv_heads > 0
                    and cfg.num_kv_heads % model_size == 0)
    long_ctx = not kv_shardable
    if needs_fsdp:
        return shd.RULES_FSDP_LONG if long_ctx else shd.RULES_FSDP
    return shd.RULES_LONG_CONTEXT if long_ctx else shd.RULES_DEFAULT


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs with shardings; zero allocation)
# ---------------------------------------------------------------------------


def _with_shardings(shapes, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        shapes, shardings)


def _batch_first(mesh: Mesh, rules: shd.Rules, ndim: int,
                 batch: Optional[int] = None) -> NamedSharding:
    axes = tuple(a for a in (rules["batch"] if isinstance(rules["batch"], tuple)
                             else (rules["batch"],)) if a in mesh.axis_names)
    # drop trailing axes until the batch dim divides (long_500k has B=1)
    while axes and batch is not None:
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if batch % size == 0:
            break
        axes = axes[:-1]
    spec = [axes if len(axes) > 1 else (axes[0] if axes else None)]
    spec += [None] * (ndim - 1)
    return NamedSharding(mesh, P(*spec))


def input_specs(cfg: ArchConfig, suite: ShapeSuite, mesh: Mesh,
                rules: shd.Rules, *, opt_cfg: Optional[OptConfig] = None,
                kv_quant: bool = False) -> Dict[str, Any]:
    """All entry-point inputs as sharded ShapeDtypeStructs."""
    B, S = suite.global_batch, suite.seq_len
    pshapes = jax.eval_shape(
        lambda k: tf.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    pshard = shd.tree_shardings(tf.param_axes(cfg), pshapes, mesh, rules)
    params = _with_shardings(pshapes, pshard)
    out: Dict[str, Any] = {"params": params, "param_shardings": pshard}

    fe = cfg.frontend
    is_audio = fe is not None and fe.kind == "encodec_stub"
    is_vlm = fe is not None and fe.kind == "vit_stub"
    bsh = lambda nd: _batch_first(mesh, rules, nd, batch=B)

    if suite.entry == "train_step":
        opt_cfg = opt_cfg or default_opt_cfg(cfg)
        oshapes = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), pshapes)
        oshard = {"m": pshard, "v": pshard,
                  "step": NamedSharding(mesh, P())}
        out["opt_state"] = _with_shardings(oshapes, oshard)
        out["opt_shardings"] = oshard
        tok_shape = (B, S, fe.num_codebooks) if is_audio else (B, S)
        if is_vlm:
            tok_shape = (B, S - fe.num_prefix_embeddings)
        batch = {
            "tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32,
                                           sharding=bsh(len(tok_shape))),
            "labels": jax.ShapeDtypeStruct(tok_shape, jnp.int32,
                                           sharding=bsh(len(tok_shape))),
        }
        if is_vlm:
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (B, fe.num_prefix_embeddings, fe.embed_dim), jnp.bfloat16,
                sharding=bsh(3))
        out["batch"] = batch
        return out

    if suite.entry == "prefill":
        tok_shape = (B, S, fe.num_codebooks) if is_audio else (B, S)
        if is_vlm:
            tok_shape = (B, S - fe.num_prefix_embeddings)
        inputs = {"tokens": jax.ShapeDtypeStruct(tok_shape, jnp.int32,
                                                 sharding=bsh(len(tok_shape)))}
        if is_vlm:
            inputs["image_embeds"] = jax.ShapeDtypeStruct(
                (B, fe.num_prefix_embeddings, fe.embed_dim), jnp.bfloat16,
                sharding=bsh(3))
        out["inputs"] = inputs
        return out

    # serve_step
    cshapes = jax.eval_shape(lambda: tf.init_cache(cfg, B, S,
                                                   kv_quant=kv_quant))
    cshard = shd.tree_shardings(tf.cache_axes(cfg, kv_quant=kv_quant),
                                cshapes, mesh, rules)
    out["cache"] = _with_shardings(cshapes, cshard)
    out["cache_shardings"] = cshard
    tok_shape = (B, fe.num_codebooks) if is_audio else (B,)
    out["inputs"] = {
        "token": jax.ShapeDtypeStruct(tok_shape, jnp.int32,
                                      sharding=bsh(len(tok_shape))),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32, sharding=bsh(1)),
    }
    return out


def default_opt_cfg(cfg: ArchConfig) -> OptConfig:
    # bf16 moments for very large models (fits v5e; DESIGN.md §4)
    big = cfg.param_count() > 100e9
    return OptConfig(moment_dtype="bfloat16" if big else "float32")


# ---------------------------------------------------------------------------
# Cell compilation
# ---------------------------------------------------------------------------


def lower_cell(cfg: ArchConfig, suite: ShapeSuite, mesh: Mesh, *,
               rules_override: Optional[str] = None,
               attn_chunk: int = 1024,
               variant: Optional[Dict[str, Any]] = None):
    """Returns (lowered, specs, cost_thunk) for one cell.

    variant: hillclimb knobs — {"accum": int, "act_mode": "model"|"none",
    "moe_group": int, "split_cache": bool, "kv_quant": bool}.
    """
    variant = variant or {}
    import dataclasses as _dc
    if variant.get("moe_group") and cfg.moe is not None:
        cfg = _dc.replace(cfg, moe=_dc.replace(
            cfg.moe, group_size=variant["moe_group"]))
    if variant.get("split_cache") is False:
        cfg = _dc.replace(cfg, local_global_pattern=False) \
            if False else _dc.replace(cfg, sliding_window=None,
                                      local_global_pattern=False)
    if variant.get("rules"):
        rules_override = variant["rules"]
    rules = rules_for(cfg, suite, mesh, rules_override)
    _opt = None
    if variant.get("moment_bf16"):
        _opt = OptConfig(moment_dtype="bfloat16")
    specs = input_specs(cfg, suite, mesh, rules, opt_cfg=_opt,
                        kv_quant=bool(variant.get("kv_quant")))
    repl = NamedSharding(mesh, P())

    if suite.entry == "train_step":
        opt_cfg = _opt or default_opt_cfg(cfg)
        # activation TP: saved residuals shard over d_model (model axis)
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        act_spec = P(batch_axes if len(batch_axes) > 1 else batch_axes[0],
                     None, "model")
        # microbatch accumulation: transient activations scale 1/accum
        accum = variant.get("accum",
                            16 if cfg.param_count() > 100e9 else 4)
        if variant.get("act_mode") == "none":
            act_spec = None
        step = make_train_step(cfg, opt_cfg, attn_chunk=attn_chunk,
                               remat=True, remat_group=1, act_spec=act_spec,
                               accum_steps=accum)
        metrics_sh = {"loss": repl, "ce": repl, "aux": repl, "tokens": repl,
                      "lr": repl, "grad_norm": repl}
        fn = jax.jit(step,
                     in_shardings=(specs["param_shardings"],
                                   specs["opt_shardings"],
                                   jax.tree.map(lambda s: s.sharding,
                                                specs["batch"])),
                     out_shardings=(specs["param_shardings"],
                                    specs["opt_shardings"], metrics_sh),
                     donate_argnums=(0, 1))
        with mesh:
            lowered = fn.lower(specs["params"], specs["opt_state"],
                               specs["batch"])
        return lowered, specs, (step, (specs["params"], specs["opt_state"],
                                       specs["batch"]))

    if suite.entry == "prefill":
        def pf(params, inputs):
            return tf.prefill(params, inputs, cfg, attn_chunk=attn_chunk)
        B = suite.global_batch
        logits_sh = _batch_first(mesh, rules_for(cfg, suite, mesh,
                                                 rules_override), 2, batch=B)
        # cache sharding: same rules as a decode cell at this length
        cshapes = jax.eval_shape(
            lambda: tf.init_cache(cfg, B, suite.seq_len))
        cshard = shd.tree_shardings(tf.cache_axes(cfg), cshapes, mesh,
                                    rules_for(cfg, suite, mesh,
                                              rules_override))
        fn = jax.jit(pf,
                     in_shardings=(specs["param_shardings"],
                                   jax.tree.map(lambda s: s.sharding,
                                                specs["inputs"])),
                     out_shardings=(logits_sh, cshard))
        with mesh:
            lowered = fn.lower(specs["params"], specs["inputs"])
        return lowered, specs, (pf, (specs["params"], specs["inputs"]))

    rules = rules_for(cfg, suite, mesh, rules_override)
    seq_axis = "model" if rules.get("kv_seq") == "model" else None

    kv_quant = bool(variant.get("kv_quant"))

    def sv(params, cache, inputs):
        return tf.serve_step(params, cache, inputs, cfg, seq_axis=seq_axis,
                             kv_quant=kv_quant)

    logits_sh = _batch_first(mesh, rules_for(cfg, suite, mesh,
                                             rules_override), 2,
                             batch=suite.global_batch)
    fn = jax.jit(sv,
                 in_shardings=(specs["param_shardings"],
                               specs["cache_shardings"],
                               jax.tree.map(lambda s: s.sharding,
                                            specs["inputs"])),
                 out_shardings=(logits_sh, specs["cache_shardings"]),
                 donate_argnums=(1,))
    with mesh:
        lowered = fn.lower(specs["params"], specs["cache"], specs["inputs"])
    return lowered, specs, (sv, (specs["params"], specs["cache"],
                                 specs["inputs"]))


def compile_cell(arch: str, shape: str, *, multi_pod: bool = False,
                 rules_override: Optional[str] = None,
                 variant: Optional[Dict[str, Any]] = None,
                 verbose: bool = True) -> Dict[str, Any]:
    cfg = get_arch(arch)
    suite = get_shape(shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    res: Dict[str, Any] = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "rules": rules_override or "auto",
                           "variant": variant or {}}
    skip = suite.skip_reason(cfg)
    if skip:
        res["status"] = "skipped"
        res["skip_reason"] = skip
        return res
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chip_count(mesh)
    t0 = time.time()
    try:
        # prefill wave-splitting: a full batch of 32k-token prefills may
        # exceed HBM for the largest archs — real serving prefills such
        # requests in sequential waves. Auto-retry at half batch until the
        # cell fits (recorded as wave_batch / num_waves).
        import dataclasses as _dc
        eff_suite = suite
        waves = 1
        while True:
            lowered, _, cost_thunk = lower_cell(cfg, eff_suite, mesh,
                                                rules_override=rules_override,
                                                variant=variant)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
            ma = compiled.memory_analysis()
            peak = ma.argument_size_in_bytes + ma.temp_size_in_bytes
            if (peak < 16e9 or suite.entry != "prefill"
                    or eff_suite.global_batch <= 1):
                break
            waves *= 2
            eff_suite = _dc.replace(eff_suite,
                                    global_batch=eff_suite.global_batch // 2)
            t0 = time.time()
        res["wave_batch"] = eff_suite.global_batch
        res["num_waves"] = waves
        # scan-aware GLOBAL flops/bytes (see hlo_cost.py); scale wave cells
        # back to the full batch so the roofline reflects the whole job
        from repro.launch.hlo_cost import jaxpr_cost
        cost_fn, cost_args = cost_thunk
        with mesh:      # tracing hits with_sharding_constraint(P...)
            jcost = jaxpr_cost(cost_fn, *cost_args)
        jcost = {k: v * waves for k, v in jcost.items()}
        hlo = compiled.as_text()
        report = rl.build_report(
            arch=arch, shape=shape, mesh_name=mesh_name, chips=chips,
            cost=jcost, hlo_text=hlo,
            model_flops=rl.model_flops_for(cfg, suite.entry, suite.seq_len,
                                           suite.global_batch),
            peak_memory=float(ma.argument_size_in_bytes
                              + ma.temp_size_in_bytes))
        res.update({
            "status": "ok",
            "t_lower_s": t_lower,
            "t_compile_s": t_compile,
            "memory": {
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_bytes": ma.argument_size_in_bytes
                + ma.temp_size_in_bytes,
                "fits_16gb": (ma.argument_size_in_bytes
                              + ma.temp_size_in_bytes) < 16e9,
            },
            "roofline": report.row(),
        })
        if verbose:
            print(f"[{mesh_name}] {arch} × {shape}: OK "
                  f"(lower {t_lower:.1f}s compile {t_compile:.1f}s, "
                  f"peak {res['memory']['peak_bytes']/1e9:.2f} GB/chip, "
                  f"bottleneck={report.bottleneck})")
    except Exception as e:  # noqa: BLE001 — failures are cell bugs, recorded
        res["status"] = "failed"
        res["error"] = f"{type(e).__name__}: {e}"
        res["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[{mesh_name}] {arch} × {shape}: FAILED {res['error']}")
    return res


# ---------------------------------------------------------------------------
# Sweep driver (resumable)
# ---------------------------------------------------------------------------


def cell_path(out_dir: str, arch: str, shape: str, mesh_name: str) -> str:
    return os.path.join(out_dir, mesh_name, f"{arch}__{shape}.json")


def run_sweep(archs, shapes, *, multi_pod: bool, out_dir: str,
              force: bool = False, rules_override: Optional[str] = None):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    for arch in archs:
        for shape in shapes:
            path = cell_path(out_dir, arch, shape, mesh_name)
            if os.path.exists(path) and not force:
                with open(path) as f:
                    prev = json.load(f)
                if prev.get("status") in ("ok", "skipped"):
                    print(f"[{mesh_name}] {arch} × {shape}: cached "
                          f"({prev['status']})")
                    continue
            res = compile_cell(arch, shape, multi_pod=multi_pod,
                               rules_override=rules_override)
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(res, f, indent=1, default=str)
            os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--rules", default=None,
                    help="override rule set (default/fsdp/long_context/fsdp_long)")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else [s.name for s in SHAPE_SUITES]
    meshes = []
    if args.both_meshes or (not args.multi_pod and not args.single_pod):
        meshes = [False, True] if args.all or args.both_meshes else [False]
    if args.single_pod:
        meshes.append(False)
    if args.multi_pod:
        meshes.append(True)
    for mp in meshes:
        run_sweep(archs, shapes, multi_pod=mp, out_dir=args.out,
                  force=args.force, rules_override=args.rules)


if __name__ == "__main__":
    main()
