"""Training driver: data pipeline -> pjit train loop -> checkpoints.

Runs REAL training on the local device(s); the production mesh path is
exercised by dryrun.py. Supports resume-from-latest (fault tolerance) and
the explicit-collective DP path with int8 gradient compression.

  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --preset 100m \
      --steps 300 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data import DataConfig, TokenStream
from repro.training import (OptConfig, init_training, latest_step,
                            make_train_step, restore_checkpoint,
                            save_checkpoint)


def preset_config(cfg, preset: str):
    """Scale an arch down to a runnable-size preset preserving its family."""
    if preset == "full":
        return cfg
    if preset == "100m":
        return dataclasses.replace(
            cfg.reduced(), name=cfg.name + "-100m", num_layers=10,
            d_model=640, num_heads=8, num_kv_heads=min(cfg.num_kv_heads, 8) or 0,
            head_dim=80 if cfg.attn_kind == "gqa" else None,
            d_ff=2560, vocab_size=32_000)
    if preset == "smoke":
        return cfg.reduced()
    raise KeyError(preset)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--preset", default="100m",
                    choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args()

    cfg = preset_config(get_arch(args.arch), args.preset)
    print(f"# arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"devices={jax.device_count()}")
    opt = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                    total_steps=args.steps)
    data = TokenStream(cfg, DataConfig(global_batch=args.batch,
                                       seq_len=args.seq, seed=0))
    params, opt_state = init_training(cfg, opt, jax.random.PRNGKey(0))

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start, state = restore_checkpoint(
            args.ckpt_dir, {"params": params, "opt": opt_state,
                            "data": data.cursor()})
        params, opt_state = state["params"], state["opt"]
        data.restore(state["data"])
        print(f"# resumed from step {start}")

    step_fn = jax.jit(make_train_step(
        cfg, opt, attn_chunk=min(256, args.seq), loss_chunk=128,
        accum_steps=args.accum))
    history = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if (step + 1) % args.log_every == 0 or step == start:
            loss = float(m["loss"])
            tput = (args.batch * args.seq * (step + 1 - start)
                    / max(time.time() - t0, 1e-9))
            print(f"step {step+1:5d} loss {loss:.4f} "
                  f"lr {float(m['lr']):.2e} tok/s {tput:,.0f}")
            history.append({"step": step + 1, "loss": loss})
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1,
                            {"params": params, "opt": opt_state,
                             "data": data.cursor()})
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps,
                        {"params": params, "opt": opt_state,
                         "data": data.cursor()})
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f)
    print("# done")


if __name__ == "__main__":
    main()
