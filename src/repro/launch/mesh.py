"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — required because the
dry-run must set XLA_FLAGS before any jax initialization.

Topology: TPU v5e pods of 16x16 = 256 chips; the multi-pod mesh stacks a
``pod`` axis (2 pods = 512 chips) used purely for data parallelism (DCN
between pods is slower than ICI; only gradient/batch collectives cross it).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh

# jax >= 0.5 has jax.sharding.AxisType and make_mesh(..., axis_types=...);
# older jax builds meshes without axis types. One fallback for both.
AxisType = getattr(jax.sharding, "AxisType", None)


def _axis_type_kwargs(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(model: int = 1, data: Optional[int] = None) -> Mesh:
    """Small mesh over the locally visible devices (tests / examples)."""
    n = len(jax.devices())
    data = data or max(n // model, 1)
    return jax.make_mesh((data, model), ("data", "model"),
                         **_axis_type_kwargs(2))


def mesh_chip_count(mesh: Mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
