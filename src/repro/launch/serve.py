"""Serving driver: TeleRAG engine + REAL LLM decode on local devices.

End-to-end RAG serving of batched requests: lookahead prefetch is
dispatched (async) before the pre-retrieval decode loop runs on an actual
reduced-size model, then hybrid retrieval + post-retrieval decode.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
      --pipeline hyde --requests 8
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

import repro.core as core
from repro.configs import get_arch
from repro.models import transformer as tf
from repro.serving import (EngineConfig, KVCacheManager, RetrievalRuntime,
                           TeleRAGEngine, latency_summary, make_traces,
                           sample)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--pipeline", default="hyde")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--vectors", type=int, default=60_000)
    ap.add_argument("--clusters", type=int, default=96)
    ap.add_argument("--nprobe", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"# building datastore ({args.vectors} x 192d, "
          f"{args.clusters} clusters)")
    store = core.synthetic_datastore(args.vectors, dim=192, seed=args.seed)
    index = core.build_ivf(store, args.clusters, page_size=96,
                           kmeans_iters=4)

    arch_full = get_arch(args.arch)
    cfg = arch_full.reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(args.seed))
    step = jax.jit(lambda p, c, i: tf.serve_step(p, c, i, cfg))

    # one shared HBM page pool: prefetch pages + KV leases draw from (and
    # are ledger-accounted against) the same slab, so size it for both
    kv_bytes = KVCacheManager(cfg).nbytes(args.batch, 128)
    page_bytes = index.paged.page_nbytes()
    eng = TeleRAGEngine(index, EngineConfig(
        nprobe=args.nprobe, top_k=3, buffer_pages=512,
        pool_pages=512 + -(-kv_bytes // page_bytes),
        lookahead_rank=min(2 * args.nprobe, args.clusters),
        kernel_mode="ref", cache_enabled=True, chips=4), arch_full)
    kv = KVCacheManager(cfg, pool=eng.pool)
    eng.calibrate_tcc()
    runtime = RetrievalRuntime(eng, include_tail=True)

    rng = np.random.default_rng(args.seed + 1)
    q = store.embeddings[rng.choice(store.num_vectors, args.requests)]
    q = q + 0.05 * rng.standard_normal(q.shape).astype(np.float32)
    q /= np.linalg.norm(q, axis=-1, keepdims=True)

    t0 = time.time()
    done = 0
    all_recs = []
    for lo in range(0, args.requests, args.batch):
        hi = min(lo + args.batch, args.requests)
        qb = q[lo:hi]
        traces = make_traces(args.pipeline, hi - lo, seed=args.seed + lo)

        # lookahead dispatch, then REAL pre-retrieval decode overlapping it
        nbytes, nfetch = eng.lookahead(
            qb, [t.pre_retrieval_tokens()[0] for t in traces])
        lease = kv.acquire(hi - lo, 128, fresh=True)
        tok = jnp.zeros((hi - lo,), jnp.int32)
        gen = max(t.pre_retrieval_tokens()[0] for t in traces)
        for t in range(min(gen, 32)):
            logits, lease.cache = step(params, lease.cache,
                                       {"token": tok,
                                        "pos": jnp.full((hi - lo,), t,
                                                        jnp.int32)})
            tok = sample(logits)
        kv.release(lease)

        # retrieval + event-clock telemetry through the runtime
        recs = [runtime.submit(qb[i], traces[i]) for i in range(hi - lo)]
        runtime.run()
        all_recs.extend(recs)
        for rec in recs:
            r = rec.result
            hit = sum(rt.hits for rt in r.rounds)
            mis = sum(rt.misses for rt in r.rounds)
            print(f"req {r.request_id:3d} [{r.pipeline}] rounds="
                  f"{len(r.rounds)} hit_rate={hit/max(hit+mis,1):.0%} "
                  f"admit->complete={rec.latency*1e3:7.1f}ms "
                  f"docs={[int(d[0]) for d in r.doc_ids[:1]]}")
        done += hi - lo
    wall = time.time() - t0
    print(f"# {done} requests in {wall:.1f}s "
          f"({done/wall:.2f} req/s real wall on CPU); "
          f"h2d={eng.buffer.stats.bytes_h2d/1e6:.1f}MB "
          f"cache_hit={eng.cache.hit_rate:.0%}")
    print(f"# event-clock {latency_summary(all_recs)}")
    led = eng.ledger.snapshot()
    adm = eng.admission.stats
    print(f"# memory ledger: prefetch={led.get('prefetch', 0)/1e6:.2f}MB "
          f"kv={led.get('kv', 0)/1e6:.2f}MB "
          f"weights={led.get('weights', 0)/1e9:.2f}GB "
          f"peak={led['peak']/1e9:.2f}GB occ={eng.ledger.occupancy():.1%}")
    print(f"# admission: admitted={adm.admitted} stalled={adm.stalled} "
          f"resumed={adm.resumed} capped={adm.capped} "
          f"spilled_pages={adm.spilled_pages}")


if __name__ == "__main__":
    main()
