"""Serving driver: TeleRAGServer + REAL LLM decode on local devices.

End-to-end RAG serving of batched requests through the unified serving
front-end: requests are submitted as typed ``RagRequest``s and the
server's decode hook runs an actual reduced-size model inside each round
frontier — *after* the policy dispatched the (async) lookahead copy, so
the real decode steps overlap the in-flight prefetch and the prefetch is
dispatched exactly once (the legacy driver called ``eng.lookahead``
manually and then the runtime prefetched again through the policy,
double-counting H2D bytes).

Decode is **asynchronous and real**: the hook returns per-request
``DecodeEvent``s (observed steps + measured wall seconds), so each
request's generation windows on the event clock come from the decode
that actually ran, not the trace's static hardware estimate.  By
default the server runs per-request continuous batching
(``--static-groups`` restores the legacy group-granular execution):
waves re-form at every round frontier, so a slow request's batch-mates
move on without it and late arrivals join in-flight decode batches.

The decode hook is a ``serving.DecodeRunner``: by default it runs on
the **paged KV substrate** — each wave leases a block table over a
shared page slab (``acquire_paged``) and every step attends through
``kernels.ops.flash_decode_paged`` (``--dense-decode`` pins the legacy
dense ``[B, max_len]`` bucket path).  Either way the lease draws from
the engine's shared HBM pool, so prefetch pages and decode KV are
accounted against the same ledger.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
      --pipeline hyde --requests 8
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax

import repro.core as core
from repro.configs import get_arch
from repro.launch import env as launch_env
from repro.models import transformer as tf
from repro.obs import SystemClock, analyze, write_jsonl, write_trace
from repro.serving import (DecodeRunner, EngineConfig, KVCacheManager,
                           RagRequest, TeleRAGServer, make_traces,
                           summarize_latency)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--pipeline", default="hyde")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--vectors", type=int, default=60_000)
    ap.add_argument("--clusters", type=int, default=96)
    ap.add_argument("--nprobe", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--static-groups", action="store_true",
                    help="legacy group-granular execution instead of "
                         "per-request continuous batching")
    ap.add_argument("--dense-decode", action="store_true",
                    help="decode on the legacy dense [B, max_len] KV "
                         "bucket path instead of the paged block-table "
                         "substrate (EngineConfig.paged_decode=False)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the run's flight-recorder stream as "
                         "Chrome/Perfetto trace-event JSON (load in "
                         "ui.perfetto.dev; see docs/OBSERVABILITY.md)")
    ap.add_argument("--print-env", action="store_true",
                    help="print the recommended launch environment "
                         "(tcmalloc preload, XLA flags) and exit")
    args = ap.parse_args()

    if args.print_env:
        launch_env.print_env()
        return

    print(f"# building datastore ({args.vectors} x 192d, "
          f"{args.clusters} clusters)")
    store = core.synthetic_datastore(args.vectors, dim=192, seed=args.seed)
    index = core.build_ivf(store, args.clusters, page_size=96,
                           kmeans_iters=4)

    arch_full = get_arch(args.arch)
    cfg = arch_full.reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(args.seed))

    # one shared HBM page pool: prefetch pages + KV leases draw from (and
    # are ledger-accounted against) the same slab, so size it for both
    kv_bytes = KVCacheManager(cfg).nbytes(args.batch, 128)
    page_bytes = index.paged.page_nbytes()

    # REAL pre-retrieval decode for each wave — runs while the wave's
    # prefetch copy (dispatched just before, once, by the policy) is
    # still in flight.  Paged block-table KV by default; the runner
    # leases per wave, releases in finally, and returns per-request
    # DecodeEvents whose measured per-step wall time drives each
    # member's generation window on the event clock.
    runner = DecodeRunner(params, cfg, max_len=128, max_steps=32,
                          slab_seqs=max(2 * args.batch, 8))

    # real serving driver: inject the REAL wall clock — scheduler
    # overhead and t_cc calibration should measure this machine here
    # (library default is the deterministic event clock)
    srv = TeleRAGServer(index, EngineConfig(
        nprobe=args.nprobe, top_k=3, buffer_pages=512,
        pool_pages=512 + -(-kv_bytes // page_bytes),
        lookahead_rank=min(2 * args.nprobe, args.clusters),
        kernel_mode="ref", cache_enabled=True, chips=4,
        paged_decode=not args.dense_decode), 1, arch_full,
        micro_batch=args.batch, include_tail=True, decode_hook=runner,
        continuous=not args.static_groups, wall_clock=SystemClock())
    runner.attach(srv)
    eng = srv.engines[0]
    eng.calibrate_tcc()

    rng = np.random.default_rng(args.seed + 1)
    q = store.embeddings[rng.choice(store.num_vectors, args.requests)]
    q = q + 0.05 * rng.standard_normal(q.shape).astype(np.float32)
    q /= np.linalg.norm(q, axis=-1, keepdims=True)

    traces = make_traces(args.pipeline, args.requests, seed=args.seed)
    t0 = time.time()
    responses = srv.serve([RagRequest(q=q[i], trace=traces[i])
                           for i in range(args.requests)])
    wall = time.time() - t0
    for r in responses:
        hit = sum(rt.hits for rt in r.rounds)
        mis = sum(rt.misses for rt in r.rounds)
        print(f"req {r.request_id:3d} [{r.pipeline}] rounds="
              f"{len(r.rounds)} hit_rate={hit/max(hit+mis,1):.0%} "
              f"arrival->complete={r.latency_s*1e3:7.1f}ms "
              f"docs={[int(d[0]) for d in r.doc_ids[:1]]}")
    print(f"# {len(responses)} requests in {wall:.1f}s "
          f"({len(responses)/wall:.2f} req/s real wall on CPU); "
          f"h2d={eng.buffer.stats.bytes_h2d/1e6:.1f}MB "
          f"cache_hit={eng.cache.hit_rate:.0%} "
          f"decode={'paged' if runner.paged else 'dense'} "
          f"(waves={runner.stats['paged_waves'] or runner.stats['dense_waves']})")
    print(f"# event-clock {summarize_latency(responses)}")
    print(srv.telemetry().summary())
    print(analyze(srv.recorder).summary())
    if args.trace_out:
        write_trace(srv.recorder, args.trace_out)
        # the lossless sibling stream: what tools/telint.py --trace and
        # tools/check_trace.py replay for happens-before invariants
        import os
        jl = os.path.splitext(args.trace_out)[0] + ".jsonl"
        write_jsonl(srv.recorder, jl)
        print(f"# trace written to {args.trace_out} (+ {jl}; "
              f"{len(srv.recorder.events)} events)")


if __name__ == "__main__":
    main()
