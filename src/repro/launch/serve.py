"""Serving driver: TeleRAGServer + REAL LLM decode on local devices.

End-to-end RAG serving of batched requests through the unified serving
front-end: requests are submitted as typed ``RagRequest``s and the
server's decode hook runs an actual reduced-size model inside each round
frontier — *after* the policy dispatched the (async) lookahead copy, so
the real decode steps overlap the in-flight prefetch and the prefetch is
dispatched exactly once (the legacy driver called ``eng.lookahead``
manually and then the runtime prefetched again through the policy,
double-counting H2D bytes).

Decode is **asynchronous and real**: the hook returns per-request
``DecodeEvent``s (observed steps + measured wall seconds), so each
request's generation windows on the event clock come from the decode
that actually ran, not the trace's static hardware estimate.  By
default the server runs per-request continuous batching
(``--static-groups`` restores the legacy group-granular execution):
waves re-form at every round frontier, so a slow request's batch-mates
move on without it and late arrivals join in-flight decode batches.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b \
      --pipeline hyde --requests 8
"""

from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

import repro.core as core
from repro.configs import get_arch
from repro.launch import env as launch_env
from repro.models import transformer as tf
from repro.obs import SystemClock, analyze, write_jsonl, write_trace
from repro.serving import (DecodeEvent, EngineConfig, KVCacheManager,
                           RagRequest, TeleRAGServer, make_traces, sample,
                           summarize_latency)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--pipeline", default="hyde")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--vectors", type=int, default=60_000)
    ap.add_argument("--clusters", type=int, default=96)
    ap.add_argument("--nprobe", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--static-groups", action="store_true",
                    help="legacy group-granular execution instead of "
                         "per-request continuous batching")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the run's flight-recorder stream as "
                         "Chrome/Perfetto trace-event JSON (load in "
                         "ui.perfetto.dev; see docs/OBSERVABILITY.md)")
    ap.add_argument("--print-env", action="store_true",
                    help="print the recommended launch environment "
                         "(tcmalloc preload, XLA flags) and exit")
    args = ap.parse_args()

    if args.print_env:
        launch_env.print_env()
        return

    print(f"# building datastore ({args.vectors} x 192d, "
          f"{args.clusters} clusters)")
    store = core.synthetic_datastore(args.vectors, dim=192, seed=args.seed)
    index = core.build_ivf(store, args.clusters, page_size=96,
                           kmeans_iters=4)

    arch_full = get_arch(args.arch)
    cfg = arch_full.reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(args.seed))
    step = jax.jit(lambda p, c, i: tf.serve_step(p, c, i, cfg))

    # one shared HBM page pool: prefetch pages + KV leases draw from (and
    # are ledger-accounted against) the same slab, so size it for both
    kv_bytes = KVCacheManager(cfg).nbytes(args.batch, 128)
    page_bytes = index.paged.page_nbytes()

    def decode_hook(replica, records, gen_tokens, rnd):
        """REAL pre-retrieval decode for this wave — runs while the
        wave's prefetch copy (dispatched just before, once, by the
        policy) is still in flight.  Returns per-request DecodeEvents:
        the measured per-step wall time drives each member's generation
        window on the event clock (async decode as the clock source,
        not the trace's static estimate)."""
        n = len(records)
        steps = min(max(gen_tokens, default=0), 32)
        lease = kv.acquire(n, 128, fresh=True, tenant=records[0].tenant)
        try:
            tok = jnp.zeros((n,), jnp.int32)
            t0 = time.perf_counter()
            logits = None
            for t in range(steps):
                logits, lease.cache = step(
                    params, lease.cache,
                    {"token": tok,
                     "pos": jnp.full((n,), t, jnp.int32)})
                tok = sample(logits)
            if logits is not None:
                jax.block_until_ready(tok)
            per_step = (time.perf_counter() - t0) / max(steps, 1)
        finally:
            # a raising decode step must still hand the bucket back for
            # recycling — leaked KV leases shrink the shared pool until
            # admission starves (telint TL001)
            kv.release(lease)
        return [DecodeEvent(request_id=r.request_id,
                            tokens=min(g, steps) if g else 0,
                            seconds=per_step * (min(g, steps) if g else 0))
                for r, g in zip(records, gen_tokens)]

    # real serving driver: inject the REAL wall clock — scheduler
    # overhead and t_cc calibration should measure this machine here
    # (library default is the deterministic event clock)
    srv = TeleRAGServer(index, EngineConfig(
        nprobe=args.nprobe, top_k=3, buffer_pages=512,
        pool_pages=512 + -(-kv_bytes // page_bytes),
        lookahead_rank=min(2 * args.nprobe, args.clusters),
        kernel_mode="ref", cache_enabled=True, chips=4), 1, arch_full,
        micro_batch=args.batch, include_tail=True, decode_hook=decode_hook,
        continuous=not args.static_groups, wall_clock=SystemClock())
    eng = srv.engines[0]
    kv = KVCacheManager(cfg, pool=eng.pool)
    eng.calibrate_tcc()

    rng = np.random.default_rng(args.seed + 1)
    q = store.embeddings[rng.choice(store.num_vectors, args.requests)]
    q = q + 0.05 * rng.standard_normal(q.shape).astype(np.float32)
    q /= np.linalg.norm(q, axis=-1, keepdims=True)

    traces = make_traces(args.pipeline, args.requests, seed=args.seed)
    t0 = time.time()
    responses = srv.serve([RagRequest(q=q[i], trace=traces[i])
                           for i in range(args.requests)])
    wall = time.time() - t0
    for r in responses:
        hit = sum(rt.hits for rt in r.rounds)
        mis = sum(rt.misses for rt in r.rounds)
        print(f"req {r.request_id:3d} [{r.pipeline}] rounds="
              f"{len(r.rounds)} hit_rate={hit/max(hit+mis,1):.0%} "
              f"arrival->complete={r.latency_s*1e3:7.1f}ms "
              f"docs={[int(d[0]) for d in r.doc_ids[:1]]}")
    print(f"# {len(responses)} requests in {wall:.1f}s "
          f"({len(responses)/wall:.2f} req/s real wall on CPU); "
          f"h2d={eng.buffer.stats.bytes_h2d/1e6:.1f}MB "
          f"cache_hit={eng.cache.hit_rate:.0%}")
    print(f"# event-clock {summarize_latency(responses)}")
    print(srv.telemetry().summary())
    print(analyze(srv.recorder).summary())
    if args.trace_out:
        write_trace(srv.recorder, args.trace_out)
        # the lossless sibling stream: what tools/telint.py --trace and
        # tools/check_trace.py replay for happens-before invariants
        import os
        jl = os.path.splitext(args.trace_out)[0] + ".jsonl"
        write_jsonl(srv.recorder, jl)
        print(f"# trace written to {args.trace_out} (+ {jl}; "
              f"{len(srv.recorder.events)} events)")


if __name__ == "__main__":
    main()
