"""Roofline-term extraction from compiled dry-run artifacts.

Sources (see hlo_cost.py — XLA's cost_analysis counts while bodies once,
so scanned models under-report by ~layers x accum; we fix it):
  * ``hlo_cost.jaxpr_cost`` — GLOBAL logical FLOPs + matmul/gather traffic
    with scan trip counts applied;
  * ``hlo_cost.collective_bytes_corrected`` — per-chip collective bytes
    from the post-SPMD HLO with while-loop trip multipliers.

Terms (seconds):
  compute    = flops_global / (chips * peak_flops)
  memory     = bytes_global / (chips * hbm_bw)
  collective = coll_bytes_per_chip / ici_bw
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.configs.base import ArchConfig
from repro.core.budget import HardwareProfile, TPU_V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|"
                       r"f64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op, by op kind.

    Matches both sync ops and -start/-done pairs (counting the -start only,
    so async collectives are not double counted).
    """
    out: Dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(.+?)\s+(%?)(" + "|".join(COLLECTIVE_OPS) +
                      r")(-start)?(\.[0-9]+)?\(", line)
        if not m:
            continue
        if re.search(r"(" + "|".join(COLLECTIVE_OPS) + r")-done", line):
            continue
        lhs, kind = m.group(1), m.group(3)
        nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(lhs))
        out[kind] += nbytes
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_global: float
    bytes_global: float
    coll_bytes_per_chip: float
    coll_breakdown: Dict[str, int]
    model_flops: float                       # 6·N(_active)·D global
    hw: HardwareProfile = TPU_V5E
    peak_memory_bytes: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.flops_global / (self.chips * self.hw.peak_flops)

    @property
    def t_memory(self) -> float:
        return self.bytes_global / (self.chips * self.hw.hbm_bw)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / self.hw.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / global HLO flops — catches remat/redundancy."""
        return self.model_flops / self.flops_global if self.flops_global \
            else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful FLOPs / (bound time × peak)."""
        denom = self.t_bound * self.hw.peak_flops * self.chips
        return self.model_flops / denom if denom else 0.0

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops_global": self.flops_global,
            "hlo_bytes_global": self.bytes_global,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "coll_breakdown": self.coll_breakdown,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
            "peak_memory_bytes": self.peak_memory_bytes,
        }


def model_flops_for(cfg: ArchConfig, entry: str, seq_len: int,
                    global_batch: int) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D forward-only (per the 6ND convention;
    decode D = batch tokens, one step)."""
    n = cfg.active_param_count()
    if entry == "train_step":
        return 6.0 * n * seq_len * global_batch
    if entry == "prefill":
        return 2.0 * n * seq_len * global_batch
    return 2.0 * n * global_batch            # serve_step: one token per seq


def build_report(*, arch: str, shape: str, mesh_name: str, chips: int,
                 cost: Dict, hlo_text: str, model_flops: float,
                 peak_memory: Optional[float] = None,
                 hw: HardwareProfile = TPU_V5E) -> RooflineReport:
    """cost: {'flops': global, 'bytes': global} from hlo_cost.jaxpr_cost."""
    from repro.launch.hlo_cost import collective_bytes_corrected
    coll = collective_bytes_corrected(hlo_text)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_global=float(cost.get("flops", 0.0)),
        bytes_global=float(cost.get("bytes", 0.0)),
        coll_bytes_per_chip=float(sum(coll.values())),
        coll_breakdown={k: int(v) for k, v in coll.items()},
        model_flops=model_flops,
        peak_memory_bytes=peak_memory, hw=hw)
