"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> re-analyse.

Runs named variants of the three selected cells and records roofline
terms per iteration to experiments/hillclimb/. Each variant is one
hypothesis from EXPERIMENTS.md §Perf; the tables there are generated
from these JSONs.

  PYTHONPATH=src python -m repro.launch.hillclimb [--cell NAME]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

from repro.launch.dryrun import compile_cell

OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "experiments", "hillclimb")

# (cell_name, arch, shape, variant_name, variant, hypothesis)
PLAN = [
    # A. most collective-bound: gemma2-27b train_4k ---------------------------
    ("gemma2_train", "gemma2-27b", "train_4k", "v0_dshard_accum4",
     {"accum": 4, "act_mode": "model"},
     "baseline: d-sharded residual activations (fits HBM) but every "
     "matmul re-gathers x over the model axis -> collective-dominated"),
    ("gemma2_train", "gemma2-27b", "train_4k", "v1_noact_accum32",
     {"accum": 32, "act_mode": "none"},
     "drop activation d-sharding; recover HBM via 8x more microbatches. "
     "Napkin: activation all-gathers (~2*x_bytes*L per mb) vanish; FSDP "
     "weight gathers grow 8x (params/256*15 per layer per mb). For "
     "gemma2 act-AG ~ 2*0.6GB*46 >> weight-AG 8*29MB*46 -> expect big "
     "collective-term drop"),
    ("gemma2_train", "gemma2-27b", "train_4k", "v2_noact_accum16",
     {"accum": 16, "act_mode": "none"},
     "halve the weight re-gather count vs v1 if activations still fit"),
    ("gemma2_train", "gemma2-27b", "train_4k", "v3_noact_accum8",
     {"accum": 8, "act_mode": "none"},
     "push further: fewer weight gathers, more activation residency"),

    ("gemma2_train", "gemma2-27b", "train_4k", "v4_dshard_accum2",
     {"accum": 2, "act_mode": "model"},
     "v1-v3 refuted the no-act direction: FSDP weight re-gathers inside "
     "the microbatch loop dominate (~1.9s per microbatch from v1's "
     "61s/32). Keep d-sharded activations, HALVE accum instead: weight "
     "gathers 7.6s->3.8s, act gathers unchanged => predict ~18s (-17%)"),
    ("gemma2_train", "gemma2-27b", "train_4k", "v5_tponly_accum4",
     {"accum": 4, "act_mode": "model", "rules": "default",
      "moment_bf16": True},
     "remove FSDP entirely: TP-only weights (3.4GB/chip, fits with bf16 "
     "moments) => zero weight re-gathers; collective = act gathers + one "
     "grad all-reduce per step. Predict ~14.5s (-34%)"),

    # B. worst useful-FLOPs fraction: granite-moe train_4k --------------------
    ("moe_train", "granite-moe-3b-a800m", "train_4k", "v0_baseline",
     {"accum": 4, "act_mode": "model"},
     "baseline MoE train: dispatch einsums at group 512 cost "
     "Tg*cf/(3*ff)=0.42x expert FLOPs; activation d-sharding collective-"
     "dominated like dense"),
    ("moe_train", "granite-moe-3b-a800m", "train_4k", "v1_noact_accum16",
     {"accum": 16, "act_mode": "none"},
     "same activation-sharding hypothesis as gemma2 v1"),
    ("moe_train", "granite-moe-3b-a800m", "train_4k", "v2_group256",
     {"accum": 16, "act_mode": "none", "moe_group": 256},
     "halve dispatch group: dispatch-einsum FLOPs scale with Tg "
     "(Tg*cf/(3*ff): 0.42 -> 0.21) at slightly higher drop variance"),
    ("moe_train", "granite-moe-3b-a800m", "train_4k", "v3_group1024",
     {"accum": 16, "act_mode": "none", "moe_group": 1024},
     "counter-hypothesis: larger groups reduce cumsum/one-hot op count "
     "but double dispatch FLOPs — expect WORSE compute term (refutation "
     "test for v2's direction)"),

    ("moe_train", "granite-moe-3b-a800m", "train_4k", "v4_group256_actmodel",
     {"accum": 4, "act_mode": "model", "moe_group": 256},
     "clean group-size comparison at the winning act config: dispatch "
     "FLOPs ratio 0.42->0.21 of expert FLOPs; predict compute term -11% "
     "and small collective win vs v0"),
    ("moe_train", "granite-moe-3b-a800m", "train_4k", "v5_tponly_accum4",
     {"accum": 4, "act_mode": "model", "rules": "default",
      "moment_bf16": True, "moe_group": 256},
     "apply the gemma2-v5 lesson: TP-only weights for a 3.4B model are "
     "only 0.42GB/chip; kill FSDP weight re-gathers entirely"),

    # C. paper-representative serving cell: gemma2-27b decode_32k -------------
    ("gemma2_decode", "gemma2-27b", "decode_32k", "v0_unsplit",
     {"split_cache": False},
     "original uniform cache: every layer holds 32k KV; memory term = "
     "weights + full cache read"),
    ("gemma2_decode", "gemma2-27b", "decode_32k", "v1_split",
     {},
     "split cache: local (sliding-window) layers keep a 4096-slot ring "
     "-> cache bytes ~halve (23/46 layers at window/Smax=1/8 size)"),
    ("gemma2_decode", "gemma2-27b", "decode_32k", "v2_split_int8",
     {"kv_quant": True},
     "int8-quantized global-layer KV (per-token,per-head scales): cache "
     "read bytes halve again; parity test shows 100% argmax agreement"),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(OUT, exist_ok=True)
    for cell, arch, shape, vname, variant, hyp in PLAN:
        if args.cell and cell != args.cell:
            continue
        path = os.path.join(OUT, f"{cell}__{vname}.json")
        if os.path.exists(path) and not args.force:
            print(f"{cell}/{vname}: cached")
            continue
        res = compile_cell(arch, shape, multi_pod=False, variant=variant,
                           verbose=False)
        res["hypothesis"] = hyp
        res["variant_name"] = vname
        with open(path, "w") as f:
            json.dump(res, f, indent=1, default=str)
        if res["status"] == "ok":
            r = res["roofline"]
            print(f"{cell}/{vname}: comp={r['t_compute_s']*1e3:.1f}ms "
                  f"mem={r['t_memory_s']*1e3:.1f}ms "
                  f"coll={r['t_collective_s']*1e3:.1f}ms "
                  f"bound={r['bottleneck']} "
                  f"peak={res['memory']['peak_bytes']/1e9:.1f}GB")
        else:
            print(f"{cell}/{vname}: {res['status']} "
                  f"{res.get('error','')[:120]}")


if __name__ == "__main__":
    main()
