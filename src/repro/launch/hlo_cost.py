"""Scan-aware cost extraction (the fix for XLA cost_analysis undercount).

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, not
times its trip count — a 46-layer scanned transformer under-reports
FLOPs/bytes/collectives by ~2 orders of magnitude. Two complementary
extractors:

1. ``jaxpr_cost(fn, *args)`` — walks the closed jaxpr, multiplying
   dot_general FLOPs and matmul operand/output traffic by enclosing scan
   lengths. GLOBAL (pre-SPMD) logical work; divide by chip count for
   per-chip roofline terms. Elementwise traffic is excluded by design
   (it fuses into the matmuls on TPU); gather/scatter (embedding, cache
   updates) contribute output-sized traffic.

2. ``collective_bytes_corrected(hlo_text)`` — parses the post-SPMD HLO
   into computations, recovers each while loop's trip count from its
   condition (the ``constant(N)`` feeding the LT compare), propagates
   multipliers through the call graph, and sums collective output bytes
   x multiplier. Shapes in the SPMD module are per-chip shards, so the
   result is per-chip collective bytes.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.launch.roofline import COLLECTIVE_OPS, _SHAPE_RE, _shape_bytes

# ---------------------------------------------------------------------------
# 1. jaxpr-level flops / matmul traffic
# ---------------------------------------------------------------------------


def _dot_flops_bytes(eqn) -> Tuple[float, float]:
    (contract, batch) = eqn.params["dimension_numbers"]
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    out = eqn.outvars[0].aval
    k = 1.0
    for d in contract[0]:
        k *= a.shape[d]
    flops = 2.0 * float(np.prod(out.shape, dtype=np.float64)) * k
    nbytes = sum(float(np.prod(v.shape, dtype=np.float64)) * v.dtype.itemsize
                 for v in (a, b, out))
    return flops, nbytes


_RECURSE_PARAMS = ("jaxpr", "call_jaxpr", "body_jaxpr", "cond_jaxpr",
                   "branches")


def _subjaxprs(val):
    """Yield any Jaxpr reachable from a primitive param value."""
    if hasattr(val, "eqns"):                  # raw Jaxpr
        yield val
    elif hasattr(val, "jaxpr"):               # ClosedJaxpr
        yield val.jaxpr
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _subjaxprs(v)


def _walk(jaxpr, mult: float, acc: Dict[str, float]) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            f, b = _dot_flops_bytes(eqn)
            acc["flops"] += mult * f
            acc["bytes"] += mult * b
            continue
        if prim in ("gather", "dynamic_slice", "take"):
            out = eqn.outvars[0].aval
            acc["bytes"] += mult * float(
                np.prod(out.shape, dtype=np.float64)) * out.dtype.itemsize
        elif prim in ("scatter", "scatter-add", "scatter_add",
                      "dynamic_update_slice"):
            upd = eqn.invars[-1].aval if eqn.invars else eqn.outvars[0].aval
            acc["bytes"] += mult * 2 * float(
                np.prod(upd.shape, dtype=np.float64)) * upd.dtype.itemsize
        # recurse into every sub-jaxpr; scan multiplies by trip count
        sub_mult = mult * float(eqn.params.get("length", 1)) \
            if prim == "scan" else mult
        for k, v in eqn.params.items():
            if k == "update_jaxpr":           # scatter combiner: trivial
                continue
            for sub in _subjaxprs(v):
                _walk(sub, sub_mult, acc)


def jaxpr_cost(fn, *args, **kwargs) -> Dict[str, float]:
    """Global logical FLOPs + matmul/gather traffic of fn(*args)."""
    closed = jax.make_jaxpr(fn, **kwargs)(*args)
    acc = {"flops": 0.0, "bytes": 0.0}
    _walk(closed.jaxpr, 1.0, acc)
    return acc


# ---------------------------------------------------------------------------
# 2. trip-count-corrected collectives from post-SPMD HLO
# ---------------------------------------------------------------------------

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(")
_WHILE_RE = re.compile(r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)",
                       re.S)
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\([^)]*\), direction=LT")


def _split_computations(hlo: str) -> Dict[str, str]:
    """Split module text into named computation bodies.

    A computation header looks like
      ``%name (p0: T, (nested, tuple)) -> T { ``
    possibly prefixed by ENTRY; params may contain nested parens, so we
    key on "-> ... {" at end of line.
    """
    comps: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        stripped = line.strip()
        is_hdr = (stripped.endswith("{") and ") -> " in stripped
                  and not stripped.startswith("HloModule"))
        m = _COMP_HDR.match(stripped) if is_hdr else None
        if m:
            if cur_name:
                comps[cur_name] = "\n".join(cur_lines)
            cur_name = m.group(1)
            cur_lines = [line]
        elif cur_name is not None:
            cur_lines.append(line)
    if cur_name:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def _trip_count(cond_body: str, default: int = 1) -> int:
    """Trip count = the s32 constant compared LT against the counter."""
    consts = [int(c) for c in _CONST_RE.findall(cond_body)]
    if not consts:
        return default
    # heuristic: the loop bound is the largest constant in the condition
    return max(consts)


def collective_bytes_corrected(hlo: str) -> Dict[str, float]:
    comps = _split_computations(hlo)
    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+%?([\w.\-]+)", line)
            if m:
                entry = m.group(1)
    if entry is None:
        # fall back: uncorrected flat parse
        from repro.launch.roofline import collective_bytes
        return {k: float(v) for k, v in collective_bytes(hlo).items()}

    # per-computation raw collective bytes + call edges
    raw: Dict[str, Dict[str, float]] = {}
    edges: Dict[str, list] = defaultdict(list)   # (callee, mult)
    for name, body in comps.items():
        acc = {k: 0.0 for k in COLLECTIVE_OPS}
        for line in body.splitlines():
            m = re.search(r"=\s*(.+?)\s+%?(" + "|".join(COLLECTIVE_OPS)
                          + r")(-start)?(\.[0-9]+)?\(", line)
            if m and not re.search(r"-done", line):
                lhs, kind = m.group(1), m.group(2)
                acc[kind] += sum(_shape_bytes(d, s)
                                 for d, s in _SHAPE_RE.findall(lhs))
        raw[name] = acc
        for wm in _WHILE_RE.finditer(body):
            cond, wbody = wm.group(1), wm.group(2)
            trips = _trip_count(comps.get(cond, ""))
            edges[name].append((wbody, float(trips)))
        for cm in _CALL_RE.finditer(body):
            callee = cm.group(1)
            if callee in comps and all(callee != b for b, _ in edges[name]):
                edges[name].append((callee, 1.0))

    # propagate multipliers from entry (cycles impossible in HLO)
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cur = order[i]
        i += 1
        for callee, m in edges.get(cur, []):
            mult[callee] += mult[cur] * m
            if callee not in seen:
                seen.add(callee)
                order.append(callee)

    out = {k: 0.0 for k in COLLECTIVE_OPS}
    for name, acc in raw.items():
        f = mult.get(name, 0.0)
        if f <= 0:
            continue
        for k, v in acc.items():
            out[k] += v * f
    return out
