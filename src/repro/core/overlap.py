"""Cluster-overlap analytics (paper §3.3, Table 1).

Coverage = |C_in ∩ C_out| / |C_out| at a given nprobe: the fraction of
clusters the rewritten query actually probes that the *input* query
predicted. The six pipelines differ in how far the rewrite moves the
embedding; we model each pipeline's rewrite strength as a perturbation
sigma calibrated so baseline coverage lands in the paper's Table 1 band.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import ivf as ivf_mod
from repro.core.embedder import synthetic_rewrite
from repro.core.ivf import IVFIndex


# Per-pipeline rewrite strengths, CALIBRATED so that cluster coverage on
# the benchmark index (320k x 256d, 256 clusters, nprobe 64) matches the
# paper's Table 1 NQ row (the calibration sweep lives in
# benchmarks/bench_overlap.py; see EXPERIMENTS.md). Self-RAG performs no
# query transform => coverage 100% by construction. Ordering matches the
# paper: Iter mildest rewrite, SubQ decomposition the strongest.
PIPELINE_SIGMA: Dict[str, float] = {
    "hyde": 0.0375,     # target coverage 0.731
    "subq": 0.0550,     # target coverage 0.632
    "iter": 0.0100,     # target coverage 0.915
    "irg": 0.0200,      # target coverage 0.838
    "flare": 0.0275,    # target coverage 0.791
    "self_rag": 0.0,    # 1.000 by construction
}


def coverage(index: IVFIndex, q_in: np.ndarray, q_out: np.ndarray,
             nprobe: int) -> float:
    """Average |C_in ∩ C_out| / |C_out| over the query batch."""
    cin = ivf_mod.probe(q_in, index, nprobe)
    cout = ivf_mod.probe(q_out, index, nprobe)
    covs = []
    for a, b in zip(cin, cout):
        sa, sb = set(a.tolist()), set(b.tolist())
        covs.append(len(sa & sb) / max(len(sb), 1))
    return float(np.mean(covs))


def pipeline_pairs(queries: np.ndarray, pipeline: str, *, seed: int = 0,
                   rounds: int = 1) -> List[Tuple[np.ndarray, np.ndarray]]:
    """(q_in, q_out) pairs for a pipeline; multi-round pipelines drift
    cumulatively (each round rewrites the previous round's query)."""
    rng = np.random.default_rng(seed)
    sigma = PIPELINE_SIGMA[pipeline]
    pairs = []
    q = queries
    for _ in range(max(rounds, 1)):
        q_out = synthetic_rewrite(q, sigma, rng) if sigma > 0 else q.copy()
        pairs.append((q, q_out))
        q = q_out
    return pairs


def overlap_table(index: IVFIndex, queries: np.ndarray, nprobe: int, *,
                  seed: int = 0) -> Dict[str, float]:
    """Table-1 analog: coverage per pipeline at the given nprobe."""
    out = {}
    for name in PIPELINE_SIGMA:
        q_in, q_out = pipeline_pairs(queries, name, seed=seed)[0]
        out[name] = coverage(index, q_in, q_out, nprobe)
    return out
