"""Prefetch-budget model (paper §4.1 + Appendix C) and calibration.

Appendix C shows the optimum lies at one of two points:
  case 1:  b_p* = B_link · t_LLM        (prefetch exactly through the
           pre-retrieval generation window — optimal whenever extra
           transfer time outweighs the marginal miss-rate reduction)
  case 2:  the stationary point of  b_p/B + r_miss(b_p)·nprobe·t_cc,
           valid only if it exceeds case 1 (rare on real link speeds).

We implement both: case 1 analytically, case 2 numerically over an
empirical miss-rate curve, and pick per Appendix C's rule. ``t̄_LLM`` is
calibrated from traces with a roofline decode-latency model (the paper
profiles 64 NQ samples; we do the same over synthetic traces).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

import numpy as np

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class HardwareProfile:
    name: str
    peak_flops: float          # bf16 FLOP/s per chip
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per link
    host_link_bw: float        # host<->chip bytes/s (the paper's "PCIe")
    hbm_bytes: float           # HBM capacity per chip
    # Effective per-query CPU scan bandwidth. 5 GB/s reproduces the
    # paper's Fig. 4/5 regime: 15 MB clusters -> ~3 ms per cluster, so
    # nprobe=256 CPU retrieval lands at ~0.8 s and makes retrieval 40-60%
    # of end-to-end latency, as measured there.
    host_mem_bw: float = 5e9
    host_search_overhead: float = 50e-6   # per-cluster dispatch overhead


TPU_V5E = HardwareProfile(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    host_link_bw=32e9,
    hbm_bytes=16e9,
)

# paper hardware (for paper-faithful modeled numbers)
RTX4090 = HardwareProfile("rtx4090", 165e12, 1008e9, 0.0, 32e9, 24e9)
H100 = HardwareProfile("h100", 989e12, 3350e9, 0.0, 64e9, 80e9)


def host_cluster_search_seconds(cluster_bytes: float, hw: HardwareProfile,
                                ) -> float:
    """CPU per-cluster similarity-search cost: memory-bound dot products
    over the cluster's vectors + fixed dispatch overhead. At the paper's
    scale (61 GB / 4096 clusters ≈ 15 MB/cluster) this lands at ~0.8 ms,
    matching the Fig. 4/5 regime where nprobe=256 CPU retrieval takes
    hundreds of ms and dominates end-to-end latency."""
    return cluster_bytes / hw.host_mem_bw + hw.host_search_overhead


# ---------------------------------------------------------------------------
# Decode latency model (memory-bound roofline; used for t̄_LLM calibration)
# ---------------------------------------------------------------------------


def decode_step_seconds(cfg: ArchConfig, hw: HardwareProfile, *,
                        batch: int, kv_len: int, chips: int = 1) -> float:
    """Per-token decode latency: max(weight+KV HBM reads, compute)."""
    act_params = cfg.active_param_count()
    weight_bytes = act_params * 2                         # bf16
    kv_bytes_per_seq = _kv_bytes_per_token(cfg) * kv_len
    mem = (weight_bytes + batch * kv_bytes_per_seq) / (hw.hbm_bw * chips)
    flops = 2 * act_params * batch + 2 * batch * _kv_flops_per_token(cfg, kv_len)
    comp = flops / (hw.peak_flops * chips)
    return max(mem, comp)


def _kv_bytes_per_token(cfg: ArchConfig) -> int:
    if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        return 0                                           # O(1) state
    if cfg.attn_kind == "mla":
        m = cfg.mla
        return (m.kv_lora_rank + m.qk_rope_head_dim) * 2
    if cfg.shared_attn_every:
        n_shared = cfg.num_layers // cfg.shared_attn_every
        return n_shared * 2 * cfg.num_kv_heads * cfg.resolved_head_dim * 2
    return cfg.num_layers * 2 * cfg.num_kv_heads * cfg.resolved_head_dim * 2


def _kv_flops_per_token(cfg: ArchConfig, kv_len: int) -> int:
    if cfg.ssm is not None and cfg.ssm.kind == "rwkv6":
        K = cfg.ssm.head_dim
        return cfg.num_layers * (cfg.d_model // K) * K * K * 2
    hd = cfg.resolved_head_dim
    L = (cfg.num_layers // cfg.shared_attn_every
         if cfg.shared_attn_every else cfg.num_layers)
    return L * cfg.num_heads * hd * kv_len * 2


def generation_window_seconds(cfg: ArchConfig, hw: HardwareProfile, *,
                              gen_tokens: Sequence[int], batch: int,
                              kv_len: int = 1024, chips: int = 1) -> float:
    """t̄_LLM: average pre-retrieval generation time over a trace sample."""
    per_tok = decode_step_seconds(cfg, hw, batch=batch, kv_len=kv_len,
                                  chips=chips)
    return float(np.mean(np.asarray(gen_tokens))) * per_tok


# ---------------------------------------------------------------------------
# Appendix C optimum
# ---------------------------------------------------------------------------


def case1_budget(t_llm: float, link_bw: float) -> int:
    return int(link_bw * t_llm)


def case2_budget(miss_rate_fn: Callable[[float], float], *,
                 link_bw: float, nprobe: int, t_cc: float,
                 b_max: float, n_grid: int = 256) -> Optional[int]:
    """Numeric stationary point of t1+t2 = b/B + r(b)·nprobe·t_cc on (0,b_max].

    Returns None when no interior minimum beats the boundary (the common
    case on modern links, per Appendix C).
    """
    bs = np.linspace(b_max / n_grid, b_max, n_grid)
    total = bs / link_bw + np.array([miss_rate_fn(b) for b in bs]) * nprobe * t_cc
    i = int(np.argmin(total))
    if 0 < i < n_grid - 1:
        return int(bs[i])
    return None


def optimal_budget(cfg: ArchConfig, hw: HardwareProfile, *,
                   gen_tokens: Sequence[int], batch: int,
                   miss_rate_fn: Optional[Callable[[float], float]] = None,
                   nprobe: int = 256, t_cc: float = 120e-6,
                   hbm_headroom_bytes: Optional[float] = None,
                   kv_len: int = 1024, chips: int = 1) -> int:
    """Full §4.1 policy: b* = B·t̄_LLM, optionally improved by case 2,
    clamped to the HBM headroom left after the model + KV cache."""
    t_llm = generation_window_seconds(cfg, hw, gen_tokens=gen_tokens,
                                      batch=batch, kv_len=kv_len, chips=chips)
    b = case1_budget(t_llm, hw.host_link_bw)
    if miss_rate_fn is not None:
        c2 = case2_budget(miss_rate_fn, link_bw=hw.host_link_bw,
                          nprobe=nprobe, t_cc=t_cc, b_max=4 * max(b, 1))
        if c2 is not None and c2 > b:
            b = c2
    if hbm_headroom_bytes is None:
        weight_bytes = cfg.active_param_count() * 2 / max(chips, 1)
        kv = _kv_bytes_per_token(cfg) * kv_len * batch / max(chips, 1)
        hbm_headroom_bytes = max(hw.hbm_bytes - weight_bytes - kv, 0) * 0.8
    return int(min(b, hbm_headroom_bytes))


def empirical_miss_curve(budgets: Sequence[float], hit_rates: Sequence[float],
                         ) -> Callable[[float], float]:
    """Interpolated r_miss(b) from profiled (budget, hit-rate) pairs."""
    bs = np.asarray(budgets, float)
    ms = 1.0 - np.asarray(hit_rates, float)
    order = np.argsort(bs)
    bs, ms = bs[order], ms[order]

    def fn(b: float) -> float:
        return float(np.interp(b, bs, ms))

    return fn
