"""Fixed-size paged device buffer for prefetched IVF clusters.

TPU analogue of the paper's pinned-CPU→GPU contiguous prefetch buffer
(Appendix D): a slab of ``num_pages`` page slots in device HBM plus a
host-side page table. All device mutation happens through ONE batched,
donated scatter per prefetch round — the JAX equivalent of an async DMA
burst (dispatch is async; the subsequent decode steps overlap with it).

Consistency invariants (tests/test_prefetch_buffer.py):
  * a device slot always holds a whole, un-corrupted page of exactly one
    cluster (page granularity transfers);
  * eviction is host bookkeeping + queued device invalidation — a slot is
    never searchable once its cluster was evicted (no duplicate results
    after refetch into different slots);
  * transfers are counted in bytes for the budget/telemetry layer.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.datastore import PagedClusters


@functools.partial(jax.jit, donate_argnums=(0, 1, 2))
def _scatter_pages(pages, page_ids, page_cluster, slots, new_pages, new_ids,
                   new_clusters):
    """One fused slab update; out-of-range slot indices are dropped (padding)."""
    pages = pages.at[slots].set(new_pages.astype(pages.dtype), mode="drop")
    page_ids = page_ids.at[slots].set(new_ids, mode="drop")
    page_cluster = page_cluster.at[slots].set(new_clusters, mode="drop")
    return pages, page_ids, page_cluster


def _round_up_pow2(n: int, lo: int = 8) -> int:
    r = lo
    while r < n:
        r *= 2
    return r


@dataclass
class TransferStats:
    bytes_h2d: int = 0
    pages_h2d: int = 0
    rounds: int = 0

    def add(self, pages: int, page_bytes: int):
        self.pages_h2d += pages
        self.bytes_h2d += pages * page_bytes
        self.rounds += 1


class PrefetchBuffer:
    def __init__(self, paged: PagedClusters, num_pages: int,
                 dtype=jnp.bfloat16):
        self.paged = paged
        self.num_pages = num_pages
        self.dtype = dtype
        ps, d = paged.page_size, paged.dim
        self.pages = jnp.zeros((num_pages, ps, d), dtype)
        self.page_ids = jnp.full((num_pages, ps), -1, jnp.int32)
        self.page_cluster = jnp.full((num_pages,), -1, jnp.int32)
        # host mirrors / page table
        self.slot_cluster = np.full(num_pages, -1, np.int64)
        self.resident: Dict[int, List[int]] = {}
        self.free: List[int] = list(range(num_pages - 1, -1, -1))
        self._pending_invalid: Set[int] = set()
        self.stats = TransferStats()

    # -- capacity ----------------------------------------------------------
    @property
    def page_nbytes(self) -> int:
        return self.paged.page_nbytes()

    @property
    def capacity_bytes(self) -> int:
        return self.num_pages * self.page_nbytes

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self.free)

    def free_pages(self) -> int:
        return len(self.free)

    def resident_clusters(self) -> Set[int]:
        return set(self.resident)

    def is_resident(self, cluster: int) -> bool:
        return cluster in self.resident

    # -- load --------------------------------------------------------------
    def load_clusters(self, clusters: Sequence[int],
                      ) -> Tuple[List[int], List[int]]:
        """Fetch whole clusters into free slots. Returns (loaded, rejected).

        Rejected = not enough free slots for the *whole* cluster (caller's
        planner should have prevented this; kept as a hard guarantee).
        """
        loaded: List[int] = []
        rejected: List[int] = []
        slot_list: List[int] = []
        np_pages: List[np.ndarray] = []
        np_ids: List[np.ndarray] = []
        np_cl: List[int] = []
        for c in clusters:
            c = int(c)
            if c in self.resident:
                loaded.append(c)
                continue
            npg = int(self.paged.cluster_num_pages[c])
            if npg > len(self.free):
                rejected.append(c)
                continue
            slots = [self.free.pop() for _ in range(npg)]
            self.resident[c] = slots
            self.slot_cluster[slots] = c
            self._pending_invalid.difference_update(slots)
            pg = self.paged.cluster_pages(c)
            pidc = self.paged.cluster_page_ids(c)
            for i, s in enumerate(slots):
                slot_list.append(s)
                np_pages.append(pg[i])
                np_ids.append(pidc[i])
                np_cl.append(c)
            loaded.append(c)

        # fold queued invalidations into the same scatter
        for s in sorted(self._pending_invalid):
            slot_list.append(s)
            np_pages.append(np.zeros((self.paged.page_size, self.paged.dim),
                                     np.float32))
            np_ids.append(np.full(self.paged.page_size, -1, np.int32))
            np_cl.append(-1)
        self._pending_invalid.clear()

        if slot_list:
            n = len(slot_list)
            cap = _round_up_pow2(n)   # bucket sizes => bounded recompiles
            slots_arr = np.full(cap, self.num_pages, np.int32)  # OOB = dropped
            slots_arr[:n] = slot_list
            pages_arr = np.zeros((cap, self.paged.page_size, self.paged.dim),
                                 np.float32)
            pages_arr[:n] = np.stack(np_pages)
            ids_arr = np.full((cap, self.paged.page_size), -1, np.int32)
            ids_arr[:n] = np.stack(np_ids)
            cl_arr = np.full(cap, -1, np.int32)
            cl_arr[:n] = np_cl
            # async dispatch: device_put + scatter overlap with LLM decode
            self.pages, self.page_ids, self.page_cluster = _scatter_pages(
                self.pages, self.page_ids, self.page_cluster,
                jnp.asarray(slots_arr), jnp.asarray(pages_arr),
                jnp.asarray(ids_arr), jnp.asarray(cl_arr))
            new_pages = sum(1 for c in np_cl if c >= 0)
            self.stats.add(new_pages, self.page_nbytes)
        return loaded, rejected

    # -- evict -------------------------------------------------------------
    def evict_clusters(self, clusters: Sequence[int]) -> int:
        """Host-side free + queued device invalidation. Returns pages freed."""
        freed = 0
        for c in clusters:
            c = int(c)
            slots = self.resident.pop(c, None)
            if slots is None:
                continue
            self.slot_cluster[slots] = -1
            self.free.extend(slots)
            self._pending_invalid.update(slots)
            freed += len(slots)
        return freed

    def flush_invalidations(self) -> None:
        """Force queued invalidations to the device (normally folded into
        the next load; needed before a search with no intervening load)."""
        if self._pending_invalid:
            self.load_clusters([])

    # -- views for the search kernel ----------------------------------------
    def device_view(self):
        return self.pages, self.page_ids, self.page_cluster

    def allowed_lut(self, clusters: Sequence[int]) -> jax.Array:
        """Boolean LUT [Nc] marking clusters searchable on-device."""
        lut = np.zeros(self.paged.num_clusters + 1, bool)   # +1: cluster -1 pad
        res = [c for c in clusters if c in self.resident]
        lut[res] = True
        return jnp.asarray(lut)
