"""Paged device buffer for prefetched IVF clusters, backed by the shared
``DevicePagePool``.

TPU analogue of the paper's pinned-CPU→GPU contiguous prefetch buffer
(Appendix D): cluster pages live in the replica-wide HBM slab owned by
``repro.memory.DevicePagePool``; this class keeps the *cluster* view —
which clusters are resident, in which page slots (their block tables),
which waves have them pinned — and routes all device mutation through
the pool's ONE batched, donated scatter per prefetch round (the JAX
equivalent of an async DMA burst; dispatch is async, so subsequent
decode steps overlap with it).

Consistency invariants (tests/test_core.py, tests/test_memory.py):
  * a device slot always holds a whole, un-corrupted page of exactly one
    cluster (page granularity transfers);
  * eviction is host bookkeeping + queued device invalidation — a slot is
    never searchable once its cluster was evicted (no duplicate results
    after refetch into different slots);
  * a cluster pinned by an in-flight wave is never evicted from under it
    (release happens on the wave's completion event);
  * transfers are counted in bytes for the budget/telemetry layer, and an
    invalidation-only scatter is NOT a transfer round (zero new pages
    moved means zero H2D rounds).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.datastore import PagedClusters
from repro.memory.pool import DevicePagePool, PageLease, Reservation


@dataclass
class TransferStats:
    bytes_h2d: int = 0
    pages_h2d: int = 0
    rounds: int = 0

    def add(self, pages: int, page_bytes: int):
        self.pages_h2d += pages
        self.bytes_h2d += pages * page_bytes
        self.rounds += 1


class PrefetchBuffer:
    def __init__(self, paged: PagedClusters, num_pages: Optional[int] = None,
                 dtype=jnp.bfloat16, *, pool: Optional[DevicePagePool] = None,
                 quota_pages: Optional[int] = None):
        if pool is None:
            if num_pages is None:
                raise ValueError("need num_pages or a pool")
            pool = DevicePagePool(paged, num_pages, dtype)
        self.paged = paged
        self.pool = pool
        # the prefetch share of the pool (cache quotas key off this, not
        # the slab extent, so pool size never changes cache behaviour)
        self.quota_pages = (quota_pages if quota_pages is not None
                            else pool.num_pages)
        # host mirrors / page table
        self.slot_cluster = np.full(pool.num_pages, -1, np.int64)
        self.resident: Dict[int, List[int]] = {}
        self._leases: Dict[int, PageLease] = {}          # cluster -> lease
        self._pins: Dict[object, List[PageLease]] = {}   # wave key -> leases
        self._pending_invalid: Set[int] = set()
        self.stats = TransferStats()

    # -- capacity ----------------------------------------------------------
    @property
    def num_pages(self) -> int:
        return self.pool.num_pages

    @property
    def pages(self) -> jax.Array:
        return self.pool.pages

    @property
    def page_ids(self) -> jax.Array:
        return self.pool.page_ids

    @property
    def page_cluster(self) -> jax.Array:
        return self.pool.page_cluster

    @property
    def page_nbytes(self) -> int:
        return self.paged.page_nbytes()

    @property
    def capacity_bytes(self) -> int:
        return self.pool.capacity_bytes

    @property
    def used_pages(self) -> int:
        return self.pool.used_pages

    def free_pages(self) -> int:
        return self.pool.free_pages()

    def resident_clusters(self) -> Set[int]:
        return set(self.resident)

    def is_resident(self, cluster: int) -> bool:
        return cluster in self.resident

    # -- pinning (waves hold their working set until completion) -----------
    def pin_clusters(self, key: object,
                     clusters: Sequence[int]) -> List[PageLease]:
        """Take a reference on each resident cluster for wave ``key`` so
        spill/eviction cannot reclaim it while the wave is in flight.
        Returns the leases pinned (for a targeted ``release_pins``)."""
        pinned = self._pins.setdefault(key, [])
        taken: List[PageLease] = []
        for c in clusters:
            lease = self._leases.get(int(c))
            if lease is not None:
                self.pool.retain(lease)
                pinned.append(lease)
                taken.append(lease)
        return taken

    def release_pins(self, key: object, leases: Sequence[PageLease]) -> None:
        """Drop exactly these previously-taken pins for wave ``key`` (a
        parked wave must not hold its tentative hit pins — other parked
        waves would mutually wait on them)."""
        held = self._pins.get(key, [])
        for lease in leases:
            held.remove(lease)
            if lease.lease_id in self.pool.leases:
                self.pool.release(lease)

    def unpin(self, key: object) -> int:
        """Drop wave ``key``'s references; returns pages made evictable."""
        pages = 0
        for lease in self._pins.pop(key, []):
            if lease.lease_id in self.pool.leases:   # force-evict already
                pages += lease.num_pages if lease.refcount == 2 else 0
                self.pool.release(lease)             # dropped stale pins
        return pages

    def pinned_clusters(self) -> Set[int]:
        return {c for c, l in self._leases.items() if l.refcount > 1}

    def _own_lease_ids(self, key: object) -> Set[int]:
        """Lease ids pinned under ``key`` — a single pin key, or a
        tuple/list/set of keys (a continuous-batching wave's view is
        the union of its member requests' pins)."""
        if key is None:
            return set()
        if isinstance(key, (tuple, list, set, frozenset)):
            own: Set[int] = set()
            for k in key:
                own.update(l.lease_id for l in self._pins.get(k, ()))
            return own
        return {l.lease_id for l in self._pins.get(key, ())}

    def reclaimable_split(self, key: object,
                          hit_clusters: Sequence[int] = (),
                          ) -> Tuple[int, int]:
        """(waitable, spillable) page counts from wave ``key``'s view:
        *waitable* pages are pinned by other in-flight waves (their
        completion events release them — legitimate stall targets),
        *spillable* pages are unpinned residency evictable right now.
        The wave's own pins and the given ``hit_clusters`` (residency
        the wave is about to pin as its device hits) count as neither.
        ``key`` may be one pin key or a collection of per-request pin
        keys (the wave's members under continuous batching)."""
        own = self._own_lease_ids(key)
        hits = {int(c) for c in hit_clusters}
        waitable = spillable = 0
        for c, lease in self._leases.items():
            if lease.lease_id in own or c in hits:
                continue
            if lease.refcount > 1:
                waitable += lease.num_pages
            else:
                spillable += lease.num_pages
        return waitable, spillable

    def pages_pinned_by_others(self, key: object) -> int:
        """Pages pinned by in-flight waves other than ``key``."""
        return self.reclaimable_split(key)[0]

    # -- load --------------------------------------------------------------
    def load_clusters(self, clusters: Sequence[int], *,
                      reservation: Optional[Reservation] = None,
                      ) -> Tuple[List[int], List[int]]:
        """Fetch whole clusters into pool slots. Returns (loaded, rejected).

        Rejected = the pool cannot lease the *whole* cluster (admission
        should have reserved headroom; kept as a hard guarantee).
        """
        loaded: List[int] = []
        rejected: List[int] = []
        slot_list: List[int] = []
        np_pages: List[np.ndarray] = []
        np_ids: List[np.ndarray] = []
        np_cl: List[int] = []
        for c in clusters:
            c = int(c)
            if c in self.resident:
                loaded.append(c)
                continue
            npg = int(self.paged.cluster_num_pages[c])
            lease = self.pool.lease_slots(npg, "prefetch", tag=c,
                                          reservation=reservation)
            if lease is None:
                rejected.append(c)
                continue
            slots = list(lease.slots)
            self.resident[c] = slots
            self._leases[c] = lease
            self.slot_cluster[slots] = c
            self._pending_invalid.difference_update(slots)
            pg = self.paged.cluster_pages(c)
            pidc = self.paged.cluster_page_ids(c)
            for i, s in enumerate(slots):
                slot_list.append(s)
                np_pages.append(pg[i])
                np_ids.append(pidc[i])
                np_cl.append(c)
            loaded.append(c)

        # fold queued invalidations into the same scatter
        for s in sorted(self._pending_invalid):
            slot_list.append(s)
            np_pages.append(np.zeros((self.paged.page_size, self.paged.dim),
                                     np.float32))
            np_ids.append(np.full(self.paged.page_size, -1, np.int32))
            np_cl.append(-1)
        self._pending_invalid.clear()

        if slot_list:
            self.pool.scatter(slot_list, np_pages, np_ids, np_cl)
            new_pages = sum(1 for c in np_cl if c >= 0)
            if new_pages:          # invalidation-only flushes move no bytes
                self.stats.add(new_pages, self.page_nbytes)
        return loaded, rejected

    # -- evict -------------------------------------------------------------
    def evict_clusters(self, clusters: Sequence[int], *,
                       force: bool = False) -> int:
        """Host-side free + queued device invalidation. Returns pages freed.

        A cluster pinned by an in-flight wave is skipped unless ``force``
        (its pages belong to that wave until its completion event).
        """
        freed = 0
        for c in clusters:
            c = int(c)
            lease = self._leases.get(c)
            if lease is None:
                continue
            if lease.refcount > 1 and not force:
                continue
            slots = self.resident.pop(c)
            del self._leases[c]
            self.slot_cluster[slots] = -1
            self._pending_invalid.update(slots)
            while lease.lease_id in self.pool.leases:
                self.pool.release(lease)   # force: strip remaining pins too
            freed += len(slots)
        return freed

    def flush_invalidations(self) -> None:
        """Force queued invalidations to the device (normally folded into
        the next load; needed before a search with no intervening load).
        Moves zero new pages, so it never counts as a transfer round."""
        if self._pending_invalid:
            self.load_clusters([])

    # -- views for the search kernel ----------------------------------------
    def device_view(self):
        return self.pool.device_view()

    def allowed_lut(self, clusters: Sequence[int]) -> jax.Array:
        """Boolean LUT [Nc] marking clusters searchable on-device."""
        lut = np.zeros(self.paged.num_clusters + 1, bool)   # +1: cluster -1 pad
        res = [c for c in clusters if c in self.resident]
        lut[res] = True
        return jnp.asarray(lut)
