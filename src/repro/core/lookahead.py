"""Lookahead retrieval planning (paper §4.1, §4.2, §4.3).

Given the clusters ranked by q_in (the *pre*-rewrite query), choose which
whole clusters to prefetch under a byte budget:

  * whole-cluster granularity with the skip-if-over-budget rule (§4.3):
    "the system fills this budget by adding whole clusters sequentially
    based on query proximity; if the next closest cluster exceeds the
    remaining budget, it is skipped entirely";
  * already-resident clusters (cache hits / earlier rounds) cost nothing
    (§4.3 multi-round incremental prefetch);
  * batched mode splits the total budget equally among the queries of a
    micro-batch (§4.2) — clusters shared between queries are charged once,
    which is exactly what the prefetching scheduler maximizes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.datastore import PagedClusters


@dataclass
class PrefetchPlan:
    fetch: List[int]                 # clusters to transfer now (rank order)
    resident_hits: List[int]         # ranked clusters already on device
    skipped: List[int]               # skipped whole clusters (budget rule)
    bytes_planned: int = 0
    pages_planned: int = 0
    ranked: Optional[Sequence] = None  # the ranking it was planned from, so
                                       # a capped replan can skip the probe

    @property
    def covered(self) -> Set[int]:
        return set(self.fetch) | set(self.resident_hits)


def plan_prefetch(ranked: Sequence[int], paged: PagedClusters, *,
                  budget_bytes: int, resident: Set[int],
                  free_pages: int) -> PrefetchPlan:
    """Single-query lookahead plan over clusters ranked by q_in proximity."""
    plan = PrefetchPlan([], [], [])
    remaining = budget_bytes
    pages_left = free_pages
    for c in ranked:
        c = int(c)
        if c in resident:
            plan.resident_hits.append(c)
            continue
        nb = paged.cluster_bytes(c)
        npg = int(paged.cluster_num_pages[c])
        if nb <= remaining and npg <= pages_left:
            plan.fetch.append(c)
            remaining -= nb
            pages_left -= npg
            plan.bytes_planned += nb
            plan.pages_planned += npg
        else:
            plan.skipped.append(c)
    return plan


def plan_batched_prefetch(ranked_per_query: Sequence[Sequence[int]],
                          paged: PagedClusters, *,
                          budget_bytes: int, resident: Set[int],
                          free_pages: int) -> Tuple[PrefetchPlan, np.ndarray]:
    """Micro-batch plan: equal per-query budget split (§4.2).

    Walks queries round-robin in rank order. A cluster chosen by an earlier
    query (or resident) is free for later ones — shared interest costs one
    transfer. Returns (plan, per_query_covered_count).
    """
    B = len(ranked_per_query)
    per_budget = np.full(B, budget_bytes / max(B, 1))
    plan = PrefetchPlan([], [], [])
    chosen: Set[int] = set()
    skipped_seen: Set[int] = set()
    pages_left = free_pages
    covered_count = np.zeros(B, np.int64)
    iters = [list(map(int, r)) for r in ranked_per_query]
    maxlen = max((len(r) for r in iters), default=0)
    for rank in range(maxlen):
        for qi in range(B):
            if rank >= len(iters[qi]):
                continue
            c = iters[qi][rank]
            if c in resident:
                if c not in plan.resident_hits:
                    plan.resident_hits.append(c)
                covered_count[qi] += 1
                continue
            if c in chosen:
                covered_count[qi] += 1
                continue
            nb = paged.cluster_bytes(c)
            npg = int(paged.cluster_num_pages[c])
            if nb <= per_budget[qi] and npg <= pages_left:
                plan.fetch.append(c)
                chosen.add(c)
                if c in skipped_seen:     # another query could afford it
                    skipped_seen.discard(c)
                    plan.skipped.remove(c)
                per_budget[qi] -= nb
                pages_left -= npg
                plan.bytes_planned += nb
                plan.pages_planned += npg
                covered_count[qi] += 1
            elif c not in skipped_seen:
                # report each skipped cluster once, not once per query
                skipped_seen.add(c)
                plan.skipped.append(c)
    return plan, covered_count


@dataclass
class RoundState:
    """Multi-round bookkeeping (§4.3): full prefetch in round one, then
    incremental top-ups of only the missing clusters."""

    fetched: Set[int] = field(default_factory=set)
    round: int = 0

    def incremental_plan(self, ranked: Sequence[int], paged: PagedClusters, *,
                         budget_bytes: int, resident: Set[int],
                         free_pages: int) -> PrefetchPlan:
        eff_resident = resident | self.fetched
        plan = plan_prefetch(ranked, paged, budget_bytes=budget_bytes,
                             resident=eff_resident, free_pages=free_pages)
        self.fetched |= set(plan.fetch)
        self.round += 1
        return plan
