"""Async H2D transfer engine: timestamped in-flight copy events (§4.1).

The paper's central mechanism is that lookahead prefetch *overlaps* the
CPU→GPU cluster copy with the LLM's pre-retrieval generation window.  The
legacy model expressed that overlap as a post-hoc ``max(t_llm,
t_prefetch)``; here each copy is a first-class ``TransferEvent`` with a
``[start_t, end_t)`` occupancy window on a double-buffered link, so
overlap (and queueing, when transfers contend) emerges from event
ordering in the ``RetrievalRuntime`` event loop instead of a closed-form
composition.

``PrefetchBuffer`` is the backing store: ``submit()`` dispatches the real
(asynchronous) device scatter through the buffer immediately — dispatch
returns before the copy completes, which is what lets subsequent decode
steps overlap it — and returns the modeled occupancy window for the
event clock.

Link model: ``channels`` independent DMA channels (2 = double buffering,
matching the paper's pinned staging buffers).  A transfer starts on the
earliest-free channel at ``max(submit_t, channel_free_at)`` and holds it
for ``nbytes / link_bw`` seconds.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.prefetch_buffer import PrefetchBuffer
from repro.memory.pool import Reservation
from repro.obs.recorder import FlightRecorder, TransferRecord


@dataclass(frozen=True)
class TransferEvent:
    """One in-flight (or completed) H2D copy on the modeled clock."""

    transfer_id: int
    clusters: Tuple[int, ...]
    nbytes: int
    channel: int
    submit_t: float
    start_t: float
    end_t: float
    kind: str = "prefetch"            # "prefetch" | "demand"

    @property
    def duration(self) -> float:
        return self.end_t - self.start_t

    @property
    def queued_s(self) -> float:
        """Time the copy waited for a free channel."""
        return self.start_t - self.submit_t

    def done_by(self, t: float) -> bool:
        return self.end_t <= t

    def overlaps(self, lo: float, hi: float) -> bool:
        """True iff the copy's link occupancy intersects window [lo, hi)."""
        return self.start_t < hi and lo < self.end_t


class TransferEngine:
    """Owns the modeled host→device link and dispatches real buffer loads."""

    def __init__(self, buffer: PrefetchBuffer, link_bw: float, *,
                 channels: int = 2):
        assert channels >= 1
        self.buffer = buffer
        self.link_bw = float(link_bw)
        self.channel_free = [0.0] * channels
        self.events: List[TransferEvent] = []
        self._next_id = 0
        # flight-recorder lane (attached by the owning engine/server)
        self.recorder: Optional[FlightRecorder] = None
        self.replica_id = -1

    # -- submission ---------------------------------------------------------
    def submit(self, clusters: Sequence[int], *, now: float = 0.0,
               nbytes: Optional[int] = None, link_bw: Optional[float] = None,
               kind: str = "prefetch",
               make_room: Optional[Callable[[int], object]] = None,
               reservation: Optional[Reservation] = None,
               ) -> TransferEvent:
        """Dispatch an async copy of whole clusters; return its event.

        The device scatter is issued immediately through the backing
        ``PrefetchBuffer`` (async dispatch).  ``reservation`` is the
        admission headroom this copy consumes its page slots from.
        ``make_room``, when given, is called with a page count if the
        buffer rejects clusters for lack of free slots, then the rejects
        are re-issued — mirroring the legacy engine's eviction-retry
        path.  ``nbytes`` overrides the byte count used for the
        occupancy window (defaults to the pages actually moved);
        ``link_bw`` overrides the link for this copy (used by the
        runtime-fetch baseline's modeled demand fetch).
        """
        clusters = [int(c) for c in clusters]
        loaded, rejected = self.buffer.load_clusters(clusters,
                                                     reservation=reservation)
        if rejected and make_room is not None:
            make_room(sum(int(self.buffer.paged.cluster_num_pages[c])
                          for c in rejected))
            _, rejected = self.buffer.load_clusters(rejected,
                                                    reservation=reservation)
        if rejected:
            # never leak planned clusters silently: shrink the copy (and
            # its modeled byte count — the link must not be occupied for
            # pages that never moved) to what actually landed
            dropped = set(rejected)
            clusters = [c for c in clusters if c not in dropped]
            if nbytes is not None:
                nbytes = max(0, nbytes - sum(
                    self.buffer.paged.cluster_bytes(c) for c in dropped))
        if nbytes is None:
            nbytes = sum(self.buffer.paged.cluster_bytes(c) for c in clusters)
        bw = self.link_bw if link_bw is None else float(link_bw)
        dur = nbytes / bw if nbytes else 0.0
        ch = min(range(len(self.channel_free)),
                 key=lambda i: self.channel_free[i])
        start = max(float(now), self.channel_free[ch])
        ev = TransferEvent(transfer_id=self._next_id,
                           clusters=tuple(clusters), nbytes=int(nbytes),
                           channel=ch, submit_t=float(now), start_t=start,
                           end_t=start + dur, kind=kind)
        self._next_id += 1
        self.channel_free[ch] = ev.end_t
        self.events.append(ev)
        if self.recorder is not None:
            # issue at submit, land at the modeled completion (emitted
            # now, stamped with its future clock time)
            for when, k in ((ev.submit_t, "transfer.issue"),
                            (ev.end_t, "transfer.land")):
                self.recorder.emit(TransferRecord(
                    t=when, kind=k, replica=self.replica_id,
                    transfer_id=ev.transfer_id, nbytes=ev.nbytes,
                    n_clusters=len(ev.clusters), channel=ev.channel,
                    start_t=ev.start_t, end_t=ev.end_t,
                    transfer_kind=ev.kind))
        return ev

    # -- queries ------------------------------------------------------------
    def in_flight(self, t: float) -> List[TransferEvent]:
        return [e for e in self.events if e.start_t <= t < e.end_t]

    def drained_at(self) -> float:
        """Clock time at which every submitted copy has completed."""
        return max(self.channel_free)

    def ready_t(self, event: TransferEvent, dispatch_t: float) -> float:
        """When ``event``'s data is usable by a consumer that dispatched
        its own view of the copy at ``dispatch_t``.

        Per-request link view (App. C): a micro-batch shares one physical
        copy, but each request models the transfer window from its own
        round boundary — ``dispatch_t + duration`` — because its lookahead
        dispatch is what it overlaps against.  Real queueing delay
        (``event.end_t``) still lower-bounds readiness so contended links
        are never under-modeled.
        """
        return max(event.end_t, dispatch_t + event.duration)

    @property
    def total_bytes(self) -> int:
        return sum(e.nbytes for e in self.events)
