"""TeleRAG core: lookahead retrieval and its supporting machinery."""

from repro.core.budget import (HardwareProfile, TPU_V5E, RTX4090, H100,
                               host_cluster_search_seconds,
                               case1_budget, case2_budget, optimal_budget,
                               decode_step_seconds, generation_window_seconds,
                               empirical_miss_curve)
from repro.core.cache import CacheConfig, ClusterCache
from repro.core.datastore import (Datastore, PagedClusters,
                                  build_paged_clusters, synthetic_datastore)
from repro.core.embedder import HashEmbedder, synthetic_rewrite
from repro.core.hybrid_search import (RetrievalResult, host_search,
                                      hybrid_retrieve, merge_topk,
                                      sharded_device_search)
from repro.core.ivf import IVFIndex, build_ivf, kmeans, probe, probe_device
from repro.core.lookahead import (PrefetchPlan, RoundState,
                                  plan_batched_prefetch, plan_prefetch)
from repro.core.overlap import (PIPELINE_SIGMA, coverage, overlap_table,
                                pipeline_pairs)
from repro.core.prefetch_buffer import PrefetchBuffer, TransferStats
from repro.core.schedulers import (Assignment, ReplicaHealth,
                                   RoundRobinScheduler, SchedulerPolicy,
                                   TeleRAGScheduler, assign_to_replicas,
                                   group_queries,
                                   grouping_shared_cluster_gain)
from repro.core.transfer import TransferEngine, TransferEvent

__all__ = [
    "HardwareProfile", "TPU_V5E", "RTX4090", "H100",
    "case1_budget", "case2_budget", "optimal_budget", "decode_step_seconds",
    "host_cluster_search_seconds",
    "generation_window_seconds", "empirical_miss_curve",
    "CacheConfig", "ClusterCache",
    "Datastore", "PagedClusters", "build_paged_clusters", "synthetic_datastore",
    "HashEmbedder", "synthetic_rewrite",
    "RetrievalResult", "host_search", "hybrid_retrieve", "merge_topk",
    "sharded_device_search",
    "IVFIndex", "build_ivf", "kmeans", "probe", "probe_device",
    "PrefetchPlan", "RoundState", "plan_batched_prefetch", "plan_prefetch",
    "PIPELINE_SIGMA", "coverage", "overlap_table", "pipeline_pairs",
    "PrefetchBuffer", "TransferStats",
    "Assignment", "ReplicaHealth", "RoundRobinScheduler", "SchedulerPolicy",
    "TeleRAGScheduler", "assign_to_replicas", "group_queries",
    "grouping_shared_cluster_gain",
    "TransferEngine", "TransferEvent",
]
