"""TeleRAG's two schedulers (paper §4.2, Fig. 7), plus the SLO layer.

Prefetching scheduler: greedily groups semantically similar queries into
micro-batches (lowest pairwise L2 distance) so grouped queries share
prefetched clusters under the split budget. O(B²) distances via one
matmul + host greedy sweep — the paper measures <0.1 s at B=256; ours is
well under that on one core.

Cache-aware scheduler: assigns micro-batches to replicas ("GPUs") by
greatest overlap between the batch's predicted clusters and each
replica's resident cache, highest-overlap-first, with a load cap so
work stays balanced (and a deadline hook for straggler re-queue).
Routing additionally reads per-replica ledger occupancy and — for
multi-tenant serving — per-tenant pool occupancy, spreading a tenant's
batches away from replicas it already loads.

Wave former: under per-request continuous batching there is no static
micro-batch — at every round frontier ``SchedulerPolicy.reform_wave``
re-batches whichever requests are *ready now* into fresh tenant-pure
waves (default: EDF within priority classes, FIFO among equals,
``micro_batch``-capped), so a straggler never drags its former
batch-mates and mid-stream admits join in-flight work.

Dispatch policy: once micro-batches are queued on a replica, a
``DispatchPolicy`` orders them.  ``EdfDispatch`` (the default) runs
priority classes first and earliest-deadline-first inside a class; with
no deadlines set it degrades exactly to the legacy (priority, FIFO)
tie-break, which is what keeps the deprecated shims pinned equivalent.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Prefetching scheduler
# ---------------------------------------------------------------------------


def group_queries(embeddings: np.ndarray, micro_batch: int,
                  ) -> List[List[int]]:
    """Greedy similarity grouping. embeddings [B, d] -> list of index groups."""
    B = embeddings.shape[0]
    if B == 0:
        return []
    # pairwise squared L2 via gram matrix (one matmul)
    sq = np.sum(embeddings ** 2, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (embeddings @ embeddings.T)
    np.fill_diagonal(d2, np.inf)
    unassigned = set(range(B))
    groups: List[List[int]] = []
    while unassigned:
        seed = min(unassigned)                      # deterministic
        group = [seed]
        unassigned.remove(seed)
        while len(group) < micro_batch and unassigned:
            # nearest unassigned query to the group (min over members)
            rows = d2[np.asarray(group)][:, np.asarray(sorted(unassigned))]
            cand_sorted = np.asarray(sorted(unassigned))
            nxt = int(cand_sorted[np.argmin(np.min(rows, axis=0))])
            group.append(nxt)
            unassigned.remove(nxt)
        groups.append(group)
    return groups


def grouping_shared_cluster_gain(ranked_per_query: Sequence[Sequence[int]],
                                 groups: Sequence[Sequence[int]],
                                 top: int = 64) -> float:
    """Diagnostic: average fraction of top clusters shared within groups."""
    fracs = []
    for g in groups:
        if len(g) < 2:
            continue
        sets = [set(list(ranked_per_query[i])[:top]) for i in g]
        union = set().union(*sets)
        total = sum(len(s) for s in sets)
        fracs.append(1.0 - len(union) / max(total, 1))
    return float(np.mean(fracs)) if fracs else 0.0


# ---------------------------------------------------------------------------
# Cache-aware scheduler
# ---------------------------------------------------------------------------


@dataclass
class Assignment:
    replica: int
    batch_index: int
    overlap: int


def assign_to_replicas(batch_clusters: Sequence[Set[int]],
                       replica_caches: Sequence[Set[int]], *,
                       max_per_replica: Optional[int] = None,
                       occupancy: Optional[Sequence[float]] = None,
                       tenant_occupancy: Optional[Sequence[Sequence[float]]]
                       = None) -> List[Assignment]:
    """Greedy max-overlap assignment (paper: pick the (batch, GPU) pair with
    the greatest cached-cluster overlap, repeat in descending order).

    ``occupancy`` (per-replica HBM occupancy fractions from the memory
    ledger, in [0, 1]) breaks overlap ties toward the replica with the
    most free device memory; it is scaled well below one overlap unit so
    it can never override a real cached-cluster advantage.

    ``tenant_occupancy`` ([n_batches][n_replicas] fractions in [0, 1]:
    how much of replica r's pool batch i's *tenant* already holds)
    nudges routing away from replicas the tenant is piling onto.  Both
    soft terms combine linearly: neither can override a real
    cached-cluster advantage, and a tenant-spread difference outweighs
    a ledger-occupancy difference only when the latter is under ~0.2
    (the 2e-4 / 1e-3 weight ratio) — spreading a tenant off an
    otherwise-balanced replica is intended; overriding a clearly
    memory-loaded one is not.

    The greedy sweep masks incrementally — one O(n_b·n_r) score matrix
    for the whole assignment instead of a fresh deep copy + full re-mask
    per pick (the old loop was O(n_b²·n_r) in copies alone).
    """
    n_b, n_r = len(batch_clusters), len(replica_caches)
    if n_r == 0:
        return []
    cap = max_per_replica or -(-n_b // n_r)
    overlap = np.zeros((n_b, n_r), np.int64)
    for i, bc in enumerate(batch_clusters):
        for r, rc in enumerate(replica_caches):
            overlap[i, r] = len(bc & rc)
    occ = (np.zeros(n_r) if occupancy is None
           else np.clip(np.asarray(occupancy, np.float64), 0.0, 1.0))
    tocc = (np.zeros((n_b, n_r)) if tenant_occupancy is None
            else np.clip(np.asarray(tenant_occupancy, np.float64), 0.0, 1.0))
    load = np.zeros(n_r, np.int64)
    taken = np.zeros(n_b, bool)
    out: List[Assignment] = []
    masked = (overlap.astype(np.float64) - 1e-3 * occ[None, :]
              - 2e-4 * tocc)
    for _ in range(n_b):
        i, r = np.unravel_index(np.argmax(masked), masked.shape)
        if np.isneginf(masked[i, r]):    # everything capped — spill
            i = int(np.argmin(taken))    # first untaken, round-robin
            r = int(np.argmin(load))
        out.append(Assignment(replica=int(r), batch_index=int(i),
                              overlap=int(overlap[i, r])))
        taken[int(i)] = True
        load[int(r)] += 1
        masked[int(i), :] = -np.inf
        if load[int(r)] >= cap:
            masked[:, int(r)] = -np.inf
    out.sort(key=lambda a: a.batch_index)
    return out


# ---------------------------------------------------------------------------
# Scheduler policy: one pluggable interface over both schedulers
# ---------------------------------------------------------------------------


class SchedulerPolicy:
    """Unifies micro-batch formation (prefetching scheduler) and replica
    routing (cache-aware scheduler) behind one strategy interface, so the
    orchestrator and the RetrievalRuntime consume a single object instead
    of two free functions plus flags.

    ``needs_cluster_hints`` tells the caller whether ``assign`` wants the
    per-batch predicted cluster sets (probing them costs a ranker pass —
    skip it for routing policies that ignore cache state).
    """

    name: str = "base"
    needs_cluster_hints: bool = False

    def group(self, q_in: np.ndarray, micro_batch: int) -> List[List[int]]:
        """Partition queries (rows of ``q_in``) into micro-batches of at
        most ``micro_batch``; returns lists of row indices."""
        raise NotImplementedError

    def assign(self, batch_clusters: Sequence[Set[int]],
               replica_caches: Sequence[Set[int]], *,
               max_per_replica: Optional[int] = None,
               occupancy: Optional[Sequence[float]] = None,
               tenant_occupancy: Optional[Sequence[Sequence[float]]] = None,
               ) -> List[Assignment]:
        """Route each micro-batch (predicted cluster set) to a replica,
        reading live replica caches, ledger occupancy fractions, and —
        for multi-tenant pools — per-tenant occupancy fractions."""
        raise NotImplementedError

    def reform_wave(self, ready: Sequence, *,
                    micro_batch: Optional[int] = None,
                    now: float = 0.0) -> List[List[int]]:
        """Re-batch the *ready set* at a continuous-batching round
        frontier: partition the requests that can start a round right
        now into execution waves, returned as lists of indices into
        ``ready`` (first wave dispatches first).

        ``ready`` items expose ``tenant`` / ``priority`` /
        ``deadline_t`` (absolute event-clock seconds, ``inf`` = no
        SLO); their order is arrival order, the FIFO anchor.  The
        default is EDF/tenant-aware: order by (priority class, absolute
        deadline, arrival), then greedily fill **tenant-pure** waves of
        at most ``micro_batch`` members (``None`` = unbounded).  Every
        ready request is placed; a policy override may instead *defer*
        requests (leave them out of every wave) to wait for batch-mates
        — the runtime keeps them ready for the next frontier, and if
        the event queue would otherwise drain it forces them through
        with this base implementation (which defers nothing)."""
        if not len(ready):
            return []
        cap = micro_batch or len(ready)
        order = sorted(range(len(ready)),
                       key=lambda i: (ready[i].priority,
                                      ready[i].deadline_t, i))
        waves: List[List[int]] = []
        open_by_tenant: Dict[str, List[int]] = {}
        for i in order:
            tenant = ready[i].tenant
            wave = open_by_tenant.get(tenant)
            if wave is None or len(wave) >= cap:
                wave = []
                waves.append(wave)
                open_by_tenant[tenant] = wave
            wave.append(i)
        return waves


def _fifo_groups(n: int, micro_batch: int) -> List[List[int]]:
    return [list(range(i, min(i + micro_batch, n)))
            for i in range(0, n, micro_batch)]


@dataclass
class TeleRAGScheduler(SchedulerPolicy):
    """The paper's pair (Fig. 7): similarity grouping + cache-aware
    routing.  Either half degrades to the naive behaviour via its flag,
    covering all four ablation cells of §5.4 with one class."""

    similarity_grouping: bool = True
    cache_aware: bool = True
    name = "telerag"

    @property
    def needs_cluster_hints(self) -> bool:          # type: ignore[override]
        return self.cache_aware

    def group(self, q_in: np.ndarray, micro_batch: int) -> List[List[int]]:
        """Similarity grouping (or FIFO when the flag is off)."""
        if self.similarity_grouping:
            return group_queries(q_in, micro_batch)
        return _fifo_groups(q_in.shape[0], micro_batch)

    def assign(self, batch_clusters, replica_caches, *,
               max_per_replica=None, occupancy=None,
               tenant_occupancy=None) -> List[Assignment]:
        """Cache-aware greedy routing (or round-robin when the flag is
        off); see ``assign_to_replicas`` for the tie-break ordering."""
        if self.cache_aware:
            return assign_to_replicas(batch_clusters, replica_caches,
                                      max_per_replica=max_per_replica,
                                      occupancy=occupancy,
                                      tenant_occupancy=tenant_occupancy)
        n_r = len(replica_caches)
        return [Assignment(replica=i % n_r, batch_index=i, overlap=0)
                for i in range(len(batch_clusters))]


class RoundRobinScheduler(TeleRAGScheduler):
    """FIFO micro-batches, round-robin routing (the no-scheduler baseline)."""

    name = "round_robin"

    def __init__(self):
        super().__init__(similarity_grouping=False, cache_aware=False)


# ---------------------------------------------------------------------------
# Dispatch policy: ordering queued micro-batches within a replica
# ---------------------------------------------------------------------------


class DispatchPolicy:
    """Orders a replica's *queued* micro-batches: when the replica
    runtime drains, the server dispatches the batch with the smallest
    ``key``.  Keys are compared lexicographically; ``deadline_t`` is an
    absolute event-clock deadline in seconds (``inf`` = no SLO) and
    ``order`` is the batch's global enqueue sequence (the FIFO anchor
    that makes every policy total and deterministic)."""

    name: str = "base"

    def key(self, *, priority: int, deadline_t: float, order: int,
            now: float) -> Tuple:
        """Sort key for one queued batch at clock time ``now``
        (seconds); the smallest key dispatches first."""
        raise NotImplementedError


class FifoDispatch(DispatchPolicy):
    """Strict arrival order — ignores priorities and deadlines (the
    SLO-blind baseline ``bench_tenants.py`` compares against)."""

    name = "fifo"

    def key(self, *, priority: int, deadline_t: float, order: int,
            now: float) -> Tuple:
        """(order,): pure FIFO."""
        return (order,)


class EdfDispatch(DispatchPolicy):
    """Priority classes first, earliest-deadline-first within a class,
    FIFO among equals.  With no deadlines set (every ``deadline_t`` is
    ``inf``) this is exactly the legacy (priority, order) tie-break, so
    single-tenant callers see unchanged dispatch order."""

    name = "edf"

    def key(self, *, priority: int, deadline_t: float, order: int,
            now: float) -> Tuple:
        """(priority class, absolute deadline, enqueue order)."""
        return (priority, deadline_t, order)


# ---------------------------------------------------------------------------
# Straggler mitigation / elastic hooks (used by the engine + tests)
# ---------------------------------------------------------------------------


@dataclass
class ReplicaHealth:
    deadline_s: float = 5.0
    last_seen: Dict[int, float] = field(default_factory=dict)

    def heartbeat(self, replica: int, now: float) -> None:
        self.last_seen[replica] = now

    def healthy(self, replicas: Sequence[int], now: float) -> List[int]:
        return [r for r in replicas
                if now - self.last_seen.get(r, now) < self.deadline_s]

    def requeue_straggler_batches(self, assignments: List[Assignment],
                                  dead: Set[int]) -> Tuple[List[Assignment],
                                                           List[int]]:
        """Drop assignments on dead replicas; return surviving + re-queue."""
        alive = [a for a in assignments if a.replica not in dead]
        requeue = [a.batch_index for a in assignments if a.replica in dead]
        return alive, requeue
