"""Hybrid device/host IVF search + on-device merge (paper §4.1 steps 2–4).

* Device side: the prefetched slab is searched with the fused
  ``ivf_topk`` kernel, restricted to the probed clusters that are
  resident (mask LUT — no data movement).
* Host side: missed clusters are searched in numpy (the paper's
  multithreaded CPU path; one core here, wall-time is modeled upstream).
* Merge: only the host candidates' *scalar* scores+ids cross the link
  ("GPU sorting", §4.3 — transferring distances, not vectors), then one
  fused top-k on device.

Also provides the beyond-paper ``sharded_device_search``: the slab is
sharded over the ``model`` mesh axis, each shard computes a local top-k,
and candidates are all-gathered and merged — the distributed-datastore
mode sketched in paper §7.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.datastore import PagedClusters
from repro.core.prefetch_buffer import PrefetchBuffer
from repro.kernels import ops


# ---------------------------------------------------------------------------
# Host search (numpy — runs on the host CPU by construction)
# ---------------------------------------------------------------------------


def host_search(paged: PagedClusters, clusters: Sequence[int],
                query: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Search the given clusters on the host. Returns (scores, ids) desc."""
    scores: List[np.ndarray] = []
    ids: List[np.ndarray] = []
    for c in clusters:
        pages = paged.cluster_pages(int(c))          # [np, ps, d]
        pid = paged.cluster_page_ids(int(c))
        flat = pages.reshape(-1, paged.dim)
        fid = pid.reshape(-1)
        valid = fid >= 0
        s = flat @ query
        s[~valid] = -np.inf
        scores.append(s)
        ids.append(fid)
    if not scores:
        return (np.full(k, -np.inf, np.float32), np.full(k, -1, np.int32))
    s = np.concatenate(scores)
    i = np.concatenate(ids)
    if len(s) > k:
        part = np.argpartition(-s, k - 1)[:k]
    else:
        part = np.arange(len(s))
    order = part[np.argsort(-s[part])]
    out_s = np.full(k, -np.inf, np.float32)
    out_i = np.full(k, -1, np.int32)
    out_s[:len(order)] = s[order]
    out_i[:len(order)] = i[order]
    return out_s, out_i


# ---------------------------------------------------------------------------
# On-device merge ("GPU sorting")
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("k",))
def merge_topk(dev_s: jax.Array, dev_i: jax.Array,
               host_s: jax.Array, host_i: jax.Array, k: int,
               ) -> Tuple[jax.Array, jax.Array]:
    """Concat candidate lists and take global top-k per query (on device)."""
    s = jnp.concatenate([dev_s, host_s], axis=-1)
    i = jnp.concatenate([dev_i, host_i], axis=-1)
    top_s, idx = jax.lax.top_k(s, k)
    return top_s, jnp.take_along_axis(i, idx, axis=-1)


# ---------------------------------------------------------------------------
# Hybrid retrieval
# ---------------------------------------------------------------------------


@dataclass
class RetrievalResult:
    doc_ids: np.ndarray              # [B, k]
    scores: np.ndarray               # [B, k]
    hit_clusters: List[List[int]]    # per query: probed ∩ resident
    missed_clusters: List[List[int]] # per query: searched on host
    nprobe: int = 0

    @property
    def hit_rate(self) -> float:
        h = sum(len(x) for x in self.hit_clusters)
        m = sum(len(x) for x in self.missed_clusters)
        return h / max(h + m, 1)


def hybrid_retrieve(buffer: PrefetchBuffer, queries: np.ndarray,
                    probed_clusters: np.ndarray, *, k: int,
                    kernel_mode: str = "auto", fused: bool = False,
                    centroids: Optional[np.ndarray] = None,
                    ) -> RetrievalResult:
    """queries [B, d]; probed_clusters [B, nprobe] (ranked by q_out).

    Device searches every probed cluster that is resident; the host
    searches the rest; results merge on device.

    ``fused=True`` (requires ``centroids``) runs the device partition as
    ONE ``probe_and_topk`` launch over the pool's resident pages: the
    centroid probe, top-nprobe cluster admission and masked document
    top-k all happen in-kernel via the device page table
    (``page_cluster``), eliminating the host-built [B, Nc] LUT, the
    [B, num_pages] mask upload, and — in kernel mode — the [B, Nc]
    score-matrix round trip.  The admitted cluster set equals
    ``probed_clusters`` (same centroid scores, tie-free), so the host
    miss partition and telemetry are unchanged.
    """
    B, nprobe = probed_clusters.shape
    buffer.flush_invalidations()
    resident = buffer.resident_clusters()
    hit: List[List[int]] = []
    miss: List[List[int]] = []
    for b in range(B):
        cs = [int(c) for c in probed_clusters[b]]
        hit.append([c for c in cs if c in resident])
        miss.append([c for c in cs if c not in resident])

    qd = jnp.asarray(queries, jnp.float32)
    if fused and centroids is not None:
        # one-launch device partition: probe + admission + top-k read the
        # pool pages in place through the device page table — a page is
        # searchable iff its cluster's centroid score reaches the
        # nprobe-th largest, which is exactly the probed set
        pages, page_ids, page_cluster = buffer.device_view()
        dev_s, dev_i = ops.probe_and_topk(
            qd, jnp.asarray(centroids, jnp.float32), pages, page_ids,
            page_cluster, nprobe=nprobe, k=k, mode=kernel_mode)
    else:
        # legacy two-launch partition — fused masked search over the slab
        # with *per-query* page masks built on host (exact per-query IVF
        # nprobe semantics; mask is page-level so the traffic is
        # num_pages bytes per query, tiny)
        Nc = buffer.paged.num_clusters
        luts = np.zeros((B, Nc), bool)
        for b in range(B):
            luts[b, hit[b]] = True
        pages, page_ids, _ = buffer.device_view()
        pc = buffer.slot_cluster                # host page-table mirror
        page_mask = np.zeros((B, buffer.num_pages), bool)
        valid_slots = pc >= 0
        page_mask[:, valid_slots] = luts[:, pc[valid_slots]]
        dev_s, dev_i = ops.ivf_topk(pages, page_ids, jnp.asarray(page_mask),
                                    qd, k, mode=kernel_mode)

    # host partition (scalar scores/ids only cross the link)
    host_results = [host_search(buffer.paged, miss[b], queries[b], k)
                    for b in range(B)]
    host_s = np.stack([r[0] for r in host_results])
    host_i = np.stack([r[1] for r in host_results])
    fs, fi = merge_topk(dev_s, dev_i, jnp.asarray(host_s), jnp.asarray(host_i),
                        k)
    return RetrievalResult(doc_ids=np.asarray(fi), scores=np.asarray(fs),
                           hit_clusters=hit, missed_clusters=miss,
                           nprobe=nprobe)


# ---------------------------------------------------------------------------
# Beyond-paper: datastore-sharded distributed search (paper §7)
# ---------------------------------------------------------------------------


def sharded_device_search(mesh, queries: jax.Array, pages: jax.Array,
                          page_ids: jax.Array, page_mask: jax.Array, *,
                          k: int, axis: str = "model",
                          ) -> Tuple[jax.Array, jax.Array]:
    """Slab sharded over ``axis`` pages-dim; local top-k then all-gather+merge.

    Collective cost: 2 * B * k * (4+4) bytes * axis_size — candidates only,
    never raw vectors; this is what makes datastore sharding viable at
    nprobe-scale slabs (roofline §Perf discusses the trade).
    """
    from jax.sharding import PartitionSpec as P

    def local(q, pg, pid, msk):
        s, i = ops.ivf_topk(pg, pid, msk, q, k, mode="ref")
        s_all = jax.lax.all_gather(s, axis, axis=1, tiled=True)   # [B, n*k]
        i_all = jax.lax.all_gather(i, axis, axis=1, tiled=True)
        top_s, idx = jax.lax.top_k(s_all, k)
        return top_s, jnp.take_along_axis(i_all, idx, axis=-1)

    from repro.compat import shard_map
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(axis), P(axis), P(axis)),
        out_specs=(P(), P()), check_vma=False)
    return fn(queries, pages, page_ids, page_mask)
