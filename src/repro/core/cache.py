"""Decay-based cluster hotness cache (paper Appendix D, Eq. 6).

Every resident cluster carries a hotness value:
    h_{r+1} = h_r / d            if unused in round r
    h_{r+1} = h_r / d + h_inc    if used in round r
New fetches start at h_init. After each served batch the cache is
consolidated: only the hottest clusters are retained, up to
``fraction * buffer_pages`` (paper default fraction = 0.5); everything
else is evicted so the next round's prefetch has deterministic headroom —
this mirrors the paper's "evict excessive clusters and consolidate after
serving each batch" reproducibility rule.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.prefetch_buffer import PrefetchBuffer


@dataclass
class CacheConfig:
    fraction: float = 0.5
    h_init: float = 1.0
    h_inc: float = 1.0
    decay: float = 2.0


class ClusterCache:
    def __init__(self, cfg: CacheConfig = CacheConfig()):
        self.cfg = cfg
        self.hotness: Dict[int, float] = {}
        self.hits = 0
        self.misses = 0

    # -- round lifecycle -----------------------------------------------------
    def on_fetched(self, clusters: Iterable[int]) -> None:
        for c in clusters:
            self.hotness.setdefault(int(c), self.cfg.h_init)

    def round_update(self, used_clusters: Iterable[int]) -> None:
        """Apply Eq. 6 across all tracked clusters."""
        used = set(int(c) for c in used_clusters)
        for c in list(self.hotness):
            h = self.hotness[c] / self.cfg.decay
            if c in used:
                h += self.cfg.h_inc
            self.hotness[c] = h

    def record_lookup(self, needed: Sequence[int], resident: Set[int]) -> None:
        for c in needed:
            if int(c) in resident:
                self.hits += 1
            else:
                self.misses += 1

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    # -- consolidation ---------------------------------------------------------
    def quota_pages(self, buffer: PrefetchBuffer) -> int:
        # keyed to the prefetch quota, not the pool extent: a pool also
        # hosting KV leases must not inflate cache retention
        return int(self.cfg.fraction * buffer.quota_pages)

    def consolidate(self, buffer: PrefetchBuffer) -> List[int]:
        """Keep the hottest clusters within the cache quota; evict the rest.

        Returns the evicted cluster list. Applied after each served batch.
        """
        quota = self.quota_pages(buffer)
        # rank resident clusters by hotness (desc), keep while quota lasts
        resident = [(c, self.hotness.get(c, 0.0)) for c in buffer.resident]
        resident.sort(key=lambda t: -t[1])
        keep: Set[int] = set()
        used = 0
        for c, _ in resident:
            npg = int(buffer.paged.cluster_num_pages[c])
            if used + npg <= quota:
                keep.add(c)
                used += npg
        evict = [c for c in buffer.resident if c not in keep]
        buffer.evict_clusters(evict, force=True)
        # hotness keys ⊆ resident ∪ just-fetched is an invariant (every key
        # enters via on_fetched and leaves with its eviction), so popping
        # the evicted set is the whole cleanup — no second full scan
        for c in evict:
            self.hotness.pop(c, None)
        return evict

    def make_room(self, buffer: PrefetchBuffer, pages_needed: int, *,
                  protect: Optional[Set[int]] = None) -> List[int]:
        """Evict coldest *unpinned* clusters until >= pages_needed slots
        are free (clusters pinned by an in-flight wave are untouchable —
        this is the admission controller's spill hook).  ``protect``
        additionally shields named clusters: the controller passes
        enough of each other tenant's residency to keep it at its
        guaranteed floor, so one tenant's spill can never dig another
        below its reservation."""
        if buffer.free_pages() >= pages_needed:
            return []
        pinned = buffer.pinned_clusters()
        if protect:
            pinned = pinned | {int(c) for c in protect}
        order = sorted((c for c in buffer.resident if c not in pinned),
                       key=lambda c: self.hotness.get(c, 0.0))
        evicted: List[int] = []
        for c in order:
            if buffer.free_pages() >= pages_needed:
                break
            buffer.evict_clusters([c])
            self.hotness.pop(c, None)
            evicted.append(c)
        return evicted
