"""Deterministic text featurizer — offline stand-in for Contriever.

Hash-n-gram bag-of-features projected through a fixed seeded Gaussian
matrix, L2-normalized. Deterministic across processes (no pretrained
weights ship offline), which is what the cluster-overlap and hit-rate
experiments need: *relative* geometry of (q_in, q_out) pairs, not absolute
retrieval quality. See DESIGN.md §2 "Embedding model".
"""

from __future__ import annotations

import hashlib
from typing import List, Sequence

import numpy as np

DEFAULT_DIM = 768
_N_BUCKETS = 8192


def _stable_hash(token: str) -> int:
    return int.from_bytes(hashlib.blake2s(token.encode(), digest_size=4).digest(),
                          "little")


class HashEmbedder:
    """Text -> unit vector in R^dim; deterministic given (dim, seed)."""

    def __init__(self, dim: int = DEFAULT_DIM, seed: int = 0):
        self.dim = dim
        rng = np.random.default_rng(seed)
        # projection from hashed n-gram buckets to the embedding space
        self._proj = rng.standard_normal((_N_BUCKETS, dim)).astype(np.float32)
        self._proj /= np.sqrt(dim)

    def _features(self, text: str) -> np.ndarray:
        counts = np.zeros(_N_BUCKETS, np.float32)
        words = text.lower().split()
        grams: List[str] = list(words)
        grams += [" ".join(words[i:i + 2]) for i in range(len(words) - 1)]
        for g in grams:
            counts[_stable_hash(g) % _N_BUCKETS] += 1.0
        return counts

    def encode(self, texts: Sequence[str]) -> np.ndarray:
        feats = np.stack([self._features(t) for t in texts])
        emb = feats @ self._proj
        norms = np.linalg.norm(emb, axis=-1, keepdims=True)
        return emb / np.maximum(norms, 1e-9)

    def encode_one(self, text: str) -> np.ndarray:
        return self.encode([text])[0]


def synthetic_rewrite(q: np.ndarray, sigma: float, rng: np.random.Generator,
                      ) -> np.ndarray:
    """Perturbed embedding standing in for the LLM's query transformation.

    The pre-retrieval LLM rewrites the query while preserving its core
    semantics (paper §3.3); geometrically this is a small rotation of the
    embedding. sigma is calibrated per pipeline so that the resulting IVF
    cluster overlap matches the paper's Table 1 band (see
    benchmarks/bench_overlap.py).
    """
    noise = rng.standard_normal(q.shape).astype(np.float32)
    out = q + sigma * noise
    return out / np.maximum(np.linalg.norm(out, axis=-1, keepdims=True), 1e-9)
