"""IVF index: JAX k-means build + centroid probing (paper Appendix B).

The coarse quantizer (centroid probe) is small — [Nc, d] with Nc=4096 —
and runs on-device every query (it is also what *lookahead* runs on q_in
before the rewrite exists). The fine search over cluster contents is the
hybrid device/host search in ``hybrid_search.py``.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.datastore import Datastore, PagedClusters, build_paged_clusters


@dataclass
class IVFIndex:
    centroids: np.ndarray       # [Nc, d] float32
    assignments: np.ndarray     # [N] int32
    paged: PagedClusters

    @property
    def num_clusters(self) -> int:
        return self.centroids.shape[0]

    @property
    def dim(self) -> int:
        return self.centroids.shape[1]


# ---------------------------------------------------------------------------
# k-means (jit, chunked over points so huge N never materializes [N, Nc])
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("chunk",))
def _assign(points: jax.Array, centroids: jax.Array, chunk: int = 65536):
    n = points.shape[0]
    nch = max(n // chunk, 1)
    if n % nch:
        nch = 1
    pts = points.reshape(nch, n // nch, -1)

    def body(_, p):
        sims = p @ centroids.T                    # inner product (unit vectors)
        return None, jnp.argmax(sims, axis=-1)

    _, a = jax.lax.scan(body, None, pts)
    return a.reshape(n)


@functools.partial(jax.jit, donate_argnums=(1,))
def _update(points: jax.Array, centroids: jax.Array, assign: jax.Array):
    nc = centroids.shape[0]
    one = jax.nn.one_hot(assign, nc, dtype=jnp.float32)       # [N, Nc]
    sums = one.T @ points
    counts = jnp.sum(one, axis=0)[:, None]
    new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), centroids)
    norms = jnp.linalg.norm(new, axis=-1, keepdims=True)
    return new / jnp.maximum(norms, 1e-9)


def kmeans(points: np.ndarray, num_clusters: int, *, iters: int = 10,
           seed: int = 0, sample: Optional[int] = None) -> np.ndarray:
    """Spherical k-means (inner-product metric, matching the paper's index)."""
    rng = np.random.default_rng(seed)
    train = points
    if sample is not None and sample < len(points):
        train = points[rng.choice(len(points), sample, replace=False)]
    init_idx = rng.choice(len(train), num_clusters, replace=False)
    cent = jnp.asarray(train[init_idx])
    pts = jnp.asarray(train)
    for _ in range(iters):
        a = _assign(pts, cent)
        cent = _update(pts, cent, a)
    return np.asarray(cent)


def build_ivf(store: Datastore, num_clusters: int, *, page_size: int = 512,
              kmeans_iters: int = 10, seed: int = 0,
              train_sample: Optional[int] = None) -> IVFIndex:
    cent = kmeans(store.embeddings, num_clusters, iters=kmeans_iters,
                  seed=seed, sample=train_sample)
    assign = np.asarray(_assign(jnp.asarray(store.embeddings), jnp.asarray(cent)))
    paged = build_paged_clusters(store, assign, num_clusters, page_size)
    return IVFIndex(centroids=cent, assignments=assign.astype(np.int32),
                    paged=paged)


# ---------------------------------------------------------------------------
# Probing
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("nprobe",))
def probe_device(queries: jax.Array, centroids: jax.Array, nprobe: int,
                 ) -> Tuple[jax.Array, jax.Array]:
    """Ranked top-nprobe clusters per query. queries [B, d] -> ids [B, nprobe]."""
    sims = jnp.einsum("bd,cd->bc", queries.astype(jnp.float32),
                      centroids.astype(jnp.float32))
    scores, ids = jax.lax.top_k(sims, nprobe)
    return scores, ids


def probe(queries: np.ndarray, index: IVFIndex, nprobe: int) -> np.ndarray:
    """Host convenience wrapper; returns [B, nprobe] int32 cluster ids."""
    q = np.atleast_2d(queries)
    _, ids = probe_device(jnp.asarray(q), jnp.asarray(index.centroids), nprobe)
    return np.asarray(ids, np.int32)
