"""Host-resident vector datastore with a paged IVF cluster layout.

The paper keeps the 61 GB Faiss index in CPU memory and moves whole IVF
clusters over PCIe on demand. Our TPU adaptation (DESIGN.md §2) stores
vectors host-side in *pages* of ``page_size`` vectors grouped by cluster:
a prefetch moves whole clusters (all their pages); the device buffer is a
fixed slab of page slots, so every transfer and every kernel sees static
shapes. Pages are the DMA unit; clusters remain the *policy* unit
(budgeting, caching, skip-if-over-budget — §4.3 of the paper).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class Datastore:
    """Raw corpus: embeddings (+ optional payload texts) in host memory."""

    embeddings: np.ndarray          # [N, d] float32, unit-norm rows
    texts: Optional[List[str]] = None

    @property
    def num_vectors(self) -> int:
        return self.embeddings.shape[0]

    @property
    def dim(self) -> int:
        return self.embeddings.shape[1]

    def nbytes(self) -> int:
        return self.embeddings.nbytes


def synthetic_datastore(num_vectors: int, dim: int = 768, *, seed: int = 0,
                        num_topics: int = 64) -> Datastore:
    """Clusterable synthetic corpus: topic centers + per-vector noise.

    Mirrors the geometry of real passage embeddings (locally clustered on
    the unit sphere) so IVF behaves realistically in tests/benchmarks.
    """
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((num_topics, dim)).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=-1, keepdims=True)
    topic = rng.integers(0, num_topics, size=num_vectors)
    emb = centers[topic] + 0.35 * rng.standard_normal((num_vectors, dim)).astype(np.float32)
    emb /= np.maximum(np.linalg.norm(emb, axis=-1, keepdims=True), 1e-9)
    return Datastore(embeddings=emb)


@dataclass
class PagedClusters:
    """Cluster-major paged layout of a datastore under an IVF assignment."""

    page_size: int
    dim: int
    # page-major storage: pages[i] is [page_size, d] (tail zero-padded)
    pages: np.ndarray               # [total_pages, page_size, d] float32
    page_ids: np.ndarray            # [total_pages, page_size] int32, -1 = pad
    page_cluster: np.ndarray        # [total_pages] int32 owning cluster
    cluster_first_page: np.ndarray  # [Nc] int32 index into pages
    cluster_num_pages: np.ndarray   # [Nc] int32
    cluster_sizes: np.ndarray       # [Nc] int32 (vector counts)

    @property
    def num_clusters(self) -> int:
        return len(self.cluster_sizes)

    @property
    def total_pages(self) -> int:
        return self.pages.shape[0]

    def cluster_pages(self, c: int) -> np.ndarray:
        f, n = self.cluster_first_page[c], self.cluster_num_pages[c]
        return self.pages[f:f + n]

    def cluster_page_ids(self, c: int) -> np.ndarray:
        f, n = self.cluster_first_page[c], self.cluster_num_pages[c]
        return self.page_ids[f:f + n]

    def cluster_bytes(self, c: int) -> int:
        """Transfer cost of cluster c (whole pages, vector payload)."""
        return int(self.cluster_num_pages[c]) * self.page_nbytes()

    def page_nbytes(self, dtype_bytes: int = 2) -> int:
        # transfers happen in bf16 (2 bytes): the device search runs in bf16
        return self.page_size * self.dim * dtype_bytes + self.page_size * 4

    def all_cluster_bytes(self) -> np.ndarray:
        return self.cluster_num_pages.astype(np.int64) * self.page_nbytes()


def build_paged_clusters(store: Datastore, assignments: np.ndarray,
                         num_clusters: int, page_size: int = 512,
                         ) -> PagedClusters:
    d = store.dim
    first_page: List[int] = []
    num_pages: List[int] = []
    sizes: List[int] = []
    pages: List[np.ndarray] = []
    pids: List[np.ndarray] = []
    pclust: List[int] = []
    order = np.argsort(assignments, kind="stable")
    bounds = np.searchsorted(assignments[order], np.arange(num_clusters + 1))
    for c in range(num_clusters):
        ids = order[bounds[c]:bounds[c + 1]]
        n = len(ids)
        npg = max(1, -(-n // page_size))
        first_page.append(len(pages))
        num_pages.append(npg)
        sizes.append(n)
        for p in range(npg):
            chunk = ids[p * page_size:(p + 1) * page_size]
            page = np.zeros((page_size, d), np.float32)
            pid = np.full(page_size, -1, np.int32)
            page[:len(chunk)] = store.embeddings[chunk]
            pid[:len(chunk)] = chunk
            pages.append(page)
            pids.append(pid)
            pclust.append(c)
    return PagedClusters(
        page_size=page_size, dim=d,
        pages=np.stack(pages), page_ids=np.stack(pids),
        page_cluster=np.asarray(pclust, np.int32),
        cluster_first_page=np.asarray(first_page, np.int32),
        cluster_num_pages=np.asarray(num_pages, np.int32),
        cluster_sizes=np.asarray(sizes, np.int32))
