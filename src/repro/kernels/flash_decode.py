"""Pallas TPU kernels: single-token flash-decode attention (GQA), dense
and paged.

Serving hot path: one new query token attends over the KV cache.  The
dense form takes a contiguous [B, S, KVH, Dh] cache; the paged form
(``flash_decode_paged``) gathers K/V pages straight through a
[B, max_blocks] block table (PagedAttention-style, scalar-prefetch index
maps), so KV leased page-wise from the shared ``DevicePagePool`` is
attended IN PLACE — no copy-out into a contiguous cache between the
memory subsystem and the kernel.  Grid = (B, KVH, S-tiles/blocks);
online-softmax state (m, l, acc) lives in VMEM scratch across the
innermost loop; positions and sliding windows are masked with iota
arithmetic — no gathers in the kernel body.

VMEM working set per step: K/V tiles 2*tile*Dh*2B + G*Dh acc; with
tile=512, Dh=128, G<=48 this stays well under 1 MiB, leaving headroom for
double-buffered tile streaming (the default pallas pipeline).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(pos_ref, q_ref, k_ref, v_ref, out_ref, m_s, l_s, acc_s, *,
            tile: int, num_tiles: int, window: int, scale: float):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0]                                    # [G, Dh]
    k = k_ref[0, :, 0, :]                              # [tile, Dh]
    v = v_ref[0, :, 0, :]
    pos = pos_ref[0]                                   # scalar int32

    s = jax.lax.dot_general(q.astype(jnp.float32), k.astype(jnp.float32),
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kp = t * tile + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
    mask = kp <= pos
    if window > 0:
        mask &= kp > pos - window
    s = jnp.where(mask, s, NEG_INF)                    # [G, tile]

    m_prev = m_s[...]                                  # [G, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(m_new > NEG_INF, m_new, 0.0)
    p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
    corr = jnp.where(m_prev > NEG_INF, jnp.exp(m_prev - m_safe), 0.0)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(t == num_tiles - 1)
    def _flush():
        out_ref[0, 0] = acc_s[...] / jnp.maximum(l_s[...], 1e-20)


@functools.partial(jax.jit, static_argnames=("tile", "window", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, pos: jax.Array, *,
                 window: int = 0, tile: int = 512,
                 interpret: bool = False) -> jax.Array:
    """q [B,KVH,G,Dh]; k,v [B,S,KVH,Dh]; pos [B] -> out [B,KVH,G,Dh] fp32."""
    B, KVH, G, Dh = q.shape
    S = k.shape[1]
    tile = min(tile, S)
    assert S % tile == 0, (S, tile)
    num_tiles = S // tile
    scale = 1.0 / math.sqrt(Dh)
    grid = (B, KVH, num_tiles)
    kern = functools.partial(_kernel, tile=tile, num_tiles=num_tiles,
                             window=window, scale=scale)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, t: (b,)),                # pos
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((1, tile, 1, Dh), lambda b, h, t: (b, t, h, 0)),
            pl.BlockSpec((1, tile, 1, Dh), lambda b, h, t: (b, t, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, t: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, Dh), jnp.float32),
        scratch_shapes=[pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, Dh), jnp.float32)],
        interpret=interpret,
    )(pos, q, k, v)


# ---------------------------------------------------------------------------
# Paged decode attention: gather K/V pages through a block table in place
# ---------------------------------------------------------------------------


def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, out_ref,
                  m_s, l_s, acc_s, *, page_size: int, max_blocks: int,
                  window: int, scale: float):
    """Same online-softmax state machine as the dense kernel; the S-tile
    loop walks the request's block table instead of a contiguous cache
    (the DMA gather happens in the BlockSpec index map via the
    scalar-prefetched table — PagedAttention-style)."""
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0]                                    # [G, Dh]
    k = k_ref[0, :, 0, :]                              # [page_size, Dh]
    v = v_ref[0, :, 0, :]
    length = len_ref[0]                                # valid tokens, int32

    s = jax.lax.dot_general(q.astype(jnp.float32), k.astype(jnp.float32),
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # token position of this block's rows in the sequence: unused tail
    # blocks (table entry -1, clamped to page 0 in the index map) land
    # entirely past `length`, so the mask zeroes their contribution
    kp = t * page_size + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
    mask = kp < length
    if window > 0:
        mask &= kp >= length - window
    s = jnp.where(mask, s, NEG_INF)                    # [G, page_size]

    m_prev = m_s[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    m_safe = jnp.where(m_new > NEG_INF, m_new, 0.0)
    p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
    corr = jnp.where(m_prev > NEG_INF, jnp.exp(m_prev - m_safe), 0.0)
    l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
        p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(t == max_blocks - 1)
    def _flush():
        out_ref[0, 0] = acc_s[...] / jnp.maximum(l_s[...], 1e-20)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def flash_decode_paged(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                       block_table: jax.Array, lengths: jax.Array, *,
                       window: int = 0, interpret: bool = False) -> jax.Array:
    """Block-table decode attention over paged KV.

    q [B, KVH, G, Dh]; k_pages, v_pages [NP, ps, KVH, Dh] — the paged KV
    slab read IN PLACE (no contiguous materialization); block_table
    [B, max_blocks] int32 (page slot of each sequence block, -1 =
    unallocated tail); lengths [B] int32 valid tokens (>= 1).  Returns
    [B, KVH, G, Dh] fp32, identical to ``flash_decode`` over the
    gathered-dense cache with ``pos = lengths - 1``.

    The block table rides the scalar-prefetch channel so each grid
    step's K/V page DMA is issued straight from the table — the kernel
    body never gathers.
    """
    B, KVH, G, Dh = q.shape
    NP, ps, _, _ = k_pages.shape
    MB = block_table.shape[1]
    scale = 1.0 / math.sqrt(Dh)
    kern = functools.partial(_paged_kernel, page_size=ps, max_blocks=MB,
                             window=window, scale=scale)

    def kv_ix(b, h, t, bt):
        return (jnp.maximum(bt[b, t], 0), 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KVH, MB),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, t, bt: (b,)),            # lengths
            pl.BlockSpec((1, 1, G, Dh), lambda b, h, t, bt: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, Dh), kv_ix),                     # k pages
            pl.BlockSpec((1, ps, 1, Dh), kv_ix),                     # v pages
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh), lambda b, h, t, bt: (b, h, 0, 0)),
        scratch_shapes=[pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, Dh), jnp.float32)],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, G, Dh), jnp.float32),
        interpret=interpret,
    )(block_table, lengths, q, k_pages, v_pages)
