"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel's tests sweep shapes/dtypes and assert_allclose against these.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ivf_topk_ref(pages: jax.Array, page_ids: jax.Array, page_mask: jax.Array,
                 queries: jax.Array, k: int) -> Tuple[jax.Array, jax.Array]:
    """Masked inner-product top-k over the prefetch slab.

    pages: [P, ps, d]; page_ids: [P, ps] (-1 = padding); page_mask: [P] or
    per-query [B, P] bool (clusters allowed for each query); queries [B, d].
    Returns (scores [B, k] fp32 desc, doc_ids [B, k] int32, -1 when empty).
    """
    P, ps, d = pages.shape
    flat = pages.reshape(P * ps, d).astype(jnp.float32)
    ids = page_ids.reshape(P * ps)
    if page_mask.ndim == 1:
        page_mask = page_mask[None, :]
    vmask = jnp.repeat(page_mask, ps, axis=1) & (ids >= 0)[None, :]  # [B?,N]
    scores = queries.astype(jnp.float32) @ flat.T               # [B, P*ps]
    scores = jnp.where(vmask, scores, -jnp.inf)
    top_s, top_i = jax.lax.top_k(scores, k)
    top_ids = jnp.where(jnp.isfinite(top_s), ids[top_i], -1)
    return top_s, top_ids


def centroid_probe_ref(centroids: jax.Array, queries: jax.Array,
                       valid: Optional[jax.Array] = None) -> jax.Array:
    """Masked centroid distances. centroids [Nc, d]; queries [B, d] -> [B, Nc]."""
    s = queries.astype(jnp.float32) @ centroids.astype(jnp.float32).T
    if valid is not None:
        s = jnp.where(valid[None, :], s, -jnp.inf)
    return s


def probe_and_topk_ref(queries: jax.Array, centroids: jax.Array,
                       valid: jax.Array, pages: jax.Array,
                       page_ids: jax.Array, page_cluster: jax.Array,
                       nprobe: int, k: int) -> Tuple[jax.Array, jax.Array]:
    """Fused-retrieval oracle: centroid probe -> top-nprobe cluster set
    -> per-query page mask over the pool slab -> masked top-k.  This IS
    the unfused composition (``lax.top_k`` selection, exact legacy
    hybrid-search semantics incl. tie-breaks); the Pallas kernel
    replicates it threshold-wise (ties at the nprobe-th score admit
    every tied cluster — identical on tie-free scores).

    queries [B, d]; centroids [Nc, d]; valid [Nc] bool; pages [P, ps, d];
    page_ids [P, ps]; page_cluster [P] (-1 = unsearchable slot).
    Returns (scores [B, k] fp32, doc ids [B, k] int32).
    """
    B = queries.shape[0]
    Nc = centroids.shape[0]
    s = centroid_probe_ref(centroids, queries, valid)          # [B, Nc]
    top_s, top_i = jax.lax.top_k(s, min(nprobe, Nc))
    lut = jnp.zeros((B, Nc), bool).at[
        jnp.arange(B)[:, None], top_i].set(jnp.isfinite(top_s))
    page_mask = jnp.where(page_cluster[None, :] >= 0,
                          lut[:, jnp.clip(page_cluster, 0)], False)  # [B, P]
    return ivf_topk_ref(pages, page_ids, page_mask, queries, k)


def flash_decode_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, window: int = 0) -> jax.Array:
    """Single-token decode attention oracle.

    q: [B, KVH, G, Dh]; k,v: [B, S, KVH, Dh]; pos: [B] (index of the new
    token; positions > pos are masked). window > 0 = sliding window.
    Returns [B, KVH, G, Dh] fp32.
    """
    B, S, KVH, Dh = k.shape
    scale = 1.0 / math.sqrt(Dh)
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    kp = jnp.arange(S, dtype=jnp.int32)[None, None, None, :]
    qp = pos[:, None, None, None]
    mask = kp <= qp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))


def flash_decode_spliced_ref(q: jax.Array, k_pages: jax.Array,
                             v_pages: jax.Array, block_table: jax.Array,
                             lengths: jax.Array, page_delta: jax.Array,
                             page_valid: jax.Array, *,
                             rope_fraction: float = 1.0,
                             rope_theta: float = 10_000.0) -> jax.Array:
    """Paged decode-attention oracle over a block table that mixes fresh
    pages with **spliced** chunk-KV pages (reordered RoPE, TurboRAG).

    Spliced pages hold K rotated at chunk-local positions; RoPE rotations
    compose (``R(p + d) = R(d) @ R(p)``), so rotating a page's stored K
    by its constant layout offset ``page_delta[b, blk]`` reindexes it to
    the wave's global positions.  ``page_valid[b, blk]`` is the number of
    live tokens on the page (< ps only for a spliced chunk's partial last
    page); the dead tail slots are masked out of the softmax.  Fresh
    pages carry ``delta = 0`` and ``valid = ps``.

    q [B, KVH, G, Dh]; k_pages, v_pages [NP, ps, KVH, Dh]; block_table /
    page_delta / page_valid [B, MB] int32; lengths [B] int32 (the new
    token sits at layout position ``lengths - 1``).  Returns
    [B, KVH, G, Dh] fp32.
    """
    from repro.models.layers import apply_rope

    B, MB = block_table.shape
    NP, ps, KVH, Dh = k_pages.shape
    bt = jnp.maximum(block_table, 0)
    k = k_pages[bt]                                    # [B, MB, ps, KVH, Dh]
    v = v_pages[bt]
    k = apply_rope(k, jnp.broadcast_to(page_delta[:, :, None], (B, MB, ps)),
                   fraction=rope_fraction, theta=rope_theta)
    k = k.reshape(B, MB * ps, KVH, Dh)
    v = v.reshape(B, MB * ps, KVH, Dh)

    scale = 1.0 / math.sqrt(Dh)
    s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    kp = jnp.arange(MB * ps, dtype=jnp.int32)          # layout positions
    live = kp[None, :] % ps < jnp.repeat(page_valid, ps, axis=1)   # [B, N]
    causal = kp[None, :] <= (lengths - 1)[:, None]
    mask = (live & causal)[:, None, None, :]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))


def flash_decode_paged_ref(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_table: jax.Array,
                           lengths: jax.Array, window: int = 0) -> jax.Array:
    """Paged decode-attention oracle: gather the block table into a
    dense cache, then run the dense oracle with ``pos = lengths - 1``
    (lengths must be >= 1; -1 table entries are unallocated tail blocks,
    masked out by the position test either way).

    q [B, KVH, G, Dh]; k_pages, v_pages [NP, ps, KVH, Dh]; block_table
    [B, MB] int32; lengths [B] int32.  Returns [B, KVH, G, Dh] fp32.
    """
    B, MB = block_table.shape
    NP, ps, KVH, Dh = k_pages.shape
    bt = jnp.maximum(block_table, 0)
    k = k_pages[bt].reshape(B, MB * ps, KVH, Dh)
    v = v_pages[bt].reshape(B, MB * ps, KVH, Dh)
    return flash_decode_ref(q, k, v, lengths - 1, window)
