"""Pallas TPU kernel: masked centroid-distance matmul (IVF coarse probe).

Probing needs top-nprobe (256) afterwards — too wide for unrolled
in-kernel selection — so the kernel emits the full masked [B, Nc]
distance matrix (Nc = 4096 is tiny) and ``lax.top_k`` runs outside.
The kernel exists because the probe runs on *every* lookahead AND every
retrieval: keeping queries VMEM-resident and streaming centroid tiles
through the MXU is the TPU-native version of Faiss's coarse quantizer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _kernel(q_ref, cent_ref, valid_ref, out_ref):
    q = q_ref[...]                                    # [B, d]
    c = cent_ref[...]                                 # [T, d]
    v = valid_ref[0]                                  # [1, T]
    s = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    out_ref[...] = jnp.where(v > 0, s, NEG_INF)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def centroid_scores(queries: jax.Array, centroids: jax.Array,
                    valid: jax.Array, *, tile: int = 512,
                    interpret: bool = False) -> jax.Array:
    """queries [B, d]; centroids [Nc, d] (Nc % tile == 0); valid [Nc].
    Returns masked scores [B, Nc] fp32."""
    B, d = queries.shape
    Nc = centroids.shape[0]
    assert Nc % tile == 0, (Nc, tile)
    num_tiles = Nc // tile
    valid2 = valid.astype(jnp.int8).reshape(num_tiles, 1, tile)
    return pl.pallas_call(
        _kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((B, d), lambda t: (0, 0)),
            pl.BlockSpec((tile, d), lambda t: (t, 0)),
            pl.BlockSpec((1, 1, tile), lambda t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((B, tile), lambda t: (0, t)),
        out_shape=jax.ShapeDtypeStruct((B, Nc), jnp.float32),
        interpret=interpret,
    )(queries, centroids, valid2)
