"""Pallas TPU kernel: fused masked inner-product + streaming top-k.

This is the paper's retrieval hot spot ("a single matrix-vector
multiplication that computes distances for all prefetched vectors",
App. D) fused with k-selection so the [B, N] distance matrix never hits
HBM. TPU-native formulation:

  * the prefetch slab is streamed through VMEM in tiles of ``tile``
    vectors; the query block stays VMEM-resident;
  * distances run on the MXU (d=768 = 6×128 lanes, tile a multiple of 8
    sublanes) with fp32 accumulation;
  * cluster masks are *page-level and per-query* (exact IVF nprobe
    semantics for every query in the batch) and expand to vectors inside
    the kernel — mask traffic is N/page_size bytes, not N;
  * k-selection is gather-free: k unrolled max+one-hot passes per tile
    (k<=32 for document top-k), then a 2k merge against the running
    top-k held in VMEM scratch across grid steps.

Roofline: memory-bound on slab reads — bytes = N*d*2 read once; FLOPs =
2*B*N*d, so arithmetic intensity = B ops/byte. Fusing the top-k removes
the 4*B*N-byte distance write+read of the unfused version (which XLA
cannot eliminate across the matmul/top_k boundary).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _tile_topk(scores: jax.Array, ids: jax.Array, k: int,
               ) -> Tuple[jax.Array, jax.Array]:
    """k unrolled (max, one-hot select, mask) passes. scores [B, T]."""
    B, T = scores.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (B, T), 1)
    out_s = []
    out_i = []
    for _ in range(k):
        m = jnp.max(scores, axis=-1, keepdims=True)                 # [B,1]
        eq = (scores == m) & (m > NEG_INF)
        first = jnp.min(jnp.where(eq, iota, T), axis=-1, keepdims=True)
        hit = iota == first                                         # one-hot
        sel_id = jnp.max(jnp.where(hit, ids, -1), axis=-1)
        out_s.append(jnp.where(jnp.isfinite(m[:, 0]), m[:, 0], NEG_INF))
        out_i.append(sel_id)
        scores = jnp.where(hit, NEG_INF, scores)
    return jnp.stack(out_s, axis=1), jnp.stack(out_i, axis=1)       # [B,k]


def _kernel(q_ref, pages_ref, ids_ref, mask_ref, out_s_ref, out_i_ref,
            acc_s, acc_i, *, k: int, num_tiles: int, page_size: int):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        acc_s[...] = jnp.full_like(acc_s, NEG_INF)
        acc_i[...] = jnp.full_like(acc_i, -1)

    q = q_ref[...]                                   # [B, d]
    tile = pages_ref[...]                            # [T, d]
    vids = ids_ref[0]                                # [1, T]
    pmask = mask_ref[0]                              # [B, T/ps]
    vmask = jnp.repeat(pmask, page_size, axis=1)     # [B, T]
    s = jax.lax.dot_general(q, tile, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [B, T]
    s = jnp.where((vmask > 0) & (vids >= 0), s, NEG_INF)
    ts, ti = _tile_topk(s, jnp.broadcast_to(vids, s.shape), k)

    merged_s = jnp.concatenate([acc_s[...], ts], axis=1)          # [B, 2k]
    merged_i = jnp.concatenate([acc_i[...], ti], axis=1)
    ms, mi = _tile_topk(merged_s, merged_i, k)
    acc_s[...] = ms
    acc_i[...] = mi

    @pl.when(t == num_tiles - 1)
    def _flush():
        out_s_ref[...] = acc_s[...]
        out_i_ref[...] = acc_i[...]


@functools.partial(jax.jit,
                   static_argnames=("k", "page_size", "tile", "interpret"))
def ivf_topk_flat(queries: jax.Array, flat_pages: jax.Array,
                  flat_ids: jax.Array, page_mask: jax.Array, *,
                  k: int, page_size: int, tile: int = 1024,
                  interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """queries [B, d]; flat_pages [N, d]; flat_ids [N]; page_mask [B, N/ps].

    N % tile == 0 and tile % page_size == 0 (ops.py pads). Returns
    (scores [B, k] fp32, doc ids [B, k] int32).
    """
    B, d = queries.shape
    N = flat_pages.shape[0]
    assert N % tile == 0 and tile % page_size == 0, (N, tile, page_size)
    num_tiles = N // tile
    ppt = tile // page_size                          # pages per tile
    ids2 = flat_ids.reshape(num_tiles, 1, tile)
    mask2 = jnp.swapaxes(
        page_mask.astype(jnp.int8).reshape(B, num_tiles, ppt), 0, 1)
    grid = (num_tiles,)
    out_shape = (jax.ShapeDtypeStruct((B, k), jnp.float32),
                 jax.ShapeDtypeStruct((B, k), jnp.int32))
    fn = pl.pallas_call(
        functools.partial(_kernel, k=k, num_tiles=num_tiles,
                          page_size=page_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, d), lambda t: (0, 0)),                 # queries
            pl.BlockSpec((tile, d), lambda t: (t, 0)),              # slab tile
            pl.BlockSpec((1, 1, tile), lambda t: (t, 0, 0)),        # ids
            pl.BlockSpec((1, B, ppt), lambda t: (t, 0, 0)),         # page mask
        ],
        out_specs=(pl.BlockSpec((B, k), lambda t: (0, 0)),
                   pl.BlockSpec((B, k), lambda t: (0, 0))),
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((B, k), jnp.float32),
                        pltpu.VMEM((B, k), jnp.int32)],
        interpret=interpret,
    )
    return fn(queries, flat_pages, ids2, mask2)
