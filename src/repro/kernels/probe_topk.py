"""Pallas TPU kernel: ONE-LAUNCH fused IVF retrieval over resident pool
pages — centroid probe + per-query page masking + masked top-k.

This closes the substrate gap between ``memory/pool.py`` and the search
kernels: the pool already keeps prefetched cluster pages in place (block
tables, ``page_cluster`` slot map), yet the unfused path still (a) runs
the centroid probe as its own launch, materializing a ``[B, Nc]`` score
matrix in HBM, (b) builds a ``[B, P]`` page mask on the *host* and ships
it over the link, and (c) reshape-pads the ``[P, ps, d]`` slab into a
compacted flat copy for ``ivf_topk``.  Fused, the whole retrieval is one
grid:

  * **probe phase** (centroid tiles): queries stay VMEM-resident, the
    masked centroid scores accumulate into a ``[B, Nc]`` VMEM scratch —
    never touching HBM;
  * **threshold**: after the last centroid tile, the per-query
    top-``nprobe`` admission score is found by a vectorized binary
    search over the scratch (``lax.top_k`` at nprobe=256 is too wide to
    unroll in-kernel; the nprobe-th largest VALUE is enough, because a
    page is searchable iff its cluster's score reaches it).  The search
    converges to the exact nprobe-th score for any tie-free row (ties
    admit every tied cluster — a superset of ``top_k``'s arbitrary
    tie-break);
  * **search phase** (page tiles of the pool slab, read IN PLACE — no
    compaction copy): each tile's per-query page mask is derived
    on-device from ``page_cluster`` via a gather-free one-hot matmul
    against the scratch scores, then the same MXU inner-product +
    unrolled top-k merge as ``ivf_topk``.

Bytes moved vs the unfused path (modeled in bench_kernels): the slab is
read once either way, but the fused launch drops the score-matrix
round-trip (2·4·B·Nc), the host-built mask upload (B·P) and the slab
compaction copy (2·2·N·d).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ivf_topk import _tile_topk

NEG_INF = float("-inf")
# invalid-centroid sentinel must stay FINITE: the one-hot matmul that
# expands cluster scores to pages multiplies by 0.0, and -inf * 0 = nan
FINITE_NEG = -1.0e30
VALID_FLOOR = -1.0e29          # scores above this came from a real centroid


def _kernel(q_ref, cent_ref, valid_ref, pages_ref, ids_ref, pc_ref,
            out_s_ref, out_i_ref, scores_s, tau_s, acc_s, acc_i, *,
            k: int, nprobe: int, cent_tile: int, page_tile: int,
            page_size: int, num_cent_tiles: int, num_page_tiles: int,
            search_iters: int = 48):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        acc_s[...] = jnp.full_like(acc_s, NEG_INF)
        acc_i[...] = jnp.full_like(acc_i, -1)

    # ---- probe phase: masked centroid scores -> VMEM scratch --------------
    @pl.when(t < num_cent_tiles)
    def _probe():
        q = q_ref[...].astype(jnp.float32)             # [B, d]
        c = cent_ref[...].astype(jnp.float32)          # [ct, d]
        v = valid_ref[0]                               # [1, ct]
        s = jax.lax.dot_general(q, c, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        scores_s[:, pl.dslice(t * cent_tile, cent_tile)] = jnp.where(
            v > 0, s, FINITE_NEG)

    # ---- threshold: nprobe-th largest score per query ---------------------
    @pl.when(t == num_cent_tiles - 1)
    def _threshold():
        s = scores_s[...]                              # [B, Nc_pad]
        valid = s > VALID_FLOOR
        hi = jnp.max(s, axis=1, keepdims=True)         # >= every valid score
        lo = jnp.min(jnp.where(valid, s, hi), axis=1, keepdims=True)

        def body(_, carry):
            lo, hi = carry
            mid = 0.5 * (lo + hi)
            cnt = jnp.sum(jnp.where(valid & (s >= mid), 1.0, 0.0),
                          axis=1, keepdims=True)
            ge = cnt >= nprobe                 # mid still admits >= nprobe
            return jnp.where(ge, mid, lo), jnp.where(ge, hi, mid)

        # invariant: count(s >= lo) >= nprobe (or every valid cluster when
        # nprobe exceeds the valid count); lo converges to the nprobe-th
        # largest value within f32 spacing
        lo, hi = jax.lax.fori_loop(0, search_iters, body, (lo, hi))
        tau_s[...] = lo

    # ---- search phase: masked top-k over pool page tiles IN PLACE ---------
    @pl.when(t >= num_cent_tiles)
    def _search():
        q = q_ref[...].astype(jnp.float32)             # [B, d]
        tile = pages_ref[...].astype(jnp.float32)      # [pt, ps, d]
        vids = ids_ref[...]                            # [pt, ps]
        pc = pc_ref[0, 0]                              # [pt]

        # gather-free page mask: cluster score -> page via one-hot matmul
        nc_pad = scores_s.shape[1]
        iota = jax.lax.broadcasted_iota(jnp.int32, (page_tile, nc_pad), 1)
        onehot = (pc[:, None] == iota).astype(jnp.float32)
        cs = jax.lax.dot_general(scores_s[...], onehot,
                                 (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [B,pt]
        allowed = ((cs >= tau_s[...]) & (cs > VALID_FLOOR)
                   & (pc >= 0)[None, :])               # [B, pt]

        flat = tile.reshape(page_tile * page_size, tile.shape[-1])
        s = jax.lax.dot_general(q, flat, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        fid = vids.reshape(1, page_tile * page_size)
        vmask = jnp.repeat(allowed, page_size, axis=1) & (fid >= 0)
        s = jnp.where(vmask, s, NEG_INF)
        ts, ti = _tile_topk(s, jnp.broadcast_to(fid, s.shape), k)

        merged_s = jnp.concatenate([acc_s[...], ts], axis=1)
        merged_i = jnp.concatenate([acc_i[...], ti], axis=1)
        ms, mi = _tile_topk(merged_s, merged_i, k)
        acc_s[...] = ms
        acc_i[...] = mi

    @pl.when(t == num_cent_tiles + num_page_tiles - 1)
    def _flush():
        out_s_ref[...] = acc_s[...]
        out_i_ref[...] = acc_i[...]


@functools.partial(jax.jit, static_argnames=("nprobe", "k", "cent_tile",
                                             "page_tile", "interpret"))
def probe_topk_fused(queries: jax.Array, centroids: jax.Array,
                     valid: jax.Array, pages: jax.Array, page_ids: jax.Array,
                     page_cluster: jax.Array, *, nprobe: int, k: int,
                     cent_tile: int = 512, page_tile: int = 8,
                     interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """queries [B, d]; centroids [Nc, d] (Nc % cent_tile == 0); valid [Nc];
    pages [P, ps, d] / page_ids [P, ps] / page_cluster [P] — the pool's
    ``device_view`` read in place (P % page_tile == 0; ops.py picks the
    tiles).  Returns (scores [B, k] fp32, doc ids [B, k] int32): top-k
    over every pool page whose cluster lands in the query's top-nprobe
    probed clusters.
    """
    B, d = queries.shape
    Nc = centroids.shape[0]
    P, ps, _ = pages.shape
    assert Nc % cent_tile == 0, (Nc, cent_tile)
    assert P % page_tile == 0, (P, page_tile)
    nct = Nc // cent_tile
    npt = P // page_tile
    valid2 = valid.astype(jnp.int8).reshape(nct, 1, cent_tile)
    pc2 = page_cluster.reshape(npt, 1, page_tile)
    grid = (nct + npt,)
    # index maps clamp each input to its own phase's range; the out-of-
    # phase block load is redundant traffic, not a correctness issue
    cent_ix = lambda t: (jnp.minimum(t, nct - 1), 0)
    valid_ix = lambda t: (jnp.minimum(t, nct - 1), 0, 0)
    page_ix = lambda t: (jnp.clip(t - nct, 0, npt - 1), 0, 0)
    pid_ix = lambda t: (jnp.clip(t - nct, 0, npt - 1), 0)
    kern = functools.partial(
        _kernel, k=k, nprobe=max(1, min(nprobe, Nc)), cent_tile=cent_tile,
        page_tile=page_tile, page_size=ps, num_cent_tiles=nct,
        num_page_tiles=npt)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((B, d), lambda t: (0, 0)),               # queries
            pl.BlockSpec((cent_tile, d), cent_ix),                # centroids
            pl.BlockSpec((1, 1, cent_tile), valid_ix),            # valid
            pl.BlockSpec((page_tile, ps, d), page_ix),            # pool slab
            pl.BlockSpec((page_tile, ps), pid_ix),                # page ids
            pl.BlockSpec((1, 1, page_tile), page_ix),             # slot->cluster
        ],
        out_specs=(pl.BlockSpec((B, k), lambda t: (0, 0)),
                   pl.BlockSpec((B, k), lambda t: (0, 0))),
        out_shape=(jax.ShapeDtypeStruct((B, k), jnp.float32),
                   jax.ShapeDtypeStruct((B, k), jnp.int32)),
        scratch_shapes=[pltpu.VMEM((B, Nc), jnp.float32),
                        pltpu.VMEM((B, 1), jnp.float32),
                        pltpu.VMEM((B, k), jnp.float32),
                        pltpu.VMEM((B, k), jnp.int32)],
        interpret=interpret,
    )(queries, centroids, valid2, pages, page_ids, pc2)
